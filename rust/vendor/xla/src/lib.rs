//! Dependency-free API shim for the `xla` PJRT FFI crate — see
//! `README.md` one directory up.
//!
//! The shim exists so the `xla-pjrt` feature of the parent crate can be
//! **built and type-checked** without the native XLA toolchain. Host-side
//! literal plumbing (`Literal::vec1`/`reshape`/`to_vec`) actually works;
//! everything that would need the PJRT plugin (`PjRtClient::cpu`,
//! compilation, execution) returns a descriptive [`Error`] instead, so
//! callers fail through their normal `Result` paths at runtime.

use std::fmt;

/// Error type mirroring the real crate's: convertible into `anyhow`
/// chains (`std::error::Error + Send + Sync + 'static`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    fn no_plugin(what: &str) -> Self {
        Self::new(format!(
            "{what}: the vendored `xla` shim has no real PJRT plugin linked \
             (swap in the real FFI crate — see rust/vendor/xla/README.md)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The shim can never construct one.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::no_plugin("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::no_plugin("PjRtClient::compile"))
    }
}

/// Parsed HLO module. Parsing needs the native text parser, so the shim
/// fails here — before anything could try to execute.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(Error::no_plugin(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation wrapping an HLO module — pure marshaling, so the
/// shim constructs it fine (it can only be reached via an
/// [`HloModuleProto`], which the shim never yields).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Compiled-and-loaded executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors the real crate's generic execute (callers write
    /// `exe.execute::<Literal>(&literals)`); returns per-device,
    /// per-output buffer vectors there — and an error here.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::no_plugin("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::no_plugin("PjRtBuffer::to_literal_sync"))
    }
}

/// Element types [`Literal::to_vec`] can read out. The shim only ever
/// holds f32 data (that is all the parent crate marshals).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl NativeType for f64 {
    fn from_f32(v: f32) -> Self {
        v as f64
    }
}

/// Host-side literal: flat f32 data plus dimensions. Fully functional —
/// input marshaling runs for real even under the shim, so shape bugs
/// surface in CI without the plugin.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// A rank-1 literal over `data`.
    pub fn vec1(data: &[f32]) -> Self {
        Self { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions; errors when element counts
    /// disagree (matching the real crate's shape check).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch ({} elements)",
                self.dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal. Shim literals are never tuples (tuples
    /// only come back from execution, which the shim refuses).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::new("to_tuple: shim literals are never tuples (nothing executes)"))
    }

    /// Read the flat data out as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_marshaling_works_without_the_plugin() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shaped = lit.reshape(&[2, 3]).expect("2x3 reshape");
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err(), "element-count mismatch must error");
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn plugin_paths_fail_with_pointers_to_the_readme() {
        let err = PjRtClient::cpu().expect_err("shim has no plugin");
        let msg = err.to_string();
        assert!(msg.contains("no real PJRT plugin"), "{msg}");
        assert!(msg.contains("vendor/xla/README.md"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
