//! Bayesian-optimized iterative search (§III-E): Gaussian-process
//! regression with expected improvement, hyperparameter selection by
//! marginal likelihood, and the phase-aware search loop shared by
//! CherryPick and Ruya.

pub mod backend;
pub mod chol;
pub mod gp;
pub mod kernel;
pub mod lowrank;
pub mod search;

pub use backend::{
    backend_by_name, backend_factory_by_name, backend_factory_with_parallelism,
    BackendFactory, BackendKind, DecideStats, Decision, GpBackend, LowRankPolicy,
    NativeBackend, XlaBackend, DECIDE_TILE, LOWRANK_CANDIDATE_THRESHOLD, LOWRANK_MIN_OBS,
    LOWRANK_NLL_OBS_THRESHOLD,
};
pub use chol::{CholFactor, FactorCache, FactorCacheStats};
pub use lowrank::{farthest_point_sample, LowRankGp, DEFAULT_MAX_INDUCING};
pub use search::{hyperparameter_grid, run_search, BoParams, SearchOutcome};
