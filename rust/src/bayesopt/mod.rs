//! Bayesian-optimized iterative search (§III-E): Gaussian-process
//! regression with expected improvement, hyperparameter selection by
//! marginal likelihood, and the phase-aware search loop shared by
//! CherryPick and Ruya.
//!
//! Searches start cold by default; a [`WarmStart`] prior (mined by
//! `coordinator::transfer` from completed searches on similar jobs)
//! seeds the initial design and narrows the hyperparameter sweep — see
//! the [`search`] module docs for the exact semantics.

pub mod backend;
pub mod chol;
pub mod gp;
pub mod kernel;
pub mod lowrank;
pub mod pool;
pub mod search;
pub mod simd;

pub use backend::{
    adaptive_gp_threads, backend_by_name, backend_factory_by_name,
    backend_factory_with_parallelism, BackendFactory, BackendKind, DecideStats, Decision,
    GpBackend, LowRankPolicy, NativeBackend, PreparedDecide, XlaBackend, DECIDE_TILE,
    GP_POOL_MIN_OBS, LOWRANK_CANDIDATE_THRESHOLD, LOWRANK_MIN_OBS,
    LOWRANK_NLL_OBS_THRESHOLD, MAX_ADAPTIVE_GP_THREADS,
};
pub use chol::{CholFactor, FactorCache, FactorCacheStats, ObsDelta};
pub use lowrank::{
    farthest_point_sample, InducingCache, LowRankGp, LowRankStats, DEFAULT_MAX_INDUCING,
    INDUCING_DRIFT_LIMIT,
};
pub use pool::{
    configure_global_pool_width, global_pool, global_pool_is_running, global_pool_width,
    next_pool_epoch, spawned_pool_threads, LaneScratch, WorkerPool,
};
pub use search::{
    hyperparameter_grid, run_search, BoParams, CursorSnapshot, SearchCursor, SearchOutcome,
    SearchStep, WarmStart,
};
pub use simd::{set_simd, simd_active, simd_available, SIMD_PARITY_RTOL};
