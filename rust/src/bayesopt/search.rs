//! The Bayesian-optimized iterative search loop (§III-E), phase-aware so
//! the same engine serves plain CherryPick (one phase: the whole space)
//! and Ruya (priority phase first, remainder second).
//!
//! Per iteration: standardize the observed costs, select hyperparameters
//! by marginal likelihood over a fixed grid, score every still-eligible
//! candidate with expected improvement through the [`GpBackend`], and run
//! the argmax configuration on the (simulated) cluster via the oracle.
//!
//! The loop's calling pattern is load-bearing for the backend's
//! incremental caches (`NativeBackend`'s distance matrix and per-grid
//! Cholesky [`FactorCache`](super::chol::FactorCache)): each iteration
//! appends exactly one observation (or slides the window by one under a
//! capacity-limited backend) and calls `nll_grid` then `decide` with the
//! *same* window, so per-iteration grid refits are rank-1 updates
//! (O(H·n²)) instead of scratch refactorizations (O(H·n³)).
//!
//! The loop itself is oblivious to the backend's worker pool
//! (`--gp-threads`): the swept nll grid, the decision vectors and the
//! EI argmax are bit-identical for any pool width (the backend's
//! deterministic-parallelism contract), so a seeded search produces the
//! same iteration trace serial or threaded —
//! `tests/parallel_gp.rs` pins exactly that.
//!
//! # The cursor step machine
//!
//! The loop is implemented as a resumable step machine,
//! [`SearchCursor`]: `advance()` surfaces the next action (execute a
//! pending pick, or ask for a GP decision over the current window) and
//! `record()` feeds an observed cost back in. [`run_search`] is a thin
//! wrapper driving the cursor to completion against an oracle — the
//! classic entry point and the step machine produce identical traces by
//! construction. The cursor's cross-iteration state (tried/costs, phase
//! cursor, pending init picks, RNG position, stopping-criterion state)
//! is plain data, exposed via [`SearchCursor::snapshot`] so the session
//! layer (`coordinator::session`) can serialize a search mid-flight and
//! resume it bit-identically.
//!
//! # Warm starts (cross-job transfer)
//!
//! A search may begin from a [`WarmStart`] prior instead of a cold
//! random draw ([`SearchCursor::with_warm_start`]). The prior carries
//! two things mined from completed searches on behaviorally similar
//! jobs (`coordinator::transfer`):
//!
//! * **seed configs** — catalog indices that replace the random initial
//!   design. Seeds outside the opening phase (or out of catalog bounds)
//!   are ignored; if fewer than `n_init` seeds survive the filter the
//!   design is topped up with the usual random draw, so a warm search
//!   spends exactly the same initial budget as a cold one.
//! * **grid slots** — a subset of the 32-slot hyperparameter grid
//!   ([`hyperparameter_grid`]). When present, the cursor sweeps only
//!   those slots in `nll_grid`; slot indices map back to the full grid
//!   via [`SearchCursor::grid_slots`]. An empty subset means the full
//!   grid (a cold search).
//!
//! An all-empty `WarmStart` is *exactly* a cold search: the RNG draw
//! sequence, grid, and trace are bit-identical to [`SearchCursor::new`].

use super::backend::GpBackend;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::Arc;

/// Search hyperparameters; defaults follow CherryPick (§III-E).
#[derive(Debug, Clone, Copy)]
pub struct BoParams {
    /// Random initial configurations before the GP takes over.
    pub n_init: usize,
    /// Minimum executions before the stopping criterion may fire.
    pub min_obs_for_stop: usize,
    /// Stop when max EI < this fraction of the best observed cost.
    pub ei_stop_rel: f64,
    /// Abort the search after this many executions regardless (safety net;
    /// the harness sets it to |space| so searches always terminate).
    pub max_iters: usize,
    /// If true the search ends when the stopping criterion fires; if
    /// false the criterion is only *recorded* (the Table II measurement
    /// protocol runs to exhaustion to find iterations-to-optimum).
    pub enforce_stop: bool,
}

impl Default for BoParams {
    fn default() -> Self {
        Self {
            n_init: 3,
            min_obs_for_stop: 6,
            ei_stop_rel: 0.1,
            max_iters: usize::MAX,
            enforce_stop: false,
        }
    }
}

/// The hyperparameter-selection grid: 8 log-spaced lengthscales x 4 noise
/// levels at unit signal variance (targets are standardized). 32 entries,
/// exactly the AOT N_GRID so the XLA backend evaluates it in one call.
pub fn hyperparameter_grid() -> Vec<[f64; 3]> {
    let mut grid = Vec::with_capacity(32);
    for i in 0..8 {
        let ls = 0.1 * (20.0f64).powf(i as f64 / 7.0); // 0.1 .. 2.0
        for noise in [1e-4, 1e-3, 1e-2, 1e-1] {
            grid.push([ls, 1.0, noise]);
        }
    }
    grid
}

/// A transfer prior for one search: seed configurations for the initial
/// design plus a hyperparameter-grid restriction, both mined from
/// completed searches on similar jobs (see the module docs and
/// `coordinator::transfer`). `Default` is the cold search.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct WarmStart {
    /// Catalog indices to execute as the initial design, best first.
    pub seeds: Vec<usize>,
    /// Full-grid slot indices (`< hyperparameter_grid().len()`) to keep
    /// in the nll sweep; empty = the full grid.
    pub grid_slots: Vec<usize>,
}

impl WarmStart {
    /// True when this prior carries no information (cold search).
    pub fn is_cold(&self) -> bool {
        self.seeds.is_empty() && self.grid_slots.is_empty()
    }
}

/// Complete trace of one search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Configuration indices in execution order.
    pub tried: Vec<usize>,
    /// Observed (normalized) cost per execution.
    pub costs: Vec<f64>,
    /// Executions completed when the stopping criterion first fired
    /// (None = never fired within the trace).
    pub stop_after: Option<usize>,
    /// Execution count at which each phase was entered.
    pub phase_starts: Vec<usize>,
    /// Times each full-grid hyperparameter slot won the nll sweep over
    /// the trace (length = `hyperparameter_grid().len()`): the per-job
    /// posterior over hyperparameters that the transfer layer persists.
    pub grid_hits: Vec<u32>,
}

impl SearchOutcome {
    /// 1-based execution index of the first cost <= `threshold`
    /// (None if never reached).
    pub fn first_within(&self, threshold: f64) -> Option<usize> {
        self.costs.iter().position(|&c| c <= threshold).map(|p| p + 1)
    }

    /// Best cost observed within the first `k` executions.
    pub fn best_after(&self, k: usize) -> f64 {
        self.costs.iter().take(k).cloned().fold(f64::INFINITY, f64::min)
    }
}

/// The next action a [`SearchCursor`] needs from its driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchStep {
    /// The search is over (phases exhausted, `max_iters` reached, or an
    /// enforced stop fired).
    Done,
    /// Execute configuration `i` next (a random init pick or the
    /// degenerate-phase fallback) and feed its cost to
    /// [`SearchCursor::record`].
    Execute(usize),
    /// A GP decision over the current window is required: either call
    /// [`SearchCursor::decide_with_backend`], or run the
    /// nll-grid/decide sequence externally (the session engine's batched
    /// fan-out) and close it with [`SearchCursor::finish_decision`].
    NeedsDecision,
}

/// The plain-data core of a mid-flight search — everything the cursor
/// carries across iterations that cannot be re-derived from its inputs.
/// `x_obs`/`tried_flag`/`cmask` are deliberately absent (recomputed from
/// `tried` and the feature matrix), keeping the snapshot compact. The
/// session layer serializes exactly these fields and uses snapshot
/// equality as the resume integrity check.
#[derive(Debug, Clone, PartialEq)]
pub struct CursorSnapshot {
    pub tried: Vec<usize>,
    pub costs: Vec<f64>,
    pub stop_after: Option<usize>,
    pub phase_starts: Vec<usize>,
    pub phase_idx: usize,
    pub phase_entered: bool,
    pub pending: Vec<usize>,
    pub pending_gate: bool,
    pub done: bool,
    pub rng_state: u128,
    pub rng_inc: u128,
}

/// Resumable form of the phased BO search loop: the control flow of
/// [`run_search`] unrolled into an explicit step machine (see the module
/// docs). One `advance()`/`record()` round-trip corresponds to exactly
/// one `observe()` of the classic loop, so the iteration trace — and
/// every RNG draw — is bit-identical to the recursive-descent original.
pub struct SearchCursor {
    /// Disjoint index sets explored in order (shared across sessions:
    /// thousands of engine sessions on one catalog hold one allocation).
    plan: Arc<Vec<Vec<usize>>>,
    m: usize,
    d: usize,
    rng: Pcg64,
    params: BoParams,
    /// The (possibly warm-narrowed) hyperparameter grid this cursor
    /// sweeps; row `r` is full-grid slot `grid_slots[r]`.
    grid: Vec<[f64; 3]>,
    /// Full-grid slot index of each `grid` row (identity when cold).
    grid_slots: Vec<usize>,
    /// Per-full-slot count of nll-sweep wins (derived state: rebuilt by
    /// resume replay, deliberately absent from [`CursorSnapshot`]).
    grid_hits: Vec<u32>,
    /// Warm seed configs for the initial design (validated, deduped;
    /// empty = cold random draw).
    warm_seeds: Vec<usize>,
    tried: Vec<usize>,
    costs: Vec<f64>,
    x_obs: Vec<f64>,
    tried_flag: Vec<bool>,
    // Candidate-eligibility mask, refilled in place each iteration: on a
    // generated 5k-config catalog an m-wide allocation per iteration
    // would dominate the small-n steps.
    cmask: Vec<bool>,
    stop_after: Option<usize>,
    phase_starts: Vec<usize>,
    /// Index of the phase currently being explored.
    phase_idx: usize,
    /// Whether `phase_starts` has been recorded (and init picks drawn)
    /// for `phase_idx` yet.
    phase_entered: bool,
    /// Queued random picks awaiting execution (init or degenerate draw).
    pending: VecDeque<usize>,
    /// True when each pending pick must re-check `max_iters` before
    /// executing (the top-of-phase init loop does; the degenerate
    /// empty-history draw does not — it defers to the main loop's gate).
    pending_gate: bool,
    done: bool,
}

impl SearchCursor {
    /// Start a search over `m` candidates of dimension `d` following
    /// `plan`'s phases. The RNG is consumed from its current position
    /// (pass a fresh `Pcg64::from_seed` for a reproducible session).
    pub fn new(plan: Arc<Vec<Vec<usize>>>, m: usize, d: usize, rng: Pcg64, params: BoParams) -> Self {
        Self::with_warm_start(plan, m, d, rng, params, &WarmStart::default())
    }

    /// Like [`Self::new`] but seeded from a transfer prior (see the
    /// module docs): `warm.seeds` replace the random initial design and
    /// `warm.grid_slots` narrow the hyperparameter sweep. A cold
    /// (`WarmStart::default`) prior reproduces `new` bit-for-bit.
    pub fn with_warm_start(
        plan: Arc<Vec<Vec<usize>>>,
        m: usize,
        d: usize,
        rng: Pcg64,
        params: BoParams,
        warm: &WarmStart,
    ) -> Self {
        for phase in plan.iter() {
            for &i in phase {
                assert!(i < m, "phase index {i} out of bounds (space size {m})");
            }
        }
        let full = hyperparameter_grid();
        let mut slots: Vec<usize> =
            warm.grid_slots.iter().copied().filter(|&s| s < full.len()).collect();
        slots.sort_unstable();
        slots.dedup();
        let grid_hits = vec![0u32; full.len()];
        let (grid, grid_slots) = if slots.is_empty() {
            let n = full.len();
            (full, (0..n).collect())
        } else {
            (slots.iter().map(|&s| full[s]).collect(), slots)
        };
        let mut warm_seeds: Vec<usize> = Vec::with_capacity(warm.seeds.len());
        for &s in &warm.seeds {
            // Out-of-catalog seeds (a prior mined on a different space)
            // are dropped rather than rejected: a stale prior degrades
            // to a cold start, it does not fail the search.
            if s < m && !warm_seeds.contains(&s) {
                warm_seeds.push(s);
            }
        }
        Self {
            plan,
            m,
            d,
            rng,
            params,
            grid,
            grid_slots,
            grid_hits,
            warm_seeds,
            tried: Vec::new(),
            costs: Vec::new(),
            x_obs: Vec::new(),
            tried_flag: vec![false; m],
            cmask: vec![false; m],
            stop_after: None,
            phase_starts: Vec::new(),
            phase_idx: 0,
            phase_entered: false,
            pending: VecDeque::new(),
            pending_gate: false,
            done: false,
        }
    }

    /// Surface the next action. Idempotent: calling `advance` again
    /// before `record`/`finish_decision` returns the same step (pending
    /// picks persist until recorded, and the eligibility mask rebuild is
    /// a pure function of `tried`).
    pub fn advance(&mut self) -> SearchStep {
        loop {
            // Queued random picks drain first.
            if let Some(&next) = self.pending.front() {
                if self.pending_gate && self.tried.len() >= self.params.max_iters {
                    // Mirrors the init loop's per-pick gate: reaching the
                    // cap mid-inits ends the whole search.
                    self.pending.clear();
                    self.done = true;
                    return SearchStep::Done;
                }
                return SearchStep::Execute(next);
            }
            if self.done {
                return SearchStep::Done;
            }
            let Some(phase) = self.plan.get(self.phase_idx) else {
                self.done = true;
                return SearchStep::Done;
            };

            if !self.phase_entered {
                self.phase_entered = true;
                self.phase_starts.push(self.tried.len());
                // Initialization (first non-empty phase only, drawn
                // inside it): warm seeds that fall inside this phase
                // replace the random design, capped at `n_init` so warm
                // and cold searches spend the same initial budget. If
                // fewer than `n_init` seeds apply, the remainder is the
                // usual random draw over the rest of the phase; with no
                // seeds the draw call — and hence the RNG position and
                // the whole trace — is identical to the cold search.
                if self.tried.is_empty() {
                    let k = self.params.n_init.min(phase.len());
                    let mut init: Vec<usize> = self
                        .warm_seeds
                        .iter()
                        .copied()
                        .filter(|s| phase.contains(s))
                        .take(k)
                        .collect();
                    if init.len() < k {
                        let rest: Vec<usize> =
                            phase.iter().copied().filter(|i| !init.contains(i)).collect();
                        let picks = self.rng.sample_distinct(rest.len(), k - init.len());
                        init.extend(picks.into_iter().map(|p| rest[p]));
                    }
                    self.pending = init.into_iter().collect();
                    self.pending_gate = true;
                    continue;
                }
            }

            // Main per-iteration loop body.
            if self.tried.len() >= self.params.max_iters {
                self.done = true;
                return SearchStep::Done;
            }
            // Eligible = this phase's untried configurations.
            for v in self.cmask.iter_mut() {
                *v = false;
            }
            let mut any_eligible = false;
            for &i in phase.iter() {
                if !self.tried_flag[i] {
                    self.cmask[i] = true;
                    any_eligible = true;
                }
            }
            if !any_eligible {
                // Phase exhausted -> next phase.
                self.phase_idx += 1;
                self.phase_entered = false;
                continue;
            }
            if self.tried.is_empty() {
                // Degenerate: empty first phases meant no inits ran yet.
                let k = self.params.n_init.min(phase.len());
                let untried: Vec<usize> =
                    phase.iter().copied().filter(|&i| !self.tried_flag[i]).collect();
                let picks = self.rng.sample_distinct(untried.len(), k.min(untried.len()));
                self.pending = picks.into_iter().map(|p| untried[p]).collect();
                self.pending_gate = false;
                continue;
            }
            return SearchStep::NeedsDecision;
        }
    }

    /// Feed the observed cost of configuration `i` back in. `i` must be
    /// the pick `advance`/`finish_decision` surfaced; `features` is the
    /// same row-major `m x d` matrix every call sees.
    pub fn record(&mut self, i: usize, cost: f64, features: &[f64]) {
        debug_assert_eq!(features.len(), self.m * self.d);
        if let Some(&front) = self.pending.front() {
            assert_eq!(front, i, "recorded config {i} but pick {front} was pending");
            self.pending.pop_front();
        }
        debug_assert!(!self.tried_flag[i], "config {i} executed twice");
        self.tried_flag[i] = true;
        self.tried.push(i);
        self.costs.push(cost);
        self.x_obs.extend_from_slice(&features[i * self.d..(i + 1) * self.d]);
    }

    /// The conditioning window for the pending decision under a backend
    /// holding at most `max_obs` observations: `(skip, n)` with
    /// `n = min(executions, max_obs)` — the windowed-history contract of
    /// the classic loop.
    pub fn window(&self, max_obs: usize) -> (usize, usize) {
        let win = self.tried.len().min(max_obs);
        (self.tried.len() - win, win)
    }

    /// Observed feature rows from `skip` on (pair with [`Self::window`]).
    pub fn x_window(&self, skip: usize) -> &[f64] {
        &self.x_obs[skip * self.d..]
    }

    /// Observed costs from `skip` on.
    pub fn y_window(&self, skip: usize) -> &[f64] {
        &self.costs[skip..]
    }

    /// The candidate-eligibility mask of the pending decision (valid
    /// after `advance` returned [`SearchStep::NeedsDecision`]).
    pub fn cmask(&self) -> &[bool] {
        &self.cmask
    }

    /// The hyperparameter-selection grid this cursor sweeps (narrowed
    /// under a warm start; see [`Self::grid_slots`] for the mapping).
    pub fn grid(&self) -> &[[f64; 3]] {
        &self.grid
    }

    /// Full-grid slot index of each [`Self::grid`] row (the identity
    /// mapping for a cold search).
    pub fn grid_slots(&self) -> &[usize] {
        &self.grid_slots
    }

    /// Per-full-slot nll-sweep win counts so far (see
    /// [`SearchOutcome::grid_hits`]).
    pub fn grid_hits(&self) -> &[u32] {
        &self.grid_hits
    }

    /// The validated warm seed configs this cursor was opened with.
    pub fn warm_seeds(&self) -> &[usize] {
        &self.warm_seeds
    }

    /// The (validated) transfer prior this cursor runs under, in the
    /// form that reconstructs it exactly: passing the returned value to
    /// [`Self::with_warm_start`] with the same plan/seed reproduces
    /// this cursor's draw sequence bit for bit. Cold cursors return
    /// `WarmStart::default()` (the identity grid encodes as empty).
    pub fn warm_start(&self) -> WarmStart {
        let grid_slots = if self.grid.len() == self.grid_hits.len() {
            Vec::new()
        } else {
            self.grid_slots.clone()
        };
        WarmStart { seeds: self.warm_seeds.clone(), grid_slots }
    }

    /// Record that `row` of [`Self::grid`] won an nll sweep. Callers
    /// running the nll/decide sequence externally (the session engine's
    /// batched fan-out) must report the winning row here so the
    /// transfer layer sees the same posterior as the direct path.
    pub fn note_grid_choice(&mut self, row: usize) {
        self.grid_hits[self.grid_slots[row]] += 1;
    }

    /// Close a decision whose EI/variance vectors were computed
    /// externally (the session engine's batched fan-out): applies the
    /// stopping criterion and returns the configuration to execute, or
    /// `None` when an enforced stop ended the search. `y_scale` is the
    /// standardization scale of the decision's window.
    pub fn finish_decision(&mut self, ei: &[f64], var: &[f64], y_scale: f64) -> Option<usize> {
        let (best_idx, ei_max_std) = argmax_masked(ei, &self.cmask);

        // Stopping criterion on the raw cost scale (CherryPick: stop
        // once expected savings drop below 10% of the best seen).
        // Both the gate and the recorded stopping point count
        // *executions performed* (`tried.len()`), not the windowed
        // conditioning count `n`: under a capacity-limited backend
        // (`max_obs`) the two diverge — the old code under-reported
        // the stop index consumed by the Fig. 5 curves, and could
        // never fire at all when `max_obs < min_obs_for_stop`.
        let best_cost = self.costs.iter().cloned().fold(f64::INFINITY, f64::min);
        let ei_max_raw = ei_max_std * y_scale;
        if self.stop_after.is_none()
            && self.tried.len() >= self.params.min_obs_for_stop
            && ei_max_raw < self.params.ei_stop_rel * best_cost
        {
            self.stop_after = Some(self.tried.len());
            if self.params.enforce_stop {
                self.done = true;
                return None;
            }
        }

        // All-zero EI (e.g. fully dominated region): explore the most
        // uncertain eligible candidate instead of an arbitrary one.
        Some(if ei_max_std > 0.0 { best_idx } else { argmax_masked(var, &self.cmask).0 })
    }

    /// Run one full decision against a backend — window, standardize,
    /// marginal-likelihood grid, EI acquisition, stopping criterion —
    /// and return the pick (`None` = enforced stop). The one decision
    /// body shared by [`run_search`], the session engine's serial path
    /// and the resume replay.
    pub fn decide_with_backend(
        &mut self,
        features: &[f64],
        backend: &mut dyn GpBackend,
    ) -> Result<Option<usize>> {
        // Window the history to the backend's conditioning capacity
        // (AOT artifacts have a frozen maximum observation count; by
        // the time the window saturates — 64 of 69 configs tried —
        // the optimum has long been recorded in `costs`).
        let (skip, n) = self.window(backend.max_obs());
        let (y_std, _, y_scale) = super::gp::standardize(&self.costs[skip..]);
        let x_win = &self.x_obs[skip * self.d..];

        // Hyperparameter selection by marginal likelihood.
        let nll = backend.nll_grid(x_win, &y_std, n, self.d, &self.grid)?;
        let row = argmin(&nll);
        self.note_grid_choice(row);
        let hyp = self.grid[row];

        // Acquisition over the eligible candidates.
        let decision =
            backend.decide(x_win, &y_std, n, self.d, features, &self.cmask, self.m, hyp)?;
        Ok(self.finish_decision(&decision.ei, &decision.var, y_scale))
    }

    /// Executions performed so far.
    pub fn executions(&self) -> usize {
        self.tried.len()
    }

    /// Configuration indices in execution order.
    pub fn tried(&self) -> &[usize] {
        &self.tried
    }

    /// Observed costs in execution order.
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// True once the search has ended.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Candidate-space size this cursor searches over.
    pub fn space_len(&self) -> usize {
        self.m
    }

    /// Feature dimension of the candidate space.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The serializable cross-iteration state (see [`CursorSnapshot`]).
    pub fn snapshot(&self) -> CursorSnapshot {
        let (rng_state, rng_inc) = self.rng.to_parts();
        CursorSnapshot {
            tried: self.tried.clone(),
            costs: self.costs.clone(),
            stop_after: self.stop_after,
            phase_starts: self.phase_starts.clone(),
            phase_idx: self.phase_idx,
            phase_entered: self.phase_entered,
            pending: self.pending.iter().copied().collect(),
            pending_gate: self.pending_gate,
            done: self.done,
            rng_state,
            rng_inc,
        }
    }

    /// The finished (or so-far) trace in [`SearchOutcome`] form.
    pub fn outcome(&self) -> SearchOutcome {
        SearchOutcome {
            tried: self.tried.clone(),
            costs: self.costs.clone(),
            stop_after: self.stop_after,
            phase_starts: self.phase_starts.clone(),
            grid_hits: self.grid_hits.clone(),
        }
    }

    /// The RNG at its current position (callers that passed a shared
    /// generator into [`run_search`] get its advanced position back).
    pub fn rng(&self) -> &Pcg64 {
        &self.rng
    }
}

/// Run a phased Bayesian-optimization search.
///
/// * `features`: row-major `m x d` candidate features (the whole space).
/// * `phases`: disjoint index sets explored in order; a phase must be
///   exhausted before the next opens (§III-D/E). Their union need not
///   cover the space (uncovered configs are never tried).
/// * `oracle`: runs configuration `i` and returns its cost.
///
/// A thin driver over [`SearchCursor`]: one `advance`/`record`
/// round-trip per execution, so the trace is identical to the session
/// engine stepping the same cursor.
pub fn run_search(
    features: &[f64],
    m: usize,
    d: usize,
    phases: &[Vec<usize>],
    oracle: &mut dyn FnMut(usize) -> f64,
    backend: &mut dyn GpBackend,
    rng: &mut Pcg64,
    params: &BoParams,
) -> Result<SearchOutcome> {
    assert_eq!(features.len(), m * d);
    let mut cursor = SearchCursor::new(Arc::new(phases.to_vec()), m, d, rng.clone(), *params);
    loop {
        match cursor.advance() {
            SearchStep::Done => break,
            SearchStep::Execute(i) => {
                let cost = oracle(i);
                cursor.record(i, cost, features);
            }
            SearchStep::NeedsDecision => {
                if let Some(pick) = cursor.decide_with_backend(features, backend)? {
                    let cost = oracle(pick);
                    cursor.record(pick, cost, features);
                }
            }
        }
    }
    // Hand the advanced RNG position back to the caller (the classic
    // loop consumed draws from the caller's generator directly).
    *rng = cursor.rng().clone();
    Ok(cursor.outcome())
}

fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v < xs[best] {
            best = i;
        }
    }
    best
}

fn argmax_masked(xs: &[f64], mask: &[bool]) -> (usize, f64) {
    let mut best: Option<usize> = None;
    for (i, v) in xs.iter().enumerate() {
        if mask[i] && best.map_or(true, |b| *v > xs[b]) {
            best = Some(i);
        }
    }
    let i = best.expect("argmax over empty mask");
    (i, xs[i])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::backend::NativeBackend;

    /// 1-D toy space: cost = (x - 0.62)^2 scaled, optimum near idx 62.
    fn toy_space(m: usize) -> (Vec<f64>, Vec<f64>) {
        let d = 6;
        let mut features = Vec::with_capacity(m * d);
        let mut costs = Vec::with_capacity(m);
        for i in 0..m {
            let t = i as f64 / (m - 1) as f64;
            features.extend_from_slice(&[t, 1.0 - t, t * t, 0.5, (3.0 * t).sin() * 0.5 + 0.5, t]);
            costs.push(1.0 + 8.0 * (t - 0.62) * (t - 0.62));
        }
        (features, costs)
    }

    fn run_toy(phases: &[Vec<usize>], seed: u64, params: &BoParams) -> SearchOutcome {
        let m = 40;
        let (features, costs) = toy_space(m);
        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(seed);
        let mut oracle = |i: usize| costs[i];
        run_search(&features, m, 6, phases, &mut oracle, &mut backend, &mut rng, params)
            .expect("search")
    }

    #[test]
    fn finds_optimum_much_faster_than_exhaustive() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let mut total = 0;
        for seed in 0..10 {
            let out = run_toy(&phases, seed, &BoParams::default());
            let first = out.first_within(1.01).expect("must find optimum");
            total += first;
        }
        let avg = total as f64 / 10.0;
        assert!(avg < 20.0, "BO took {avg} executions on a smooth 1-D bowl");
    }

    #[test]
    fn never_tries_a_config_twice() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let out = run_toy(&phases, 3, &BoParams::default());
        let mut seen = out.tried.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), out.tried.len());
    }

    #[test]
    fn exhausts_the_whole_space() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let out = run_toy(&phases, 4, &BoParams::default());
        assert_eq!(out.tried.len(), 40);
    }

    #[test]
    fn respects_phase_order() {
        let priority: Vec<usize> = (20..30).collect();
        let rest: Vec<usize> = (0..40).filter(|i| !priority.contains(i)).collect();
        let phases = vec![priority.clone(), rest];
        let out = run_toy(&phases, 5, &BoParams::default());
        // The first |priority| executions must all come from the priority set.
        for &i in out.tried.iter().take(priority.len()) {
            assert!(priority.contains(&i), "config {i} escaped the priority phase");
        }
        assert_eq!(out.phase_starts, vec![0, 10]);
    }

    #[test]
    fn phase_restriction_speeds_up_search() {
        // Priority group containing the optimum (idx ~25 of 0..40 maps to
        // t=0.64 near optimum 0.62): searching 10 configs beats 40.
        let priority: Vec<usize> = (20..30).collect();
        let rest: Vec<usize> = (0..40).filter(|i| !priority.contains(i)).collect();
        let mut phased_total = 0;
        let mut flat_total = 0;
        for seed in 0..10 {
            let phased = run_toy(&[priority.clone(), rest.clone()], seed, &BoParams::default());
            let flat = run_toy(&[(0..40).collect()], seed, &BoParams::default());
            phased_total += phased.first_within(1.01).unwrap();
            flat_total += flat.first_within(1.01).unwrap();
        }
        assert!(
            phased_total < flat_total,
            "priority phase did not help: {phased_total} vs {flat_total}"
        );
    }

    #[test]
    fn stopping_criterion_fires_and_is_recorded() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let out = run_toy(&phases, 6, &BoParams::default());
        let stop = out.stop_after.expect("criterion should fire on a smooth bowl");
        assert!(stop >= 6);
        assert!(stop < 40, "stop at {stop} means it never converged");
        // Non-enforcing mode still explored everything.
        assert_eq!(out.tried.len(), 40);
    }

    #[test]
    fn enforced_stop_truncates_search() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let params = BoParams { enforce_stop: true, ..Default::default() };
        let out = run_toy(&phases, 7, &params);
        assert_eq!(out.tried.len(), out.stop_after.unwrap());
        assert!(out.tried.len() < 40);
    }

    #[test]
    fn max_iters_caps_executions() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let params = BoParams { max_iters: 5, ..Default::default() };
        let out = run_toy(&phases, 8, &params);
        assert_eq!(out.tried.len(), 5);
    }

    #[test]
    fn small_priority_group_shrinks_inits() {
        let phases = vec![vec![7usize], (0..40).filter(|&i| i != 7).collect()];
        let out = run_toy(&phases, 9, &BoParams::default());
        assert_eq!(out.tried[0], 7, "single-config priority must be tried first");
    }

    #[test]
    fn windowed_backend_stop_counts_executions() {
        use crate::testkit::CappedBackend;
        // Regression: `stop_after` used to record the windowed observation
        // count (`tried.len().min(max_obs)`) instead of executions
        // performed — under-reporting the stopping point, and (because the
        // gate used the same windowed count) never firing at all once
        // `max_obs < min_obs_for_stop`.
        let m = 40;
        let (features, costs) = toy_space(m);
        let phases = vec![(0..m).collect::<Vec<_>>()];
        let cap = 8;
        let min_stop = 10; // above the window: the old gate can never pass
        let mut fired = 0;
        for seed in 0..10u64 {
            let run = |enforce: bool| {
                let mut backend = CappedBackend::new(NativeBackend::new(), cap);
                let mut rng = Pcg64::from_seed(seed);
                let mut oracle = |i: usize| costs[i];
                let params = BoParams {
                    min_obs_for_stop: min_stop,
                    ei_stop_rel: 0.5,
                    enforce_stop: enforce,
                    ..Default::default()
                };
                run_search(&features, m, 6, &phases, &mut oracle, &mut backend, &mut rng, &params)
                    .expect("windowed search")
            };
            let out = run(false);
            if let Some(stop) = out.stop_after {
                fired += 1;
                assert!(stop >= min_stop, "stop {stop} below the execution gate");
                assert!(stop > cap, "stop {stop} capped at the backend window");
                // The enforced run under the same seed must end exactly at
                // the recorded stopping point with an identical prefix.
                let enf = run(true);
                assert_eq!(enf.tried.len(), stop, "enforced stop diverges from recorded stop");
                assert_eq!(enf.stop_after, Some(stop));
                assert_eq!(out.tried[..stop], enf.tried[..]);
            }
        }
        assert!(fired > 0, "stopping criterion never fired under the windowed backend");
    }

    #[test]
    fn search_drives_incremental_factor_path() {
        // The search's append-one / same-window calling pattern must keep
        // the backend on the rank-1 paths: cold refactorizations happen
        // only on the first GP iteration (one per grid point) plus rare
        // PD fallbacks, every later nll_grid extends, and each decide
        // right after nll_grid reuses its factor.
        let m = 40;
        let (features, costs) = toy_space(m);
        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(17);
        let mut oracle = |i: usize| costs[i];
        let phases = vec![(0..m).collect::<Vec<_>>()];
        let out = run_search(
            &features,
            m,
            6,
            &phases,
            &mut oracle,
            &mut backend,
            &mut rng,
            &BoParams::default(),
        )
        .expect("search");
        assert_eq!(out.tried.len(), m);
        let s = backend.factor_stats();
        assert!(s.appends > 0, "append path never engaged: {s:?}");
        assert!(s.reuses > 0, "decide never reused the nll_grid factor: {s:?}");
        assert!(
            s.cold_fits < 32 + (s.appends + s.slides) / 8,
            "cold fits should be a one-off warmup, not the steady state: {s:?}"
        );
        // Sliding only happens under a capacity-limited backend: run one.
        let mut capped = crate::testkit::CappedBackend::new(NativeBackend::new(), 10);
        let mut rng = Pcg64::from_seed(17);
        let mut oracle = |i: usize| costs[i];
        run_search(
            &features,
            m,
            6,
            &phases,
            &mut oracle,
            &mut capped,
            &mut rng,
            &BoParams::default(),
        )
        .expect("windowed search");
        let s = capped.inner.factor_stats();
        assert!(s.slides > 0, "windowed search never took the slide path: {s:?}");
    }

    #[test]
    fn deterministic_under_seed() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let a = run_toy(&phases, 11, &BoParams::default());
        let b = run_toy(&phases, 11, &BoParams::default());
        assert_eq!(a.tried, b.tried);
    }

    #[test]
    fn grid_has_aot_size() {
        assert_eq!(hyperparameter_grid().len(), 32);
    }

    #[test]
    fn cursor_stepping_matches_run_search() {
        // The wrapper and a hand-driven cursor must produce identical
        // traces and identical final snapshots — the step machine IS the
        // loop, not an approximation of it.
        let m = 40;
        let (features, costs) = toy_space(m);
        let phases: Vec<Vec<usize>> = vec![(5..25).collect(), (0..40).filter(|i| !(5..25).contains(i)).collect()];
        let params = BoParams::default();

        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(23);
        let mut oracle = |i: usize| costs[i];
        let reference =
            run_search(&features, m, 6, &phases, &mut oracle, &mut backend, &mut rng, &params)
                .expect("search");

        let mut backend = NativeBackend::new();
        let mut cursor =
            SearchCursor::new(Arc::new(phases.clone()), m, 6, Pcg64::from_seed(23), params);
        loop {
            match cursor.advance() {
                SearchStep::Done => break,
                SearchStep::Execute(i) => cursor.record(i, costs[i], &features),
                SearchStep::NeedsDecision => {
                    let pick = cursor
                        .decide_with_backend(&features, &mut backend)
                        .expect("decision");
                    if let Some(pick) = pick {
                        cursor.record(pick, costs[pick], &features);
                    }
                }
            }
        }
        let out = cursor.outcome();
        assert_eq!(out.tried, reference.tried);
        assert_eq!(
            out.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            reference.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(out.stop_after, reference.stop_after);
        assert_eq!(out.phase_starts, reference.phase_starts);
        // The wrapper also hands back the advanced RNG position.
        assert_eq!(rng.to_parts(), cursor.rng().to_parts());
    }

    fn run_warm(phases: &[Vec<usize>], seed: u64, warm: &WarmStart) -> (SearchOutcome, Vec<usize>) {
        let m = 40;
        let (features, costs) = toy_space(m);
        let mut backend = NativeBackend::new();
        let mut cursor = SearchCursor::with_warm_start(
            Arc::new(phases.to_vec()),
            m,
            6,
            Pcg64::from_seed(seed),
            BoParams::default(),
            warm,
        );
        loop {
            match cursor.advance() {
                SearchStep::Done => break,
                SearchStep::Execute(i) => cursor.record(i, costs[i], &features),
                SearchStep::NeedsDecision => {
                    if let Some(p) =
                        cursor.decide_with_backend(&features, &mut backend).expect("decision")
                    {
                        cursor.record(p, costs[p], &features);
                    }
                }
            }
        }
        let slots = cursor.grid_slots().to_vec();
        (cursor.outcome(), slots)
    }

    #[test]
    fn warm_seeds_replace_the_random_initial_design() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let warm = WarmStart { seeds: vec![30, 10, 2, 5], grid_slots: vec![] };
        let (out, _) = run_warm(&phases, 13, &warm);
        // n_init = 3: exactly the first three seeds, in order.
        assert_eq!(out.tried[..3], [30, 10, 2]);
    }

    #[test]
    fn short_warm_seed_list_is_topped_up_randomly() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        // 99 is out of catalog, 7 repeats: one usable seed survives.
        let warm = WarmStart { seeds: vec![99, 7, 7], grid_slots: vec![] };
        let (out, _) = run_warm(&phases, 13, &warm);
        assert_eq!(out.tried[0], 7);
        let mut inits = out.tried[..3].to_vec();
        inits.sort_unstable();
        inits.dedup();
        assert_eq!(inits.len(), 3, "initial design must stay {} distinct configs", 3);
    }

    #[test]
    fn out_of_phase_warm_seeds_fall_back_to_cold_draw() {
        // Seeds outside the priority phase are ignored, and with none
        // applying the trace is bit-identical to the cold search.
        let priority: Vec<usize> = (20..30).collect();
        let rest: Vec<usize> = (0..40).filter(|i| !priority.contains(i)).collect();
        let phases = vec![priority, rest];
        let warm = WarmStart { seeds: vec![0, 35], grid_slots: vec![] };
        let (warm_out, _) = run_warm(&phases, 13, &warm);
        let cold = run_toy(&phases, 13, &BoParams::default());
        assert_eq!(warm_out.tried, cold.tried);
    }

    #[test]
    fn warm_grid_slots_narrow_the_sweep() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        // Duplicate and out-of-range slots are dropped; the kept rows
        // must be exactly the named full-grid entries.
        let warm = WarmStart { seeds: vec![], grid_slots: vec![6, 4, 99, 4, 5, 7] };
        let (out, slots) = run_warm(&phases, 13, &warm);
        assert_eq!(slots, vec![4, 5, 6, 7]);
        assert_eq!(out.grid_hits.len(), hyperparameter_grid().len());
        for (s, &h) in out.grid_hits.iter().enumerate() {
            assert!(
                h == 0 || slots.contains(&s),
                "full-grid slot {s} won a sweep outside the narrowed set"
            );
        }
        // Every decision lands one hit; 40 executions minus 3 inits.
        let total: u32 = out.grid_hits.iter().sum();
        assert_eq!(total as usize, out.tried.len() - 3);
    }

    #[test]
    fn cold_cursor_sweeps_the_identity_grid() {
        let phases = vec![(0..40).collect::<Vec<_>>()];
        let (out, slots) = run_warm(&phases, 11, &WarmStart::default());
        assert_eq!(slots, (0..hyperparameter_grid().len()).collect::<Vec<_>>());
        let cold = run_toy(&phases, 11, &BoParams::default());
        assert_eq!(out.tried, cold.tried);
    }

    #[test]
    fn advance_is_idempotent() {
        let m = 40;
        let (features, costs) = toy_space(m);
        let phases: Vec<Vec<usize>> = vec![(0..m).collect()];
        let mut cursor = SearchCursor::new(
            Arc::new(phases),
            m,
            6,
            Pcg64::from_seed(3),
            BoParams::default(),
        );
        for _ in 0..8 {
            let a = cursor.advance();
            let b = cursor.advance();
            assert_eq!(a, b, "advance must not consume state without a record");
            match a {
                SearchStep::Execute(i) => cursor.record(i, costs[i], &features),
                _ => break,
            }
        }
    }
}
