//! Incremental Cholesky factorization — the per-iteration hot path of the
//! BO search.
//!
//! The search loop appends exactly one observation per iteration (and,
//! once a capacity-limited backend saturates, slides its history window
//! by one). Refitting the 32 hyperparameter-grid GPs from scratch on
//! every step costs O(H·n³); this module keeps one Cholesky factor per
//! grid point alive across iterations and updates it in O(n²) instead.
//!
//! # Packed lower-triangular storage
//!
//! [`CholFactor`] stores `L` *packed*: row `i` holds exactly its `i + 1`
//! meaningful entries, starting at offset `i·(i+1)/2` (so `L[i][j]` lives
//! at `i·(i+1)/2 + j`, `j <= i`, and the whole factor occupies
//! `n·(n+1)/2` slots with no strict-upper-triangle padding). Two
//! consequences drive the layout:
//!
//! * a rank-1 **append is a pure push**: the new row `[zᵀ, √pivot]` goes
//!   exactly at the end of the buffer — no O(n²) re-striding of the
//!   existing rows (the dense row-major layout paid a full row shift per
//!   append);
//! * a **drop-first downdate stays contiguous**: dropping column 0 turns
//!   old row `i`'s entries `1..=i` into new row `i-1`, which are already
//!   adjacent in packed form — one `copy_within` per row, front to back.
//!
//! All triangular solves and the blocked TRSM in
//! [`gp::predict_into`](super::gp::predict_into) index the packed form
//! directly via [`packed_row_start`].
//!
//! # Update math
//!
//! **Rank-1 append.** Given `K = L Lᵀ` over `n` observations and a new
//! observation with cross-kernel row `k` (length `n`) and diagonal `κ =
//! k(x,x) + noise + jitter`, the factor of the bordered matrix
//! `[[K, k], [kᵀ, κ]]` is
//!
//! ```text
//! L' = [[L, 0], [zᵀ, sqrt(κ - zᵀz)]]   with   L z = k.
//! ```
//!
//! One forward solve: O(n²). The pivot `κ - zᵀz` is the posterior
//! variance of the new point (plus noise); it must stay positive for the
//! bordered matrix to be SPD.
//!
//! **Drop-first downdate.** Removing the *oldest* observation partitions
//! `L = [[l₁₁, 0], [l₂₁, L₂₂]]`, and the trailing Gram block satisfies
//! `K₂₂ = L₂₂ L₂₂ᵀ + l₂₁ l₂₁ᵀ`. The factor of `K₂₂` is therefore the
//! rank-1 *update* `cholupdate(L₂₂, l₂₁)` — computed with Givens-style
//! rotations (LINPACK `dchud`), which always succeeds because adding
//! `l₂₁ l₂₁ᵀ` keeps the matrix SPD. A window slide is a drop-first
//! followed by an append. No hyperbolic (potentially unstable) downdate
//! is ever needed.
//!
//! # Fallback conditions
//!
//! The updated factor is mathematically identical to a scratch
//! refactorization (the Cholesky factor of an SPD matrix is unique) but
//! not bit-identical; rounding differs in the last ulps. Two guards keep
//! the incremental path numerically equivalent to a cold fit within
//! [`APPEND_PIVOT_RTOL`]:
//!
//! * [`CholFactor::append`] refuses when the pivot `κ - zᵀz <= rtol · κ`
//!   — the bordered matrix has (numerically) lost positive definiteness,
//!   exactly the regime where accumulated update error could be
//!   amplified. The caller falls back to a cold refactorization, which
//!   either succeeds (and resyncs the factor to scratch bits) or reports
//!   the Gram as not SPD, matching the scratch path's behavior.
//! * [`FactorCache`] invalidates a slot whenever the observation set
//!   changes in any way other than the append/slide the search performs
//!   (or when hyperparameters change shape), so a factor can never drift
//!   across an unrelated data set.
//!
//! # Deterministic-reduction contract
//!
//! The 32 grid slots are independent extend+solve work, and
//! `NativeBackend::nll_grid` sweeps them across a worker pool
//! (`--gp-threads`). [`FactorCache::plan_grid`] supports that by handing
//! out one disjoint [`SlotTask`] per distinct hyperparameter triple: a
//! task owns exclusive access to its slot, builds its cross-row / Gram
//! from the shared read-only distance matrix with the *same* arithmetic
//! in the *same* order as the serial sweep, and writes its nll to a
//! fixed output position. No accumulation ever crosses slots, so the
//! swept results are **bit-identical for every worker count** — the
//! contract `testkit::assert_parallel_parity` pins. Worker-local path
//! counters are merged back with [`FactorCache::absorb_stats`] (a plain
//! sum, also order-independent).
//!
//! # Multi-RHS noise batching and the SIMD parity contract
//!
//! The 4 noise levels of one (ls, var) grid group share a cross-row /
//! Gram build but own independent factors; their marginal-likelihood
//! solves are pure latency chains. [`nll_multi`] batches up to
//! [`NLL_STREAMS`] of them into one interleaved multi-RHS
//! forward+backward pass in which **every stream replays the exact
//! scalar single-solve accumulation order** — so per-slot results are
//! bit-identical for any batch width (1 stream ≡ the legacy
//! `solve_into` path on scalar dispatch), and serial and pooled sweeps
//! agree to the bit whichever way a grid is chunked. The single-slot
//! [`SlotTask::nll`] / [`FactorCache::nll`] run the same core with one
//! stream, so batched and unbatched nll can never drift.
//!
//! Which paths stay bit-exact under SIMD dispatch (see
//! [`super::simd`]): the nll solves above always accumulate in scalar
//! order — their bits do not depend on the dispatch mode at all. The
//! factorizations themselves ([`cholesky_packed_in_place`], the append
//! forward solve, and the decide-path `solve_into`) run on the
//! dispatched `kernel::dot`, which reassociates under SIMD — those
//! results are pinned to the scalar path within
//! [`super::simd::SIMD_PARITY_RTOL`] instead, and reproduce today's
//! bits exactly when SIMD is off (`RUYA_FORCE_SCALAR` /
//! `set_simd(false)`). Cross-path contracts (serial vs pooled,
//! incremental vs scratch) hold in either mode because both sides share
//! the same dispatched kernels.

// `kernel::dot` is shared with the dense solves in `gp`, so packed and
// dense arithmetic agree bit-for-bit by construction.
use super::gp::JITTER;
use super::kernel::dot;

/// Relative pivot floor for the rank-1 append: pivots below
/// `APPEND_PIVOT_RTOL * diag` trigger the cold-refactorization fallback.
pub const APPEND_PIVOT_RTOL: f64 = 1e-12;

/// Offset of packed lower-triangular row `i`: its `i + 1` entries occupy
/// `packed_row_start(i) ..= packed_row_start(i) + i`.
#[inline]
pub fn packed_row_start(i: usize) -> usize {
    i * (i + 1) / 2
}

/// Packed in-place Cholesky factorization (see the module docs for the
/// layout). Column-by-column identical arithmetic to the dense
/// [`gp::cholesky_in_place`](super::gp::cholesky_in_place) — only the
/// addressing differs — so a packed cold fit produces the same bits as
/// the dense scratch path it replaced. Returns false if not SPD.
fn cholesky_packed_in_place(l: &mut [f64], n: usize) -> bool {
    for j in 0..n {
        // Split so row j (read+write) and rows i>j (write) borrow cleanly:
        // packed row j ends exactly at packed_row_start(j + 1).
        let (head, tail) = l.split_at_mut(packed_row_start(j + 1));
        let row_j = &mut head[packed_row_start(j)..];
        let d = row_j[j] - dot(&row_j[..j], &row_j[..j]);
        if d <= 0.0 {
            return false;
        }
        let d = d.sqrt();
        row_j[j] = d;
        let base = packed_row_start(j + 1);
        for i in (j + 1)..n {
            let off = packed_row_start(i) - base;
            let row_i = &mut tail[off..off + i + 1];
            row_i[j] = (row_i[j] - dot(&row_i[..j], &row_j[..j])) / d;
        }
    }
    true
}

/// Solve `L z = b` (forward substitution) over a packed factor, in place.
pub fn solve_lower_packed(l: &[f64], n: usize, b: &mut [f64]) {
    for i in 0..n {
        let rs = packed_row_start(i);
        let s = b[i] - dot(&l[rs..rs + i], &b[..i]);
        b[i] = s / l[rs + i];
    }
}

/// Solve `Lᵀ x = b` (backward substitution) over a packed factor, in place.
pub fn solve_upper_t_packed(l: &[f64], n: usize, b: &mut [f64]) {
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[packed_row_start(k) + i] * b[k];
        }
        b[i] = s / l[packed_row_start(i) + i];
    }
}

/// A packed lower-triangular Cholesky factor with O(n) rank-1 append
/// (plus the O(n²) forward solve that computes the new row) and O(n²)
/// drop-first downdate. See the module docs for the storage scheme.
#[derive(Debug, Clone, Default)]
pub struct CholFactor {
    n: usize,
    l: Vec<f64>,
    scratch: Vec<f64>,
}

impl CholFactor {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The factor in packed lower-triangular form (`n·(n+1)/2` entries;
    /// row `i` starts at [`packed_row_start`]`(i)`).
    pub fn packed(&self) -> &[f64] {
        &self.l[..packed_row_start(self.n)]
    }

    /// Entry `L[i][j]` (requires `j <= i < n`).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.n);
        self.l[packed_row_start(i) + j]
    }

    /// Expand into a dense row-major `n x n` lower triangle (strict upper
    /// triangle zeroed) — the debug/test bridge to dense references.
    pub fn to_dense(&self, out: &mut Vec<f64>) {
        let n = self.n;
        out.clear();
        out.resize(n * n, 0.0);
        for i in 0..n {
            let rs = packed_row_start(i);
            out[i * n..i * n + i + 1].copy_from_slice(&self.l[rs..rs + i + 1]);
        }
    }

    /// Cold path: factorize `gram + diag_add * I` from scratch (the
    /// noiseless Gram plus noise and jitter on the diagonal). Returns
    /// false — leaving the factor unusable — if the matrix is not SPD.
    pub fn refactorize(&mut self, gram: &[f64], n: usize, diag_add: f64) -> bool {
        assert_eq!(gram.len(), n * n);
        self.l.clear();
        self.l.reserve(packed_row_start(n + 1));
        for i in 0..n {
            self.l.extend_from_slice(&gram[i * n..i * n + i]);
            self.l.push(gram[i * n + i] + diag_add);
        }
        self.n = n;
        cholesky_packed_in_place(&mut self.l, n)
    }

    /// Rank-1 append: extend the factor by one observation with noiseless
    /// cross-kernel `row` (length `n`) and diagonal `diag` (kernel
    /// self-covariance plus noise and jitter). The forward solve for the
    /// new row is O(n²); placing it is a pure push (the packed layout's
    /// point). Returns false — leaving the factor untouched — when the
    /// pivot drops below [`APPEND_PIVOT_RTOL`]` * diag` (loss of positive
    /// definiteness); the caller must then fall back to
    /// [`Self::refactorize`].
    pub fn append(&mut self, row: &[f64], diag: f64) -> bool {
        let n = self.n;
        assert_eq!(row.len(), n);
        if n == 0 {
            if diag <= 0.0 {
                return false;
            }
            self.l.clear();
            self.l.push(diag.sqrt());
            self.n = 1;
            return true;
        }
        // z = L^-1 row; pivot = diag - |z|^2.
        let mut z = std::mem::take(&mut self.scratch);
        z.clear();
        z.extend_from_slice(row);
        solve_lower_packed(&self.l, n, &mut z);
        let pivot = diag - z.iter().map(|v| v * v).sum::<f64>();
        if pivot <= APPEND_PIVOT_RTOL * diag {
            self.scratch = z;
            return false;
        }
        // The new packed row [z, sqrt(pivot)] lands exactly at the end.
        self.l.extend_from_slice(&z);
        self.l.push(pivot.sqrt());
        self.n = n + 1;
        self.scratch = z;
        true
    }

    /// Drop the first (oldest) observation: the trailing block becomes
    /// `cholupdate(L22, l21)`, a rank-1 Givens update that always
    /// succeeds. O(n²); the row shifts are contiguous in packed form.
    pub fn drop_first(&mut self) {
        let n = self.n;
        if n <= 1 {
            self.n = 0;
            self.l.clear();
            return;
        }
        let m = n - 1;
        // w = first column below the diagonal (each row's entry 0).
        let mut w = std::mem::take(&mut self.scratch);
        w.clear();
        for i in 1..n {
            w.push(self.l[packed_row_start(i)]);
        }
        // Old row i entries 1..=i become new row i-1 (already adjacent).
        for i in 1..n {
            let rs = packed_row_start(i);
            self.l.copy_within(rs + 1..rs + i + 1, packed_row_start(i - 1));
        }
        self.l.truncate(packed_row_start(m));
        chol_rank1_update_packed(&mut self.l, m, &mut w);
        self.n = m;
        self.scratch = w;
    }

    /// `sum_i ln L[i,i]` — half the log-determinant of the factored
    /// matrix, the same convention `NativeGp::nll` folds in.
    pub fn sum_log_diag(&self) -> f64 {
        (0..self.n).map(|i| self.l[packed_row_start(i) + i].ln()).sum()
    }

    /// Solve `L z = b` in place against this factor (the forward half of
    /// a posterior-variance computation).
    pub fn forward_solve(&self, b: &mut [f64]) {
        assert_eq!(b.len(), self.n);
        solve_lower_packed(&self.l, self.n, b);
    }

    /// alpha = (L Lᵀ)⁻¹ y via forward + backward substitution.
    pub fn solve_into(&self, y: &[f64], alpha: &mut Vec<f64>) {
        assert_eq!(y.len(), self.n);
        alpha.clear();
        alpha.extend_from_slice(y);
        solve_lower_packed(&self.l, self.n, alpha);
        solve_upper_t_packed(&self.l, self.n, alpha);
    }
}

/// LINPACK-style rank-1 Cholesky *update* over the packed layout: on
/// return `L L^T == old L L^T + w w^T`. Always succeeds for finite
/// inputs with a positive diagonal. Same rotation order as the dense
/// predecessor, so the downdate's bits are unchanged by the layout.
fn chol_rank1_update_packed(l: &mut [f64], n: usize, w: &mut [f64]) {
    debug_assert!(w.len() >= n);
    for k in 0..n {
        let dk = packed_row_start(k) + k;
        let lkk = l[dk];
        let r = lkk.hypot(w[k]);
        let c = r / lkk;
        let s = w[k] / lkk;
        l[dk] = r;
        for i in (k + 1)..n {
            let idx = packed_row_start(i) + k;
            l[idx] = (l[idx] + s * w[i]) / c;
            w[i] = c * w[i] - s * l[idx];
        }
    }
}

/// How the observation set changed relative to the previous backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObsDelta {
    /// Exactly the same rows (e.g. `decide` right after `nll_grid`).
    Unchanged,
    /// One new observation appended at the end.
    Appended,
    /// Oldest observation dropped, one appended (fixed-size window).
    Slid,
    /// Any other change: every cached factor is stale.
    #[default]
    Replaced,
}

impl ObsDelta {
    /// Classify how the row set `x` (`n` rows, `d` columns) relates to
    /// the previously seen set `prev` (`prev_n` rows, `prev_d` columns):
    /// identical rows → [`Unchanged`](Self::Unchanged); the previous
    /// rows plus one appended at the end → [`Appended`](Self::Appended);
    /// the previous rows shifted forward by one with one appended →
    /// [`Slid`](Self::Slid); anything else (including a dimension
    /// change) → [`Replaced`](Self::Replaced). `Unchanged` wins over
    /// `Slid` when both match (degenerate constant rows).
    ///
    /// This is THE delta detector of the incremental caches: the
    /// backend's pairwise-distance cache (`NativeBackend::update_d2`)
    /// and the low-rank inducing-set cache
    /// ([`InducingCache`](super::lowrank::InducingCache)) both key their
    /// incremental updates on exactly this comparison, so the two caches
    /// can never disagree about what the search loop did.
    pub fn classify(
        prev: &[f64],
        prev_n: usize,
        prev_d: usize,
        x: &[f64],
        n: usize,
        d: usize,
    ) -> ObsDelta {
        debug_assert_eq!(prev.len(), prev_n * prev_d);
        debug_assert_eq!(x.len(), n * d);
        if prev_d == d && prev_n == n && prev == x {
            ObsDelta::Unchanged
        } else if prev_d == d && n == prev_n + 1 && x[..prev_n * d] == *prev {
            ObsDelta::Appended
        } else if prev_d == d && n == prev_n && n > 0 && x[..(n - 1) * d] == prev[d..] {
            ObsDelta::Slid
        } else {
            ObsDelta::Replaced
        }
    }
}

/// What a slot must do to serve the current observation set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitPlan {
    /// The factor already describes the current observations.
    Reuse,
    /// Rank-1 append of the newest observation.
    Extend,
    /// Drop-first downdate, then append the newest observation.
    Slide,
    /// Cold refactorization from the full Gram.
    Cold,
}

/// Counters for the factorization paths taken — exposed so benches and
/// tests can verify the incremental path actually engages (the CI smoke
/// run asserts `appends > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FactorCacheStats {
    pub cold_fits: u64,
    pub appends: u64,
    pub slides: u64,
    pub reuses: u64,
    /// Appends/slides that lost positive definiteness and fell back cold.
    pub fallbacks: u64,
}

impl FactorCacheStats {
    /// Fold another counter set into this one (worker-local counters of
    /// the parallel sweep merge back through here — a plain sum, so the
    /// totals are independent of worker count and completion order).
    pub fn merge(&mut self, o: FactorCacheStats) {
        self.cold_fits += o.cold_fits;
        self.appends += o.appends;
        self.slides += o.slides;
        self.reuses += o.reuses;
        self.fallbacks += o.fallbacks;
    }
}

#[derive(Debug, Clone)]
struct Slot {
    hyp: [f64; 3],
    factor: CholFactor,
    /// Observation-set generation this factor describes.
    gen: u64,
    valid: bool,
    alpha: Vec<f64>,
}

/// Per-hyperparameter Cholesky factors, alpha vectors and
/// log-determinants, kept alive across BO iterations.
///
/// The owner reports how the observation set changed via
/// [`Self::note_delta`]; [`Self::plan`] then tells it, per
/// hyperparameter triple, whether the cached factor can be reused,
/// extended by a rank-1 append / slide, or must be refactorized cold —
/// or [`Self::plan_grid`] does so for a whole grid at once, handing out
/// disjoint [`SlotTask`]s for the worker-pool sweep. Slots are keyed by
/// exact hyperparameter bits (the selection grid is deterministic), and
/// invalidated whenever the window changes shape or the data is replaced
/// wholesale.
#[derive(Debug, Clone, Default)]
pub struct FactorCache {
    slots: Vec<Slot>,
    gen: u64,
    last_delta: ObsDelta,
    stats: FactorCacheStats,
}

impl FactorCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> FactorCacheStats {
        self.stats
    }

    /// Record how the observation set changed since the previous call.
    pub fn note_delta(&mut self, delta: ObsDelta) {
        if delta != ObsDelta::Unchanged {
            self.gen += 1;
            self.last_delta = delta;
        }
    }

    /// Slot index + required action for `hyp` over `n` observations,
    /// without the capacity valve (callers that batch-plan run the valve
    /// once up front so indices stay stable across the batch).
    fn plan_slot(&mut self, hyp: [f64; 3], n: usize) -> (usize, FitPlan) {
        let idx = match self.slots.iter().position(|s| s.hyp == hyp) {
            Some(i) => i,
            None => {
                self.slots.push(Slot {
                    hyp,
                    factor: CholFactor::new(),
                    gen: 0,
                    valid: false,
                    alpha: Vec::new(),
                });
                self.slots.len() - 1
            }
        };
        let s = &self.slots[idx];
        let plan = if s.valid && s.gen == self.gen && s.factor.n() == n {
            FitPlan::Reuse
        } else if s.valid && self.gen > 0 && s.gen == self.gen - 1 {
            match self.last_delta {
                ObsDelta::Appended if s.factor.n() + 1 == n => FitPlan::Extend,
                ObsDelta::Slid if s.factor.n() == n && n > 0 => FitPlan::Slide,
                _ => FitPlan::Cold,
            }
        } else {
            FitPlan::Cold
        };
        (idx, plan)
    }

    /// Slot index + required action for `hyp` over `n` observations.
    /// Creates the slot on first sight of a hyperparameter triple.
    pub fn plan(&mut self, hyp: [f64; 3], n: usize) -> (usize, FitPlan) {
        // Safety valve against unbounded growth under adversarial
        // (non-grid) usage; the selection grid has 32 entries.
        if self.slots.len() >= 128 && !self.slots.iter().any(|s| s.hyp == hyp) {
            self.slots.clear();
        }
        self.plan_slot(hyp, n)
    }

    /// Plan a whole hyperparameter grid at once: one disjoint
    /// [`SlotTask`] per *distinct* triple (duplicate grid entries share
    /// the first occurrence's task), plus a map from grid index to task
    /// index. The tasks borrow non-overlapping slots, so a worker pool
    /// can update them concurrently; afterwards fold each task's
    /// [`SlotTask::stats`] back via [`Self::absorb_stats`].
    pub fn plan_grid<'a>(
        &'a mut self,
        grid: &[[f64; 3]],
        n: usize,
    ) -> (Vec<SlotTask<'a>>, Vec<usize>) {
        // Run the capacity valve once up front: plan() clearing slots
        // mid-batch would invalidate indices planned earlier in the loop.
        // Like plan(), only *distinct unseen* triples count toward the
        // cap, so a backend alternating between a few known grids keeps
        // its warm factors instead of clearing on every call.
        let mut unseen: Vec<&[f64; 3]> = Vec::new();
        for h in grid {
            if !self.slots.iter().any(|s| s.hyp == *h) && !unseen.contains(&h) {
                unseen.push(h);
            }
        }
        if !unseen.is_empty() && self.slots.len() + unseen.len() >= 128 {
            self.slots.clear();
        }
        let mut map = Vec::with_capacity(grid.len());
        let mut planned: Vec<(usize, FitPlan)> = Vec::new();
        for hyp in grid {
            if let Some(t) =
                planned.iter().position(|&(si, _)| self.slots[si].hyp == *hyp)
            {
                map.push(t);
                continue;
            }
            let (idx, plan) = self.plan_slot(*hyp, n);
            map.push(planned.len());
            planned.push((idx, plan));
        }
        let gen = self.gen;
        let mut refs: Vec<Option<&mut Slot>> = self.slots.iter_mut().map(Some).collect();
        let tasks = planned
            .into_iter()
            .map(|(idx, plan)| SlotTask {
                slot: refs[idx].take().expect("grid plan mapped two triples to one slot"),
                plan,
                gen,
                stats: FactorCacheStats::default(),
            })
            .collect();
        (tasks, map)
    }

    /// A [`SlotTask`] view of one already-planned slot — the single-slot
    /// companion of [`Self::plan_grid`] (`NativeBackend::decide` plans
    /// one triple via [`Self::plan`], then updates it through the same
    /// task body the grid sweep uses, so the two paths cannot drift).
    /// Fold the task's stats back via [`Self::absorb_stats`].
    pub fn task(&mut self, idx: usize, plan: FitPlan) -> SlotTask<'_> {
        SlotTask {
            gen: self.gen,
            slot: &mut self.slots[idx],
            plan,
            stats: FactorCacheStats::default(),
        }
    }

    /// Merge worker-local counters back into the cache (see
    /// [`FactorCacheStats::merge`]).
    pub fn absorb_stats(&mut self, s: FactorCacheStats) {
        self.stats.merge(s);
    }

    /// Record that a planned [`FitPlan::Reuse`] was actually taken (the
    /// owner may override a plan — e.g. the scratch baseline forces
    /// cold — so the counter is driven by the action, not the plan).
    pub fn note_reuse(&mut self) {
        self.stats.reuses += 1;
    }

    /// Rank-1 extend of slot `idx` with the noiseless cross-kernel `row`
    /// against the *current* first `n-1` observations (for a slide, the
    /// drop-first downdate runs first). Returns false on loss of positive
    /// definiteness; the slot is then invalid until [`Self::cold`].
    pub fn extend(&mut self, idx: usize, row: &[f64], slide: bool) -> bool {
        let gen = self.gen;
        extend_slot(&mut self.slots[idx], gen, &mut self.stats, row, slide)
    }

    /// Cold refactorization of slot `idx` from the noiseless `gram`
    /// (noise + jitter added internally). Returns false if not SPD.
    pub fn cold(&mut self, idx: usize, gram: &[f64], n: usize) -> bool {
        let gen = self.gen;
        cold_slot(&mut self.slots[idx], gen, &mut self.stats, gram, n)
    }

    /// The (valid) factor of slot `idx`.
    pub fn factor(&self, idx: usize) -> &CholFactor {
        debug_assert!(self.slots[idx].valid, "factor() on an invalid slot");
        &self.slots[idx].factor
    }

    /// Negative log marginal likelihood of `y` under slot `idx`'s factor
    /// (recomputes the slot's alpha; the fold order matches
    /// `NativeGp::nll` exactly).
    pub fn nll(&mut self, idx: usize, y: &[f64]) -> f64 {
        slot_nll(&mut self.slots[idx], y)
    }
}

/// Shared slot-update bodies: [`FactorCache`] (serial, by index) and
/// [`SlotTask`] (detached, by exclusive borrow) both run exactly this
/// code, so the two paths cannot drift apart.
fn extend_slot(
    s: &mut Slot,
    gen: u64,
    stats: &mut FactorCacheStats,
    row: &[f64],
    slide: bool,
) -> bool {
    let diag = s.hyp[1] + s.hyp[2] + JITTER;
    if slide {
        s.factor.drop_first();
    }
    if s.factor.append(row, diag) {
        s.gen = gen;
        s.valid = true;
        if slide {
            stats.slides += 1;
        } else {
            stats.appends += 1;
        }
        true
    } else {
        s.valid = false;
        stats.fallbacks += 1;
        false
    }
}

fn cold_slot(
    s: &mut Slot,
    gen: u64,
    stats: &mut FactorCacheStats,
    gram: &[f64],
    n: usize,
) -> bool {
    let ok = s.factor.refactorize(gram, n, s.hyp[2] + JITTER);
    s.valid = ok;
    s.gen = gen;
    stats.cold_fits += 1;
    ok
}

/// Maximum solve streams interleaved by one [`nll_multi`] pass — the
/// grid groups 4 noise levels per (ls, var) pair, and 4 independent
/// chains are enough to saturate the FPU's add latency.
pub const NLL_STREAMS: usize = 4;

fn slot_nll(s: &mut Slot, y: &[f64]) -> f64 {
    slots_nll_multi(&mut [s], y)[0]
}

/// Batched multi-RHS marginal likelihood over one (ls, var) group's
/// noise slots: up to [`NLL_STREAMS`] independent forward+backward
/// triangular solves interleave in one pass, hiding each chain's
/// serial add latency behind the others. Every stream accumulates in
/// exactly the scalar single-solve order, so per-slot results (and the
/// slots' refreshed alpha vectors) are **bit-identical for any batch
/// width** — `nll_multi(&mut [t], y)[0] == t.nll(y)` to the bit, and a
/// grid sweep may chunk groups however it likes without changing a
/// single output bit.
pub fn nll_multi(tasks: &mut [&mut SlotTask<'_>], y: &[f64]) -> Vec<f64> {
    let mut slots: Vec<&mut Slot> = tasks.iter_mut().map(|t| &mut *t.slot).collect();
    slots_nll_multi(&mut slots, y)
}

fn slots_nll_multi(slots: &mut [&mut Slot], y: &[f64]) -> Vec<f64> {
    let n = y.len();
    let mut out = Vec::with_capacity(slots.len());
    for chunk in slots.chunks_mut(NLL_STREAMS) {
        {
            let mut streams: Vec<(&[f64], &mut [f64])> = Vec::with_capacity(chunk.len());
            for s in chunk.iter_mut() {
                debug_assert!(s.valid);
                debug_assert_eq!(n, s.factor.n());
                s.alpha.clear();
                s.alpha.extend_from_slice(y);
                let Slot { factor, alpha, .. } = &mut **s;
                streams.push((factor.l.as_slice(), alpha.as_mut_slice()));
            }
            solve_streams(&mut streams, n);
        }
        for s in chunk.iter() {
            let quad: f64 = y.iter().zip(&s.alpha).map(|(a, b)| a * b).sum::<f64>() * 0.5;
            out.push(
                quad + s.factor.sum_log_diag()
                    + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln(),
            );
        }
    }
    out
}

/// Interleave `alpha = (L Lᵀ)⁻¹ y` over up to [`NLL_STREAMS`] packed
/// factors. Monomorphized per stream count so the per-position
/// `0..K` loops unroll; each stream's arithmetic order is exactly
/// [`solve_lower_packed`] / [`solve_upper_t_packed`] with the scalar
/// dot.
fn solve_streams(streams: &mut [(&[f64], &mut [f64])], n: usize) {
    match streams.len() {
        0 => {}
        1 => solve_streams_k::<1>(streams, n),
        2 => solve_streams_k::<2>(streams, n),
        3 => solve_streams_k::<3>(streams, n),
        4 => solve_streams_k::<4>(streams, n),
        _ => unreachable!("nll_multi chunks by NLL_STREAMS"),
    }
}

fn solve_streams_k<const K: usize>(streams: &mut [(&[f64], &mut [f64])], n: usize) {
    debug_assert_eq!(streams.len(), K);
    // Forward substitution: per stream, b[i] = (b[i] - Σ_k L[i,k]·b[k])
    // / L[i,i] with the sum accumulated in ascending k — the scalar
    // solve order — while the K independent chains interleave.
    for i in 0..n {
        let rs = packed_row_start(i);
        let mut acc = [0.0f64; K];
        for k in 0..i {
            for (c, a) in acc.iter_mut().enumerate() {
                let (l, b) = &streams[c];
                *a += l[rs + k] * b[k];
            }
        }
        for (c, a) in acc.iter().enumerate() {
            let (l, b) = &mut streams[c];
            b[i] = (b[i] - a) / l[rs + i];
        }
    }
    // Backward substitution, mirroring solve_upper_t_packed per stream.
    for i in (0..n).rev() {
        let mut acc = [0.0f64; K];
        for (c, a) in acc.iter_mut().enumerate() {
            *a = streams[c].1[i];
        }
        for k in (i + 1)..n {
            let ks = packed_row_start(k);
            for (c, a) in acc.iter_mut().enumerate() {
                let (l, b) = &streams[c];
                *a -= l[ks + i] * b[k];
            }
        }
        let rs = packed_row_start(i);
        for (c, a) in acc.iter().enumerate() {
            let (l, b) = &mut streams[c];
            b[i] = a / l[rs + i];
        }
    }
}

/// One planned unit of the grid-parallel nll sweep: exclusive access to
/// a single cache slot plus the action required to bring it up to date
/// (see the module docs' deterministic-reduction contract). Obtained
/// from [`FactorCache::plan_grid`]; safe to move to a worker thread.
/// Path counters accumulate locally in [`Self::stats`] and are merged
/// back through [`FactorCache::absorb_stats`] after the sweep.
pub struct SlotTask<'a> {
    slot: &'a mut Slot,
    plan: FitPlan,
    gen: u64,
    stats: FactorCacheStats,
}

impl SlotTask<'_> {
    /// The slot's hyperparameter triple (lengthscale, variance, noise).
    pub fn hyp(&self) -> [f64; 3] {
        self.slot.hyp
    }

    /// The planned action for this slot.
    pub fn plan(&self) -> FitPlan {
        self.plan
    }

    /// Override the plan to a cold refactorization (the scratch-baseline
    /// switch of `NativeBackend::set_incremental(false)`).
    pub fn force_cold(&mut self) {
        self.plan = FitPlan::Cold;
    }

    /// Record a taken [`FitPlan::Reuse`].
    pub fn note_reuse(&mut self) {
        self.stats.reuses += 1;
    }

    /// Rank-1 extend with the noiseless cross-kernel `row` (drop-first
    /// downdate first when `slide`). Returns false on loss of positive
    /// definiteness; the slot is then invalid until [`Self::cold`].
    pub fn extend(&mut self, row: &[f64], slide: bool) -> bool {
        extend_slot(self.slot, self.gen, &mut self.stats, row, slide)
    }

    /// Cold refactorization from the noiseless `gram` (noise + jitter
    /// added internally). Returns false if not SPD.
    pub fn cold(&mut self, gram: &[f64], n: usize) -> bool {
        cold_slot(self.slot, self.gen, &mut self.stats, gram, n)
    }

    /// Negative log marginal likelihood of `y` under this slot's factor.
    pub fn nll(&mut self, y: &[f64]) -> f64 {
        slot_nll(self.slot, y)
    }

    /// The worker-local path counters accumulated by this task.
    pub fn stats(&self) -> FactorCacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::gp::matern52;

    fn gram(x: &[f64], n: usize, d: usize, ls: f64, var: f64) -> Vec<f64> {
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] =
                    matern52(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d], ls, var);
            }
        }
        k
    }

    fn points(n: usize, d: usize) -> Vec<f64> {
        (0..n * d).map(|i| ((i * 37 + 11) % 101) as f64 / 101.0).collect()
    }

    fn assert_factors_close(a: &CholFactor, b: &CholFactor, tol: f64) {
        assert_eq!(a.n(), b.n());
        let n = a.n();
        for i in 0..n {
            for j in 0..=i {
                let (x, y) = (a.at(i, j), b.at(i, j));
                let scale = x.abs().max(y.abs()).max(1.0);
                assert!((x - y).abs() <= tol * scale, "L[{i},{j}]: {x} vs {y}");
            }
        }
    }

    #[test]
    fn append_matches_scratch_factorization() {
        let (d, ls, var, noise) = (3, 0.6, 1.0, 1e-3);
        let total = 12;
        let x = points(total, d);
        let mut inc = CholFactor::new();
        for n in 1..=total {
            let row: Vec<f64> = (0..n - 1)
                .map(|j| {
                    matern52(&x[(n - 1) * d..n * d], &x[j * d..(j + 1) * d], ls, var)
                })
                .collect();
            assert!(inc.append(&row, var + noise + JITTER), "append failed at n={n}");
            let mut cold = CholFactor::new();
            assert!(cold.refactorize(&gram(&x[..n * d], n, d, ls, var), n, noise + JITTER));
            assert_factors_close(&inc, &cold, 1e-11);
        }
    }

    #[test]
    fn drop_first_then_append_matches_scratch() {
        let (d, ls, var, noise) = (2, 0.5, 1.0, 1e-2);
        let total = 16;
        let w = 6;
        let x = points(total, d);
        // Seed the window [0, w).
        let mut inc = CholFactor::new();
        assert!(inc.refactorize(&gram(&x[..w * d], w, d, ls, var), w, noise + JITTER));
        for start in 1..=(total - w) {
            inc.drop_first();
            let new = start + w - 1;
            let row: Vec<f64> = (start..new)
                .map(|j| matern52(&x[new * d..(new + 1) * d], &x[j * d..(j + 1) * d], ls, var))
                .collect();
            assert!(inc.append(&row, var + noise + JITTER), "slide failed at {start}");
            let mut cold = CholFactor::new();
            assert!(cold.refactorize(
                &gram(&x[start * d..(start + w) * d], w, d, ls, var),
                w,
                noise + JITTER
            ));
            assert_factors_close(&inc, &cold, 1e-10);
        }
    }

    #[test]
    fn append_rejects_indefinite_border() {
        // Identity factor; a cross row far larger than the diagonal makes
        // the bordered matrix indefinite.
        let mut f = CholFactor::new();
        assert!(f.refactorize(&[1.0, 0.0, 0.0, 1.0], 2, 0.0));
        let before = f.packed().to_vec();
        assert!(!f.append(&[10.0, 0.0], 1.0), "indefinite append must fail");
        assert_eq!(f.n(), 2, "failed append must leave the factor untouched");
        assert_eq!(f.packed(), &before[..]);
        // ... and the factor is still extendable with a sane row.
        assert!(f.append(&[0.1, 0.1], 1.0));
        assert_eq!(f.n(), 3);
    }

    #[test]
    fn empty_factor_appends_from_zero() {
        let mut f = CholFactor::new();
        assert!(f.append(&[], 4.0));
        assert_eq!(f.n(), 1);
        assert!((f.packed()[0] - 2.0).abs() < 1e-15);
        assert!(!CholFactor::new().append(&[], 0.0));
    }

    #[test]
    fn packed_layout_round_trips_through_dense() {
        // at(), packed() and to_dense() describe the same factor: the
        // dense expansion carries exactly the packed entries below the
        // diagonal and zeros above it.
        let d = 2;
        let n = 7;
        let x = points(n, d);
        let mut f = CholFactor::new();
        assert!(f.refactorize(&gram(&x, n, d, 0.6, 1.0), n, 1e-3));
        assert_eq!(f.packed().len(), n * (n + 1) / 2);
        let mut dense = Vec::new();
        f.to_dense(&mut dense);
        for i in 0..n {
            for j in 0..n {
                if j <= i {
                    assert_eq!(dense[i * n + j].to_bits(), f.at(i, j).to_bits(), "({i},{j})");
                } else {
                    assert_eq!(dense[i * n + j], 0.0, "upper triangle ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn rank1_update_reconstructs() {
        // L = chol(A); after update with w, L L^T == A + w w^T.
        let n = 4;
        let x = points(n, 2);
        let a = gram(&x, n, 2, 0.7, 1.0);
        let mut f = CholFactor::new();
        assert!(f.refactorize(&a, n, 0.1));
        let mut w = vec![0.3, -0.2, 0.5, 0.1];
        let w0 = w.clone();
        chol_rank1_update_packed(&mut f.l, n, &mut w);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..=i.min(j) {
                    s += f.at(i, k) * f.at(j, k);
                }
                let diag = if i == j { 0.1 } else { 0.0 };
                let want = a[i * n + j] + diag + w0[i] * w0[j];
                assert!((s - want).abs() < 1e-12, "({i},{j}): {s} vs {want}");
            }
        }
    }

    #[test]
    fn classify_detects_every_delta_family() {
        let d = 2;
        let rows: Vec<f64> = (0..6 * d).map(|i| i as f64 * 0.5).collect();
        let prev = &rows[..4 * d];
        // Same rows: unchanged.
        assert_eq!(ObsDelta::classify(prev, 4, d, prev, 4, d), ObsDelta::Unchanged);
        // Previous rows plus one at the end: appended.
        assert_eq!(
            ObsDelta::classify(prev, 4, d, &rows[..5 * d], 5, d),
            ObsDelta::Appended
        );
        // Shifted forward by one, one appended: slid.
        assert_eq!(
            ObsDelta::classify(prev, 4, d, &rows[d..5 * d], 4, d),
            ObsDelta::Slid
        );
        // Arbitrary jump or dimension change: replaced.
        assert_eq!(
            ObsDelta::classify(prev, 4, d, &rows[2 * d..6 * d], 4, d),
            ObsDelta::Replaced
        );
        assert_eq!(ObsDelta::classify(prev, 4, d, &rows[..8], 8, 1), ObsDelta::Replaced);
        // Empty previous set (fresh cache): replaced, never appended.
        assert_eq!(ObsDelta::classify(&[], 0, 0, prev, 4, d), ObsDelta::Replaced);
        // Constant rows match both Unchanged and Slid: Unchanged wins.
        let flat = vec![1.0; 4 * d];
        assert_eq!(ObsDelta::classify(&flat, 4, d, &flat, 4, d), ObsDelta::Unchanged);
    }

    #[test]
    fn cache_plans_follow_deltas() {
        let hyp = [0.5, 1.0, 1e-3];
        let mut c = FactorCache::new();
        // Fresh cache: cold.
        c.note_delta(ObsDelta::Replaced);
        let (idx, plan) = c.plan(hyp, 3);
        assert_eq!(plan, FitPlan::Cold);
        let x = points(3, 2);
        assert!(c.cold(idx, &gram(&x, 3, 2, hyp[0], hyp[1]), 3));
        // Same data again: reuse.
        assert_eq!(c.plan(hyp, 3).1, FitPlan::Reuse);
        // One appended: extend.
        c.note_delta(ObsDelta::Appended);
        assert_eq!(c.plan(hyp, 4).1, FitPlan::Extend);
        // Unknown hyp under the same delta: cold.
        assert_eq!(c.plan([0.9, 1.0, 1e-3], 4).1, FitPlan::Cold);
        // Two generations behind (slot never extended): cold again.
        c.note_delta(ObsDelta::Appended);
        assert_eq!(c.plan(hyp, 5).1, FitPlan::Cold);
    }

    #[test]
    fn cache_fallback_marks_slot_invalid() {
        let hyp = [0.5, 1.0, 0.0];
        let mut c = FactorCache::new();
        c.note_delta(ObsDelta::Replaced);
        let (idx, _) = c.plan(hyp, 2);
        assert!(c.cold(idx, &[1.0 + 1e-6, 0.0, 0.0, 1.0 + 1e-6], 2));
        c.note_delta(ObsDelta::Appended);
        let (idx, plan) = c.plan(hyp, 3);
        assert_eq!(plan, FitPlan::Extend);
        assert!(!c.extend(idx, &[10.0, 10.0], false), "indefinite extend must fail");
        assert_eq!(c.stats().fallbacks, 1);
        // The slot is invalid until a cold fit rebuilds it.
        assert_eq!(c.plan(hyp, 3).1, FitPlan::Cold);
    }

    #[test]
    fn plan_grid_hands_out_disjoint_tasks_and_maps_duplicates() {
        let d = 2;
        let n = 3;
        let x = points(n, d);
        let grid = [[0.5, 1.0, 1e-3], [0.5, 1.0, 1e-2], [0.5, 1.0, 1e-3]];
        let mut c = FactorCache::new();
        c.note_delta(ObsDelta::Replaced);
        let (mut tasks, map) = c.plan_grid(&grid, n);
        assert_eq!(tasks.len(), 2, "duplicate triples must share a task");
        assert_eq!(map, vec![0, 1, 0]);
        let mut merged = FactorCacheStats::default();
        for t in tasks.iter_mut() {
            assert_eq!(t.plan(), FitPlan::Cold);
            let g = gram(&x, n, d, t.hyp()[0], t.hyp()[1]);
            assert!(t.cold(&g, n));
            assert!(t.nll(&[0.1, -0.2, 0.3]).is_finite());
            merged.merge(t.stats());
        }
        drop(tasks);
        c.absorb_stats(merged);
        assert_eq!(c.stats().cold_fits, 2);
        // Both slots are now current: the next batch plans pure reuse.
        let (tasks, _) = c.plan_grid(&grid, n);
        assert!(tasks.iter().all(|t| t.plan() == FitPlan::Reuse));
    }

    #[test]
    fn nll_multi_is_bit_identical_to_single_solves() {
        // One (ls, var) pair swept over 5 noise levels — more than
        // NLL_STREAMS, so the batch exercises both a full interleave
        // chunk and a remainder chunk. Every stream replays the exact
        // scalar solve order, so batched and one-at-a-time marginals
        // must agree to the bit (in either dispatch mode).
        let d = 2;
        let n = 9;
        let x = points(n, d);
        let y: Vec<f64> = (0..n).map(|i| ((i * 7 + 2) % 13) as f64 / 13.0 - 0.4).collect();
        let grid: Vec<[f64; 3]> = [1e-4, 1e-3, 1e-2, 1e-1, 0.5]
            .iter()
            .map(|&noise| [0.7, 1.2, noise])
            .collect();

        fn fit<'a>(
            c: &'a mut FactorCache,
            grid: &[[f64; 3]],
            x: &[f64],
            n: usize,
            d: usize,
        ) -> Vec<SlotTask<'a>> {
            let (mut tasks, _) = c.plan_grid(grid, n);
            for t in tasks.iter_mut() {
                let g = gram(x, n, d, t.hyp()[0], t.hyp()[1]);
                assert!(t.cold(&g, n));
            }
            tasks
        }

        let mut single = FactorCache::new();
        single.note_delta(ObsDelta::Replaced);
        let mut tasks = fit(&mut single, &grid, &x, n, d);
        let want: Vec<f64> = tasks.iter_mut().map(|t| t.nll(&y)).collect();

        let mut batched = FactorCache::new();
        batched.note_delta(ObsDelta::Replaced);
        let mut tasks = fit(&mut batched, &grid, &x, n, d);
        let mut refs: Vec<&mut SlotTask<'_>> = tasks.iter_mut().collect();
        let got = nll_multi(&mut refs, &y);

        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(g.is_finite());
            assert_eq!(g.to_bits(), w.to_bits(), "slot {i}: {g} vs {w}");
        }
    }
}
