//! Incremental Cholesky factorization — the per-iteration hot path of the
//! BO search.
//!
//! The search loop appends exactly one observation per iteration (and,
//! once a capacity-limited backend saturates, slides its history window
//! by one). Refitting the 32 hyperparameter-grid GPs from scratch on
//! every step costs O(H·n³); this module keeps one Cholesky factor per
//! grid point alive across iterations and updates it in O(n²) instead.
//!
//! # Update math
//!
//! **Rank-1 append.** Given `K = L Lᵀ` over `n` observations and a new
//! observation with cross-kernel row `k` (length `n`) and diagonal `κ =
//! k(x,x) + noise + jitter`, the factor of the bordered matrix
//! `[[K, k], [kᵀ, κ]]` is
//!
//! ```text
//! L' = [[L, 0], [zᵀ, sqrt(κ - zᵀz)]]   with   L z = k.
//! ```
//!
//! One forward solve: O(n²). The pivot `κ - zᵀz` is the posterior
//! variance of the new point (plus noise); it must stay positive for the
//! bordered matrix to be SPD.
//!
//! **Drop-first downdate.** Removing the *oldest* observation partitions
//! `L = [[l₁₁, 0], [l₂₁, L₂₂]]`, and the trailing Gram block satisfies
//! `K₂₂ = L₂₂ L₂₂ᵀ + l₂₁ l₂₁ᵀ`. The factor of `K₂₂` is therefore the
//! rank-1 *update* `cholupdate(L₂₂, l₂₁)` — computed with Givens-style
//! rotations (LINPACK `dchud`), which always succeeds because adding
//! `l₂₁ l₂₁ᵀ` keeps the matrix SPD. A window slide is a drop-first
//! followed by an append. No hyperbolic (potentially unstable) downdate
//! is ever needed.
//!
//! # Fallback conditions
//!
//! The updated factor is mathematically identical to a scratch
//! refactorization (the Cholesky factor of an SPD matrix is unique) but
//! not bit-identical; rounding differs in the last ulps. Two guards keep
//! the incremental path numerically equivalent to a cold fit within
//! [`APPEND_PIVOT_RTOL`]:
//!
//! * [`CholFactor::append`] refuses when the pivot `κ - zᵀz <= rtol · κ`
//!   — the bordered matrix has (numerically) lost positive definiteness,
//!   exactly the regime where accumulated update error could be
//!   amplified. The caller falls back to a cold refactorization, which
//!   either succeeds (and resyncs the factor to scratch bits) or reports
//!   the Gram as not SPD, matching the scratch path's behavior.
//! * [`FactorCache`] invalidates a slot whenever the observation set
//!   changes in any way other than the append/slide the search performs
//!   (or when hyperparameters change shape), so a factor can never drift
//!   across an unrelated data set.

use super::gp::{
    cholesky_in_place, solve_lower_in_place, solve_upper_t_in_place, JITTER,
};

/// Relative pivot floor for the rank-1 append: pivots below
/// `APPEND_PIVOT_RTOL * diag` trigger the cold-refactorization fallback.
pub const APPEND_PIVOT_RTOL: f64 = 1e-12;

/// A dense lower-triangular Cholesky factor with O(n²) rank-1 append and
/// drop-first downdate. Storage is row-major `n x n` with the strict
/// upper triangle zeroed — directly usable by the triangular solves in
/// [`gp`](super::gp).
#[derive(Debug, Clone, Default)]
pub struct CholFactor {
    n: usize,
    l: Vec<f64>,
    scratch: Vec<f64>,
}

impl CholFactor {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The factor as a row-major `n x n` lower-triangular slice.
    pub fn l(&self) -> &[f64] {
        &self.l[..self.n * self.n]
    }

    /// Cold path: factorize `gram + diag_add * I` from scratch (the
    /// noiseless Gram plus noise and jitter on the diagonal). Returns
    /// false — leaving the factor unusable — if the matrix is not SPD.
    pub fn refactorize(&mut self, gram: &[f64], n: usize, diag_add: f64) -> bool {
        assert_eq!(gram.len(), n * n);
        self.l.clear();
        self.l.extend_from_slice(gram);
        for i in 0..n {
            self.l[i * n + i] += diag_add;
        }
        self.n = n;
        cholesky_in_place(&mut self.l, n)
    }

    /// Rank-1 append: extend the factor by one observation with noiseless
    /// cross-kernel `row` (length `n`) and diagonal `diag` (kernel
    /// self-covariance plus noise and jitter). O(n²). Returns false —
    /// leaving the factor untouched — when the pivot drops below
    /// [`APPEND_PIVOT_RTOL`]` * diag` (loss of positive definiteness);
    /// the caller must then fall back to [`Self::refactorize`].
    pub fn append(&mut self, row: &[f64], diag: f64) -> bool {
        let n = self.n;
        assert_eq!(row.len(), n);
        if n == 0 {
            if diag <= 0.0 {
                return false;
            }
            self.l.clear();
            self.l.push(diag.sqrt());
            self.n = 1;
            return true;
        }
        // z = L^-1 row; pivot = diag - |z|^2.
        let mut z = std::mem::take(&mut self.scratch);
        z.clear();
        z.extend_from_slice(row);
        solve_lower_in_place(&self.l, n, &mut z);
        let pivot = diag - z.iter().map(|v| v * v).sum::<f64>();
        if pivot <= APPEND_PIVOT_RTOL * diag {
            self.scratch = z;
            return false;
        }
        // Grow the storage from stride n to stride n+1 in place, moving
        // rows back to front (row i keeps its i+1 meaningful entries).
        let m = n + 1;
        self.l.resize(m * m, 0.0);
        for i in (1..n).rev() {
            self.l.copy_within(i * n..i * n + i + 1, i * m);
        }
        // Zero the (stale) strict upper triangle of every moved row.
        for i in 0..n {
            for j in (i + 1)..m {
                self.l[i * m + j] = 0.0;
            }
        }
        self.l[n * m..n * m + n].copy_from_slice(&z);
        self.l[n * m + n] = pivot.sqrt();
        self.n = m;
        self.scratch = z;
        true
    }

    /// Drop the first (oldest) observation: the trailing block becomes
    /// `cholupdate(L22, l21)`, a rank-1 Givens update that always
    /// succeeds. O(n²).
    pub fn drop_first(&mut self) {
        let n = self.n;
        if n <= 1 {
            self.n = 0;
            self.l.clear();
            return;
        }
        let m = n - 1;
        // w = first column below the diagonal; sub = trailing factor block.
        let mut w = std::mem::take(&mut self.scratch);
        w.clear();
        for i in 1..n {
            w.push(self.l[i * n]);
        }
        for i in 0..m {
            self.l.copy_within((i + 1) * n + 1..(i + 1) * n + 1 + (i + 1), i * m);
        }
        self.l.truncate(m * m);
        for i in 0..m {
            for j in (i + 1)..m {
                self.l[i * m + j] = 0.0;
            }
        }
        chol_rank1_update(&mut self.l, m, &mut w);
        self.n = m;
        self.scratch = w;
    }

    /// `sum_i ln L[i,i]` — half the log-determinant of the factored
    /// matrix, the same convention `NativeGp::nll` folds in.
    pub fn sum_log_diag(&self) -> f64 {
        let n = self.n;
        (0..n).map(|i| self.l[i * n + i].ln()).sum()
    }

    /// alpha = (L Lᵀ)⁻¹ y via forward + backward substitution.
    pub fn solve_into(&self, y: &[f64], alpha: &mut Vec<f64>) {
        assert_eq!(y.len(), self.n);
        alpha.clear();
        alpha.extend_from_slice(y);
        solve_lower_in_place(&self.l, self.n, alpha);
        solve_upper_t_in_place(&self.l, self.n, alpha);
    }
}

/// LINPACK-style rank-1 Cholesky *update*: on return `L L^T == old L L^T
/// + w w^T`. Always succeeds for finite inputs with a positive diagonal.
fn chol_rank1_update(l: &mut [f64], n: usize, w: &mut [f64]) {
    debug_assert!(w.len() >= n);
    for k in 0..n {
        let lkk = l[k * n + k];
        let r = lkk.hypot(w[k]);
        let c = r / lkk;
        let s = w[k] / lkk;
        l[k * n + k] = r;
        for i in (k + 1)..n {
            l[i * n + k] = (l[i * n + k] + s * w[i]) / c;
            w[i] = c * w[i] - s * l[i * n + k];
        }
    }
}

/// How the observation set changed relative to the previous backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObsDelta {
    /// Exactly the same rows (e.g. `decide` right after `nll_grid`).
    Unchanged,
    /// One new observation appended at the end.
    Appended,
    /// Oldest observation dropped, one appended (fixed-size window).
    Slid,
    /// Any other change: every cached factor is stale.
    #[default]
    Replaced,
}

/// What a slot must do to serve the current observation set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitPlan {
    /// The factor already describes the current observations.
    Reuse,
    /// Rank-1 append of the newest observation.
    Extend,
    /// Drop-first downdate, then append the newest observation.
    Slide,
    /// Cold refactorization from the full Gram.
    Cold,
}

/// Counters for the factorization paths taken — exposed so benches and
/// tests can verify the incremental path actually engages (the CI smoke
/// run asserts `appends > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FactorCacheStats {
    pub cold_fits: u64,
    pub appends: u64,
    pub slides: u64,
    pub reuses: u64,
    /// Appends/slides that lost positive definiteness and fell back cold.
    pub fallbacks: u64,
}

#[derive(Debug, Clone)]
struct Slot {
    hyp: [f64; 3],
    factor: CholFactor,
    /// Observation-set generation this factor describes.
    gen: u64,
    valid: bool,
    alpha: Vec<f64>,
}

/// Per-hyperparameter Cholesky factors, alpha vectors and
/// log-determinants, kept alive across BO iterations.
///
/// The owner reports how the observation set changed via
/// [`Self::note_delta`]; [`Self::plan`] then tells it, per
/// hyperparameter triple, whether the cached factor can be reused,
/// extended by a rank-1 append / slide, or must be refactorized cold.
/// Slots are keyed by exact hyperparameter bits (the selection grid is
/// deterministic), and invalidated whenever the window changes shape or
/// the data is replaced wholesale.
#[derive(Debug, Clone, Default)]
pub struct FactorCache {
    slots: Vec<Slot>,
    gen: u64,
    last_delta: ObsDelta,
    stats: FactorCacheStats,
}

impl FactorCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> FactorCacheStats {
        self.stats
    }

    /// Record how the observation set changed since the previous call.
    pub fn note_delta(&mut self, delta: ObsDelta) {
        if delta != ObsDelta::Unchanged {
            self.gen += 1;
            self.last_delta = delta;
        }
    }

    /// Slot index + required action for `hyp` over `n` observations.
    /// Creates the slot on first sight of a hyperparameter triple.
    pub fn plan(&mut self, hyp: [f64; 3], n: usize) -> (usize, FitPlan) {
        let idx = match self.slots.iter().position(|s| s.hyp == hyp) {
            Some(i) => i,
            None => {
                // Safety valve against unbounded growth under adversarial
                // (non-grid) usage; the selection grid has 32 entries.
                if self.slots.len() >= 128 {
                    self.slots.clear();
                }
                self.slots.push(Slot {
                    hyp,
                    factor: CholFactor::new(),
                    gen: 0,
                    valid: false,
                    alpha: Vec::new(),
                });
                self.slots.len() - 1
            }
        };
        let s = &self.slots[idx];
        let plan = if s.valid && s.gen == self.gen && s.factor.n() == n {
            FitPlan::Reuse
        } else if s.valid && self.gen > 0 && s.gen == self.gen - 1 {
            match self.last_delta {
                ObsDelta::Appended if s.factor.n() + 1 == n => FitPlan::Extend,
                ObsDelta::Slid if s.factor.n() == n && n > 0 => FitPlan::Slide,
                _ => FitPlan::Cold,
            }
        } else {
            FitPlan::Cold
        };
        (idx, plan)
    }

    /// Record that a planned [`FitPlan::Reuse`] was actually taken (the
    /// owner may override a plan — e.g. the scratch baseline forces
    /// cold — so the counter is driven by the action, not the plan).
    pub fn note_reuse(&mut self) {
        self.stats.reuses += 1;
    }

    /// Rank-1 extend of slot `idx` with the noiseless cross-kernel `row`
    /// against the *current* first `n-1` observations (for a slide, the
    /// drop-first downdate runs first). Returns false on loss of positive
    /// definiteness; the slot is then invalid until [`Self::cold`].
    pub fn extend(&mut self, idx: usize, row: &[f64], slide: bool) -> bool {
        let s = &mut self.slots[idx];
        let diag = s.hyp[1] + s.hyp[2] + JITTER;
        if slide {
            s.factor.drop_first();
        }
        if s.factor.append(row, diag) {
            s.gen = self.gen;
            s.valid = true;
            if slide {
                self.stats.slides += 1;
            } else {
                self.stats.appends += 1;
            }
            true
        } else {
            s.valid = false;
            self.stats.fallbacks += 1;
            false
        }
    }

    /// Cold refactorization of slot `idx` from the noiseless `gram`
    /// (noise + jitter added internally). Returns false if not SPD.
    pub fn cold(&mut self, idx: usize, gram: &[f64], n: usize) -> bool {
        let s = &mut self.slots[idx];
        let ok = s.factor.refactorize(gram, n, s.hyp[2] + JITTER);
        s.valid = ok;
        s.gen = self.gen;
        self.stats.cold_fits += 1;
        ok
    }

    /// The (valid) factor of slot `idx`.
    pub fn factor(&self, idx: usize) -> &CholFactor {
        debug_assert!(self.slots[idx].valid, "factor() on an invalid slot");
        &self.slots[idx].factor
    }

    /// Negative log marginal likelihood of `y` under slot `idx`'s factor
    /// (recomputes the slot's alpha; the fold order matches
    /// `NativeGp::nll` exactly).
    pub fn nll(&mut self, idx: usize, y: &[f64]) -> f64 {
        let s = &mut self.slots[idx];
        debug_assert!(s.valid);
        let n = y.len();
        debug_assert_eq!(n, s.factor.n());
        s.factor.solve_into(y, &mut s.alpha);
        let quad: f64 = y.iter().zip(&s.alpha).map(|(a, b)| a * b).sum::<f64>() * 0.5;
        quad + s.factor.sum_log_diag() + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::gp::matern52;

    fn gram(x: &[f64], n: usize, d: usize, ls: f64, var: f64) -> Vec<f64> {
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] =
                    matern52(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d], ls, var);
            }
        }
        k
    }

    fn points(n: usize, d: usize) -> Vec<f64> {
        (0..n * d).map(|i| ((i * 37 + 11) % 101) as f64 / 101.0).collect()
    }

    fn assert_factors_close(a: &CholFactor, b: &CholFactor, tol: f64) {
        assert_eq!(a.n(), b.n());
        let n = a.n();
        for i in 0..n {
            for j in 0..=i {
                let (x, y) = (a.l()[i * n + j], b.l()[i * n + j]);
                let scale = x.abs().max(y.abs()).max(1.0);
                assert!((x - y).abs() <= tol * scale, "L[{i},{j}]: {x} vs {y}");
            }
        }
    }

    #[test]
    fn append_matches_scratch_factorization() {
        let (d, ls, var, noise) = (3, 0.6, 1.0, 1e-3);
        let total = 12;
        let x = points(total, d);
        let mut inc = CholFactor::new();
        for n in 1..=total {
            let row: Vec<f64> = (0..n - 1)
                .map(|j| {
                    matern52(&x[(n - 1) * d..n * d], &x[j * d..(j + 1) * d], ls, var)
                })
                .collect();
            assert!(inc.append(&row, var + noise + JITTER), "append failed at n={n}");
            let mut cold = CholFactor::new();
            assert!(cold.refactorize(&gram(&x[..n * d], n, d, ls, var), n, noise + JITTER));
            assert_factors_close(&inc, &cold, 1e-11);
        }
    }

    #[test]
    fn drop_first_then_append_matches_scratch() {
        let (d, ls, var, noise) = (2, 0.5, 1.0, 1e-2);
        let total = 16;
        let w = 6;
        let x = points(total, d);
        // Seed the window [0, w).
        let mut inc = CholFactor::new();
        assert!(inc.refactorize(&gram(&x[..w * d], w, d, ls, var), w, noise + JITTER));
        for start in 1..=(total - w) {
            inc.drop_first();
            let new = start + w - 1;
            let row: Vec<f64> = (start..new)
                .map(|j| matern52(&x[new * d..(new + 1) * d], &x[j * d..(j + 1) * d], ls, var))
                .collect();
            assert!(inc.append(&row, var + noise + JITTER), "slide failed at {start}");
            let mut cold = CholFactor::new();
            assert!(cold.refactorize(
                &gram(&x[start * d..(start + w) * d], w, d, ls, var),
                w,
                noise + JITTER
            ));
            assert_factors_close(&inc, &cold, 1e-10);
        }
    }

    #[test]
    fn append_rejects_indefinite_border() {
        // Identity factor; a cross row far larger than the diagonal makes
        // the bordered matrix indefinite.
        let mut f = CholFactor::new();
        assert!(f.refactorize(&[1.0, 0.0, 0.0, 1.0], 2, 0.0));
        let before = f.l().to_vec();
        assert!(!f.append(&[10.0, 0.0], 1.0), "indefinite append must fail");
        assert_eq!(f.n(), 2, "failed append must leave the factor untouched");
        assert_eq!(f.l(), &before[..]);
        // ... and the factor is still extendable with a sane row.
        assert!(f.append(&[0.1, 0.1], 1.0));
        assert_eq!(f.n(), 3);
    }

    #[test]
    fn empty_factor_appends_from_zero() {
        let mut f = CholFactor::new();
        assert!(f.append(&[], 4.0));
        assert_eq!(f.n(), 1);
        assert!((f.l()[0] - 2.0).abs() < 1e-15);
        assert!(!CholFactor::new().append(&[], 0.0));
    }

    #[test]
    fn rank1_update_reconstructs() {
        // L = chol(A); after update with w, L L^T == A + w w^T.
        let n = 4;
        let x = points(n, 2);
        let mut a = gram(&x, n, 2, 0.7, 1.0);
        for i in 0..n {
            a[i * n + i] += 0.1;
        }
        let orig = a.clone();
        assert!(cholesky_in_place(&mut a, n));
        let mut w = vec![0.3, -0.2, 0.5, 0.1];
        let w0 = w.clone();
        chol_rank1_update(&mut a, n, &mut w);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * a[j * n + k];
                }
                let want = orig[i * n + j] + w0[i] * w0[j];
                assert!((s - want).abs() < 1e-12, "({i},{j}): {s} vs {want}");
            }
        }
    }

    #[test]
    fn cache_plans_follow_deltas() {
        let hyp = [0.5, 1.0, 1e-3];
        let mut c = FactorCache::new();
        // Fresh cache: cold.
        c.note_delta(ObsDelta::Replaced);
        let (idx, plan) = c.plan(hyp, 3);
        assert_eq!(plan, FitPlan::Cold);
        let x = points(3, 2);
        assert!(c.cold(idx, &gram(&x, 3, 2, hyp[0], hyp[1]), 3));
        // Same data again: reuse.
        assert_eq!(c.plan(hyp, 3).1, FitPlan::Reuse);
        // One appended: extend.
        c.note_delta(ObsDelta::Appended);
        assert_eq!(c.plan(hyp, 4).1, FitPlan::Extend);
        // Unknown hyp under the same delta: cold.
        assert_eq!(c.plan([0.9, 1.0, 1e-3], 4).1, FitPlan::Cold);
        // Two generations behind (slot never extended): cold again.
        c.note_delta(ObsDelta::Appended);
        assert_eq!(c.plan(hyp, 5).1, FitPlan::Cold);
    }

    #[test]
    fn cache_fallback_marks_slot_invalid() {
        let hyp = [0.5, 1.0, 0.0];
        let mut c = FactorCache::new();
        c.note_delta(ObsDelta::Replaced);
        let (idx, _) = c.plan(hyp, 2);
        assert!(c.cold(idx, &[1.0 + 1e-6, 0.0, 0.0, 1.0 + 1e-6], 2));
        c.note_delta(ObsDelta::Appended);
        let (idx, plan) = c.plan(hyp, 3);
        assert_eq!(plan, FitPlan::Extend);
        assert!(!c.extend(idx, &[10.0, 10.0], false), "indefinite extend must fail");
        assert_eq!(c.stats().fallbacks, 1);
        // The slot is invalid until a cold fit rebuilds it.
        assert_eq!(c.plan(hyp, 3).1, FitPlan::Cold);
    }
}
