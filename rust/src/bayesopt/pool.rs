//! The **process-global** GP worker pool — the one execution engine
//! behind every `NativeBackend` parallel path (the hyperparameter-grid
//! nll sweep, its low-rank counterpart, the decide tile fan-out) *and*
//! the `SessionEngine`'s batched scoring fan-out.
//!
//! # Why one pool per process
//!
//! Earlier designs owned a [`WorkerPool`] per backend (and one more per
//! session engine). Correct, but a `--threads T` engine instantiating
//! `--gp-threads G` backends parked T×G threads — quadratic thread
//! growth that capped how many concurrent searches a resident `ruya
//! serve` process could multiplex. Now [`global_pool`] lazily spawns a
//! single shared pool (width = [`adaptive_gp_threads`] unless
//! [`configure_global_pool_width`] overrode it first) and every fan-out
//! in the process attaches to it: total parked worker threads never
//! exceed the pool width, no matter how many backends, engines or
//! engine workers exist ([`spawned_pool_threads`] makes the budget
//! observable; the `bench_sessions --smoke` CI guard asserts it).
//!
//! [`adaptive_gp_threads`]: super::backend::adaptive_gp_threads
//!
//! # Shared-pool determinism contract
//!
//! [`WorkerPool::run_groups`] deals whole work groups round-robin:
//! group `g` of `G` goes to lane `g % min(width, G)`, in order — the
//! same stable lane order per fan-out as the per-backend pools used.
//! Every item writes only its own caller-disjoint outputs and no
//! floating-point reduction crosses items, so each fan-out's results
//! are **bit-identical for any pool width** and independent of whatever
//! other fan-outs run concurrently: two backends interleaving on the
//! shared lanes cannot perturb each other's outputs because a lane runs
//! one fan-out's task to completion before taking the next, and the
//! task's arithmetic depends only on its own inputs and scratch (see
//! below). `testkit::assert_parallel_parity` pins the serial-vs-pooled
//! contract; its shared-pool mode (`assert_shared_pool_parity`) pins
//! the concurrent-backends case under the randomized script fuzz.
//!
//! # Per-lane scratch, keyed by backend epoch
//!
//! Each worker owns a [`LaneScratch`] that survives across fan-outs —
//! the cross-row/Gram buffers of the exact sweep, the prediction
//! buffers of the decide tiles, and a whole [`LowRankGp`] for the
//! low-rank sweep — so a backend's steady-state fan-outs allocate
//! nothing per call. Because the lanes are now shared, scratch is keyed
//! per **(lane, backend epoch)**: every backend (and session engine)
//! draws a unique epoch from [`next_pool_epoch`] and stamps its tasks
//! with it, and a worker resets its scratch to defaults whenever the
//! incoming epoch differs from the one the scratch last served. A
//! backend that has the pool to itself keeps its warm buffers exactly
//! as before; interleaved backends trade reuse for a reset, never for
//! cross-backend leakage. Consumers still fully overwrite the buffers
//! they read (and re-seed their memo keys per fan-out), so the reset is
//! a belt-and-suspenders guarantee, not a correctness crutch.
//!
//! # Panic behavior
//!
//! A panic inside a work closure is caught on the worker, reported back
//! over the fan-out's private completion channel, and re-raised on the
//! caller after every submitted lane has drained — workers stay alive
//! (the pool and the other fan-outs survive), and a failing `assert!`
//! inside swept code surfaces in the test that caused it.

use super::lowrank::LowRankGp;
use super::simd;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};
use std::thread::JoinHandle;

/// Reusable per-lane buffers, owned by one worker thread and keyed to
/// the backend epoch they last served (see the module docs). One field
/// per consumer:
///
/// * `row` / `gram` — the exact nll sweep's (lengthscale, variance)
///   memoized cross-row and Gram builds;
/// * `ks` / `acc` — `gp::predict_into`'s cross-kernel block and
///   accumulator for the decide tile fan-out;
/// * `lowrank` — a full low-rank posterior (with its own internal
///   scratch) for the Woodbury nll sweep's per-lane fits.
#[derive(Debug, Default)]
pub struct LaneScratch {
    pub row: Vec<f64>,
    pub gram: Vec<f64>,
    pub ks: Vec<f64>,
    pub acc: Vec<f64>,
    pub lowrank: LowRankGp,
}

impl LaneScratch {
    /// Pre-size the exact-sweep buffers for `n` observations — the
    /// cross-row (n-1 entries) and the n × n Gram build — padding the
    /// capacities to whole SIMD lane groups ([`simd::lane_padded`]).
    /// The search loop grows its observation window by one row per BO
    /// iteration, so lane-group-rounded capacities absorb the next few
    /// one-longer builds in already-owned storage instead of
    /// reallocating at the top of a fan-out. Lengths are untouched:
    /// every consumer still fully overwrites what it reads (the module
    /// docs' scratch contract).
    pub fn reserve_sweep(&mut self, n: usize) {
        reserve_to(&mut self.row, simd::lane_padded(n));
        reserve_to(&mut self.gram, simd::lane_padded(n * n));
    }

    /// Pre-size the prediction buffers for `gp::predict_into` over `n`
    /// observations and up-to-`tile`-wide candidate tiles: the n × tile
    /// cross-kernel block and the
    /// [`PREDICT_ROW_BLOCK`](super::gp::PREDICT_ROW_BLOCK)-row
    /// accumulator, with the same lane-padded capacities as
    /// [`Self::reserve_sweep`].
    pub fn reserve_tiles(&mut self, n: usize, tile: usize) {
        reserve_to(&mut self.ks, simd::lane_padded(n * tile));
        let acc_rows = super::gp::PREDICT_ROW_BLOCK.min(n.max(1));
        reserve_to(&mut self.acc, simd::lane_padded(acc_rows * tile));
    }
}

/// Grow `v`'s capacity to at least `cap` entries (length untouched).
fn reserve_to(v: &mut Vec<f64>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// A unit of submitted work: the closure runs once on a worker against
/// that lane's persistent scratch (reset first if `epoch` differs from
/// the scratch's last owner), then the result — unit or a caught panic
/// payload — is acknowledged on the submitting fan-out's private `ack`
/// channel. Closures are type-erased to `'static` inside
/// [`WorkerPool::run_groups`], which blocks until every task has
/// acknowledged completion — see the SAFETY note there.
struct Task {
    epoch: u64,
    work: Box<dyn FnOnce(&mut LaneScratch) + Send + 'static>,
    ack: Sender<std::thread::Result<()>>,
}

/// A fixed-width pool of parked worker threads (see the module docs).
/// Production code shares the one process-global instance behind
/// [`global_pool`]; unit tests may still build private pools directly.
/// Concurrent [`run_groups`](Self::run_groups) calls from different
/// threads are safe: each fan-out carries its own completion channel,
/// and lane submission goes through a short per-lane mutex.
pub struct WorkerPool {
    /// One submission channel per worker: lane → worker pinning is
    /// 1:1 and stable, so each lane's scratch stays with its lane. The
    /// mutex only guards the `send` (senders are cheap to serialize);
    /// workers never contend on it.
    txs: Vec<Mutex<Sender<Task>>>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("width", &self.txs.len()).finish()
    }
}

/// Live `gp-pool-*` worker threads in this process (spawned minus
/// exited) — the thread-budget observable the `bench_sessions --smoke`
/// CI guard asserts stays at or below [`global_pool_width`] no matter
/// how many engines and backends exist.
static POOL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Decrements [`POOL_THREADS`] when a worker's loop exits (drop-guard,
/// so even an unexpected unwind keeps the count honest).
struct ThreadCountGuard;

impl Drop for ThreadCountGuard {
    fn drop(&mut self) {
        POOL_THREADS.fetch_sub(1, Ordering::SeqCst);
    }
}

impl WorkerPool {
    /// Spawn `width` parked workers (floored at 1), each owning a fresh
    /// [`LaneScratch`].
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let mut txs = Vec::with_capacity(width);
        let mut handles = Vec::with_capacity(width);
        for lane in 0..width {
            let (tx, rx) = channel::<Task>();
            POOL_THREADS.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("gp-pool-{lane}"))
                .spawn(move || {
                    let _count = ThreadCountGuard;
                    let mut scratch = LaneScratch::default();
                    // Epoch the scratch last served; 0 never matches a
                    // real epoch (next_pool_epoch starts at 1), so the
                    // first task always claims the scratch explicitly.
                    let mut owner = 0u64;
                    while let Ok(Task { epoch, work, ack }) = rx.recv() {
                        if epoch != owner {
                            scratch = LaneScratch::default();
                            owner = epoch;
                        }
                        // The closure (and every borrow it captured) is
                        // consumed — dropped — before the ack is sent.
                        let result = catch_unwind(AssertUnwindSafe(|| work(&mut scratch)));
                        // A dead ack receiver means the submitting
                        // fan-out is gone; nothing left to report.
                        let _ = ack.send(result);
                    }
                })
                .unwrap_or_else(|e| {
                    POOL_THREADS.fetch_sub(1, Ordering::SeqCst);
                    panic!("spawning a GP pool worker: {e}");
                });
            txs.push(Mutex::new(tx));
            handles.push(handle);
        }
        Self { txs, handles }
    }

    /// The number of worker lanes.
    pub fn width(&self) -> usize {
        self.txs.len()
    }

    /// Deal `groups` round-robin across the lanes (group `g` → lane
    /// `g % min(width, groups)`, in order — the deterministic dealing of
    /// the module docs) and run `work` once per used lane over that
    /// lane's items, against the lane's persistent [`LaneScratch`]
    /// (reset first when its last owner differs from `epoch` — pass the
    /// caller's [`next_pool_epoch`] handle). Blocks until every lane has
    /// finished; re-raises the first caught panic after all lanes have
    /// drained. Safe to call concurrently from many threads: every call
    /// waits on its own private completion channel.
    pub fn run_groups<T, F>(&self, epoch: u64, groups: Vec<Vec<T>>, work: F)
    where
        T: Send,
        F: Fn(Vec<T>, &mut LaneScratch) + Sync,
    {
        if groups.is_empty() {
            return;
        }
        let used = self.width().min(groups.len());
        let mut lanes: Vec<Vec<T>> = (0..used).map(|_| Vec::new()).collect();
        for (g, group) in groups.into_iter().enumerate() {
            lanes[g % used].extend(group);
        }
        let (ack_tx, ack_rx) = channel::<std::thread::Result<()>>();
        let work_ref = &work;
        for (lane_idx, lane) in lanes.into_iter().enumerate() {
            let task: Box<dyn FnOnce(&mut LaneScratch) + Send + '_> =
                Box::new(move |scratch: &mut LaneScratch| work_ref(lane, scratch));
            // SAFETY: the task borrows `work` and whatever `lane`'s items
            // borrow from the caller's frame. We erase those lifetimes to
            // ship the task to a persistent thread, which is sound
            // because this function does not return until the completion
            // loop below has received one ack per submitted task, and a
            // worker sends its ack only after the task has run *and been
            // dropped* — no borrow outlives this call, even on panic
            // (the payload is re-raised only after all lanes drained).
            let work_erased: Box<dyn FnOnce(&mut LaneScratch) + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce(&mut LaneScratch) + Send + '_>,
                    Box<dyn FnOnce(&mut LaneScratch) + Send + 'static>,
                >(task)
            };
            let task = Task { epoch, work: work_erased, ack: ack_tx.clone() };
            let tx = self.txs[lane_idx].lock().unwrap_or_else(|p| p.into_inner());
            // A send can only fail if a worker exited its recv loop,
            // which cannot happen while the pool owns the channels — but
            // if that invariant is ever broken, unwinding here would
            // free the caller frame while already-submitted tasks still
            // borrow it. Abort instead: the SAFETY contract must hold on
            // every path, not just the expected one.
            if tx.send(task).is_err() {
                eprintln!("fatal: GP pool worker died with tasks in flight");
                std::process::abort();
            }
        }
        // Drop our own sender so a worker dropping an unrun task (its
        // ack sender with it) is distinguishable from "still running".
        drop(ack_tx);
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..used {
            let ack = ack_rx.recv().unwrap_or_else(|_| {
                // Same reasoning as the send above: returning (or
                // unwinding) before every ack arrives would dangle the
                // erased borrows of any still-running task.
                eprintln!("fatal: GP pool worker died before acknowledging");
                std::process::abort();
            });
            match ack {
                Ok(()) => {}
                // Keep the first payload received (the contract above);
                // later ones are dropped after their lanes drained.
                Err(payload) => {
                    if panic.is_none() {
                        panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the submission channels ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-global pool width chosen before (or at) first spawn.
static GLOBAL_WIDTH: OnceLock<usize> = OnceLock::new();

/// The process-global pool itself (spawned lazily by [`global_pool`]).
static GLOBAL_POOL: OnceLock<WorkerPool> = OnceLock::new();

/// Backend-epoch counter for [`next_pool_epoch`]; starts at 1 so the
/// workers' "no owner yet" sentinel 0 never collides.
static NEXT_EPOCH: AtomicU64 = AtomicU64::new(1);

/// Draw a fresh backend epoch for scratch keying (see the module docs).
/// Every [`WorkerPool::run_groups`] caller owns exactly one.
pub fn next_pool_epoch() -> u64 {
    NEXT_EPOCH.fetch_add(1, Ordering::Relaxed)
}

/// Set the process-global pool width **once per process** (the
/// `--gp-threads` CLI knob lands here): `0` resolves to the adaptive
/// default, anything else is floored at 1. The first call wins — later
/// calls (and a pool already spawned at the adaptive default) keep the
/// established width, because resizing a shared pool under live
/// fan-outs is exactly the lifecycle churn the global design removes.
/// Returns the width the process settled on.
pub fn configure_global_pool_width(threads: usize) -> usize {
    let requested =
        if threads == 0 { super::backend::adaptive_gp_threads() } else { threads.max(1) };
    *GLOBAL_WIDTH.get_or_init(|| requested)
}

/// The width of the process-global pool: the spawned pool's lane count,
/// or the width it *will* spawn with (configured, else adaptive).
pub fn global_pool_width() -> usize {
    if let Some(pool) = GLOBAL_POOL.get() {
        return pool.width();
    }
    *GLOBAL_WIDTH.get_or_init(super::backend::adaptive_gp_threads)
}

/// The process-global worker pool, spawned on first use at
/// [`global_pool_width`] lanes and alive for the rest of the process.
pub fn global_pool() -> &'static WorkerPool {
    global_pool_acquire().0
}

/// [`global_pool`], also reporting whether *this* call spawned it —
/// the backend stats use the flag to count process-level pool creation
/// exactly once without a second synchronization point.
pub fn global_pool_acquire() -> (&'static WorkerPool, bool) {
    let mut spawned_here = false;
    let pool = GLOBAL_POOL.get_or_init(|| {
        spawned_here = true;
        WorkerPool::new(global_pool_width())
    });
    (pool, spawned_here)
}

/// True once the process-global pool has spawned.
pub fn global_pool_is_running() -> bool {
    GLOBAL_POOL.get().is_some()
}

/// Live GP pool worker threads in this process, counting the global
/// pool and any private [`WorkerPool`]s alike. With only the global
/// pool in play this is `<= global_pool_width()` for the whole process
/// lifetime — the no-T×G-multiplication acceptance guard.
pub fn spawned_pool_threads() -> usize {
    POOL_THREADS.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_borrowed_work_to_disjoint_slots() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.width(), 4);
        let epoch = next_pool_epoch();
        let mut out = vec![0.0f64; 10];
        let inputs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        {
            let groups: Vec<Vec<(usize, &mut f64)>> =
                out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
            let inputs = &inputs;
            pool.run_groups(epoch, groups, |lane, _scratch| {
                for (i, slot) in lane {
                    *slot = inputs[i] * 2.0;
                }
            });
        }
        assert_eq!(out, (0..10).map(|i| i as f64 * 2.0).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_repeated_runs_and_reuses_scratch() {
        let pool = WorkerPool::new(2);
        let epoch = next_pool_epoch();
        for round in 0..5 {
            let mut out = vec![0usize; 6];
            let groups: Vec<Vec<(usize, &mut usize)>> =
                out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
            pool.run_groups(epoch, groups, |lane, scratch| {
                // Persistent scratch: grow a marker buffer across runs.
                scratch.row.push(round as f64);
                for (i, slot) in lane {
                    *slot = i + round;
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + round, "round {round}");
            }
        }
    }

    #[test]
    fn scratch_resets_when_the_epoch_changes_hands() {
        // One lane, two epochs: the second epoch must not see the first
        // epoch's scratch contents, and the first must start over when
        // it comes back — the (lane, backend-epoch) keying contract.
        let pool = WorkerPool::new(1);
        let a = next_pool_epoch();
        let b = next_pool_epoch();
        let observe = |pool: &WorkerPool, epoch: u64| -> usize {
            let mut len = 0usize;
            {
                let groups: Vec<Vec<&mut usize>> = vec![vec![&mut len]];
                pool.run_groups(epoch, groups, |lane, scratch| {
                    for slot in lane {
                        *slot = scratch.row.len();
                    }
                    scratch.row.push(1.0);
                });
            }
            len
        };
        assert_eq!(observe(&pool, a), 0, "epoch a starts fresh");
        assert_eq!(observe(&pool, a), 1, "same epoch keeps its scratch");
        assert_eq!(observe(&pool, b), 0, "epoch b must not inherit a's scratch");
        assert_eq!(observe(&pool, a), 0, "a returning after b starts over, not from 2");
    }

    #[test]
    fn pool_uses_at_most_one_lane_per_group() {
        // 3 groups over 8 lanes: only 3 lanes are used, in order.
        let pool = WorkerPool::new(8);
        let epoch = next_pool_epoch();
        let mut out = vec![String::new(), String::new(), String::new()];
        let groups: Vec<Vec<(usize, &mut String)>> =
            out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
        pool.run_groups(epoch, groups, |lane, _| {
            let name = std::thread::current().name().unwrap_or("?").to_string();
            for (_, slot) in lane {
                *slot = name.clone();
            }
        });
        // Deterministic dealing: group g lands on lane g % 3... of the
        // first min(width, groups) lanes only.
        for (g, name) in out.iter().enumerate() {
            assert_eq!(name, &format!("gp-pool-{g}"), "group {g} on the wrong lane");
        }
    }

    #[test]
    fn concurrent_fanouts_share_the_lanes_without_crosstalk() {
        // Many threads fanning out on one pool at once: every fan-out's
        // private ack channel must pair its own tasks, and the disjoint
        // outputs must come back exactly as a solo run produces them.
        let pool = std::sync::Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let pool = std::sync::Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let epoch = next_pool_epoch();
                for round in 0..16u64 {
                    let mut out = vec![0u64; 12];
                    {
                        let groups: Vec<Vec<(usize, &mut u64)>> =
                            out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
                        pool.run_groups(epoch, groups, |lane, _| {
                            for (i, slot) in lane {
                                *slot = t * 1000 + round * 100 + i as u64;
                            }
                        });
                    }
                    for (i, v) in out.iter().enumerate() {
                        assert_eq!(*v, t * 1000 + round * 100 + i as u64);
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("concurrent fan-out thread");
        }
    }

    #[test]
    fn pool_propagates_worker_panics_after_draining() {
        let pool = WorkerPool::new(2);
        let epoch = next_pool_epoch();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let groups: Vec<Vec<usize>> = vec![vec![0], vec![1]];
            pool.run_groups(epoch, groups, |lane, _| {
                if lane.contains(&1) {
                    panic!("lane boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the caller");
        // The pool stays usable after a propagated panic.
        let mut out = vec![0usize; 2];
        let groups: Vec<Vec<(usize, &mut usize)>> =
            out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
        pool.run_groups(epoch, groups, |lane, _| {
            for (i, slot) in lane {
                *slot = i + 7;
            }
        });
        assert_eq!(out, vec![7, 8]);
    }

    #[test]
    fn global_pool_spawns_once_and_counts_its_threads() {
        let (pool, _) = global_pool_acquire();
        assert!(global_pool_is_running());
        assert_eq!(pool.width(), global_pool_width());
        let (again, spawned_again) = global_pool_acquire();
        assert!(!spawned_again, "second acquire must reuse the global pool");
        assert!(std::ptr::eq(pool, again));
        // Configuration after the fact cannot resize it.
        let width = configure_global_pool_width(pool.width() + 5);
        assert_eq!(width, global_pool_width());
        assert_eq!(global_pool().width(), pool.width());
        // The thread budget covers at least the global lanes; private
        // test pools may add to the count transiently, never subtract.
        assert!(spawned_pool_threads() >= pool.width());
    }
}
