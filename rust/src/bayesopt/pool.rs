//! The persistent GP worker pool — the always-on execution engine behind
//! `NativeBackend`'s parallel paths (the hyperparameter-grid nll sweep,
//! its low-rank counterpart, and the decide tile fan-out).
//!
//! # Why persistent
//!
//! The previous design spawned `std::thread::scope` workers per call:
//! correct, but the spawn/join overhead (~tens of µs) recurs every BO
//! iteration — twice per iteration (`nll_grid` + `decide`), thousands of
//! iterations per experiment. [`WorkerPool`] spawns its lanes once
//! (lazily, on the first fan-out that clears the backend's work-size
//! floor) and keeps them parked on a channel; a fan-out is then two
//! channel sends and a completion wait per lane.
//!
//! # Per-lane scratch
//!
//! Each worker owns a [`LaneScratch`] that survives across fan-outs: the
//! cross-row/Gram buffers of the exact sweep, the prediction buffers of
//! the decide tiles, and a whole [`LowRankGp`] (with all its internal
//! fit scratch) for the low-rank sweep. Steady-state fan-outs therefore
//! allocate nothing per call — the pool analog of the backend's serial
//! scratch fields. Every consumer fully overwrites the buffers it reads
//! (and re-seeds its memo keys per fan-out), so stale scratch can never
//! leak into results: bit-determinism is preserved by construction.
//!
//! # Determinism contract
//!
//! [`WorkerPool::run_groups`] deals whole work groups round-robin across
//! its lanes exactly as the former per-call scaffold did: group `g` of
//! `G` goes to lane `g % min(width, G)`, in order. Every item writes
//! only its own caller-disjoint outputs and no floating-point reduction
//! crosses items, so results are **bit-identical for any pool width** —
//! the same contract `testkit::assert_parallel_parity` pins (now also
//! under the randomized script fuzz).
//!
//! # Panic behavior
//!
//! A panic inside a work closure is caught on the worker, reported back
//! over the completion channel, and re-raised on the caller after every
//! submitted lane has drained — workers stay alive (the scratch and the
//! lanes survive), and a failing `assert!` inside swept code surfaces in
//! the test that caused it, just as it did under scoped threads.

use super::lowrank::LowRankGp;
use super::simd;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Reusable per-lane buffers, owned by one worker thread for its
/// lifetime. One field per consumer:
///
/// * `row` / `gram` — the exact nll sweep's (lengthscale, variance)
///   memoized cross-row and Gram builds;
/// * `ks` / `acc` — `gp::predict_into`'s cross-kernel block and
///   accumulator for the decide tile fan-out;
/// * `lowrank` — a full low-rank posterior (with its own internal
///   scratch) for the Woodbury nll sweep's per-lane fits.
#[derive(Debug, Default)]
pub struct LaneScratch {
    pub row: Vec<f64>,
    pub gram: Vec<f64>,
    pub ks: Vec<f64>,
    pub acc: Vec<f64>,
    pub lowrank: LowRankGp,
}

impl LaneScratch {
    /// Pre-size the exact-sweep buffers for `n` observations — the
    /// cross-row (n-1 entries) and the n × n Gram build — padding the
    /// capacities to whole SIMD lane groups ([`simd::lane_padded`]).
    /// The search loop grows its observation window by one row per BO
    /// iteration, so lane-group-rounded capacities absorb the next few
    /// one-longer builds in already-owned storage instead of
    /// reallocating at the top of a fan-out. Lengths are untouched:
    /// every consumer still fully overwrites what it reads (the module
    /// docs' scratch contract).
    pub fn reserve_sweep(&mut self, n: usize) {
        reserve_to(&mut self.row, simd::lane_padded(n));
        reserve_to(&mut self.gram, simd::lane_padded(n * n));
    }

    /// Pre-size the prediction buffers for `gp::predict_into` over `n`
    /// observations and up-to-`tile`-wide candidate tiles: the n × tile
    /// cross-kernel block and the
    /// [`PREDICT_ROW_BLOCK`](super::gp::PREDICT_ROW_BLOCK)-row
    /// accumulator, with the same lane-padded capacities as
    /// [`Self::reserve_sweep`].
    pub fn reserve_tiles(&mut self, n: usize, tile: usize) {
        reserve_to(&mut self.ks, simd::lane_padded(n * tile));
        let acc_rows = super::gp::PREDICT_ROW_BLOCK.min(n.max(1));
        reserve_to(&mut self.acc, simd::lane_padded(acc_rows * tile));
    }
}

/// Grow `v`'s capacity to at least `cap` entries (length untouched).
fn reserve_to(v: &mut Vec<f64>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// A unit of submitted work: runs once on a worker against that lane's
/// persistent scratch. Tasks are type-erased to `'static` inside
/// [`WorkerPool::run_groups`], which blocks until every task has
/// acknowledged completion — see the SAFETY note there.
type Task = Box<dyn FnOnce(&mut LaneScratch) + Send + 'static>;

/// A fixed-width pool of parked worker threads (see the module docs).
/// Owned by `NativeBackend`; created lazily and dropped (threads joined)
/// when the backend is dropped or its width changes.
pub struct WorkerPool {
    /// One submission channel per worker: lane → worker pinning is
    /// 1:1 and stable, so each lane's scratch stays with its lane.
    txs: Vec<Sender<Task>>,
    /// Completion acknowledgements (one per submitted task; `Err`
    /// carries a captured panic payload).
    done_rx: Receiver<std::thread::Result<()>>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("width", &self.txs.len()).finish()
    }
}

impl WorkerPool {
    /// Spawn `width` parked workers (floored at 1), each owning a fresh
    /// [`LaneScratch`].
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let (done_tx, done_rx) = channel();
        let mut txs = Vec::with_capacity(width);
        let mut handles = Vec::with_capacity(width);
        for lane in 0..width {
            let (tx, rx) = channel::<Task>();
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("gp-pool-{lane}"))
                .spawn(move || {
                    let mut scratch = LaneScratch::default();
                    while let Ok(task) = rx.recv() {
                        // The task (and every borrow it captured) is
                        // consumed — dropped — before the ack is sent.
                        let result = catch_unwind(AssertUnwindSafe(|| task(&mut scratch)));
                        if done.send(result).is_err() {
                            break; // owner dropped mid-shutdown
                        }
                    }
                })
                .expect("spawning a GP pool worker");
            txs.push(tx);
            handles.push(handle);
        }
        Self { txs, done_rx, handles }
    }

    /// The number of worker lanes.
    pub fn width(&self) -> usize {
        self.txs.len()
    }

    /// Deal `groups` round-robin across the lanes (group `g` → lane
    /// `g % min(width, groups)`, in order — the deterministic dealing of
    /// the module docs) and run `work` once per used lane over that
    /// lane's items, against the lane's persistent [`LaneScratch`].
    /// Blocks until every lane has finished; re-raises the first caught
    /// panic after all lanes have drained.
    pub fn run_groups<T, F>(&self, groups: Vec<Vec<T>>, work: F)
    where
        T: Send,
        F: Fn(Vec<T>, &mut LaneScratch) + Sync,
    {
        if groups.is_empty() {
            return;
        }
        let used = self.width().min(groups.len());
        let mut lanes: Vec<Vec<T>> = (0..used).map(|_| Vec::new()).collect();
        for (g, group) in groups.into_iter().enumerate() {
            lanes[g % used].extend(group);
        }
        let work_ref = &work;
        for (lane_idx, lane) in lanes.into_iter().enumerate() {
            let task: Box<dyn FnOnce(&mut LaneScratch) + Send + '_> =
                Box::new(move |scratch: &mut LaneScratch| work_ref(lane, scratch));
            // SAFETY: the task borrows `work` and whatever `lane`'s items
            // borrow from the caller's frame. We erase those lifetimes to
            // ship the task to a persistent thread, which is sound
            // because this function does not return until the completion
            // loop below has received one ack per submitted task, and a
            // worker sends its ack only after the task has run *and been
            // dropped* — no borrow outlives this call, even on panic
            // (the payload is re-raised only after all lanes drained).
            let task: Task = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce(&mut LaneScratch) + Send + '_>,
                    Box<dyn FnOnce(&mut LaneScratch) + Send + 'static>,
                >(task)
            };
            // A send can only fail if a worker exited its recv loop,
            // which cannot happen while the pool owns the channels — but
            // if that invariant is ever broken, unwinding here would
            // free the caller frame while already-submitted tasks still
            // borrow it. Abort instead: the SAFETY contract must hold on
            // every path, not just the expected one.
            if self.txs[lane_idx].send(task).is_err() {
                eprintln!("fatal: GP pool worker died with tasks in flight");
                std::process::abort();
            }
        }
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..used {
            let ack = self.done_rx.recv().unwrap_or_else(|_| {
                // Same reasoning as the send above: returning (or
                // unwinding) before every ack arrives would dangle the
                // erased borrows of any still-running task.
                eprintln!("fatal: GP pool worker died before acknowledging");
                std::process::abort();
            });
            match ack {
                Ok(()) => {}
                // Keep the first payload received (the contract above);
                // later ones are dropped after their lanes drained.
                Err(payload) => {
                    if panic.is_none() {
                        panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the submission channels ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_borrowed_work_to_disjoint_slots() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.width(), 4);
        let mut out = vec![0.0f64; 10];
        let inputs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        {
            let groups: Vec<Vec<(usize, &mut f64)>> =
                out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
            let inputs = &inputs;
            pool.run_groups(groups, |lane, _scratch| {
                for (i, slot) in lane {
                    *slot = inputs[i] * 2.0;
                }
            });
        }
        assert_eq!(out, (0..10).map(|i| i as f64 * 2.0).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_repeated_runs_and_reuses_scratch() {
        let pool = WorkerPool::new(2);
        for round in 0..5 {
            let mut out = vec![0usize; 6];
            let groups: Vec<Vec<(usize, &mut usize)>> =
                out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
            pool.run_groups(groups, |lane, scratch| {
                // Persistent scratch: grow a marker buffer across runs.
                scratch.row.push(round as f64);
                for (i, slot) in lane {
                    *slot = i + round;
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + round, "round {round}");
            }
        }
    }

    #[test]
    fn pool_uses_at_most_one_lane_per_group() {
        // 3 groups over 8 lanes: only 3 lanes are used, in order.
        let pool = WorkerPool::new(8);
        let mut out = vec![String::new(), String::new(), String::new()];
        let groups: Vec<Vec<(usize, &mut String)>> =
            out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
        pool.run_groups(groups, |lane, _| {
            let name = std::thread::current().name().unwrap_or("?").to_string();
            for (_, slot) in lane {
                *slot = name.clone();
            }
        });
        // Deterministic dealing: group g lands on lane g % 3... of the
        // first min(width, groups) lanes only.
        for (g, name) in out.iter().enumerate() {
            assert_eq!(name, &format!("gp-pool-{g}"), "group {g} on the wrong lane");
        }
    }

    #[test]
    fn pool_propagates_worker_panics_after_draining() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let groups: Vec<Vec<usize>> = vec![vec![0], vec![1]];
            pool.run_groups(groups, |lane, _| {
                if lane.contains(&1) {
                    panic!("lane boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the caller");
        // The pool stays usable after a propagated panic.
        let mut out = vec![0usize; 2];
        let groups: Vec<Vec<(usize, &mut usize)>> =
            out.iter_mut().enumerate().map(|(i, s)| vec![(i, s)]).collect();
        pool.run_groups(groups, |lane, _| {
            for (i, slot) in lane {
                *slot = i + 7;
            }
        });
        assert_eq!(out, vec![7, 8]);
    }
}
