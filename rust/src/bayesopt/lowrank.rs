//! Nyström / inducing-point low-rank GP posterior — the candidate-scoring
//! path for full-cloud-catalog-scale search spaces (thousands of
//! configurations), selected by `NativeBackend` once the candidate count
//! crosses [`super::backend::LOWRANK_CANDIDATE_THRESHOLD`]. The exact
//! rank-1 [`CholFactor`](super::chol::CholFactor) path keeps serving
//! small spaces.
//!
//! # Model and Woodbury identities
//!
//! Let `X` be the `n` observations, `Z ⊆ X` a set of `u` inducing points
//! chosen by deterministic farthest-point sampling
//! ([`farthest_point_sample`]), and write `Kuu = K(Z,Z)`,
//! `Kuf = K(Z,X)`, `k*u = K(Z,x*)`. The deterministic-training-
//! conditional (DTC/Nyström) posterior under noise `σ²` is
//!
//! ```text
//! μ(x*)  = k*uᵀ M⁻¹ Kuf y                 with M = σ² Kuu + Kuf Kufᵀ
//! σ²(x*) = k(x*,x*) − k*uᵀ Kuu⁻¹ k*u + σ² k*uᵀ M⁻¹ k*u
//! ```
//!
//! Both are evaluated through two Cholesky factors instead of any
//! explicit inverse (the Woodbury form): with `Lu Luᵀ = Kuu + jitter·I`,
//! `B = Lu⁻¹ Kuf` and `Lm Lmᵀ = σ² I + B Bᵀ` it holds that
//! `M = Lu Lm Lmᵀ Luᵀ`, so per candidate
//!
//! ```text
//! a = Lu⁻¹ k*u,   t = Lm⁻¹ a
//! μ(x*)  = k*uᵀ w           (w = M⁻¹ Kuf y, precomputed at fit time)
//! σ²(x*) = k(x*,x*) − |a|² + σ² |t|²
//! ```
//!
//! Fitting costs O(n·u² + n·u·d); each candidate costs O(u·d + u²)
//! independent of `n` — the asymptotic win over the exact posterior's
//! O(n²) per candidate once `n ≫ u`.
//!
//! # Bounds and the exact-equality special case
//!
//! * `k** − |a|²` is a Schur complement of the PSD bordered matrix
//!   `[[Kuu, k*u], [k*uᵀ, k**]]`, so the predictive variance is never
//!   negative; `σ²|t|² = σ² aᵀ(σ²I + BBᵀ)⁻¹a ≤ |a|²` keeps it below the
//!   prior variance. Both bounds are pinned by `tests/prop_lowrank.rs`.
//! * When the inducing set is the full training set (`u = n`, i.e.
//!   `Z = X`), the DTC equations reduce algebraically to the exact GP
//!   posterior: `Kuu⁻¹ − σ²M⁻¹ = (Kff + σ²I)⁻¹` and
//!   `M⁻¹Kuf = (Kff + σ²I)⁻¹`. The testkit parity harness exploits this
//!   to pin the low-rank backend against the exact one to tight
//!   tolerance on small spaces (the only residual difference is the
//!   jitter placement on `Kuu`).
//!
//! Besides the posterior, [`LowRankGp::nll`] evaluates the DTC
//! *marginal likelihood* in Woodbury form (O(n·u), no n×n objects), so
//! `NativeBackend::nll_grid` can select hyperparameters past a few
//! thousand observations without the exact sweep's O(n²) distance cache
//! or O(n³) cold refits.
//!
//! # Stage-split fitting
//!
//! Of everything a fit computes, only `Lm = chol(σ²I + BBᵀ)`, the mean
//! weights `w` and the marginal's quadratic/log-det depend on the noise
//! σ²; `Kuu`, `Lu = chol(Kuu + εI)`, `B = Lu⁻¹Kuf`, the Gram `BBᵀ`, the
//! projection `By` and `yᵀy` depend only on (lengthscale, variance).
//! [`LowRankGp::fit_hyp_stage`] computes the latter group once;
//! [`LowRankGp::fit_noise_stage`] completes the fit for one σ² in
//! O(u³ + u²) — no kernel or O(n·u) work at all. The 32-slot
//! hyperparameter grid has 8 (lengthscale, variance) groups of 4 noise
//! levels, so a grid sweep does the dominant kernel/GEMM work 8 times
//! instead of 32 (the low-rank mirror of the exact sweep's cross-row /
//! Gram memo). [`LowRankGp::fit_with_inducing`] is exactly the two
//! stages back to back, so the split is bit-identical to the unsplit
//! per-point evaluation — pinned by `tests/prop_lowrank.rs`.
//!
//! # Incremental inducing refresh
//!
//! Re-selecting the inducing set by farthest-point sampling on every fit
//! costs O(n·u·d) per BO iteration — the last per-iteration O(n·u) term
//! on the generated-catalog path. [`InducingCache`] keeps the selection
//! (plus FPS's min-distance field) alive across iterations, keyed on the
//! same [`ObsDelta`](super::chol::ObsDelta) classification the factor
//! cache uses:
//!
//! * **Appended**: the new row competes only against the cached
//!   min-distance vector (O(u·d)); it is selected only while the set is
//!   under its cap, via the same argmax-with-lex-tiebreak step FPS runs.
//! * **Slid**: the departed oldest row is evicted *lazily* — it leaves
//!   the selection, but the min-distance field it shaped is not
//!   recomputed (the cached distances remain valid lower bounds, which
//!   can only make later continuation picks more conservative). Then the
//!   appended row is handled as above.
//! * **Replaced** (or a changed inducing cap): full FPS re-selection.
//!
//! **Drift bound**: after [`INDUCING_DRIFT_LIMIT`] consecutive
//! incremental (append/slide) refreshes, the next refresh forces a full
//! FPS re-selection, so the cached set is never more than
//! `INDUCING_DRIFT_LIMIT` single-row deltas away from an exact
//! farthest-point selection — and is *exactly* the scratch FPS result at
//! every resync point. `tests/prop_lowrank.rs` pins both halves.
//! Determinism is unaffected: the refreshed set is a pure function of
//! the observation-row history, so serial and pooled backends replaying
//! the same script stay bit-identical.

use super::chol::ObsDelta;
use super::gp::{solve_lower_in_place, JITTER, VAR_FLOOR};
use super::kernel::{dot, matern52_cross};
use super::simd;

/// Default inducing-set cap used by the auto-selected backend path.
/// 64 points keep the per-candidate cost (~u² flops) near the exact
/// path's 69-config baseline while covering the encoded 6-d feature cube
/// densely enough that the EI argmax survives the approximation (see
/// `bench_large_space`).
pub const DEFAULT_MAX_INDUCING: usize = 64;

/// Jitter on the inducing Gram `Kuu`. Deliberately much smaller than the
/// shared [`JITTER`]: any `Kuu` perturbation breaks the `Z = X` exact-
/// equality reduction by `O(jitter / λmin(Kff + σ²I))` — and EI then
/// amplifies the variance part by `1/(2σ)` — so a 1e-6 jitter could cost
/// ~1e-3 of parity while 1e-12 keeps the whole chain below ~1e-6 even at
/// the grid's smallest noise level. FPS picks well-separated inducing
/// points, so `Kuu` is well-conditioned and barely needs the help; if
/// its factorization still fails, `fit` reports it and the backend falls
/// back to the exact path.
pub const INDUCING_JITTER: f64 = 1e-12;

/// Lexicographic row comparison — FPS's deterministic tiebreak (a pure
/// order-statistic: no floating-point accumulation whose rounding could
/// depend on candidate order).
fn lex_lt(a: &[f64], b: &[f64]) -> bool {
    for (va, vb) in a.iter().zip(b) {
        if va < vb {
            return true;
        }
        if va > vb {
            return false;
        }
    }
    false
}

/// Squared Euclidean distance between two rows.
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (va, vb) in a.iter().zip(b) {
        let diff = va - vb;
        s += diff * diff;
    }
    s
}

/// One farthest-point selection step over an existing min-distance
/// field: pick the row maximizing `min_d2` (lex-smaller row wins ties),
/// append it to `selected` and fold its distances into `min_d2`.
/// Returns false when only exact duplicates of selected rows remain
/// (`max min_d2 <= 0`). Shared verbatim by [`farthest_point_sample`]'s
/// main loop and [`InducingCache`]'s incremental continuation, so the
/// two cannot drift.
fn fps_step(
    x: &[f64],
    n: usize,
    d: usize,
    selected: &mut Vec<usize>,
    min_d2: &mut [f64],
) -> bool {
    let row = |i: usize| &x[i * d..(i + 1) * d];
    let mut pick = None;
    let mut pick_d2 = 0.0;
    for i in 0..n {
        if min_d2[i] > pick_d2
            || (min_d2[i] == pick_d2
                && min_d2[i] > 0.0
                && pick.is_some_and(|p: usize| lex_lt(row(i), row(p))))
        {
            pick = Some(i);
            pick_d2 = min_d2[i];
        }
    }
    let Some(p) = pick.filter(|_| pick_d2 > 0.0) else {
        return false; // only duplicates of selected rows remain
    };
    selected.push(p);
    for i in 0..n {
        let d2 = sqdist(row(i), row(p));
        if d2 < min_d2[i] {
            min_d2[i] = d2;
        }
    }
    true
}

/// Deterministic farthest-point sampling of up to `k` row indices from
/// `n` row-major `d`-dimensional rows.
///
/// The seed point is the lexicographically smallest row; each further
/// point maximizes the minimum squared distance to the already-selected
/// set. All ties break toward the lexicographically smaller feature row,
/// which makes the selected *row set* a pure function of the row
/// multiset: deterministic across processes and invariant to candidate
/// order. Selection stops early when only exact duplicates of
/// already-selected rows remain, so the result never contains two
/// identical rows.
pub fn farthest_point_sample(x: &[f64], n: usize, d: usize, k: usize) -> Vec<usize> {
    farthest_point_sample_with_state(x, n, d, k).0
}

/// [`farthest_point_sample`] returning the final min-distance field as
/// well (`min_d2[i]` = squared distance of row `i` to the selected set)
/// — the state [`InducingCache`] keeps alive across BO iterations.
fn farthest_point_sample_with_state(
    x: &[f64],
    n: usize,
    d: usize,
    k: usize,
) -> (Vec<usize>, Vec<f64>) {
    assert_eq!(x.len(), n * d);
    let k = k.min(n);
    if k == 0 || n == 0 {
        return (Vec::new(), Vec::new());
    }
    let row = |i: usize| &x[i * d..(i + 1) * d];

    // Seed: the lexicographically smallest row.
    let mut first = 0usize;
    for i in 1..n {
        if lex_lt(row(i), row(first)) {
            first = i;
        }
    }

    let mut selected = Vec::with_capacity(k);
    selected.push(first);
    // min_d2[i] = distance of row i to the selected set.
    let mut min_d2: Vec<f64> = (0..n).map(|i| sqdist(row(i), row(first))).collect();
    while selected.len() < k {
        if !fps_step(x, n, d, &mut selected, &mut min_d2) {
            break;
        }
    }
    (selected, min_d2)
}

/// Maximum consecutive incremental (append/slide) refreshes
/// [`InducingCache`] serves before forcing a full farthest-point
/// re-selection — the documented drift bound of the module docs. 32
/// deltas = half the default inducing cap: far enough to amortize the
/// O(n·u·d) re-selection across a whole search phase, close enough that
/// a sliding window can never carry a mostly-departed selection.
pub const INDUCING_DRIFT_LIMIT: usize = 32;

/// The inducing-set selection kept alive across BO iterations (see the
/// module docs' *Incremental inducing refresh*). Owned by
/// `NativeBackend` next to its distance/factor caches; both its
/// low-rank paths (`decide` and the Woodbury `nll_grid`) refresh
/// through here instead of re-running farthest-point sampling per fit.
#[derive(Debug, Clone, Default)]
pub struct InducingCache {
    /// The observation rows of the last refresh (the delta baseline).
    last_x: Vec<f64>,
    n: usize,
    d: usize,
    /// Requested cap of the cached selection (pre-clamp, so a constant
    /// caller-side cap stays stable while `n` grows past it).
    k: usize,
    selected: Vec<usize>,
    /// FPS min-distance field over the current `n` rows. After a lazy
    /// slide eviction the entries are lower bounds (module docs).
    min_d2: Vec<f64>,
    /// Incremental refreshes since the last full re-selection.
    drift: usize,
}

impl InducingCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Incremental refreshes since the last full re-selection.
    pub fn drift(&self) -> usize {
        self.drift
    }

    /// The cached selection (row indices into the last-refreshed `x`).
    pub fn selected(&self) -> &[usize] {
        &self.selected
    }

    /// Bring the selection up to date with the current observation rows
    /// and cap; returns the selected indices plus whether a **full**
    /// FPS re-selection ran (false = incremental reuse). The decision is
    /// driven by [`ObsDelta::classify`] against the previously seen rows
    /// and the drift bound [`INDUCING_DRIFT_LIMIT`].
    pub fn refresh(&mut self, x: &[f64], n: usize, d: usize, k: usize) -> (&[usize], bool) {
        assert_eq!(x.len(), n * d);
        assert!(n > 0 && k > 0, "inducing refresh needs rows and a positive cap");
        let delta = ObsDelta::classify(&self.last_x, self.n, self.d, x, n, d);
        let mut full = self.selected.is_empty()
            || self.k != k
            || delta == ObsDelta::Replaced
            || (delta != ObsDelta::Unchanged && self.drift >= INDUCING_DRIFT_LIMIT);
        if !full {
            match delta {
                ObsDelta::Unchanged => {}
                ObsDelta::Appended => {
                    self.apply_append(x, n, d, k);
                    self.drift += 1;
                }
                ObsDelta::Slid => {
                    self.apply_slide(x, n, d, k);
                    self.drift += 1;
                }
                ObsDelta::Replaced => unreachable!("full reselect handles Replaced"),
            }
            // A slide can evict the only selected point (k = 1): fall
            // back to a full re-selection rather than serve an empty set.
            full = self.selected.is_empty();
        }
        if full {
            let (sel, min_d2) = farthest_point_sample_with_state(x, n, d, k);
            self.selected = sel;
            self.min_d2 = min_d2;
            self.drift = 0;
        }
        self.k = k;
        self.n = n;
        self.d = d;
        self.last_x.clear();
        self.last_x.extend_from_slice(x);
        (&self.selected, full)
    }

    /// Append handling: the new last row enters the min-distance field
    /// in O(u·d) and is selected only if the set is under its cap (via
    /// the shared [`fps_step`] continuation).
    fn apply_append(&mut self, x: &[f64], n: usize, d: usize, k: usize) {
        let new = &x[(n - 1) * d..n * d];
        let nd2 = self
            .selected
            .iter()
            .map(|&s| sqdist(new, &x[s * d..(s + 1) * d]))
            .fold(f64::INFINITY, f64::min);
        self.min_d2.push(nd2);
        self.fill_to_cap(x, n, d, k);
    }

    /// Slide handling: evict the departed oldest row lazily, shift the
    /// surviving indices/field, then treat the appended row as above.
    fn apply_slide(&mut self, x: &[f64], n: usize, d: usize, k: usize) {
        // The oldest row (index 0) left the window; its field entry goes
        // with it. If it was selected, it simply leaves the set — the
        // min-distance entries it shaped are NOT recomputed (they stay
        // valid lower bounds; see the module docs' drift-bound note).
        self.min_d2.remove(0);
        if let Some(pos) = self.selected.iter().position(|&s| s == 0) {
            self.selected.remove(pos);
        }
        if self.selected.is_empty() {
            // The eviction emptied the set (k = 1): the field has no
            // anchor left — let the caller re-select from scratch.
            self.min_d2.clear();
            return;
        }
        for s in self.selected.iter_mut() {
            *s -= 1;
        }
        let new = &x[(n - 1) * d..n * d];
        let nd2 = self
            .selected
            .iter()
            .map(|&s| sqdist(new, &x[s * d..(s + 1) * d]))
            .fold(f64::INFINITY, f64::min);
        self.min_d2.push(nd2);
        self.fill_to_cap(x, n, d, k);
    }

    /// FPS continuation: grow the selection toward its cap with the
    /// exact per-step logic of [`farthest_point_sample`], against the
    /// cached min-distance field.
    fn fill_to_cap(&mut self, x: &[f64], n: usize, d: usize, k: usize) {
        let cap = k.min(n);
        while self.selected.len() < cap {
            if !fps_step(x, n, d, &mut self.selected, &mut self.min_d2) {
                break;
            }
        }
    }
}

/// Counters of the stage-split fit paths taken ([`LowRankGp::stats`]) —
/// how `NativeBackend`'s `DecideStats` observes that a low-rank grid
/// sweep really did the kernel/GEMM work once per (lengthscale,
/// variance) group rather than once per grid point.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LowRankStats {
    /// [`LowRankGp::fit_hyp_stage`] executions (`Kuu`/`B`/`BBᵀ` builds).
    pub hyp_builds: u64,
    /// [`LowRankGp::fit_noise_stage`] executions (`Lm`/weights per σ²).
    pub noise_builds: u64,
}

impl LowRankStats {
    /// Fold another counter set into this one (order-independent sum).
    pub fn merge(&mut self, o: LowRankStats) {
        self.hyp_builds += o.hyp_builds;
        self.noise_builds += o.noise_builds;
    }
}

/// A fitted Nyström/DTC low-rank posterior (see the module docs for the
/// math and the stage-split fitting scheme). Scratch buffers are reused
/// across refits, mirroring [`NativeGp`](super::gp::NativeGp)'s
/// allocation discipline.
#[derive(Debug, Clone, Default)]
pub struct LowRankGp {
    d: usize,
    u: usize,
    /// Observation count of the current fit (the width of `B`).
    n: usize,
    hyp: [f64; 3],
    sigma2: f64,
    /// Inducing rows, row-major u x d.
    z: Vec<f64>,
    /// chol(Kuu + jitter I), row-major u x u lower-triangular.
    lu: Vec<f64>,
    /// chol(sigma² I + B Bᵀ), row-major u x u lower-triangular.
    lm: Vec<f64>,
    /// w = M⁻¹ Kuf y — the mean weights (length u).
    w: Vec<f64>,
    // --- hyperparameter-stage products (noise-independent) ---
    /// B Bᵀ (u x u), *without* the σ² diagonal — the noise stage adds it.
    bbt: Vec<f64>,
    /// B y (length u).
    by: Vec<f64>,
    /// yᵀ y of the fitted targets.
    yty: f64,
    /// The hyperparameter stage succeeded (Lu/B/BBᵀ/By are current).
    hyp_ok: bool,
    /// A noise stage completed on top of it (Lm/w/σ² are current).
    fit_ok: bool,
    // scratch
    b_mat: Vec<f64>,
    m_mat: Vec<f64>,
    kt_mat: Vec<f64>,
    col_acc: Vec<f64>,
    stats: LowRankStats,
}

/// Forward-solve `L X = B` for a row-major `u x w` right-hand side in
/// place (column-per-candidate layout; same substitution order as
/// [`solve_lower_in_place`] per column). The column loops run on the
/// bit-exact [`simd`] column-lane kernels (one candidate per vector
/// lane, no FMA), so SIMD dispatch never changes the solve bits.
fn solve_lower_multi(l: &[f64], u: usize, b: &mut [f64], w: usize) {
    debug_assert_eq!(b.len(), u * w);
    for i in 0..u {
        let (prior, cur) = b.split_at_mut(i * w);
        let row_i = &mut cur[..w];
        for k in 0..i {
            let lik = l[i * u + k];
            let zk = &prior[k * w..(k + 1) * w];
            simd::axpy_sub(row_i, lik, zk);
        }
        simd::scale_div(row_i, l[i * u + i]);
    }
}

/// Dense lower-Cholesky of a row-major `u x u` matrix in place; returns
/// false if not SPD. (Thin wrapper so this module has no dependency on
/// the exact GP beyond shared primitives.)
fn cholesky(a: &mut [f64], u: usize) -> bool {
    super::gp::cholesky_in_place(a, u)
}

impl LowRankGp {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of inducing points of the current fit.
    pub fn inducing_count(&self) -> usize {
        self.u
    }

    /// The selected inducing rows (row-major, `inducing_count() x d`).
    pub fn inducing_rows(&self) -> &[f64] {
        &self.z[..self.u * self.d]
    }

    /// Fit on `n` observations with at most `max_inducing` inducing
    /// points chosen by farthest-point sampling from the observations.
    /// Returns false (leaving the fit unusable) if the inducing Gram or
    /// the Woodbury inner matrix loses positive definiteness — the
    /// caller falls back to the exact path.
    pub fn fit(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        hyp: [f64; 3],
        max_inducing: usize,
    ) -> bool {
        let inducing = farthest_point_sample(x, n, d, max_inducing.max(1));
        self.fit_with_inducing(x, y, n, d, hyp, &inducing)
    }

    /// [`Self::fit`] with a caller-selected inducing set (row indices
    /// into `x`). Farthest-point selection depends only on the rows —
    /// not the hyperparameters — so a marginal-likelihood sweep
    /// (`NativeBackend::nll_grid`'s low-rank path) selects once and
    /// reuses the set across the whole grid instead of re-sweeping the
    /// full data per grid point. Exactly [`Self::fit_hyp_stage`]
    /// followed by [`Self::fit_noise_stage`], so a grouped grid sweep
    /// that shares the hyperparameter stage across noise levels is
    /// bit-identical to calling this per grid point.
    pub fn fit_with_inducing(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        hyp: [f64; 3],
        inducing: &[usize],
    ) -> bool {
        self.fit_hyp_stage(x, y, n, d, hyp[0], hyp[1], inducing)
            && self.fit_noise_stage(hyp[2])
    }

    /// The (lengthscale, variance) stage of the stage-split fit (module
    /// docs): gather the inducing rows, factor `Lu = chol(Kuu + εI)`,
    /// build `B = Lu⁻¹Kuf`, the Gram `BBᵀ`, the projection `By` and
    /// `yᵀy` — everything the noise level does NOT touch, and all of the
    /// O(n·u·d + n·u²) work. Returns false (leaving the fit unusable)
    /// if the inducing Gram loses positive definiteness; the caller
    /// falls back to the exact path.
    #[allow(clippy::too_many_arguments)]
    pub fn fit_hyp_stage(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        ls: f64,
        var: f64,
        inducing: &[usize],
    ) -> bool {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        assert!(n > 0, "low-rank fit needs at least one observation");
        // u <= n keeps the marginal's (n - u) log-det factor well-formed
        // (FPS never selects duplicates; external callers must not either).
        assert!(inducing.len() <= n, "more inducing indices than observations");
        self.hyp_ok = false;
        self.fit_ok = false;

        let u = inducing.len();
        self.z.clear();
        for &i in inducing {
            assert!(i < n, "inducing index {i} out of bounds (n = {n})");
            self.z.extend_from_slice(&x[i * d..(i + 1) * d]);
        }
        self.d = d;
        self.u = u;
        self.n = n;
        // The noise slot stays unset until a noise stage completes.
        self.hyp = [ls, var, f64::NAN];
        self.stats.hyp_builds += 1;

        // Lu = chol(Kuu + inducing-jitter I).
        let mut kuu = std::mem::take(&mut self.lu);
        matern52_cross(&self.z, u, &self.z, u, d, ls, var, &mut kuu);
        for i in 0..u {
            kuu[i * u + i] += INDUCING_JITTER;
        }
        if !cholesky(&mut kuu, u) {
            self.lu = kuu;
            self.u = 0;
            return false;
        }
        self.lu = kuu;

        // B = Lu⁻¹ Kuf (u x n).
        let mut b = std::mem::take(&mut self.b_mat);
        matern52_cross(&self.z, u, x, n, d, ls, var, &mut b);
        solve_lower_multi(&self.lu, u, &mut b, n);

        // BBᵀ (no σ² yet — the noise stage adds its diagonal). Each
        // entry is a row-pair dot over the n-wide B rows — the shared
        // dispatched [`dot`] (scalar order preserved with SIMD off).
        self.bbt.clear();
        self.bbt.resize(u * u, 0.0);
        for i in 0..u {
            let bi = &b[i * n..(i + 1) * n];
            for j in 0..=i {
                let s = dot(bi, &b[j * n..(j + 1) * n]);
                self.bbt[i * u + j] = s;
                self.bbt[j * u + i] = s;
            }
        }

        // By and yᵀy — the y-projections every noise level shares.
        self.by.clear();
        self.by.resize(u, 0.0);
        for i in 0..u {
            self.by[i] = dot(&b[i * n..(i + 1) * n], y);
        }
        self.yty = y.iter().map(|v| v * v).sum();
        self.b_mat = b;
        self.hyp_ok = true;
        true
    }

    /// The σ² stage of the stage-split fit: `Lm = chol(σ²I + BBᵀ)` and
    /// the mean weights `w = Lu⁻ᵀ Lm⁻ᵀ Lm⁻¹ (By)` — O(u³ + u²), no
    /// kernel or O(n) work. Requires a successful
    /// [`Self::fit_hyp_stage`]; may be called repeatedly with different
    /// noise levels against the same stage (the grid sweep's 4 noise
    /// levels per group). Returns false if the Woodbury inner matrix
    /// loses positive definiteness.
    pub fn fit_noise_stage(&mut self, noise: f64) -> bool {
        assert!(
            self.hyp_ok && self.u > 0,
            "noise stage before a successful hyperparameter stage"
        );
        let u = self.u;
        let sigma2 = noise + JITTER;
        self.hyp[2] = noise;
        self.sigma2 = sigma2;
        self.fit_ok = false;
        self.stats.noise_builds += 1;

        // Lm = chol(sigma² I + B Bᵀ).
        let mut m = std::mem::take(&mut self.m_mat);
        m.clear();
        m.extend_from_slice(&self.bbt);
        for i in 0..u {
            m[i * u + i] += sigma2;
        }
        if !cholesky(&mut m, u) {
            self.m_mat = m;
            return false;
        }
        // `m` now holds Lm; swap it into place and recycle the old Lm
        // buffer as the next stage's scratch (no per-fit allocation).
        std::mem::swap(&mut self.lm, &mut m);
        self.m_mat = m;

        // w = M⁻¹ Kuf y = Lu⁻ᵀ Lm⁻ᵀ Lm⁻¹ (B y).
        self.w.clear();
        self.w.extend_from_slice(&self.by);
        solve_lower_in_place(&self.lm, u, &mut self.w);
        super::gp::solve_upper_t_in_place(&self.lm, u, &mut self.w);
        super::gp::solve_upper_t_in_place(&self.lu, u, &mut self.w);
        self.fit_ok = true;
        true
    }

    /// Stage-execution counters accumulated since construction or the
    /// last [`Self::take_stats`].
    pub fn stats(&self) -> LowRankStats {
        self.stats
    }

    /// Return and reset the stage-execution counters (how worker lanes
    /// hand their group-local counts back to the backend).
    pub fn take_stats(&mut self) -> LowRankStats {
        std::mem::take(&mut self.stats)
    }

    /// Posterior (mean, variance) for all `m` candidates, streamed in
    /// fixed-size tiles (no m-wide intermediate beyond the outputs).
    /// `mu_out`/`var_out` are cleared and resized to `m`.
    pub fn predict_batch(
        &mut self,
        xc: &[f64],
        m: usize,
        mu_out: &mut Vec<f64>,
        var_out: &mut Vec<f64>,
    ) {
        // One tiling policy for both candidate-scoring paths.
        const TILE: usize = super::backend::DECIDE_TILE;
        assert!(
            self.fit_ok && self.u > 0,
            "predict on an unfitted low-rank posterior (both fit stages must succeed)"
        );
        let (ls, var, _) = (self.hyp[0], self.hyp[1], self.hyp[2]);
        let (u, d) = (self.u, self.d);
        assert_eq!(xc.len(), m * d);
        mu_out.clear();
        mu_out.resize(m, 0.0);
        var_out.clear();
        var_out.resize(m, var);

        let mut kt = std::mem::take(&mut self.kt_mat);
        let mut acc = std::mem::take(&mut self.col_acc);
        for start in (0..m).step_by(TILE) {
            let w = TILE.min(m - start);
            let tile = &xc[start * d..(start + w) * d];
            // K(Z, tile): u x w.
            matern52_cross(&self.z, u, tile, w, d, ls, var, &mut kt);
            // Means first: mu = k*uᵀ w before kt is overwritten by solves.
            for i in 0..u {
                let row = &kt[i * w..(i + 1) * w];
                simd::axpy(&mut mu_out[start..start + w], self.w[i], row);
            }
            // a = Lu⁻¹ k*u per column; |a|² accumulates into acc.
            solve_lower_multi(&self.lu, u, &mut kt, w);
            acc.clear();
            acc.resize(w, 0.0);
            for i in 0..u {
                simd::sq_accum(&mut acc, &kt[i * w..(i + 1) * w]);
            }
            for c in 0..w {
                var_out[start + c] = var - acc[c];
            }
            // t = Lm⁻¹ a; add back sigma² |t|².
            solve_lower_multi(&self.lm, u, &mut kt, w);
            acc.clear();
            acc.resize(w, 0.0);
            for i in 0..u {
                simd::sq_accum(&mut acc, &kt[i * w..(i + 1) * w]);
            }
            for c in 0..w {
                var_out[start + c] = (var_out[start + c] + self.sigma2 * acc[c]).max(VAR_FLOOR);
            }
        }
        self.kt_mat = kt;
        self.col_acc = acc;
    }

    /// Posterior (mean, variance) at one candidate row — the scalar
    /// convenience over [`Self::predict_batch`].
    pub fn predict(&mut self, xc: &[f64]) -> (f64, f64) {
        assert_eq!(xc.len(), self.d);
        let mut mu = Vec::new();
        let mut var = Vec::new();
        self.predict_batch(xc, 1, &mut mu, &mut var);
        (mu[0], var[0])
    }

    /// Prior signal variance of the current fit (the variance upper
    /// bound the property tests pin).
    pub fn prior_variance(&self) -> f64 {
        self.hyp[1]
    }

    /// DTC marginal negative log likelihood of the fitted data, in
    /// Woodbury form — the low-rank counterpart of `NativeGp::nll` that
    /// `NativeBackend::nll_grid` uses past its observation threshold.
    ///
    /// Under the DTC model `y ~ N(0, Qff + σ²I)` with `Qff = Bᵀ B`
    /// (`B = Lu⁻¹ Kuf` from the fit). With `t = Lm⁻¹ (B y)`:
    ///
    /// ```text
    /// yᵀ (Qff + σ²I)⁻¹ y = (yᵀy − |t|²) / σ²
    /// ln det(Qff + σ²I)  = (n − u) ln σ² + 2 Σᵢ ln Lm[i,i]
    /// ```
    ///
    /// (both are the standard Woodbury/determinant-lemma identities
    /// through the fit's `Lm Lmᵀ = σ²I + B Bᵀ` factor). The projections
    /// `B y` and `yᵀy` come straight from the hyperparameter stage's
    /// cache, so per noise level only the O(u²) solve and O(u) folds
    /// remain. Cost O(u²): independent of any n×n (or even n-length)
    /// object. The `0.5·n·ln 2π` fold constant matches `NativeGp::nll`,
    /// and at `Z = X` (`u = n`) the value reduces to the exact marginal
    /// up to [`INDUCING_JITTER`] — the pin `tests/prop_lowrank.rs`
    /// enforces.
    ///
    /// `y` must be the targets the posterior was fitted on (the cached
    /// projections are of that vector). Debug builds verify that by
    /// recomputing `yᵀy` against the cached fold bit-for-bit — a
    /// different same-length target vector fails loudly instead of
    /// silently returning the fitted targets' likelihood.
    pub fn nll(&self, y: &[f64]) -> f64 {
        let (u, n) = (self.u, self.n);
        assert!(self.fit_ok && u > 0, "nll on an unfitted low-rank posterior");
        assert_eq!(y.len(), n);
        debug_assert!(
            y.iter().map(|v| v * v).sum::<f64>().to_bits() == self.yty.to_bits(),
            "nll called with targets that differ from the fitted ones"
        );
        // t = Lm^-1 (B y), from the hyperparameter stage's cached By.
        let mut t = self.by.clone();
        solve_lower_in_place(&self.lm, u, &mut t);
        let t2: f64 = t.iter().map(|v| v * v).sum();
        let quad = 0.5 * (self.yty - t2) / self.sigma2;
        let half_logdet = 0.5 * (n - u) as f64 * self.sigma2.ln()
            + (0..u).map(|i| self.lm[i * u + i].ln()).sum::<f64>();
        quad + half_logdet + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::gp::NativeGp;

    fn grid_x(n: usize, d: usize) -> Vec<f64> {
        (0..n * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect()
    }

    #[test]
    fn fps_selects_distinct_spread_points() {
        let d = 2;
        let n = 30;
        let x = grid_x(n, d);
        let sel = farthest_point_sample(&x, n, d, 8);
        assert_eq!(sel.len(), 8);
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "duplicate selections in {sel:?}");
    }

    #[test]
    fn fps_skips_exact_duplicates() {
        let d = 2;
        // Three distinct rows, each duplicated.
        let x = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let sel = farthest_point_sample(&x, 6, d, 6);
        assert_eq!(sel.len(), 3, "must stop at the distinct-row count, got {sel:?}");
        let rows: Vec<&[f64]> = sel.iter().map(|&i| &x[i * d..(i + 1) * d]).collect();
        for i in 0..rows.len() {
            for j in (i + 1)..rows.len() {
                assert_ne!(rows[i], rows[j]);
            }
        }
    }

    #[test]
    fn full_inducing_set_matches_exact_gp() {
        // u = n: the DTC posterior reduces to the exact GP (module docs).
        let n = 10;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let hyp = [0.6, 1.4, 1e-3];
        let mut exact = NativeGp::new();
        assert!(exact.fit(&x, &y, n, d, hyp));
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, hyp, n));
        assert_eq!(lr.inducing_count(), n);
        let m = 15;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 13 + 3) % 71) as f64 / 71.0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, m, &mut mu, &mut var);
        for j in 0..m {
            let (me, ve) = exact.predict(&xc[j * d..(j + 1) * d]);
            assert!(
                (mu[j] - me).abs() <= 1e-6 * me.abs().max(1.0),
                "mu[{j}]: lowrank {} vs exact {me}",
                mu[j]
            );
            assert!(
                (var[j] - ve).abs() <= 1e-6,
                "var[{j}]: lowrank {} vs exact {ve}",
                var[j]
            );
        }
    }

    #[test]
    fn variance_within_prior_bounds() {
        let n = 40;
        let d = 4;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).cos()).collect();
        let hyp = [0.4, 2.0, 1e-2];
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, hyp, 12));
        assert!(lr.inducing_count() <= 12);
        let m = 50;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 29 + 11) % 83) as f64 / 83.0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, m, &mut mu, &mut var);
        for j in 0..m {
            assert!(var[j] >= 0.0, "negative variance {}", var[j]);
            assert!(var[j] <= hyp[1] + 1e-9, "variance {} above prior {}", var[j], hyp[1]);
        }
    }

    #[test]
    fn noise_stage_reuse_is_bit_identical_to_fresh_fits() {
        // One hyperparameter stage + several noise stages must produce
        // exactly the bits of a full fit per noise level — the stage-
        // split contract the grouped grid sweep relies on.
        let n = 24;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.53).sin()).collect();
        let (ls, var) = (0.7, 1.3);
        let inducing = farthest_point_sample(&x, n, d, 10);
        let m = 9;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 19 + 5) % 67) as f64 / 67.0).collect();

        let mut staged = LowRankGp::new();
        assert!(staged.fit_hyp_stage(&x, &y, n, d, ls, var, &inducing));
        for noise in [1e-4, 1e-3, 1e-2, 1e-1] {
            assert!(staged.fit_noise_stage(noise));
            let mut fresh = LowRankGp::new();
            assert!(fresh.fit_with_inducing(&x, &y, n, d, [ls, var, noise], &inducing));
            assert_eq!(
                staged.nll(&y).to_bits(),
                fresh.nll(&y).to_bits(),
                "nll bits diverged at noise {noise}"
            );
            let (mut mu_s, mut var_s) = (Vec::new(), Vec::new());
            let (mut mu_f, mut var_f) = (Vec::new(), Vec::new());
            staged.predict_batch(&xc, m, &mut mu_s, &mut var_s);
            fresh.predict_batch(&xc, m, &mut mu_f, &mut var_f);
            for j in 0..m {
                assert_eq!(mu_s[j].to_bits(), mu_f[j].to_bits(), "mu[{j}] at {noise}");
                assert_eq!(var_s[j].to_bits(), var_f[j].to_bits(), "var[{j}] at {noise}");
            }
        }
        let s = staged.stats();
        assert_eq!((s.hyp_builds, s.noise_builds), (1, 4), "stage counters: {s:?}");
    }

    #[test]
    fn inducing_cache_tracks_append_slide_and_reuse() {
        let d = 2;
        let total = 30;
        let x = grid_x(total, d);
        let k = 5;
        let mut cache = InducingCache::new();
        // First sight: full FPS, equal to scratch.
        let n0 = 12;
        let (sel, full) = cache.refresh(&x[..n0 * d], n0, d, k);
        assert!(full);
        assert_eq!(sel, &farthest_point_sample(&x[..n0 * d], n0, d, k)[..]);
        // Same rows again: incremental reuse of the identical set.
        let before = cache.selected().to_vec();
        let (sel, full) = cache.refresh(&x[..n0 * d], n0, d, k);
        assert!(!full);
        assert_eq!(sel, &before[..]);
        assert_eq!(cache.drift(), 0, "unchanged rows must not count as drift");
        // Appends: incremental, still a valid distinct selection.
        for n in (n0 + 1)..=(n0 + 4) {
            let (sel, full) = cache.refresh(&x[..n * d], n, d, k);
            assert!(!full, "append at n={n} forced a full re-select");
            assert!(sel.len() <= k && sel.iter().all(|&i| i < n));
            let mut uniq = sel.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), sel.len(), "duplicate inducing index");
        }
        assert_eq!(cache.drift(), 4);
        // A slide: departed index evicted lazily, survivors shifted.
        let n = n0 + 4;
        let (sel, full) = cache.refresh(&x[d..(n + 1) * d], n, d, k);
        assert!(!full);
        assert!(sel.iter().all(|&i| i < n));
        // A wholesale jump: full re-select, equal to scratch again.
        let (sel, full) = cache.refresh(&x[10 * d..(10 + n0) * d], n0, d, k);
        assert!(full);
        assert_eq!(
            sel,
            &farthest_point_sample(&x[10 * d..(10 + n0) * d], n0, d, k)[..]
        );
        assert_eq!(cache.drift(), 0, "full re-select must reset drift");
        // A changed cap also forces a re-select.
        let (_, full) = cache.refresh(&x[10 * d..(10 + n0) * d], n0, d, k + 2);
        assert!(full, "cap change must force a full re-select");
    }

    #[test]
    fn inducing_cache_drift_bound_forces_reselect() {
        let d = 2;
        let total = INDUCING_DRIFT_LIMIT + 20;
        let x = grid_x(total, d);
        let k = 4;
        let mut cache = InducingCache::new();
        let n0 = 10;
        let (_, full) = cache.refresh(&x[..n0 * d], n0, d, k);
        assert!(full);
        // Exactly INDUCING_DRIFT_LIMIT appends stay incremental ...
        for step in 1..=INDUCING_DRIFT_LIMIT {
            let n = n0 + step;
            let (_, full) = cache.refresh(&x[..n * d], n, d, k);
            assert!(!full, "append {step} within the bound re-selected");
        }
        // ... and the next delta resyncs to scratch FPS exactly.
        let n = n0 + INDUCING_DRIFT_LIMIT + 1;
        let (sel, full) = cache.refresh(&x[..n * d], n, d, k);
        assert!(full, "drift bound never forced a re-select");
        assert_eq!(sel, &farthest_point_sample(&x[..n * d], n, d, k)[..]);
        assert_eq!(cache.drift(), 0);
    }

    #[test]
    fn predict_scalar_matches_batch() {
        let n = 20;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, [0.5, 1.0, 1e-3], 8));
        let xc = [0.2, 0.4, 0.6];
        let (mu1, var1) = lr.predict(&xc);
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, 1, &mut mu, &mut var);
        assert_eq!(mu[0], mu1);
        assert_eq!(var[0], var1);
    }
}
