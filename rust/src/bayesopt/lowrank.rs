//! Nyström / inducing-point low-rank GP posterior — the candidate-scoring
//! path for full-cloud-catalog-scale search spaces (thousands of
//! configurations), selected by `NativeBackend` once the candidate count
//! crosses [`super::backend::LOWRANK_CANDIDATE_THRESHOLD`]. The exact
//! rank-1 [`CholFactor`](super::chol::CholFactor) path keeps serving
//! small spaces.
//!
//! # Model and Woodbury identities
//!
//! Let `X` be the `n` observations, `Z ⊆ X` a set of `u` inducing points
//! chosen by deterministic farthest-point sampling
//! ([`farthest_point_sample`]), and write `Kuu = K(Z,Z)`,
//! `Kuf = K(Z,X)`, `k*u = K(Z,x*)`. The deterministic-training-
//! conditional (DTC/Nyström) posterior under noise `σ²` is
//!
//! ```text
//! μ(x*)  = k*uᵀ M⁻¹ Kuf y                 with M = σ² Kuu + Kuf Kufᵀ
//! σ²(x*) = k(x*,x*) − k*uᵀ Kuu⁻¹ k*u + σ² k*uᵀ M⁻¹ k*u
//! ```
//!
//! Both are evaluated through two Cholesky factors instead of any
//! explicit inverse (the Woodbury form): with `Lu Luᵀ = Kuu + jitter·I`,
//! `B = Lu⁻¹ Kuf` and `Lm Lmᵀ = σ² I + B Bᵀ` it holds that
//! `M = Lu Lm Lmᵀ Luᵀ`, so per candidate
//!
//! ```text
//! a = Lu⁻¹ k*u,   t = Lm⁻¹ a
//! μ(x*)  = k*uᵀ w           (w = M⁻¹ Kuf y, precomputed at fit time)
//! σ²(x*) = k(x*,x*) − |a|² + σ² |t|²
//! ```
//!
//! Fitting costs O(n·u² + n·u·d); each candidate costs O(u·d + u²)
//! independent of `n` — the asymptotic win over the exact posterior's
//! O(n²) per candidate once `n ≫ u`.
//!
//! # Bounds and the exact-equality special case
//!
//! * `k** − |a|²` is a Schur complement of the PSD bordered matrix
//!   `[[Kuu, k*u], [k*uᵀ, k**]]`, so the predictive variance is never
//!   negative; `σ²|t|² = σ² aᵀ(σ²I + BBᵀ)⁻¹a ≤ |a|²` keeps it below the
//!   prior variance. Both bounds are pinned by `tests/prop_lowrank.rs`.
//! * When the inducing set is the full training set (`u = n`, i.e.
//!   `Z = X`), the DTC equations reduce algebraically to the exact GP
//!   posterior: `Kuu⁻¹ − σ²M⁻¹ = (Kff + σ²I)⁻¹` and
//!   `M⁻¹Kuf = (Kff + σ²I)⁻¹`. The testkit parity harness exploits this
//!   to pin the low-rank backend against the exact one to tight
//!   tolerance on small spaces (the only residual difference is the
//!   jitter placement on `Kuu`).
//!
//! Besides the posterior, [`LowRankGp::nll`] evaluates the DTC
//! *marginal likelihood* in Woodbury form (O(n·u), no n×n objects), so
//! `NativeBackend::nll_grid` can select hyperparameters past a few
//! thousand observations without the exact sweep's O(n²) distance cache
//! or O(n³) cold refits.
//!
//! Open follow-up in ROADMAP.md: refreshing the inducing set
//! incrementally across BO iterations instead of re-sampling per fit.

use super::gp::{solve_lower_in_place, JITTER, VAR_FLOOR};
use super::kernel::matern52_cross;

/// Default inducing-set cap used by the auto-selected backend path.
/// 64 points keep the per-candidate cost (~u² flops) near the exact
/// path's 69-config baseline while covering the encoded 6-d feature cube
/// densely enough that the EI argmax survives the approximation (see
/// `bench_large_space`).
pub const DEFAULT_MAX_INDUCING: usize = 64;

/// Jitter on the inducing Gram `Kuu`. Deliberately much smaller than the
/// shared [`JITTER`]: any `Kuu` perturbation breaks the `Z = X` exact-
/// equality reduction by `O(jitter / λmin(Kff + σ²I))` — and EI then
/// amplifies the variance part by `1/(2σ)` — so a 1e-6 jitter could cost
/// ~1e-3 of parity while 1e-12 keeps the whole chain below ~1e-6 even at
/// the grid's smallest noise level. FPS picks well-separated inducing
/// points, so `Kuu` is well-conditioned and barely needs the help; if
/// its factorization still fails, `fit` reports it and the backend falls
/// back to the exact path.
pub const INDUCING_JITTER: f64 = 1e-12;

/// Deterministic farthest-point sampling of up to `k` row indices from
/// `n` row-major `d`-dimensional rows.
///
/// The seed point is the lexicographically smallest row (a pure
/// order-statistic — unlike a centroid it involves no floating-point
/// accumulation whose rounding could depend on candidate order); each
/// further point maximizes the minimum squared distance to the
/// already-selected set. All ties break toward the lexicographically
/// smaller feature row, which makes the selected *row set* a pure
/// function of the row multiset: deterministic across processes and
/// invariant to candidate order. Selection stops early when only exact
/// duplicates of already-selected rows remain, so the result never
/// contains two identical rows.
pub fn farthest_point_sample(x: &[f64], n: usize, d: usize, k: usize) -> Vec<usize> {
    assert_eq!(x.len(), n * d);
    let k = k.min(n);
    if k == 0 || n == 0 {
        return Vec::new();
    }
    let row = |i: usize| &x[i * d..(i + 1) * d];
    let lex_lt = |a: &[f64], b: &[f64]| -> bool {
        for (va, vb) in a.iter().zip(b) {
            if va < vb {
                return true;
            }
            if va > vb {
                return false;
            }
        }
        false
    };
    let sqdist = |a: &[f64], b: &[f64]| -> f64 {
        let mut s = 0.0;
        for (va, vb) in a.iter().zip(b) {
            let diff = va - vb;
            s += diff * diff;
        }
        s
    };

    // Seed: the lexicographically smallest row.
    let mut first = 0usize;
    for i in 1..n {
        if lex_lt(row(i), row(first)) {
            first = i;
        }
    }

    let mut selected = Vec::with_capacity(k);
    selected.push(first);
    // min_d2[i] = distance of row i to the selected set.
    let mut min_d2: Vec<f64> = (0..n).map(|i| sqdist(row(i), row(first))).collect();
    while selected.len() < k {
        let mut pick = None;
        let mut pick_d2 = 0.0;
        for i in 0..n {
            if min_d2[i] > pick_d2
                || (min_d2[i] == pick_d2
                    && min_d2[i] > 0.0
                    && pick.is_some_and(|p: usize| lex_lt(row(i), row(p))))
            {
                pick = Some(i);
                pick_d2 = min_d2[i];
            }
        }
        let Some(p) = pick.filter(|_| pick_d2 > 0.0) else {
            break; // only duplicates of selected rows remain
        };
        selected.push(p);
        for i in 0..n {
            let d2 = sqdist(row(i), row(p));
            if d2 < min_d2[i] {
                min_d2[i] = d2;
            }
        }
    }
    selected
}

/// A fitted Nyström/DTC low-rank posterior (see the module docs for the
/// math). Scratch buffers are reused across refits, mirroring
/// [`NativeGp`](super::gp::NativeGp)'s allocation discipline.
#[derive(Debug, Clone, Default)]
pub struct LowRankGp {
    d: usize,
    u: usize,
    /// Observation count of the current fit (the width of `B`).
    n: usize,
    hyp: [f64; 3],
    sigma2: f64,
    /// Inducing rows, row-major u x d.
    z: Vec<f64>,
    /// chol(Kuu + jitter I), row-major u x u lower-triangular.
    lu: Vec<f64>,
    /// chol(sigma² I + B Bᵀ), row-major u x u lower-triangular.
    lm: Vec<f64>,
    /// w = M⁻¹ Kuf y — the mean weights (length u).
    w: Vec<f64>,
    // scratch
    b_mat: Vec<f64>,
    m_mat: Vec<f64>,
    kt_mat: Vec<f64>,
    col_acc: Vec<f64>,
}

/// Forward-solve `L X = B` for a row-major `u x w` right-hand side in
/// place (column-per-candidate layout; same substitution order as
/// [`solve_lower_in_place`] per column).
fn solve_lower_multi(l: &[f64], u: usize, b: &mut [f64], w: usize) {
    debug_assert_eq!(b.len(), u * w);
    for i in 0..u {
        let (prior, cur) = b.split_at_mut(i * w);
        let row_i = &mut cur[..w];
        for k in 0..i {
            let lik = l[i * u + k];
            let zk = &prior[k * w..(k + 1) * w];
            for c in 0..w {
                row_i[c] -= lik * zk[c];
            }
        }
        let diag = l[i * u + i];
        for v in row_i.iter_mut() {
            *v /= diag;
        }
    }
}

/// Dense lower-Cholesky of a row-major `u x u` matrix in place; returns
/// false if not SPD. (Thin wrapper so this module has no dependency on
/// the exact GP beyond shared primitives.)
fn cholesky(a: &mut [f64], u: usize) -> bool {
    super::gp::cholesky_in_place(a, u)
}

impl LowRankGp {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of inducing points of the current fit.
    pub fn inducing_count(&self) -> usize {
        self.u
    }

    /// The selected inducing rows (row-major, `inducing_count() x d`).
    pub fn inducing_rows(&self) -> &[f64] {
        &self.z[..self.u * self.d]
    }

    /// Fit on `n` observations with at most `max_inducing` inducing
    /// points chosen by farthest-point sampling from the observations.
    /// Returns false (leaving the fit unusable) if the inducing Gram or
    /// the Woodbury inner matrix loses positive definiteness — the
    /// caller falls back to the exact path.
    pub fn fit(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        hyp: [f64; 3],
        max_inducing: usize,
    ) -> bool {
        let inducing = farthest_point_sample(x, n, d, max_inducing.max(1));
        self.fit_with_inducing(x, y, n, d, hyp, &inducing)
    }

    /// [`Self::fit`] with a caller-selected inducing set (row indices
    /// into `x`). Farthest-point selection depends only on the rows —
    /// not the hyperparameters — so a marginal-likelihood sweep
    /// (`NativeBackend::nll_grid`'s low-rank path) selects once and
    /// reuses the set across the whole grid instead of re-sweeping the
    /// full data per grid point.
    pub fn fit_with_inducing(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        hyp: [f64; 3],
        inducing: &[usize],
    ) -> bool {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        assert!(n > 0, "low-rank fit needs at least one observation");
        // u <= n keeps the marginal's (n - u) log-det factor well-formed
        // (FPS never selects duplicates; external callers must not either).
        assert!(inducing.len() <= n, "more inducing indices than observations");
        let (ls, var, noise) = (hyp[0], hyp[1], hyp[2]);
        let sigma2 = noise + JITTER;

        let u = inducing.len();
        self.z.clear();
        for &i in inducing {
            assert!(i < n, "inducing index {i} out of bounds (n = {n})");
            self.z.extend_from_slice(&x[i * d..(i + 1) * d]);
        }
        self.d = d;
        self.u = u;
        self.n = n;
        self.hyp = hyp;
        self.sigma2 = sigma2;

        // Lu = chol(Kuu + inducing-jitter I).
        let mut kuu = std::mem::take(&mut self.lu);
        matern52_cross(&self.z, u, &self.z, u, d, ls, var, &mut kuu);
        for i in 0..u {
            kuu[i * u + i] += INDUCING_JITTER;
        }
        if !cholesky(&mut kuu, u) {
            self.lu = kuu;
            self.u = 0;
            return false;
        }
        self.lu = kuu;

        // B = Lu⁻¹ Kuf (u x n).
        let mut b = std::mem::take(&mut self.b_mat);
        matern52_cross(&self.z, u, x, n, d, ls, var, &mut b);
        solve_lower_multi(&self.lu, u, &mut b, n);

        // Lm = chol(sigma² I + B Bᵀ).
        let mut m = std::mem::take(&mut self.m_mat);
        m.clear();
        m.resize(u * u, 0.0);
        for i in 0..u {
            for j in 0..=i {
                let mut s = 0.0;
                for c in 0..n {
                    s += b[i * n + c] * b[j * n + c];
                }
                m[i * u + j] = s;
                m[j * u + i] = s;
            }
            m[i * u + i] += sigma2;
        }
        let ok = cholesky(&mut m, u);
        if !ok {
            self.b_mat = b;
            self.m_mat = m;
            self.u = 0;
            return false;
        }
        // `m` now holds Lm; swap it into place and recycle the old Lm
        // buffer as next fit's scratch (no per-fit allocation).
        std::mem::swap(&mut self.lm, &mut m);
        self.m_mat = m;

        // w = M⁻¹ Kuf y = Lu⁻ᵀ Lm⁻ᵀ Lm⁻¹ (B y).
        self.w.clear();
        self.w.resize(u, 0.0);
        for i in 0..u {
            let mut s = 0.0;
            for c in 0..n {
                s += b[i * n + c] * y[c];
            }
            self.w[i] = s;
        }
        self.b_mat = b;
        solve_lower_in_place(&self.lm, u, &mut self.w);
        super::gp::solve_upper_t_in_place(&self.lm, u, &mut self.w);
        super::gp::solve_upper_t_in_place(&self.lu, u, &mut self.w);
        true
    }

    /// Posterior (mean, variance) for all `m` candidates, streamed in
    /// fixed-size tiles (no m-wide intermediate beyond the outputs).
    /// `mu_out`/`var_out` are cleared and resized to `m`.
    pub fn predict_batch(
        &mut self,
        xc: &[f64],
        m: usize,
        mu_out: &mut Vec<f64>,
        var_out: &mut Vec<f64>,
    ) {
        // One tiling policy for both candidate-scoring paths.
        const TILE: usize = super::backend::DECIDE_TILE;
        assert!(self.u > 0, "predict on an unfitted low-rank posterior");
        let (ls, var, _) = (self.hyp[0], self.hyp[1], self.hyp[2]);
        let (u, d) = (self.u, self.d);
        assert_eq!(xc.len(), m * d);
        mu_out.clear();
        mu_out.resize(m, 0.0);
        var_out.clear();
        var_out.resize(m, var);

        let mut kt = std::mem::take(&mut self.kt_mat);
        let mut acc = std::mem::take(&mut self.col_acc);
        for start in (0..m).step_by(TILE) {
            let w = TILE.min(m - start);
            let tile = &xc[start * d..(start + w) * d];
            // K(Z, tile): u x w.
            matern52_cross(&self.z, u, tile, w, d, ls, var, &mut kt);
            // Means first: mu = k*uᵀ w before kt is overwritten by solves.
            for i in 0..u {
                let wi = self.w[i];
                let row = &kt[i * w..(i + 1) * w];
                for c in 0..w {
                    mu_out[start + c] += row[c] * wi;
                }
            }
            // a = Lu⁻¹ k*u per column; |a|² accumulates into acc.
            solve_lower_multi(&self.lu, u, &mut kt, w);
            acc.clear();
            acc.resize(w, 0.0);
            for i in 0..u {
                let row = &kt[i * w..(i + 1) * w];
                for c in 0..w {
                    acc[c] += row[c] * row[c];
                }
            }
            for c in 0..w {
                var_out[start + c] = var - acc[c];
            }
            // t = Lm⁻¹ a; add back sigma² |t|².
            solve_lower_multi(&self.lm, u, &mut kt, w);
            acc.clear();
            acc.resize(w, 0.0);
            for i in 0..u {
                let row = &kt[i * w..(i + 1) * w];
                for c in 0..w {
                    acc[c] += row[c] * row[c];
                }
            }
            for c in 0..w {
                var_out[start + c] = (var_out[start + c] + self.sigma2 * acc[c]).max(VAR_FLOOR);
            }
        }
        self.kt_mat = kt;
        self.col_acc = acc;
    }

    /// Posterior (mean, variance) at one candidate row — the scalar
    /// convenience over [`Self::predict_batch`].
    pub fn predict(&mut self, xc: &[f64]) -> (f64, f64) {
        assert_eq!(xc.len(), self.d);
        let mut mu = Vec::new();
        let mut var = Vec::new();
        self.predict_batch(xc, 1, &mut mu, &mut var);
        (mu[0], var[0])
    }

    /// Prior signal variance of the current fit (the variance upper
    /// bound the property tests pin).
    pub fn prior_variance(&self) -> f64 {
        self.hyp[1]
    }

    /// DTC marginal negative log likelihood of the fitted data, in
    /// Woodbury form — the low-rank counterpart of `NativeGp::nll` that
    /// `NativeBackend::nll_grid` uses past its observation threshold.
    ///
    /// Under the DTC model `y ~ N(0, Qff + σ²I)` with `Qff = Bᵀ B`
    /// (`B = Lu⁻¹ Kuf` from the fit). With `t = Lm⁻¹ (B y)`:
    ///
    /// ```text
    /// yᵀ (Qff + σ²I)⁻¹ y = (yᵀy − |t|²) / σ²
    /// ln det(Qff + σ²I)  = (n − u) ln σ² + 2 Σᵢ ln Lm[i,i]
    /// ```
    ///
    /// (both are the standard Woodbury/determinant-lemma identities
    /// through the fit's `Lm Lmᵀ = σ²I + B Bᵀ` factor). Cost O(n·u):
    /// independent of any n×n object. The `0.5·n·ln 2π` fold constant
    /// matches `NativeGp::nll`, and at `Z = X` (`u = n`) the value
    /// reduces to the exact marginal up to [`INDUCING_JITTER`] — the pin
    /// `tests/prop_lowrank.rs` enforces.
    pub fn nll(&self, y: &[f64]) -> f64 {
        let (u, n) = (self.u, self.n);
        assert!(u > 0, "nll on an unfitted low-rank posterior");
        assert_eq!(y.len(), n);
        let b = &self.b_mat;
        // t = Lm^-1 (B y).
        let mut t = vec![0.0; u];
        for (i, ti) in t.iter_mut().enumerate() {
            let mut s = 0.0;
            for c in 0..n {
                s += b[i * n + c] * y[c];
            }
            *ti = s;
        }
        solve_lower_in_place(&self.lm, u, &mut t);
        let yty: f64 = y.iter().map(|v| v * v).sum();
        let t2: f64 = t.iter().map(|v| v * v).sum();
        let quad = 0.5 * (yty - t2) / self.sigma2;
        let half_logdet = 0.5 * (n - u) as f64 * self.sigma2.ln()
            + (0..u).map(|i| self.lm[i * u + i].ln()).sum::<f64>();
        quad + half_logdet + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::gp::NativeGp;

    fn grid_x(n: usize, d: usize) -> Vec<f64> {
        (0..n * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect()
    }

    #[test]
    fn fps_selects_distinct_spread_points() {
        let d = 2;
        let n = 30;
        let x = grid_x(n, d);
        let sel = farthest_point_sample(&x, n, d, 8);
        assert_eq!(sel.len(), 8);
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "duplicate selections in {sel:?}");
    }

    #[test]
    fn fps_skips_exact_duplicates() {
        let d = 2;
        // Three distinct rows, each duplicated.
        let x = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let sel = farthest_point_sample(&x, 6, d, 6);
        assert_eq!(sel.len(), 3, "must stop at the distinct-row count, got {sel:?}");
        let rows: Vec<&[f64]> = sel.iter().map(|&i| &x[i * d..(i + 1) * d]).collect();
        for i in 0..rows.len() {
            for j in (i + 1)..rows.len() {
                assert_ne!(rows[i], rows[j]);
            }
        }
    }

    #[test]
    fn full_inducing_set_matches_exact_gp() {
        // u = n: the DTC posterior reduces to the exact GP (module docs).
        let n = 10;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let hyp = [0.6, 1.4, 1e-3];
        let mut exact = NativeGp::new();
        assert!(exact.fit(&x, &y, n, d, hyp));
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, hyp, n));
        assert_eq!(lr.inducing_count(), n);
        let m = 15;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 13 + 3) % 71) as f64 / 71.0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, m, &mut mu, &mut var);
        for j in 0..m {
            let (me, ve) = exact.predict(&xc[j * d..(j + 1) * d]);
            assert!(
                (mu[j] - me).abs() <= 1e-6 * me.abs().max(1.0),
                "mu[{j}]: lowrank {} vs exact {me}",
                mu[j]
            );
            assert!(
                (var[j] - ve).abs() <= 1e-6,
                "var[{j}]: lowrank {} vs exact {ve}",
                var[j]
            );
        }
    }

    #[test]
    fn variance_within_prior_bounds() {
        let n = 40;
        let d = 4;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).cos()).collect();
        let hyp = [0.4, 2.0, 1e-2];
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, hyp, 12));
        assert!(lr.inducing_count() <= 12);
        let m = 50;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 29 + 11) % 83) as f64 / 83.0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, m, &mut mu, &mut var);
        for j in 0..m {
            assert!(var[j] >= 0.0, "negative variance {}", var[j]);
            assert!(var[j] <= hyp[1] + 1e-9, "variance {} above prior {}", var[j], hyp[1]);
        }
    }

    #[test]
    fn predict_scalar_matches_batch() {
        let n = 20;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, [0.5, 1.0, 1e-3], 8));
        let xc = [0.2, 0.4, 0.6];
        let (mu1, var1) = lr.predict(&xc);
        let mut mu = Vec::new();
        let mut var = Vec::new();
        lr.predict_batch(&xc, 1, &mut mu, &mut var);
        assert_eq!(mu[0], mu1);
        assert_eq!(var[0], var1);
    }
}
