//! The Matérn-5/2 covariance kernel and its batched Gram/cross builders —
//! shared by the exact GP ([`super::gp`]), the incremental factor cache
//! ([`super::chol`] via the backend) and the Nyström low-rank posterior
//! ([`super::lowrank`]). Factored out of `gp.rs` so neither posterior
//! family owns the kernel math; the same arithmetic (and therefore the
//! same bits) feeds every path.

pub const SQRT5: f64 = 2.23606797749979;

/// Slice dot product written so LLVM auto-vectorizes it — the hot inner
/// kernel of every factorization and triangular solve. Lives here (not
/// per consumer) because the packed ([`super::chol`]) and dense
/// ([`super::gp`]) linear algebra must share one accumulation order for
/// their bit-parity contract to hold by construction.
#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Matérn-5/2 covariance from a squared distance.
#[inline]
pub fn matern52_from_d2(d2: f64, lengthscale: f64, variance: f64) -> f64 {
    let r = d2.sqrt() / lengthscale;
    variance * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2 / (lengthscale * lengthscale))
        * (-SQRT5 * r).exp()
}

/// Matérn-5/2 covariance between two feature rows.
#[inline]
pub fn matern52(a: &[f64], b: &[f64], lengthscale: f64, variance: f64) -> f64 {
    let mut d2 = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        d2 += d * d;
    }
    matern52_from_d2(d2, lengthscale, variance)
}

/// Pairwise squared distances of `n` rows (row-major, `d` columns) into
/// `out` (resized to n*n). Hyperparameter-independent — computed once per
/// decision and shared across the whole hyperparameter grid (§Perf).
pub fn pairwise_sqdist(x: &[f64], n: usize, d: usize, out: &mut Vec<f64>) {
    out.clear();
    out.resize(n * n, 0.0);
    for i in 0..n {
        for j in 0..i {
            let mut d2 = 0.0;
            for k in 0..d {
                let diff = x[i * d + k] - x[j * d + k];
                d2 += diff * diff;
            }
            out[i * n + j] = d2;
            out[j * n + i] = d2;
        }
    }
}

/// Tiled Matérn-5/2 Gram build from a precomputed squared-distance
/// matrix: the lower triangle is computed in cache-sized blocks and
/// mirrored, halving the transcendental count versus a full pointwise
/// map and keeping both `d2` reads and `out` writes block-local. Shared
/// by every cold-fit path (`fit_from_sqdist`, the backend's grid
/// refactorizations).
pub fn matern52_gram_from_d2(d2: &[f64], n: usize, ls: f64, var: f64, out: &mut Vec<f64>) {
    const B: usize = 64;
    assert_eq!(d2.len(), n * n);
    out.clear();
    out.resize(n * n, 0.0);
    for ib in (0..n).step_by(B) {
        let ie = (ib + B).min(n);
        for jb in (0..=ib).step_by(B) {
            let je = (jb + B).min(n);
            for i in ib..ie {
                for j in jb..je.min(i + 1) {
                    let k = matern52_from_d2(d2[i * n + j], ls, var);
                    out[i * n + j] = k;
                    out[j * n + i] = k;
                }
            }
        }
    }
}

/// Cross-kernel block `K(a, b)` of two row sets into `out` (resized to
/// `na * nb`, row-major: row i = k(a_i, b_*)). The low-rank posterior
/// builds its inducing-vs-observation and inducing-vs-candidate blocks
/// through this one function so both sides share the arithmetic.
#[allow(clippy::too_many_arguments)]
pub fn matern52_cross(
    a: &[f64],
    na: usize,
    b: &[f64],
    nb: usize,
    d: usize,
    ls: f64,
    var: f64,
    out: &mut Vec<f64>,
) {
    assert_eq!(a.len(), na * d);
    assert_eq!(b.len(), nb * d);
    out.clear();
    out.resize(na * nb, 0.0);
    for i in 0..na {
        let ai = &a[i * d..(i + 1) * d];
        let row = &mut out[i * nb..(i + 1) * nb];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = matern52(ai, &b[j * d..(j + 1) * d], ls, var);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_block_matches_pointwise() {
        let d = 3;
        let a: Vec<f64> = (0..4 * d).map(|i| ((i * 13 + 1) % 31) as f64 / 31.0).collect();
        let b: Vec<f64> = (0..5 * d).map(|i| ((i * 17 + 3) % 29) as f64 / 29.0).collect();
        let mut out = Vec::new();
        matern52_cross(&a, 4, &b, 5, d, 0.7, 1.3, &mut out);
        assert_eq!(out.len(), 20);
        for i in 0..4 {
            for j in 0..5 {
                let want = matern52(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d], 0.7, 1.3);
                assert_eq!(out[i * 5 + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn gram_from_d2_matches_cross_with_itself() {
        let d = 2;
        let n = 7;
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 23 + 5) % 41) as f64 / 41.0).collect();
        let mut d2 = Vec::new();
        pairwise_sqdist(&x, n, d, &mut d2);
        let mut gram = Vec::new();
        matern52_gram_from_d2(&d2, n, 0.5, 2.0, &mut gram);
        let mut cross = Vec::new();
        matern52_cross(&x, n, &x, n, d, 0.5, 2.0, &mut cross);
        for (i, (g, c)) in gram.iter().zip(&cross).enumerate() {
            assert!((g - c).abs() < 1e-12, "entry {i}: {g} vs {c}");
        }
    }
}
