//! The Matérn-5/2 covariance kernel and its batched Gram/cross builders —
//! shared by the exact GP ([`super::gp`]), the incremental factor cache
//! ([`super::chol`] via the backend) and the Nyström low-rank posterior
//! ([`super::lowrank`]). Factored out of `gp.rs` so neither posterior
//! family owns the kernel math; the same arithmetic (and therefore the
//! same bits) feeds every path.
//!
//! # Parity contract (see [`super::simd`])
//!
//! The builders here run on the dispatched micro-kernels of
//! `bayesopt/simd.rs`, which split into two classes:
//!
//! * **Bit-exact regardless of dispatch**: [`pairwise_sqdist`] (and the
//!   backend's incremental d2 rows) accumulate one pair per vector
//!   lane in the exact scalar feature order with no FMA, so SIMD-on
//!   and SIMD-off produce identical bits and exact-equality suites may
//!   pin them directly.
//! * **Tolerance-pinned under SIMD**: [`matern52_gram_from_d2`] and
//!   [`matern52_cross`] map rows through a vector `exp` polynomial
//!   (~2 ulp vs libm), and [`dot`] reassociates across accumulators —
//!   with SIMD dispatched these differ from the scalar twins within
//!   [`super::simd::SIMD_PARITY_RTOL`]. With SIMD off
//!   (`RUYA_FORCE_SCALAR` / `set_simd(false)`) every path reproduces
//!   the legacy scalar bits exactly.
//!
//! Cross-path comparisons (serial vs pooled, incremental vs fresh,
//! Gram vs cross) stay bit-stable in either mode because both sides of
//! each comparison share these builders.

use super::simd;

pub const SQRT5: f64 = 2.23606797749979;

/// Slice dot product — the hot inner kernel of every factorization and
/// triangular solve. Lives here (not per consumer) because the packed
/// ([`super::chol`]) and dense ([`super::gp`]) linear algebra must share
/// one accumulation order for their bit-parity contract to hold by
/// construction. Dispatches to the multi-accumulator AVX2+FMA kernel
/// when SIMD is active (tolerance class — reassociates), and to the
/// legacy serial loop otherwise. Public so the bench harness can
/// measure its standalone throughput (`bench_gp_hotpath`'s per-kernel
/// GFLOP/s section).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    simd::dot(a, b)
}

/// Matérn-5/2 covariance from a squared distance.
#[inline]
pub fn matern52_from_d2(d2: f64, lengthscale: f64, variance: f64) -> f64 {
    let r = d2.sqrt() / lengthscale;
    variance * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2 / (lengthscale * lengthscale))
        * (-SQRT5 * r).exp()
}

/// Matérn-5/2 covariance between two feature rows.
#[inline]
pub fn matern52(a: &[f64], b: &[f64], lengthscale: f64, variance: f64) -> f64 {
    let mut d2 = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        d2 += d * d;
    }
    matern52_from_d2(d2, lengthscale, variance)
}

/// Mirror the (strict) lower triangle of an `n x n` row-major matrix
/// into the upper triangle, in cache-sized blocks. Shared by the
/// distance and Gram builders so in-loop strided `out[j * n + i]`
/// stores never land on the hot path.
fn mirror_lower(out: &mut [f64], n: usize) {
    const B: usize = 64;
    for ib in (0..n).step_by(B) {
        let ie = (ib + B).min(n);
        for jb in (0..=ib).step_by(B) {
            let je = (jb + B).min(n);
            for i in ib..ie {
                for j in jb..je.min(i) {
                    out[j * n + i] = out[i * n + j];
                }
            }
        }
    }
}

/// Pairwise squared distances of `n` rows (row-major, `d` columns) into
/// `out` (resized to n*n). Hyperparameter-independent — computed once per
/// decision and shared across the whole hyperparameter grid (§Perf).
///
/// Computes the lower triangle in cache-sized blocks with block-local
/// row-contiguous stores (one vectorized [`simd::sqdist_row`] segment
/// per row) and mirrors in a separate pass — same bits as the legacy
/// in-loop double store, without the strided writes.
pub fn pairwise_sqdist(x: &[f64], n: usize, d: usize, out: &mut Vec<f64>) {
    const B: usize = 64;
    out.clear();
    out.resize(n * n, 0.0);
    for ib in (0..n).step_by(B) {
        let ie = (ib + B).min(n);
        for jb in (0..=ib).step_by(B) {
            let je = (jb + B).min(n);
            for i in ib..ie {
                let jhi = je.min(i); // strictly below the diagonal
                if jb >= jhi {
                    continue;
                }
                let seg = i * n + jb..i * n + jhi;
                simd::sqdist_row(&x[i * d..(i + 1) * d], &x[jb * d..jhi * d], d, &mut out[seg]);
            }
        }
    }
    mirror_lower(out, n);
}

/// Tiled Matérn-5/2 Gram build from a precomputed squared-distance
/// matrix: the lower triangle is computed in cache-sized blocks and
/// mirrored in a separate pass, halving the transcendental count versus
/// a full pointwise map and keeping both `d2` reads and `out` writes
/// block-local. Each row segment maps through the dispatched
/// [`simd::matern52_map_from_d2`] (vector `exp` under SIMD — tolerance
/// class). Shared by every cold-fit path (`fit_from_sqdist`, the
/// backend's grid refactorizations).
pub fn matern52_gram_from_d2(d2: &[f64], n: usize, ls: f64, var: f64, out: &mut Vec<f64>) {
    const B: usize = 64;
    assert_eq!(d2.len(), n * n);
    out.clear();
    out.resize(n * n, 0.0);
    for ib in (0..n).step_by(B) {
        let ie = (ib + B).min(n);
        for jb in (0..=ib).step_by(B) {
            let je = (jb + B).min(n);
            for i in ib..ie {
                let jhi = je.min(i + 1); // diagonal inclusive
                if jb >= jhi {
                    continue;
                }
                let seg = i * n + jb..i * n + jhi;
                out[seg.clone()].copy_from_slice(&d2[seg.clone()]);
                simd::matern52_map_from_d2(ls, var, &mut out[seg]);
            }
        }
    }
    mirror_lower(out, n);
}

/// Cross-kernel block `K(a, b)` of two row sets into `out` (resized to
/// `na * nb`, row-major: row i = k(a_i, b_*)). The low-rank posterior
/// builds its inducing-vs-observation and inducing-vs-candidate blocks
/// through this one function so both sides share the arithmetic.
///
/// Routed through the same blocked builder shape as the Gram build: the
/// `b` side is tiled in cache-sized column blocks held hot across all
/// `a` rows (no per-pair feature-difference recomputation thrashing on
/// large `d`), with each segment computed as a vectorized squared-
/// distance row plus an in-place Matérn map.
#[allow(clippy::too_many_arguments)]
pub fn matern52_cross(
    a: &[f64],
    na: usize,
    b: &[f64],
    nb: usize,
    d: usize,
    ls: f64,
    var: f64,
    out: &mut Vec<f64>,
) {
    const B: usize = 64;
    assert_eq!(a.len(), na * d);
    assert_eq!(b.len(), nb * d);
    out.clear();
    out.resize(na * nb, 0.0);
    for jb in (0..nb).step_by(B) {
        let je = (jb + B).min(nb);
        for ib in (0..na).step_by(B) {
            let ie = (ib + B).min(na);
            for i in ib..ie {
                let seg = i * nb + jb..i * nb + je;
                let seg_out = &mut out[seg.clone()];
                simd::sqdist_row(&a[i * d..(i + 1) * d], &b[jb * d..je * d], d, seg_out);
                simd::matern52_map_from_d2(ls, var, &mut out[seg]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::property;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn cross_block_matches_pointwise() {
        let d = 3;
        let a: Vec<f64> = (0..4 * d).map(|i| ((i * 13 + 1) % 31) as f64 / 31.0).collect();
        let b: Vec<f64> = (0..5 * d).map(|i| ((i * 17 + 3) % 29) as f64 / 29.0).collect();
        let mut out = Vec::new();
        matern52_cross(&a, 4, &b, 5, d, 0.7, 1.3, &mut out);
        assert_eq!(out.len(), 20);
        for i in 0..4 {
            for j in 0..5 {
                let want = matern52(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d], 0.7, 1.3);
                if simd::simd_active() {
                    // The blocked builder maps through the vector exp;
                    // pointwise matern52 stays on libm.
                    assert!(
                        rel(out[i * 5 + j], want) <= simd::SIMD_PARITY_RTOL,
                        "({i},{j}): {} vs {}",
                        out[i * 5 + j],
                        want
                    );
                } else {
                    assert_eq!(out[i * 5 + j], want, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn gram_from_d2_matches_cross_with_itself() {
        let d = 2;
        let n = 7;
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 23 + 5) % 41) as f64 / 41.0).collect();
        let mut d2 = Vec::new();
        pairwise_sqdist(&x, n, d, &mut d2);
        let mut gram = Vec::new();
        matern52_gram_from_d2(&d2, n, 0.5, 2.0, &mut gram);
        let mut cross = Vec::new();
        matern52_cross(&x, n, &x, n, d, 0.5, 2.0, &mut cross);
        for (i, (g, c)) in gram.iter().zip(&cross).enumerate() {
            assert!((g - c).abs() < 1e-12, "entry {i}: {g} vs {c}");
        }
    }

    #[test]
    fn blocked_builders_match_pointwise_across_boundaries() {
        // Random shapes up to and past the 64-wide block and 4-wide
        // lane boundaries (including n % 4 != 0): the restructured
        // pairwise build must reproduce the legacy per-pair bits
        // exactly in both dispatch modes, and the Gram/cross builders
        // must match the pointwise scalar map within SIMD_PARITY_RTOL
        // (exactly when SIMD is off).
        property("blocked builders vs pointwise", 12, |g| {
            let n = g.usize_in(1, 131);
            let d = g.usize_in(1, 6);
            let (ls, var) = (g.f64_in(0.2, 2.0), g.f64_in(0.3, 3.0));
            let x = g.vec_f64(n * d, -2.0, 2.0);

            let mut d2 = Vec::new();
            pairwise_sqdist(&x, n, d, &mut d2);
            for i in 0..n {
                for j in 0..n {
                    let mut want = 0.0;
                    for k in 0..d {
                        let diff = x[i * d + k] - x[j * d + k];
                        want += diff * diff;
                    }
                    if i == j {
                        want = 0.0;
                    }
                    prop_assert!(
                        d2[i * n + j].to_bits() == want.to_bits(),
                        "d2[{i},{j}] (n={n}, d={d}): {} vs {}",
                        d2[i * n + j],
                        want
                    );
                }
            }

            let mut gram = Vec::new();
            matern52_gram_from_d2(&d2, n, ls, var, &mut gram);
            let mut cross = Vec::new();
            matern52_cross(&x, n, &x, n, d, ls, var, &mut cross);
            for i in 0..n {
                for j in 0..n {
                    let want = matern52_from_d2(d2[i * n + j], ls, var);
                    let (gv, cv) = (gram[i * n + j], cross[i * n + j]);
                    if simd::simd_active() {
                        prop_assert!(
                            rel(gv, want) <= simd::SIMD_PARITY_RTOL
                                && rel(cv, want) <= simd::SIMD_PARITY_RTOL,
                            "kernel[{i},{j}] (n={n}): gram {gv} cross {cv} vs {want}"
                        );
                    } else {
                        prop_assert!(
                            gv.to_bits() == want.to_bits() && cv.to_bits() == want.to_bits(),
                            "kernel[{i},{j}] (n={n}): gram {gv} cross {cv} vs {want}"
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
