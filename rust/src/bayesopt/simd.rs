//! Explicitly vectorized micro-kernels for the dense GP core, behind
//! runtime feature detection with scalar fallbacks that preserve the
//! legacy loops bit for bit.
//!
//! # Dispatch
//!
//! On x86_64 the AVX2(+FMA) arms engage when the CPU reports the
//! features at runtime ([`simd_available`]); no compile-time target
//! flags are required. The process-global mode is resolved once on
//! first use and can be overridden:
//!
//! * `RUYA_FORCE_SCALAR=1` in the environment forces the scalar arms
//!   before the first kernel call (the CI matrix leg uses this);
//! * [`set_simd`]`(false)` / `(true)` toggles programmatically —
//!   intended for single-threaded harnesses (benches, the dedicated
//!   SIMD parity suite). The flag is process-global, so never toggle it
//!   while other threads run kernel code.
//!
//! # Parity contract
//!
//! The kernels fall into two classes:
//!
//! * **Bit-exact class** — lane-per-pair and column-lane kernels whose
//!   every lane replays the scalar operation sequence (separate
//!   multiply and add, no FMA): [`sqdist_row`] and the column-lane
//!   TRSM/fold helpers ([`axpy`], [`axpy_sub`], [`sub_div`],
//!   [`sq_accum`], [`scale_div`]). These produce identical bits
//!   whichever arm dispatch picks, so suites pinning exact equality
//!   across paths (the incremental d2 cache, serial-vs-pooled tiles)
//!   hold with SIMD on or off.
//! * **Tolerance class** — reductions and transcendentals that
//!   reassociate accumulation (multi-accumulator [`dot`]) or replace
//!   libm's `exp` with a vector polynomial ([`matern52_map_from_d2`]).
//!   Their SIMD arms are pinned to the scalar twins within
//!   [`SIMD_PARITY_RTOL`] by seeded property tests here and by the
//!   backend-level suite in `tests/simd_parity.rs`.
//!
//! Cross-path bit contracts (serial vs pooled, staged vs fresh,
//! prepared vs direct decide) survive either way because both sides of
//! each comparison route through the same dispatched kernel.

use std::sync::atomic::{AtomicU8, Ordering};

/// Relative tolerance pinning every tolerance-class SIMD kernel to its
/// scalar twin, and whole-backend SIMD-on vs SIMD-off traces.
///
/// Why 1e-10: the reassociated reductions differ from the serial order
/// by a few ulps over the ≤ few-thousand-term sums the GP builds, and
/// the vector `exp` is accurate to ~2 ulp, so primitive-level
/// divergence sits around 1e-15 relative. Triangular solves and the
/// NLL fold amplify that by the factor conditioning — bounded well
/// below 1e-10 for jittered covariance matrices — while a genuinely
/// wrong kernel (bad polynomial constant, dropped remainder lane)
/// misses by ≥1e-8. 1e-10 leaves headroom without masking real bugs.
pub const SIMD_PARITY_RTOL: f64 = 1e-10;

/// f64 lanes per vector register on the AVX2 arm; scratch buffers
/// sized to a multiple of this keep remainder handling off the hot
/// tiles (see [`lane_padded`]).
pub const LANES: usize = 4;

/// Round `n` up to a whole number of [`LANES`] — used when reserving
/// per-lane scratch so vector bodies cover full rows and only the
/// final row tail falls to the scalar remainder loop.
pub fn lane_padded(n: usize) -> usize {
    n.div_ceil(LANES) * LANES
}

const MODE_UNSET: u8 = 0;
const MODE_SIMD: u8 = 1;
const MODE_SCALAR: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Whether this CPU supports the vector arms (AVX2 + FMA on x86_64).
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn env_forces_scalar() -> bool {
    std::env::var_os("RUYA_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0")
}

/// Whether the vector arms are currently dispatched. Resolved on first
/// call from feature detection and `RUYA_FORCE_SCALAR`; overridable
/// via [`set_simd`].
#[inline]
pub fn simd_active() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_SIMD => true,
        MODE_SCALAR => false,
        _ => {
            let on = simd_available() && !env_forces_scalar();
            MODE.store(if on { MODE_SIMD } else { MODE_SCALAR }, Ordering::Relaxed);
            on
        }
    }
}

/// Force the dispatch mode; `set_simd(true)` still falls back to
/// scalar when the CPU lacks the features. Returns the mode actually
/// in effect. Process-global — only call from single-threaded
/// harnesses (or under a lock that also guards every kernel caller).
pub fn set_simd(on: bool) -> bool {
    let eff = on && simd_available();
    MODE.store(if eff { MODE_SIMD } else { MODE_SCALAR }, Ordering::Relaxed);
    eff
}

// ---------------------------------------------------------------------------
// dot — tolerance class (multi-accumulator reassociation + FMA)
// ---------------------------------------------------------------------------

/// Serial-order dot product: the legacy accumulation every bit-exact
/// suite pins. The dispatched [`dot`] falls back to this exact loop.
#[inline]
pub(crate) fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Dot product, dispatched: the AVX2+FMA arm splits the sum across
/// two 4-lane accumulators (breaking the serial add-latency chain),
/// which reassociates — tolerance class. Short slices stay scalar.
#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if a.len() >= 8 && simd_active() {
        // SAFETY: simd_active() implies AVX2+FMA were detected.
        return unsafe { dot_avx2(a, b) };
    }
    dot_scalar(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= n {
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_pd(
            _mm256_loadu_pd(pa.add(i + 4)),
            _mm256_loadu_pd(pb.add(i + 4)),
            acc1,
        );
        i += 8;
    }
    if i + 4 <= n {
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)), acc0);
        i += 4;
    }
    let acc = _mm256_add_pd(acc0, acc1);
    let lo = _mm256_castpd256_pd128(acc);
    let hi = _mm256_extractf128_pd::<1>(acc);
    let pair = _mm_add_pd(lo, hi);
    let mut s = _mm_cvtsd_f64(_mm_add_sd(pair, _mm_unpackhi_pd(pair, pair)));
    while i < n {
        s += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    s
}

// ---------------------------------------------------------------------------
// sqdist row — bit-exact class (lane per pair, scalar order per lane)
// ---------------------------------------------------------------------------

/// Scalar squared-distance row: `out[j] = |p - rows_j|²` accumulated in
/// ascending feature order — the legacy per-pair loop.
#[inline]
pub(crate) fn sqdist_row_scalar(p: &[f64], rows: &[f64], d: usize, out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len() * d);
    for (j, slot) in out.iter_mut().enumerate() {
        let r = &rows[j * d..(j + 1) * d];
        let mut d2 = 0.0;
        for (x, y) in p.iter().zip(r) {
            let diff = x - y;
            d2 += diff * diff;
        }
        *slot = d2;
    }
}

/// Squared distances from point `p` (length `d`) to each row of `rows`
/// (row-major, `out.len()` rows), dispatched. **Bit-exact class**: the
/// AVX2 arm assigns one pair per lane and replays the scalar
/// subtract/multiply/add sequence per feature (no FMA), so both arms
/// produce identical bits — the incremental d2 cache and the fresh
/// pairwise build can therefore share this kernel under an exact
/// equality pin.
#[inline]
pub(crate) fn sqdist_row(p: &[f64], rows: &[f64], d: usize, out: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if out.len() >= LANES && d > 0 && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { sqdist_row_avx2(p, rows, d, out) };
        return;
    }
    sqdist_row_scalar(p, rows, d, out)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sqdist_row_avx2(p: &[f64], rows: &[f64], d: usize, out: &mut [f64]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(rows.len(), out.len() * d);
    let m = out.len();
    let rp = rows.as_ptr();
    let mut j = 0usize;
    while j + LANES <= m {
        let mut acc = _mm256_setzero_pd();
        let base = j * d;
        for (k, &pk) in p.iter().enumerate() {
            // Lane l holds row j+l; rows are d-strided, so gather the
            // k-th feature of the four rows with scalar loads.
            let xs = _mm256_set_pd(
                *rp.add(base + 3 * d + k),
                *rp.add(base + 2 * d + k),
                *rp.add(base + d + k),
                *rp.add(base + k),
            );
            let diff = _mm256_sub_pd(_mm256_set1_pd(pk), xs);
            // Separate multiply + add (no FMA): per-lane bits match the
            // scalar `d2 += diff * diff`.
            acc = _mm256_add_pd(acc, _mm256_mul_pd(diff, diff));
        }
        _mm256_storeu_pd(out.as_mut_ptr().add(j), acc);
        j += LANES;
    }
    if j < m {
        sqdist_row_scalar(p, &rows[j * d..], d, &mut out[j..]);
    }
}

// ---------------------------------------------------------------------------
// Matérn-5/2 row map — tolerance class (vector exp)
// ---------------------------------------------------------------------------

/// Scalar in-place Matérn-5/2 map over a squared-distance row — the
/// legacy pointwise build.
#[inline]
pub(crate) fn matern52_map_scalar(ls: f64, var: f64, row: &mut [f64]) {
    for v in row.iter_mut() {
        *v = super::kernel::matern52_from_d2(*v, ls, var);
    }
}

/// In-place Matérn-5/2 map over a squared-distance row, dispatched.
/// **Tolerance class**: the AVX2 arm evaluates `exp` with a Cephes-style
/// vector polynomial (~2 ulp) instead of libm, so SIMD-on rows differ
/// from scalar rows within [`SIMD_PARITY_RTOL`]. Remainder entries (row
/// length % 4) use the scalar map — deterministic by position.
#[inline]
pub(crate) fn matern52_map_from_d2(ls: f64, var: f64, row: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if row.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2+FMA were detected.
        unsafe { matern52_map_avx2(ls, var, row) };
        return;
    }
    matern52_map_scalar(ls, var, row)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matern52_map_avx2(ls: f64, var: f64, row: &mut [f64]) {
    use std::arch::x86_64::*;
    let inv_ls = _mm256_set1_pd(1.0 / ls);
    let sqrt5 = _mm256_set1_pd(super::kernel::SQRT5);
    let five_thirds = _mm256_set1_pd(5.0 / 3.0);
    let inv_ls2 = _mm256_set1_pd(1.0 / (ls * ls));
    let one = _mm256_set1_pd(1.0);
    let varv = _mm256_set1_pd(var);
    let n = row.len();
    let ptr = row.as_mut_ptr();
    let mut i = 0usize;
    while i + LANES <= n {
        let d2 = _mm256_loadu_pd(ptr.add(i));
        // r = sqrt(d2) / ls; a = sqrt(5) * r
        let a = _mm256_mul_pd(sqrt5, _mm256_mul_pd(_mm256_sqrt_pd(d2), inv_ls));
        // poly = 1 + a + (5/3) * d2 / ls^2
        let t = _mm256_mul_pd(_mm256_mul_pd(five_thirds, d2), inv_ls2);
        let poly = _mm256_add_pd(_mm256_add_pd(one, a), t);
        let e = exp256(_mm256_sub_pd(_mm256_setzero_pd(), a));
        _mm256_storeu_pd(ptr.add(i), _mm256_mul_pd(_mm256_mul_pd(varv, poly), e));
        i += LANES;
    }
    if i < n {
        matern52_map_scalar(ls, var, &mut row[i..]);
    }
}

/// 4-lane `exp` after Cephes `exp.c`: Cody–Waite range reduction
/// against ln 2, a degree-(2,3) rational in the reduced argument, and
/// 2^n reassembled through the exponent bits. ~2 ulp over the
/// covariance domain (arguments ≤ 0); inputs below the f64 underflow
/// threshold flush to +0 like libm.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn exp256(x: std::arch::x86_64::__m256d) -> std::arch::x86_64::__m256d {
    use std::arch::x86_64::*;
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    const C1: f64 = 6.93145751953125e-1;
    const C2: f64 = 1.42860682030941723212e-6;
    const P0: f64 = 1.26177193074810590878e-4;
    const P1: f64 = 3.02994407707441961300e-2;
    const P2: f64 = 0.999999999999999999910;
    const Q0: f64 = 3.00198505138664455042e-6;
    const Q1: f64 = 2.52448340349684104192e-3;
    const Q2: f64 = 2.27265548208155028766e-1;
    const Q3: f64 = 2.00000000000000000005;
    // Arguments this far down underflow to zero even as denormals.
    const UNDERFLOW: f64 = -708.396418532264078749;

    let n = _mm256_floor_pd(_mm256_fmadd_pd(x, _mm256_set1_pd(LOG2E), _mm256_set1_pd(0.5)));
    let mut r = _mm256_fnmadd_pd(n, _mm256_set1_pd(C1), x);
    r = _mm256_fnmadd_pd(n, _mm256_set1_pd(C2), r);
    let rr = _mm256_mul_pd(r, r);
    // px = r * P(r^2); e^r = 1 + 2 * px / (Q(r^2) - px)
    let mut p = _mm256_set1_pd(P0);
    p = _mm256_fmadd_pd(p, rr, _mm256_set1_pd(P1));
    p = _mm256_fmadd_pd(p, rr, _mm256_set1_pd(P2));
    let px = _mm256_mul_pd(r, p);
    let mut q = _mm256_set1_pd(Q0);
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(Q1));
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(Q2));
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(Q3));
    let frac = _mm256_div_pd(px, _mm256_sub_pd(q, px));
    let er = _mm256_fmadd_pd(_mm256_set1_pd(2.0), frac, _mm256_set1_pd(1.0));
    // 2^n through the exponent field (n is integral and |n| < 1075 for
    // non-underflowing inputs, so the i32 round-trip is exact).
    let ni = _mm256_cvtepi32_epi64(_mm256_cvtpd_epi32(n));
    let pow2 = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
        ni,
        _mm256_set1_epi64x(1023),
    )));
    let res = _mm256_mul_pd(er, pow2);
    // Flush underflow-range inputs (where the 2^n bit trick is out of
    // range anyway) to zero.
    let keep = _mm256_cmp_pd::<_CMP_GT_OQ>(x, _mm256_set1_pd(UNDERFLOW));
    _mm256_and_pd(res, keep)
}

/// Test/bench hook for the vector `exp`: evaluates the AVX2 polynomial
/// on each element when the features exist, otherwise libm. Exposed so
/// the property suite can pin the polynomial against libm directly.
pub(crate) fn vexp_slice(xs: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: feature-detected above.
        unsafe { vexp_slice_avx2(xs) };
        return;
    }
    for v in xs.iter_mut() {
        *v = v.exp();
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn vexp_slice_avx2(xs: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = xs.len();
    let ptr = xs.as_mut_ptr();
    let mut i = 0usize;
    while i + LANES <= n {
        _mm256_storeu_pd(ptr.add(i), exp256(_mm256_loadu_pd(ptr.add(i))));
        i += LANES;
    }
    while i < n {
        let mut last = [*ptr.add(i); LANES];
        _mm256_storeu_pd(last.as_mut_ptr(), exp256(_mm256_loadu_pd(last.as_ptr())));
        *ptr.add(i) = last[0];
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Column-lane helpers — bit-exact class (elementwise, no FMA)
// ---------------------------------------------------------------------------

/// `acc[c] += s * z[c]` across a column tile — the GEMM step of the
/// blocked multi-column TRSM in `predict_into`.
///
/// **Bit-exact class**: elementwise with separate multiply and add —
/// both arms produce identical bits.
#[inline]
pub(crate) fn axpy(acc: &mut [f64], s: f64, z: &[f64]) {
    debug_assert_eq!(acc.len(), z.len());
    #[cfg(target_arch = "x86_64")]
    if acc.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { axpy_avx2(acc, s, z) };
        return;
    }
    for (a, v) in acc.iter_mut().zip(z) {
        *a += s * v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(acc: &mut [f64], s: f64, z: &[f64]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let (pa, pz) = (acc.as_mut_ptr(), z.as_ptr());
    let sv = _mm256_set1_pd(s);
    let mut i = 0usize;
    while i + LANES <= n {
        let a = _mm256_loadu_pd(pa.add(i));
        let v = _mm256_loadu_pd(pz.add(i));
        _mm256_storeu_pd(pa.add(i), _mm256_add_pd(a, _mm256_mul_pd(sv, v)));
        i += LANES;
    }
    for (a, v) in acc[i..].iter_mut().zip(&z[i..]) {
        *a += s * v;
    }
}

/// `acc[c] -= s * z[c]` across a column tile — the elimination step of
/// the low-rank multi-column forward solve.
///
/// **Bit-exact class**: elementwise with separate multiply and
/// subtract — both arms produce identical bits.
#[inline]
pub(crate) fn axpy_sub(acc: &mut [f64], s: f64, z: &[f64]) {
    debug_assert_eq!(acc.len(), z.len());
    #[cfg(target_arch = "x86_64")]
    if acc.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { axpy_sub_avx2(acc, s, z) };
        return;
    }
    for (a, v) in acc.iter_mut().zip(z) {
        *a -= s * v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_sub_avx2(acc: &mut [f64], s: f64, z: &[f64]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let (pa, pz) = (acc.as_mut_ptr(), z.as_ptr());
    let sv = _mm256_set1_pd(s);
    let mut i = 0usize;
    while i + LANES <= n {
        let a = _mm256_loadu_pd(pa.add(i));
        let v = _mm256_loadu_pd(pz.add(i));
        _mm256_storeu_pd(pa.add(i), _mm256_sub_pd(a, _mm256_mul_pd(sv, v)));
        i += LANES;
    }
    for (a, v) in acc[i..].iter_mut().zip(&z[i..]) {
        *a -= s * v;
    }
}

/// `row[c] = (row[c] - a[c]) / diag` across a column tile — the
/// per-pivot step of the blocked multi-column TRSM.
///
/// **Bit-exact class**: elementwise subtract and divide.
#[inline]
pub(crate) fn sub_div(row: &mut [f64], a: &[f64], diag: f64) {
    debug_assert_eq!(row.len(), a.len());
    #[cfg(target_arch = "x86_64")]
    if row.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { sub_div_avx2(row, a, diag) };
        return;
    }
    for (r, v) in row.iter_mut().zip(a) {
        *r = (*r - v) / diag;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sub_div_avx2(row: &mut [f64], a: &[f64], diag: f64) {
    use std::arch::x86_64::*;
    let n = row.len();
    let (pr, pa) = (row.as_mut_ptr(), a.as_ptr());
    let dv = _mm256_set1_pd(diag);
    let mut i = 0usize;
    while i + LANES <= n {
        let r = _mm256_loadu_pd(pr.add(i));
        let v = _mm256_loadu_pd(pa.add(i));
        _mm256_storeu_pd(pr.add(i), _mm256_div_pd(_mm256_sub_pd(r, v), dv));
        i += LANES;
    }
    for (r, v) in row[i..].iter_mut().zip(&a[i..]) {
        *r = (*r - v) / diag;
    }
}

/// `acc[c] += z[c] * z[c]` across a column tile — the |z|² fold of the
/// posterior-variance path.
///
/// **Bit-exact class**: elementwise with separate multiply and add.
#[inline]
pub(crate) fn sq_accum(acc: &mut [f64], z: &[f64]) {
    debug_assert_eq!(acc.len(), z.len());
    #[cfg(target_arch = "x86_64")]
    if acc.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { sq_accum_avx2(acc, z) };
        return;
    }
    for (a, v) in acc.iter_mut().zip(z) {
        *a += v * v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sq_accum_avx2(acc: &mut [f64], z: &[f64]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let (pa, pz) = (acc.as_mut_ptr(), z.as_ptr());
    let mut i = 0usize;
    while i + LANES <= n {
        let a = _mm256_loadu_pd(pa.add(i));
        let v = _mm256_loadu_pd(pz.add(i));
        _mm256_storeu_pd(pa.add(i), _mm256_add_pd(a, _mm256_mul_pd(v, v)));
        i += LANES;
    }
    for (a, v) in acc[i..].iter_mut().zip(&z[i..]) {
        *a += v * v;
    }
}

/// `row[c] /= diag` across a column tile — the pivot-scale step of the
/// low-rank multi-column forward solve.
///
/// **Bit-exact class**: elementwise divide.
#[inline]
pub(crate) fn scale_div(row: &mut [f64], diag: f64) {
    #[cfg(target_arch = "x86_64")]
    if row.len() >= LANES && simd_active() {
        // SAFETY: simd_active() implies AVX2 was detected.
        unsafe { scale_div_avx2(row, diag) };
        return;
    }
    for r in row.iter_mut() {
        *r /= diag;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_div_avx2(row: &mut [f64], diag: f64) {
    use std::arch::x86_64::*;
    let n = row.len();
    let pr = row.as_mut_ptr();
    let dv = _mm256_set1_pd(diag);
    let mut i = 0usize;
    while i + LANES <= n {
        _mm256_storeu_pd(pr.add(i), _mm256_div_pd(_mm256_loadu_pd(pr.add(i)), dv));
        i += LANES;
    }
    for r in row[i..].iter_mut() {
        *r /= diag;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::property;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn lane_padding_rounds_up() {
        assert_eq!(lane_padded(0), 0);
        assert_eq!(lane_padded(1), 4);
        assert_eq!(lane_padded(4), 4);
        assert_eq!(lane_padded(9), 12);
    }

    // NOTE: `set_simd` itself is exercised in `tests/simd_parity.rs`,
    // which serializes every global-toggle test behind one lock. The
    // unit tests here compare the `_scalar`/`_avx2` twins directly and
    // never touch the process-global dispatch mode, so they can run
    // concurrently with the rest of the lib test binary.

    #[test]
    fn dot_simd_matches_scalar_within_rtol() {
        if !simd_available() {
            return;
        }
        property("dot simd-vs-scalar", 200, |g| {
            // Lengths past both the 8-wide unroll and the 4-lane step,
            // including remainders.
            let n = g.usize_in(0, 300);
            let a = g.vec_f64(n, -3.0, 3.0);
            let b = g.vec_f64(n, -3.0, 3.0);
            let want = dot_scalar(&a, &b);
            // SAFETY: feature-detected above.
            let got = unsafe { dot_avx2(&a, &b) };
            prop_assert!(
                rel(want, got) <= SIMD_PARITY_RTOL,
                "n={n}: scalar {want} vs simd {got}"
            );
            Ok(())
        });
    }

    #[test]
    fn sqdist_row_simd_is_bit_exact() {
        if !simd_available() {
            return;
        }
        property("sqdist_row bit parity", 200, |g| {
            let d = g.usize_in(1, 9);
            let m = g.usize_in(1, 70);
            let p = g.vec_f64(d, -2.0, 2.0);
            let rows = g.vec_f64(m * d, -2.0, 2.0);
            let mut want = vec![0.0; m];
            let mut got = vec![0.0; m];
            sqdist_row_scalar(&p, &rows, d, &mut want);
            // SAFETY: feature-detected above.
            unsafe { sqdist_row_avx2(&p, &rows, d, &mut got) };
            for j in 0..m {
                prop_assert!(
                    want[j].to_bits() == got[j].to_bits(),
                    "m={m} d={d} j={j}: {} vs {}",
                    want[j],
                    got[j]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn vector_exp_matches_libm_within_ulps() {
        if !simd_available() {
            return;
        }
        property("vexp vs libm", 200, |g| {
            let n = g.usize_in(1, 40);
            // Covariance domain (≤ 0) through the underflow edge.
            let mut xs = g.vec_f64(n, -760.0, 0.0);
            xs.push(0.0);
            xs.push(-708.5);
            let want: Vec<f64> = xs.iter().map(|v| v.exp()).collect();
            vexp_slice(&mut xs);
            for (i, (w, got)) in want.iter().zip(&xs).enumerate() {
                // ~2 ulp relative, and exact zero flush at underflow.
                prop_assert!(
                    rel(*w, *got) <= 1e-14 || (*w < 1e-300 && *got == 0.0),
                    "exp[{i}]: libm {w} vs vector {got}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn matern_map_simd_matches_scalar_within_rtol() {
        if !simd_available() {
            return;
        }
        property("matern row map simd-vs-scalar", 200, |g| {
            let n = g.usize_in(1, 200);
            let ls = g.f64_in(0.05, 3.0);
            let var = g.f64_in(0.1, 4.0);
            let d2 = g.vec_f64(n, 0.0, 50.0);
            let mut want = d2.clone();
            let mut got = d2;
            matern52_map_scalar(ls, var, &mut want);
            // SAFETY: feature-detected above.
            unsafe { matern52_map_avx2(ls, var, &mut got) };
            for j in 0..n {
                prop_assert!(
                    rel(want[j], got[j]) <= SIMD_PARITY_RTOL,
                    "n={n} j={j}: {} vs {}",
                    want[j],
                    got[j]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn column_lane_helpers_are_bit_exact() {
        if !simd_available() {
            return;
        }
        property("column-lane bit parity", 200, |g| {
            let n = g.usize_in(1, 70);
            let s = g.f64_in(-2.0, 2.0);
            let z = g.vec_f64(n, -2.0, 2.0);
            let base = g.vec_f64(n, -2.0, 2.0);
            let diag = g.f64_in(0.3, 2.0);

            let check = |name: &str, a: &[f64], b: &[f64]| -> Result<(), String> {
                for j in 0..a.len() {
                    prop_assert!(
                        a[j].to_bits() == b[j].to_bits(),
                        "{name} j={j}: {} vs {}",
                        a[j],
                        b[j]
                    );
                }
                Ok(())
            };

            let (mut sc, mut vx) = (base.clone(), base.clone());
            for (acc, v) in sc.iter_mut().zip(&z) {
                *acc += s * v;
            }
            // SAFETY: feature-detected above.
            unsafe { axpy_avx2(&mut vx, s, &z) };
            check("axpy", &sc, &vx)?;

            let (mut sc, mut vx) = (base.clone(), base.clone());
            for (acc, v) in sc.iter_mut().zip(&z) {
                *acc -= s * v;
            }
            // SAFETY: feature-detected above.
            unsafe { axpy_sub_avx2(&mut vx, s, &z) };
            check("axpy_sub", &sc, &vx)?;

            let (mut sc, mut vx) = (base.clone(), base.clone());
            for (r, v) in sc.iter_mut().zip(&z) {
                *r = (*r - v) / diag;
            }
            // SAFETY: feature-detected above.
            unsafe { sub_div_avx2(&mut vx, &z, diag) };
            check("sub_div", &sc, &vx)?;

            let (mut sc, mut vx) = (base.clone(), base.clone());
            for (acc, v) in sc.iter_mut().zip(&z) {
                *acc += v * v;
            }
            // SAFETY: feature-detected above.
            unsafe { sq_accum_avx2(&mut vx, &z) };
            check("sq_accum", &sc, &vx)?;

            let (mut sc, mut vx) = (base.clone(), base);
            for r in sc.iter_mut() {
                *r /= diag;
            }
            // SAFETY: feature-detected above.
            unsafe { scale_div_avx2(&mut vx, diag) };
            check("scale_div", &sc, &vx)?;
            Ok(())
        });
    }
}
