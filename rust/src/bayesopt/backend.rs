//! The GP backend abstraction: the same decision interface served either
//! by the native f64 implementation or by the AOT-compiled XLA artifacts
//! (the deployed path). The search loop is backend-agnostic; integration
//! tests assert both backends propose the same configurations.

use super::chol::{FactorCache, FactorCacheStats, FitPlan, ObsDelta};
use super::gp::{expected_improvement, matern52_from_d2, matern52_gram_from_d2, NativeGp};
use super::lowrank::{LowRankGp, DEFAULT_MAX_INDUCING};
use crate::runtime::{GpExecutor, XlaRuntime};
use anyhow::Result;

/// Candidate count above which [`NativeBackend::decide`] switches from
/// the exact posterior to the Nyström low-rank path (policy
/// [`LowRankPolicy::Auto`]). Below this the exact O(n²)-per-candidate
/// scoring is cheap enough that the low-rank machinery only adds
/// overhead; the paper's 69-config scout space stays far under it.
pub const LOWRANK_CANDIDATE_THRESHOLD: usize = 512;

/// Observation count at or below which the exact path is always used,
/// even over a large candidate set. Equal to the default inducing cap on
/// purpose: with `n <= DEFAULT_MAX_INDUCING` farthest-point sampling
/// would select every observation as an inducing point — exact math
/// through a costlier scratch fit, bypassing the incremental factor
/// cache for no approximation benefit. The low-rank path engages only
/// where it genuinely approximates (`u < n`).
pub const LOWRANK_MIN_OBS: usize = DEFAULT_MAX_INDUCING;

/// Tile width of the chunked batched acquisition: `decide` streams
/// candidates through `predict_batch` in fixed-size tiles so the
/// intermediate cross-kernel block stays `n x 1024` instead of `n x m`
/// for a generated 5k-config catalog. Per-column arithmetic is
/// independent of the tiling, so results are bit-identical to one
/// m-wide call.
pub const DECIDE_TILE: usize = 1024;

/// How [`NativeBackend`] chooses between the exact and the Nyström
/// low-rank posterior when scoring candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LowRankPolicy {
    /// Low-rank when `m > LOWRANK_CANDIDATE_THRESHOLD` and
    /// `n > LOWRANK_MIN_OBS`; exact otherwise.
    #[default]
    Auto,
    /// Always exact (the scratch baseline for benches and parity tests).
    Off,
    /// Always low-rank with the given inducing cap (parity tests use
    /// `max_inducing >= n` to hit the exact-equality special case).
    Force { max_inducing: usize },
}

/// Which `decide` paths a [`NativeBackend`] has taken — the observable
/// the `bench_large_space --smoke` CI step asserts on.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecideStats {
    /// Decisions served by the exact (Cholesky-factor) posterior.
    pub exact: u64,
    /// Decisions served by the Nyström low-rank posterior.
    pub lowrank: u64,
    /// Low-rank fits that lost positive definiteness and fell back to
    /// the exact path.
    pub lowrank_fallbacks: u64,
}

/// Posterior + acquisition over all candidates for one search iteration.
#[derive(Debug, Clone)]
pub struct Decision {
    pub ei: Vec<f64>,
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// One GP evaluation service. `x`/`xc` are row-major with `d` columns.
pub trait GpBackend {
    /// Fit on (x, y) and score all `m` candidates; `cmask[i] = false`
    /// forces `ei[i] = 0` (already tried / outside the current phase).
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision>;

    /// Negative log marginal likelihood per hyperparameter triple.
    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>>;

    /// Maximum observation count this backend can condition on. The
    /// search loop windows its history to this (the AOT artifacts have a
    /// frozen capacity; native is unbounded).
    fn max_obs(&self) -> usize {
        usize::MAX
    }

    fn name(&self) -> &'static str;
}

/// Creates one independent GP backend per evaluation worker. The
/// parallel experiment engine calls the factory from inside each scoped
/// worker thread, so the factory must be shareable (`Send + Sync`) but
/// the backends it produces never cross a thread boundary and need no
/// `Send` bound of their own (the PJRT-backed XLA backend is not
/// thread-safe). Construction is fallible (the XLA backend loads and
/// compiles artifacts); workers propagate the error instead of panicking.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn GpBackend>> + Send + Sync>;

/// Pure-rust backend (no artifacts needed).
///
/// Carries two caches across BO iterations: the hyperparameter-
/// independent pairwise-distance matrix ([`Self::update_d2`]) and one
/// Cholesky [`FactorCache`] slot per hyperparameter-grid point, updated
/// by rank-1 append/slide instead of refactorized from scratch — the
/// O(H·n³) → O(H·n²) hot-path win (see [`super::chol`]).
///
/// Candidate scoring in [`GpBackend::decide`] is two-tier: small spaces
/// go through the exact posterior in [`DECIDE_TILE`]-wide chunks, while
/// generated-catalog-scale spaces (see [`LowRankPolicy`] and
/// [`LOWRANK_CANDIDATE_THRESHOLD`]) are served by the Nyström low-rank
/// posterior of [`super::lowrank`], whose per-candidate cost is
/// independent of the observation count. `nll_grid` (observation-only
/// work) always stays on the exact incremental path.
#[derive(Default)]
pub struct NativeBackend {
    gp: NativeGp,
    /// Pairwise-distance cache shared across the hyperparameter grid
    /// (hyperparameter-independent) *and* across BO iterations — see
    /// [`Self::update_d2`].
    d2: Vec<f64>,
    /// Swap buffer for the grow/slide rebuild of `d2` (reused across
    /// iterations so the steady state allocates nothing).
    d2_swap: Vec<f64>,
    cache_x: Vec<f64>,
    cache_n: usize,
    cache_d: usize,
    /// Per-hyperparameter Cholesky factors kept across iterations.
    factors: FactorCache,
    /// When false every fit refactorizes cold — the scratch baseline the
    /// benches and the incremental-vs-scratch property tests compare
    /// against.
    incremental_off: bool,
    row_scratch: Vec<f64>,
    kern_scratch: Vec<f64>,
    /// The large-space candidate-scoring posterior and its policy.
    lowrank: LowRankGp,
    lowrank_policy: LowRankPolicy,
    decide_stats: DecideStats,
    /// Per-tile prediction buffers of the chunked exact path.
    mu_tile: Vec<f64>,
    var_tile: Vec<f64>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable/disable the incremental factor path (on by default).
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental_off = !on;
    }

    /// Select how `decide` chooses between the exact and the low-rank
    /// candidate-scoring path (default [`LowRankPolicy::Auto`]).
    pub fn set_lowrank_policy(&mut self, policy: LowRankPolicy) {
        self.lowrank_policy = policy;
    }

    /// Counters of the factorization paths taken so far.
    pub fn factor_stats(&self) -> FactorCacheStats {
        self.factors.stats()
    }

    /// Counters of the decide paths taken so far.
    pub fn decide_stats(&self) -> DecideStats {
        self.decide_stats
    }

    /// Inducing cap to use for this decision, or None for the exact path.
    fn lowrank_limit(&self, n: usize, m: usize) -> Option<usize> {
        match self.lowrank_policy {
            LowRankPolicy::Off => None,
            LowRankPolicy::Force { max_inducing } => {
                (n > 0).then_some(max_inducing.max(1))
            }
            LowRankPolicy::Auto => (m > LOWRANK_CANDIDATE_THRESHOLD
                && n > LOWRANK_MIN_OBS)
                .then_some(DEFAULT_MAX_INDUCING),
        }
    }

    /// Ensure `self.d2` holds the pairwise squared distances of `x`, and
    /// report how the observation set changed.
    ///
    /// The search loop appends exactly one observation per BO iteration
    /// (and slides its window by one once a capacity-limited backend
    /// saturates), so instead of recomputing all n² distances on every
    /// `nll_grid`/`decide` call the cache grows or shifts by one
    /// row+column. New entries use the same per-pair arithmetic as
    /// [`pairwise_sqdist`](super::gp::pairwise_sqdist), keeping every
    /// cached value bit-identical to a fresh computation. The returned
    /// [`ObsDelta`] drives the [`FactorCache`] plans.
    fn update_d2(&mut self, x: &[f64], n: usize, d: usize) -> ObsDelta {
        debug_assert_eq!(x.len(), n * d);
        let (pn, pd) = (self.cache_n, self.cache_d);
        let appended_one = pd == d && n == pn + 1 && x[..pn * d] == self.cache_x[..];
        let slid_one =
            pd == d && n == pn && n > 0 && x[..(n - 1) * d] == self.cache_x[d..];
        if pd == d && pn == n && self.cache_x.as_slice() == x {
            return ObsDelta::Unchanged; // exact hit (e.g. `decide` right after `nll_grid`)
        } else if appended_one || slid_one {
            let old = n - 1; // rows of the previous matrix that survive
            // Build into the swap buffer (reads come from the old d2),
            // keeping the steady-state iteration allocation-free.
            let mut d2 = std::mem::take(&mut self.d2_swap);
            d2.clear();
            d2.resize(n * n, 0.0);
            if appended_one {
                for i in 0..old {
                    d2[i * n..i * n + old].copy_from_slice(&self.d2[i * pn..i * pn + old]);
                }
            } else {
                for i in 0..old {
                    for j in 0..old {
                        d2[i * n + j] = self.d2[(i + 1) * n + (j + 1)];
                    }
                }
            }
            let i = n - 1;
            for j in 0..i {
                let mut s = 0.0;
                for k in 0..d {
                    let diff = x[i * d + k] - x[j * d + k];
                    s += diff * diff;
                }
                d2[i * n + j] = s;
                d2[j * n + i] = s;
            }
            std::mem::swap(&mut self.d2, &mut d2);
            self.d2_swap = d2;
        } else {
            super::gp::pairwise_sqdist(x, n, d, &mut self.d2);
        }
        let delta = if appended_one {
            ObsDelta::Appended
        } else if slid_one {
            ObsDelta::Slid
        } else {
            ObsDelta::Replaced
        };
        self.cache_x.clear();
        self.cache_x.extend_from_slice(x);
        self.cache_n = n;
        self.cache_d = d;
        delta
    }

    /// Bring the [`FactorCache`] slot for `hyp` up to date with the
    /// current `n` observations (distance matrix already refreshed by
    /// [`Self::update_d2`]). `row_key`/`gram_key` memoize the (ls, var)
    /// of `row_scratch`/`kern_scratch` across the grid — the 4 noise
    /// levels per lengthscale share one cross-row (extend path) or one
    /// Gram build (cold path). Returns the slot index, or None when the
    /// Gram is not SPD even from a cold refactorization.
    fn ensure_factor(
        &mut self,
        hyp: [f64; 3],
        n: usize,
        row_key: &mut (f64, f64),
        gram_key: &mut (f64, f64),
    ) -> Option<usize> {
        let (idx, mut plan) = self.factors.plan(hyp, n);
        if self.incremental_off && plan != FitPlan::Cold {
            plan = FitPlan::Cold;
        }
        let key = (hyp[0], hyp[1]);
        let extended = match plan {
            FitPlan::Reuse => {
                self.factors.note_reuse();
                return Some(idx);
            }
            FitPlan::Extend | FitPlan::Slide => {
                if *row_key != key {
                    // Cross-kernel of the newest observation against the
                    // current first n-1 rows: the last d2 row.
                    let last = n - 1;
                    self.row_scratch.clear();
                    for j in 0..last {
                        self.row_scratch
                            .push(matern52_from_d2(self.d2[last * n + j], hyp[0], hyp[1]));
                    }
                    *row_key = key;
                }
                self.factors.extend(idx, &self.row_scratch, plan == FitPlan::Slide)
            }
            FitPlan::Cold => false,
        };
        if !extended {
            if *gram_key != key {
                matern52_gram_from_d2(&self.d2, n, hyp[0], hyp[1], &mut self.kern_scratch);
                *gram_key = key;
            }
            if !self.factors.cold(idx, &self.kern_scratch, n) {
                return None;
            }
        }
        Some(idx)
    }
}

impl GpBackend for NativeBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);

        // Large-space path: Nyström low-rank posterior, per-candidate
        // cost independent of n (see LOWRANK_CANDIDATE_THRESHOLD /
        // LowRankPolicy). The factor cache is untouched — nll_grid keeps
        // maintaining it, and its own update_d2 call still sees the
        // append/slide deltas of the search loop.
        if let Some(max_inducing) = self.lowrank_limit(n, m) {
            if self.lowrank.fit(x, y, n, d, hyp, max_inducing) {
                self.decide_stats.lowrank += 1;
                let mut mu = Vec::with_capacity(m);
                let mut var = Vec::with_capacity(m);
                self.lowrank.predict_batch(xc, m, &mut mu, &mut var);
                let ei = (0..m)
                    .map(|i| {
                        if cmask[i] { expected_improvement(mu[i], var[i], best) } else { 0.0 }
                    })
                    .collect();
                return Ok(Decision { ei, mu, var });
            }
            // Degenerate inducing Gram: fall through to the exact path.
            self.decide_stats.lowrank_fallbacks += 1;
        }

        let delta = self.update_d2(x, n, d);
        self.factors.note_delta(delta);
        let (mut row_key, mut gram_key) = ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
        let idx = self
            .ensure_factor(hyp, n, &mut row_key, &mut gram_key)
            .ok_or_else(|| anyhow::anyhow!("gram matrix not SPD"))?;
        self.gp.fit_from_factor(x, y, n, d, self.factors.factor(idx), hyp);
        self.decide_stats.exact += 1;
        let mut mu = Vec::with_capacity(m);
        let mut var = Vec::with_capacity(m);
        // Batched solves over the candidate columns, streamed in
        // DECIDE_TILE-wide chunks: the n x tile cross-kernel block stays
        // a fixed size however large the space is, and per-column
        // arithmetic is identical to one m-wide call. No candidate mask
        // is passed: the Decision contract exposes mu/var for *every*
        // candidate (the XLA-parity tests and the search's exploration
        // fallback read them) — only the EI respects `cmask`.
        for start in (0..m).step_by(DECIDE_TILE) {
            let w = DECIDE_TILE.min(m - start);
            self.gp.predict_batch(
                &xc[start * d..(start + w) * d],
                w,
                None,
                &mut self.mu_tile,
                &mut self.var_tile,
            );
            mu.extend_from_slice(&self.mu_tile);
            var.extend_from_slice(&self.var_tile);
        }
        let ei = (0..m)
            .map(|i| if cmask[i] { expected_improvement(mu[i], var[i], best) } else { 0.0 })
            .collect();
        Ok(Decision { ei, mu, var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        // Reuse across the grid and across iterations (§Perf): the
        // distance matrix is hyperparameter-independent (cached across
        // BO iterations, see update_d2); each grid point keeps its
        // Cholesky factor alive across iterations and rank-1 extends it
        // (O(n²)) instead of refactorizing (O(n³)); and on the cold path
        // grid entries sharing (lengthscale, variance) — the 4 noise
        // levels per lengthscale — reuse one cross-row / Gram build.
        let delta = self.update_d2(x, n, d);
        self.factors.note_delta(delta);
        let mut out = vec![f64::INFINITY; grid.len()];
        let mut order: Vec<usize> = (0..grid.len()).collect();
        order.sort_by(|&a, &b| {
            (grid[a][0], grid[a][1]).partial_cmp(&(grid[b][0], grid[b][1])).unwrap()
        });
        let (mut row_key, mut gram_key) = ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
        for &gi in &order {
            if let Some(idx) = self.ensure_factor(grid[gi], n, &mut row_key, &mut gram_key) {
                out[gi] = self.factors.nll(idx, y);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The deployed backend: AOT artifacts through PJRT.
pub struct XlaBackend {
    exec: GpExecutor,
    // keep the runtime alive as long as the executables
    _rt: XlaRuntime,
}

impl XlaBackend {
    /// Load from the default artifact directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let rt = XlaRuntime::new(XlaRuntime::default_artifact_dir())?;
        let exec = GpExecutor::new(&rt)?;
        Ok(Self { exec, _rt: rt })
    }

    pub fn call_count(&self) -> u64 {
        self.exec.call_count()
    }
}

impl GpBackend for XlaBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        let cm: Vec<f64> = cmask.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let out = self.exec.gp_ei(x, y, n, xc, &cm, m, hyp)?;
        Ok(Decision { ei: out.ei, mu: out.mu, var: out.var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        self.exec.gp_nll(x, y, n, grid)
    }

    fn max_obs(&self) -> usize {
        crate::runtime::AOT_N_OBS
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// The backend families selectable by name. Both [`backend_by_name`]
/// and [`backend_factory_by_name`] parse through this, so an unknown
/// name fails identically on both paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl BackendKind {
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            other => anyhow::bail!("unknown backend {other:?} (expected native|xla)"),
        }
    }
}

/// Backend selection by name (CLI `--backend native|xla`).
pub fn backend_by_name(name: &str) -> Result<Box<dyn GpBackend>> {
    match BackendKind::parse(name)? {
        BackendKind::Native => Ok(Box::new(NativeBackend::new())),
        BackendKind::Xla => Ok(Box::new(XlaBackend::from_default_artifacts()?)),
    }
}

/// Backend *factory* selection by name — the parallel experiment engine
/// instantiates one backend per worker thread from this. Name validation
/// is shared with [`backend_by_name`] through [`BackendKind::parse`];
/// the xla arm additionally probes the artifacts so an obviously bad
/// configuration fails at startup, while the expensive PJRT client
/// creation + artifact compilation happens once per worker, inside the
/// worker.
pub fn backend_factory_by_name(name: &str) -> Result<BackendFactory> {
    match BackendKind::parse(name)? {
        BackendKind::Native => {
            Ok(Box::new(|| -> Result<Box<dyn GpBackend>> { Ok(Box::new(NativeBackend::new())) }))
        }
        BackendKind::Xla => {
            anyhow::ensure!(
                XlaRuntime::artifacts_available(),
                "XLA backend unavailable: AOT artifacts not found (run `make artifacts`; \
                 the binary must also be built with the `xla-pjrt` feature)"
            );
            Ok(Box::new(|| -> Result<Box<dyn GpBackend>> {
                Ok(Box::new(XlaBackend::from_default_artifacts()?))
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_masks_candidates() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9];
        let y = [1.0, 2.0];
        let xc = [0.1, 0.2, 0.5, 0.5];
        let d = b
            .decide(&x, &y, 2, 2, &xc, &[false, true], 2, [0.5, 1.0, 1e-4])
            .unwrap();
        assert_eq!(d.ei[0], 0.0);
        assert!(d.mu[0].is_finite());
    }

    #[test]
    fn native_nll_grid_len() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9, 0.4, 0.6];
        let y = [1.0, 2.0, 1.5];
        let grid = [[0.5, 1.0, 1e-3], [1.0, 1.0, 1e-2]];
        let nll = b.nll_grid(&x, &y, 3, 2, &grid).unwrap();
        assert_eq!(nll.len(), 2);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        assert!(backend_by_name("tpu").is_err());
    }

    #[test]
    fn unknown_backend_fails_identically_on_both_paths() {
        let direct = backend_by_name("tpu").unwrap_err().to_string();
        let factory = backend_factory_by_name("tpu").unwrap_err().to_string();
        assert_eq!(direct, factory, "name validation diverged between the two paths");
        assert!(direct.contains("expected native|xla"));
    }

    #[test]
    fn default_impls_are_usable() {
        assert_eq!(NativeBackend::default().name(), "native");
        assert_eq!(NativeGp::default().n_obs(), 0);
    }

    #[test]
    fn incremental_grid_refit_matches_scratch() {
        // Drive a growth-then-slide sequence through two backends — one
        // incremental, one forced to cold-refit every call — and pin the
        // nll grid and decisions to each other within 1e-9, all through
        // the shared testkit parity harness (the same entry point that
        // pins low-rank-vs-exact in tests/prop_lowrank.rs).
        use crate::testkit::{assert_backend_parity, ParityScript};
        let d = 3;
        let total = 14usize;
        let window = 9usize;
        let rows: Vec<f64> =
            (0..total * d).map(|i| ((i * 23 + 5) % 73) as f64 / 73.0).collect();
        let ys: Vec<f64> = (0..total).map(|i| (i as f64 * 0.37).sin()).collect();
        let script =
            ParityScript::new(rows, ys, d).growth(window).slides(window, total - window);
        let grid = crate::bayesopt::hyperparameter_grid();
        let m = 6;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect();

        let mut inc = NativeBackend::new();
        let mut scr = NativeBackend::new();
        scr.set_incremental(false);
        let report = assert_backend_parity(&mut inc, &mut scr, &script, &xc, m, &grid, 1e-9);
        assert_eq!(report.steps, total, "growth + slide steps");
        let si = inc.factor_stats();
        assert!(si.appends > 0, "append path never taken: {si:?}");
        assert!(si.slides > 0, "slide path never taken: {si:?}");
        assert!(si.reuses > 0, "decide after nll_grid should reuse: {si:?}");
        let ss = scr.factor_stats();
        assert_eq!(ss.appends + ss.slides, 0, "scratch backend must stay cold: {ss:?}");
    }

    #[test]
    fn backend_factory_by_name_builds_native() {
        let factory = backend_factory_by_name("native").unwrap();
        assert_eq!(factory().unwrap().name(), "native");
        assert!(backend_factory_by_name("tpu").is_err());
    }

    #[test]
    fn decide_matches_per_row_predict() {
        use crate::bayesopt::gp::NativeGp;
        let n = 6;
        let d = 3;
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let m = 9;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        let cmask: Vec<bool> = (0..m).map(|i| i % 3 != 0).collect();
        let hyp = [0.7, 1.0, 1e-3];

        let mut b = NativeBackend::new();
        let dec = b.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();

        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, hyp));
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        for i in 0..m {
            let (mu, var) = gp.predict(&xc[i * d..(i + 1) * d]);
            assert!((dec.mu[i] - mu).abs() <= 1e-12, "mu[{i}]");
            assert!((dec.var[i] - var).abs() <= 1e-12, "var[{i}]");
            let ei = if cmask[i] { expected_improvement(mu, var, best) } else { 0.0 };
            assert!((dec.ei[i] - ei).abs() <= 1e-12, "ei[{i}]");
        }
    }

    /// Synthetic observation rows + candidate rows for path tests.
    fn synth(n: usize, m: usize, d: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        (x, y, xc)
    }

    #[test]
    fn auto_policy_follows_documented_thresholds() {
        let d = 3;
        let hyp = [0.7, 1.0, 1e-3];
        let engaged = LOWRANK_MIN_OBS + 1; // smallest history the Auto policy approximates
        let mut b = NativeBackend::new();
        // Below the candidate threshold: exact, regardless of n.
        let (x, y, xc) = synth(engaged, 16, d);
        b.decide(&x, &y, engaged, d, &xc, &vec![true; 16], 16, hyp).unwrap();
        assert_eq!(b.decide_stats(), DecideStats { exact: 1, ..Default::default() });
        // Above the candidate threshold with enough observations: lowrank.
        let m = LOWRANK_CANDIDATE_THRESHOLD + 1;
        let (x, y, xc) = synth(engaged, m, d);
        b.decide(&x, &y, engaged, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(b.decide_stats(), DecideStats { exact: 1, lowrank: 1, ..Default::default() });
        // Large space but history within the inducing cap (the low-rank
        // posterior would be exact math at extra cost): exact again.
        let (x, y, xc) = synth(LOWRANK_MIN_OBS, m, d);
        b.decide(&x, &y, LOWRANK_MIN_OBS, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(b.decide_stats(), DecideStats { exact: 2, lowrank: 1, ..Default::default() });
        // Policy Off never takes the low-rank path.
        let mut off = NativeBackend::new();
        off.set_lowrank_policy(LowRankPolicy::Off);
        let (x, y, xc) = synth(engaged, m, d);
        off.decide(&x, &y, engaged, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(off.decide_stats().lowrank, 0);
        assert_eq!(off.decide_stats().exact, 1);
    }

    #[test]
    fn forced_full_inducing_decide_matches_exact() {
        // Force { max_inducing >= n } pins the exact-equality special
        // case (module docs of `lowrank`) at the backend level.
        let d = 3;
        let (n, m) = (12, 20);
        let (x, y, xc) = synth(n, m, d);
        let cmask = vec![true; m];
        let hyp = [0.6, 1.0, 1e-3];
        let mut exact = NativeBackend::new();
        exact.set_lowrank_policy(LowRankPolicy::Off);
        let mut forced = NativeBackend::new();
        forced.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 64 });
        let de = exact.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        let df = forced.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        assert_eq!(forced.decide_stats().lowrank, 1);
        for j in 0..m {
            assert!((de.mu[j] - df.mu[j]).abs() <= 1e-6, "mu[{j}]: {} vs {}", de.mu[j], df.mu[j]);
            assert!((de.var[j] - df.var[j]).abs() <= 1e-6, "var[{j}]");
            // EI amplifies variance error by ~1/(2 sigma); give it headroom.
            assert!((de.ei[j] - df.ei[j]).abs() <= 1e-5, "ei[{j}]");
        }
    }

    #[test]
    fn tiled_decide_matches_per_row_predict_across_tile_boundary() {
        use crate::bayesopt::gp::NativeGp;
        let d = 3;
        let n = 6;
        let m = DECIDE_TILE * 2 + 37; // three tiles, last one ragged
        let (x, y, xc) = synth(n, m, d);
        let cmask = vec![true; m];
        let hyp = [0.7, 1.0, 1e-3];
        let mut b = NativeBackend::new(); // Auto, but n < LOWRANK_MIN_OBS -> exact
        let dec = b.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        assert_eq!(b.decide_stats().exact, 1);
        assert_eq!(dec.mu.len(), m);
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, hyp));
        // Spot-check columns straddling every tile boundary plus the ends.
        for &j in &[0, 1, DECIDE_TILE - 1, DECIDE_TILE, 2 * DECIDE_TILE - 1, 2 * DECIDE_TILE, m - 1]
        {
            let (mu, var) = gp.predict(&xc[j * d..(j + 1) * d]);
            assert!((dec.mu[j] - mu).abs() <= 1e-12, "mu[{j}]");
            assert!((dec.var[j] - var).abs() <= 1e-12, "var[{j}]");
        }
    }

    #[test]
    fn d2_cache_incremental_matches_fresh() {
        let d = 3;
        let rows: Vec<f64> = (0..11 * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let grid = [[0.5, 1.0, 1e-3]];
        let mut b = NativeBackend::new();
        // Growth path: one appended observation per call.
        for n in 1..=10usize {
            let x = &rows[..n * d];
            let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
            b.nll_grid(x, &y, n, d, &grid).unwrap();
            let mut fresh = Vec::new();
            crate::bayesopt::gp::pairwise_sqdist(x, n, d, &mut fresh);
            assert_eq!(b.d2, fresh, "grown cache diverged at n={n}");
        }
        // Sliding-window path: drop the oldest row, append a new one.
        let n = 10;
        let x: Vec<f64> = rows[d..(n + 1) * d].to_vec();
        let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let mut fresh = Vec::new();
        crate::bayesopt::gp::pairwise_sqdist(&x, n, d, &mut fresh);
        assert_eq!(b.d2, fresh, "slid cache diverged");
    }
}
