//! The GP backend abstraction: the same decision interface served either
//! by the native f64 implementation or by the AOT-compiled XLA artifacts
//! (the deployed path). The search loop is backend-agnostic; integration
//! tests assert both backends propose the same configurations.
//!
//! # Deterministic parallelism — on by default
//!
//! [`NativeBackend`] fans its parallel work across the **process-global**
//! worker pool ([`super::pool::global_pool`]): the hyperparameter-grid
//! nll sweep fans its independent [`FactorCache`] slots (or, past the
//! low-rank routing threshold, its (lengthscale, variance) stage groups)
//! across the shared lanes, and a single exact decide fans its
//! [`DECIDE_TILE`] candidate chunks the same way. Every unit of work
//! writes to a fixed, disjoint output slot and no floating-point
//! reduction ever crosses units, so **results are bit-identical for any
//! pool width** — and independent of any other backend concurrently
//! sharing the lanes. `testkit::assert_parallel_parity`, its shared-pool
//! mode, the CI determinism stress test and the randomized script fuzz
//! (`tests/fuzz_parity.rs`) pin nll grids, posteriors, EI and the chosen
//! argmax across `--gp-threads` 1/2/4/8.
//!
//! # Pool lifecycle
//!
//! * **Width**: process-global, chosen once per process (`--gp-threads
//!   N` lands in [`super::pool::configure_global_pool_width`] before the
//!   pool first spawns); unset or `0` resolves to
//!   [`adaptive_gp_threads`] — the machine's `available_parallelism`
//!   capped at [`MAX_ADAPTIVE_GP_THREADS`] (the grid sweep has only 8
//!   fan-out groups, so wider pools cannot help it). The parallel sweep
//!   is therefore **on by default** on multicore hosts.
//!   [`NativeBackend::set_parallelism`] no longer sizes a pool of its
//!   own: it only gates *whether* this backend fans out (`<= 1` pins it
//!   serial — the per-worker default of the experiment engine).
//! * **Attachment**: lazy — the global pool spawns on the process's
//!   first fan-out that clears the serial floor, then serves every
//!   backend and session engine for the process lifetime with reusable
//!   per-lane scratch keyed by backend epoch
//!   ([`super::pool::LaneScratch`]). However many backends `--threads T`
//!   workers instantiate, parked pool threads never exceed the global
//!   width — the old per-backend design's T×G multiplication is gone.
//! * **Serial floor**: grid sweeps over `n <=` [`GP_POOL_MIN_OBS`]
//!   observations stay serial — at that size the per-call handoff
//!   overhead exceeds the O(n²) slot work, so tiny scout-scale runs
//!   never regress; decide fan-outs use the column-scaled equivalent
//!   (`n·m` against a floor-sized tile), since their work grows with
//!   the candidate count (override via
//!   [`NativeBackend::set_pool_min_obs`]).
//!
//! [`DecideStats`] counters make all of it observable: routing
//! (`nll_exact`/`nll_lowrank`), fan-outs (`parallel_nll_sweeps`,
//! `parallel_decide_fanouts`), pool lifecycle (`global_pool_attach`,
//! `pool_thread_count`, `pool_creates`, `pool_reuses`,
//! `serial_floor_bypasses`), inducing refreshes
//! (`fps_full_refreshes`/`fps_incremental_refreshes`) and the low-rank
//! stage split (`lowrank_hyp_stage_builds`/`lowrank_noise_stage_builds`).

use super::chol::{
    nll_multi, CholFactor, FactorCache, FactorCacheStats, FitPlan, ObsDelta, SlotTask,
};
use super::gp::{expected_improvement, matern52_gram_from_d2, predict_into};
use super::lowrank::{
    InducingCache, LowRankGp, LowRankStats, DEFAULT_MAX_INDUCING,
};
use super::pool;
use super::simd;
use crate::runtime::{ExecutorPool, XlaRuntime};
use anyhow::Result;

/// Candidate count above which [`NativeBackend::decide`] switches from
/// the exact posterior to the Nyström low-rank path (policy
/// [`LowRankPolicy::Auto`]). Below this the exact O(n²)-per-candidate
/// scoring is cheap enough that the low-rank machinery only adds
/// overhead; the paper's 69-config scout space stays far under it.
pub const LOWRANK_CANDIDATE_THRESHOLD: usize = 512;

/// Observation count at or below which the exact path is always used,
/// even over a large candidate set. Equal to the default inducing cap on
/// purpose: with `n <= DEFAULT_MAX_INDUCING` farthest-point sampling
/// would select every observation as an inducing point — exact math
/// through a costlier scratch fit, bypassing the incremental factor
/// cache for no approximation benefit. The low-rank path engages only
/// where it genuinely approximates (`u < n`).
pub const LOWRANK_MIN_OBS: usize = DEFAULT_MAX_INDUCING;

/// Observation count above which `nll_grid` switches from the exact
/// incremental factor sweep to the Woodbury low-rank marginal
/// ([`LowRankGp::nll`]; override via
/// [`NativeBackend::set_lowrank_nll_threshold`]). The exact sweep is
/// O(H·n²) per iteration once warm — ideal for the windowed search
/// regime — but its cold refits are O(H·n³) and its distance cache
/// O(n²); past a few thousand observations the DTC marginal
/// (O(H·n·u²), no n×n intermediates) is what keeps hyperparameter
/// selection tractable.
pub const LOWRANK_NLL_OBS_THRESHOLD: usize = 2048;

/// Tile width of the chunked batched acquisition: `decide` streams
/// candidates through [`predict_into`] in fixed-size tiles so the
/// intermediate cross-kernel block stays `n x 1024` instead of `n x m`
/// for a generated 5k-config catalog. Per-column arithmetic is
/// independent of the tiling, so results are bit-identical to one
/// m-wide call — which also makes the tiles safe to fan across worker
/// threads (each tile owns a fixed disjoint output range).
pub const DECIDE_TILE: usize = 1024;

/// Observation count at or below which a grid nll sweep stays serial
/// even with a multi-lane pool configured (the work-size floor of the
/// module docs): a 32-slot sweep at n = 16 is ~32·256 flops of slot
/// work — comfortably below the per-call pool handoff cost — while the
/// floor still admits every window the paper's searches actually reach.
/// `decide`, whose work scales with the candidate count, uses the
/// column-scaled equivalent (`n·m <= GP_POOL_MIN_OBS · DECIDE_TILE`),
/// so a huge catalog fans out even over a short history. Override per
/// backend via [`NativeBackend::set_pool_min_obs`].
pub const GP_POOL_MIN_OBS: usize = 16;

/// Cap on the adaptive `--gp-threads` default: the grid nll sweep fans
/// whole (lengthscale, variance) groups and the selection grid has 8 of
/// them, so lanes beyond 8 can never receive exact-sweep work.
pub const MAX_ADAPTIVE_GP_THREADS: usize = 8;

/// The adaptive GP worker-pool width: `std::thread::available_parallelism`
/// capped at [`MAX_ADAPTIVE_GP_THREADS`] (1 when the host count is
/// unavailable). This is what `--gp-threads 0` — the CLI default — and
/// [`NativeBackend::set_parallelism`]`(0)` resolve to, making the
/// parallel sweep on by default without oversubscribing small hosts.
pub fn adaptive_gp_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_ADAPTIVE_GP_THREADS)
}

/// How [`NativeBackend`] chooses between the exact and the Nyström
/// low-rank posterior when scoring candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LowRankPolicy {
    /// Low-rank when `m > LOWRANK_CANDIDATE_THRESHOLD` and
    /// `n > LOWRANK_MIN_OBS`, or whenever the history has outgrown the
    /// nll threshold (past which the exact factor cache is no longer
    /// maintained — see [`LOWRANK_NLL_OBS_THRESHOLD`]); exact otherwise.
    #[default]
    Auto,
    /// Always exact (the scratch baseline for benches and parity tests).
    Off,
    /// Always low-rank with the given inducing cap (parity tests use
    /// `max_inducing >= n` to hit the exact-equality special case).
    Force { max_inducing: usize },
}

/// Which `decide`/`nll_grid` paths a [`NativeBackend`] has taken — the
/// observable the CI smoke steps assert on.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecideStats {
    /// Decisions served by the exact (Cholesky-factor) posterior.
    pub exact: u64,
    /// Decisions served by the Nyström low-rank posterior.
    pub lowrank: u64,
    /// Low-rank fits that lost positive definiteness and fell back to
    /// the exact path.
    pub lowrank_fallbacks: u64,
    /// `nll_grid` calls served by the exact incremental factor sweep.
    pub nll_exact: u64,
    /// `nll_grid` calls served by the Woodbury low-rank marginal.
    pub nll_lowrank: u64,
    /// nll sweeps that actually ran on the worker pool (gp-threads > 1
    /// and more than one unit of work).
    pub parallel_nll_sweeps: u64,
    /// Decides whose tiles fanned out across the worker pool.
    pub parallel_decide_fanouts: u64,
    /// 1 once this backend has attached to the process-global pool (its
    /// first fan-out that cleared the serial floor), 0 while it has only
    /// run serially — the thread-budget observable per backend.
    pub global_pool_attach: u64,
    /// The global pool width observed at attach time (0 before attach).
    pub pool_thread_count: u64,
    /// Fan-outs by *this* backend that actually spawned the process-
    /// global pool — at most 1, and 0 whenever another backend (or a
    /// session engine) got there first.
    pub pool_creates: u64,
    /// Fan-outs after the first attach, served by the already-running
    /// shared pool — the persistence win.
    pub pool_reuses: u64,
    /// Fan-outs that stayed serial under the work-size floor
    /// ([`GP_POOL_MIN_OBS`]) despite a multi-lane pool being configured.
    pub serial_floor_bypasses: u64,
    /// Full farthest-point inducing re-selections (first sight,
    /// wholesale replace, cap change, or the drift bound).
    pub fps_full_refreshes: u64,
    /// Incremental inducing refreshes (append/slide/unchanged served
    /// from the cached selection).
    pub fps_incremental_refreshes: u64,
    /// Low-rank hyperparameter-stage builds (`Kuu`/`B`/`BBᵀ` work) —
    /// one per (lengthscale, variance) group under the stage split.
    pub lowrank_hyp_stage_builds: u64,
    /// Low-rank noise-stage builds (`Lm`/weights) — one per grid point.
    pub lowrank_noise_stage_builds: u64,
    /// Exact nll sweeps' (lengthscale, variance) groups carrying two or
    /// more noise levels, whose per-slot triangular solves ran as one
    /// interleaved multi-RHS batch ([`nll_multi`]) instead of
    /// sequentially. Bit-identical to the per-slot solves by
    /// construction (each stream replays the scalar accumulation
    /// order); the bench smoke guard asserts this engages.
    pub multi_rhs_noise_solves: u64,
}

impl DecideStats {
    /// Fold a [`LowRankGp`]'s stage counters into the backend totals.
    fn absorb_lowrank(&mut self, s: LowRankStats) {
        self.lowrank_hyp_stage_builds += s.hyp_builds;
        self.lowrank_noise_stage_builds += s.noise_builds;
    }
}

/// Posterior + acquisition over all candidates for one search iteration.
#[derive(Debug, Clone)]
pub struct Decision {
    pub ei: Vec<f64>,
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// The fitted-model half of a [`NativeBackend::decide`], produced by
/// [`NativeBackend::prepare_decide`]: which posterior path the routing
/// chose and (on the exact path) which [`FactorCache`] slot carries the
/// up-to-date Cholesky factor. The session engine runs the fit phase of
/// many sessions serially through this, then fans the pure
/// candidate-scoring phase of *all* of them across one shared worker
/// pool ([`NativeBackend::exact_score_view`] /
/// [`NativeBackend::lowrank_mut`]) — the cross-session batched decide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreparedDecide {
    /// Exact posterior: score through [`predict_into`] against the
    /// borrowed factor + weights of [`NativeBackend::exact_score_view`].
    Exact { slot: usize },
    /// Nyström low-rank posterior: score through
    /// [`LowRankGp::predict_batch`] on [`NativeBackend::lowrank_mut`].
    LowRank,
}

/// One GP evaluation service. `x`/`xc` are row-major with `d` columns.
pub trait GpBackend {
    /// Fit on (x, y) and score all `m` candidates; `cmask[i] = false`
    /// forces `ei[i] = 0` (already tried / outside the current phase).
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision>;

    /// Negative log marginal likelihood per hyperparameter triple.
    /// `grid` is whatever the caller sweeps — usually the full
    /// [`hyperparameter_grid`](super::hyperparameter_grid), but a
    /// warm-started search passes a narrowed subset of its rows
    /// ([`WarmStart::grid_slots`](super::WarmStart)); implementations
    /// must size their output by `grid.len()`, not assume the AOT
    /// 32-slot shape.
    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>>;

    /// Maximum observation count this backend can condition on. The
    /// search loop windows its history to this (the AOT artifacts have a
    /// frozen capacity; native is unbounded).
    fn max_obs(&self) -> usize {
        usize::MAX
    }

    fn name(&self) -> &'static str;
}

/// Creates one independent GP backend per evaluation worker. The
/// parallel experiment engine calls the factory from inside each scoped
/// worker thread, so the factory must be shareable (`Send + Sync`) but
/// the backends it produces never cross a thread boundary and need no
/// `Send` bound of their own (the PJRT-backed XLA backend is not
/// thread-safe). Construction is fallible (the XLA backend loads and
/// compiles artifacts); workers propagate the error instead of panicking.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn GpBackend>> + Send + Sync>;

/// Grouping key of the (lengthscale, variance)-shared kernel builds: the
/// 4 noise levels per lengthscale share one cross-row (extend path) or
/// one Gram build (cold path). Bit keys sort positives in numeric order
/// and, unlike `f64` tuples, totally — no NaN partial-ordering edge.
fn hyp_group_key(hyp: [f64; 3]) -> (u64, u64) {
    (hyp[0].to_bits(), hyp[1].to_bits())
}

/// Grid indices grouped by [`hyp_group_key`], groups in ascending key
/// order — THE grouping of the stage-shared sweeps (the fan-out unit
/// count for pool engagement, the low-rank stage-split groups, and the
/// contract the exact pooled sweep's task sort mirrors on its
/// [`SlotTask`]s). One definition so the engagement unit count can
/// never drift from the groups actually fanned out.
fn group_grid_indices(grid: &[[f64; 3]]) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..grid.len()).collect();
    order.sort_by_key(|&g| hyp_group_key(grid[g]));
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut last_key = None;
    for g in order {
        let key = hyp_group_key(grid[g]);
        if last_key != Some(key) {
            groups.push(Vec::new());
            last_key = Some(key);
        }
        groups.last_mut().expect("group pushed above").push(g);
    }
    groups
}

/// [`group_grid_indices`]'s count-only twin for the per-iteration exact
/// sweep: one flat sort+dedup, no nested group materialization (the
/// exact path only needs the unit count for pool engagement — its
/// fan-out groups the planned [`SlotTask`]s by the same key).
fn distinct_group_count(grid: &[[f64; 3]]) -> usize {
    let mut keys: Vec<(u64, u64)> = grid.iter().map(|&h| hyp_group_key(h)).collect();
    keys.sort_unstable();
    keys.dedup();
    keys.len()
}

/// Bring one planned slot up to date from the shared distance matrix,
/// returning whether its factor is usable (false = Gram not SPD even
/// from a cold refactorization). THE single slot-update body: the
/// serial nll sweep, every lane of the worker pool, and `decide`'s
/// [`NativeBackend::ensure_factor`] all run exactly this code —
/// identical arithmetic in identical order, so the paths cannot drift
/// and the swept grid is bit-identical for any worker count.
/// `row`/`gram` plus their keys memoize the (lengthscale,
/// variance)-shared builds across consecutive tasks of one lane.
#[allow(clippy::too_many_arguments)]
fn update_task(
    task: &mut SlotTask<'_>,
    d2: &[f64],
    n: usize,
    row: &mut Vec<f64>,
    gram: &mut Vec<f64>,
    row_key: &mut (f64, f64),
    gram_key: &mut (f64, f64),
) -> bool {
    let hyp = task.hyp();
    let key = (hyp[0], hyp[1]);
    let extended = match task.plan() {
        FitPlan::Reuse => {
            task.note_reuse();
            return true;
        }
        FitPlan::Extend | FitPlan::Slide => {
            let slide = task.plan() == FitPlan::Slide;
            if *row_key != key {
                // Cross-kernel of the newest observation against the
                // current first n-1 rows: the last d2 row, mapped
                // through the dispatched Matérn kernel (vector exp
                // under SIMD — tolerance class, same as the builders).
                let last = n - 1;
                row.clear();
                row.extend_from_slice(&d2[last * n..last * n + last]);
                simd::matern52_map_from_d2(hyp[0], hyp[1], row);
                *row_key = key;
            }
            task.extend(&row[..], slide)
        }
        FitPlan::Cold => false,
    };
    if !extended {
        if *gram_key != key {
            matern52_gram_from_d2(d2, n, hyp[0], hyp[1], gram);
            *gram_key = key;
        }
        if !task.cold(gram, n) {
            return false;
        }
    }
    true
}

/// Planned [`SlotTask`]s zipped with their output slots, sorted and
/// split into whole (lengthscale, variance) groups ([`hyp_group_key`],
/// mirroring [`group_grid_indices`] on the planned tasks) — the fan-out
/// *and* multi-RHS batching unit of the exact sweep, serial or pooled.
fn group_sweep_tasks<'s, 'f>(
    tasks: &'s mut [SlotTask<'f>],
    nlls: &'s mut [f64],
) -> Vec<Vec<(&'s mut SlotTask<'f>, &'s mut f64)>> {
    let mut items: Vec<(&'s mut SlotTask<'f>, &'s mut f64)> =
        tasks.iter_mut().zip(nlls.iter_mut()).collect();
    items.sort_by_key(|(t, _)| hyp_group_key(t.hyp()));
    let mut groups: Vec<Vec<(&'s mut SlotTask<'f>, &'s mut f64)>> = Vec::new();
    let mut last_key = None;
    for item in items {
        let key = hyp_group_key(item.0.hyp());
        if last_key != Some(key) {
            groups.push(Vec::new());
            last_key = Some(key);
        }
        groups.last_mut().expect("group pushed above").push(item);
    }
    groups
}

/// [`update_task`] over one whole (lengthscale, variance) group, then
/// one batched multi-RHS nll for every usable slot ([`nll_multi`]'s
/// interleaved triangular solves; unusable slots score INFINITY). The
/// group is the natural batching unit: its noise levels share the
/// memoized cross-row / Gram build *and* the factor size, and
/// `nll_multi` is bit-identical to per-slot solves, so this body swept
/// serially or across pool lanes cannot drift from the legacy
/// one-task-at-a-time loop.
#[allow(clippy::too_many_arguments)]
fn sweep_group(
    group: Vec<(&mut SlotTask<'_>, &mut f64)>,
    d2: &[f64],
    y: &[f64],
    n: usize,
    row: &mut Vec<f64>,
    gram: &mut Vec<f64>,
    row_key: &mut (f64, f64),
    gram_key: &mut (f64, f64),
) {
    let mut ready: Vec<(&mut SlotTask<'_>, &mut f64)> = Vec::with_capacity(group.len());
    for (task, out) in group {
        if update_task(task, d2, n, row, gram, row_key, gram_key) {
            ready.push((task, out));
        } else {
            *out = f64::INFINITY;
        }
    }
    if ready.is_empty() {
        return;
    }
    let mut refs: Vec<&mut SlotTask<'_>> =
        ready.iter_mut().map(|(t, _)| &mut **t).collect();
    let vals = nll_multi(&mut refs, y);
    drop(refs);
    for ((_, out), v) in ready.into_iter().zip(vals) {
        *out = v;
    }
}

/// Pure-rust backend (no artifacts needed).
///
/// Carries two caches across BO iterations: the hyperparameter-
/// independent pairwise-distance matrix ([`Self::update_d2`]) and one
/// Cholesky [`FactorCache`] slot per hyperparameter-grid point, updated
/// by rank-1 append/slide instead of refactorized from scratch — the
/// O(H·n³) → O(H·n²) hot-path win (see [`super::chol`], including the
/// packed storage that makes an append a pure push).
///
/// `decide` *borrows* the cached packed factor (no clone into a GP):
/// the weights `alpha = (L Lᵀ)⁻¹ y` are solved against it in place and
/// candidates stream through [`predict_into`] in [`DECIDE_TILE`]-wide
/// chunks — serially, or fanned across the worker pool
/// ([`Self::set_parallelism`]) with bit-identical results.
///
/// Candidate scoring is two-tier: generated-catalog-scale spaces (see
/// [`LowRankPolicy`] and [`LOWRANK_CANDIDATE_THRESHOLD`]) are served by
/// the Nyström low-rank posterior of [`super::lowrank`], whose
/// per-candidate cost is independent of the observation count.
/// `nll_grid` stays on the exact incremental sweep up to
/// [`LOWRANK_NLL_OBS_THRESHOLD`] observations and switches to the
/// Woodbury low-rank marginal above it.
pub struct NativeBackend {
    /// Pairwise-distance cache shared across the hyperparameter grid
    /// (hyperparameter-independent) *and* across BO iterations — see
    /// [`Self::update_d2`].
    d2: Vec<f64>,
    /// Swap buffer for the grow/slide rebuild of `d2` (reused across
    /// iterations so the steady state allocates nothing).
    d2_swap: Vec<f64>,
    cache_x: Vec<f64>,
    cache_n: usize,
    cache_d: usize,
    /// Per-hyperparameter Cholesky factors kept across iterations.
    factors: FactorCache,
    /// When false every fit refactorizes cold — the scratch baseline the
    /// benches and the incremental-vs-scratch property tests compare
    /// against.
    incremental_off: bool,
    row_scratch: Vec<f64>,
    kern_scratch: Vec<f64>,
    /// The large-space candidate-scoring posterior and its policy.
    lowrank: LowRankGp,
    lowrank_policy: LowRankPolicy,
    decide_stats: DecideStats,
    /// Decide's borrowed-factor weights `(L Lᵀ)⁻¹ y` (reused scratch).
    alpha_scratch: Vec<f64>,
    /// Serial-path prediction scratch (each pool worker owns its own).
    ks_scratch: Vec<f64>,
    acc_scratch: Vec<f64>,
    /// Fan-out gate for the grid nll sweep and the decide tiles: `<= 1`
    /// pins this backend serial, anything larger lets it attach to the
    /// process-global pool (whose width is set once per process, not
    /// here). Defaults to [`adaptive_gp_threads`].
    gp_threads: usize,
    /// This backend's scratch-keying epoch on the shared pool: stamped
    /// on every task so a lane's persistent [`pool::LaneScratch`] is
    /// reset whenever it changes hands between backends.
    epoch: u64,
    /// Observation floor below which fan-outs stay serial
    /// ([`GP_POOL_MIN_OBS`]; settable for tests and benches).
    pool_min_obs: usize,
    /// The inducing-set selection kept alive across BO iterations —
    /// shared by the low-rank decide and nll paths.
    inducing: InducingCache,
    /// `nll_grid` switches to the low-rank marginal above this many
    /// observations (default [`LOWRANK_NLL_OBS_THRESHOLD`]).
    nll_lowrank_min_obs: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self {
            d2: Vec::new(),
            d2_swap: Vec::new(),
            cache_x: Vec::new(),
            cache_n: 0,
            cache_d: 0,
            factors: FactorCache::new(),
            incremental_off: false,
            row_scratch: Vec::new(),
            kern_scratch: Vec::new(),
            lowrank: LowRankGp::new(),
            lowrank_policy: LowRankPolicy::Auto,
            decide_stats: DecideStats::default(),
            alpha_scratch: Vec::new(),
            ks_scratch: Vec::new(),
            acc_scratch: Vec::new(),
            gp_threads: adaptive_gp_threads(),
            epoch: pool::next_pool_epoch(),
            pool_min_obs: GP_POOL_MIN_OBS,
            inducing: InducingCache::new(),
            nll_lowrank_min_obs: LOWRANK_NLL_OBS_THRESHOLD,
        }
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable/disable the incremental factor path (on by default).
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental_off = !on;
    }

    /// Select how `decide` chooses between the exact and the low-rank
    /// candidate-scoring path (default [`LowRankPolicy::Auto`]).
    pub fn set_lowrank_policy(&mut self, policy: LowRankPolicy) {
        self.lowrank_policy = policy;
    }

    /// Fan-out gate for the grid nll sweep and the decide tiles
    /// (default [`adaptive_gp_threads`], which `0` also resolves to):
    /// `1` pins this backend serial, anything larger lets its engaging
    /// fan-outs run on the process-global pool. Outputs are
    /// bit-identical for every value — the module docs' deterministic-
    /// parallelism contract. The pool's *width* is process-global
    /// ([`pool::configure_global_pool_width`], set once before first
    /// spawn); this knob no longer sizes or respawns anything.
    pub fn set_parallelism(&mut self, threads: usize) {
        self.gp_threads = if threads == 0 { adaptive_gp_threads() } else { threads };
    }

    /// The configured fan-out gate (see [`Self::set_parallelism`]).
    pub fn parallelism(&self) -> usize {
        self.gp_threads
    }

    /// Observation floor below which fan-outs stay serial (default
    /// [`GP_POOL_MIN_OBS`]; parity tests and benches lower it to 0 to
    /// exercise the pool at tiny sizes).
    pub fn set_pool_min_obs(&mut self, n: usize) {
        self.pool_min_obs = n;
    }

    /// Decide whether a fan-out of `units` work groups over `n`
    /// observations runs on the process-global pool, attaching to it as
    /// needed (and counting every outcome in [`DecideStats`]). True
    /// means [`pool::global_pool`] is running. The grid sweeps gate on
    /// the observation floor directly; `decide` gates on its
    /// column-scaled equivalent ([`Self::engage_pool_gated`]).
    fn engage_pool(&mut self, units: usize, n: usize) -> bool {
        let below_floor = n <= self.pool_min_obs;
        self.engage_pool_gated(units, below_floor)
    }

    /// The shared pool-engagement body: `below_floor` is the caller's
    /// work-size judgement (counted as a bypass when it blocks an
    /// otherwise-eligible fan-out).
    fn engage_pool_gated(&mut self, units: usize, below_floor: bool) -> bool {
        if self.gp_threads <= 1 || units <= 1 {
            return false;
        }
        if below_floor {
            self.decide_stats.serial_floor_bypasses += 1;
            return false;
        }
        let (shared, spawned_here) = pool::global_pool_acquire();
        if self.decide_stats.global_pool_attach == 0 {
            self.decide_stats.global_pool_attach = 1;
            self.decide_stats.pool_thread_count = shared.width() as u64;
            if spawned_here {
                self.decide_stats.pool_creates += 1;
            }
        } else {
            self.decide_stats.pool_reuses += 1;
        }
        true
    }

    /// Observation count above which `nll_grid` uses the Woodbury
    /// low-rank marginal (default [`LOWRANK_NLL_OBS_THRESHOLD`]; benches
    /// and tests lower it to exercise the routing cheaply).
    pub fn set_lowrank_nll_threshold(&mut self, min_obs: usize) {
        self.nll_lowrank_min_obs = min_obs;
    }

    /// Counters of the factorization paths taken so far.
    pub fn factor_stats(&self) -> FactorCacheStats {
        self.factors.stats()
    }

    /// Counters of the decide paths taken so far.
    pub fn decide_stats(&self) -> DecideStats {
        self.decide_stats
    }

    /// Inducing cap to use for this decision, or None for the exact path.
    fn lowrank_limit(&self, n: usize, m: usize) -> Option<usize> {
        match self.lowrank_policy {
            LowRankPolicy::Off => None,
            LowRankPolicy::Force { max_inducing } => {
                (n > 0).then_some(max_inducing.max(1))
            }
            LowRankPolicy::Auto => {
                let large_space = m > LOWRANK_CANDIDATE_THRESHOLD && n > LOWRANK_MIN_OBS;
                // Past the nll threshold the factor cache is no longer
                // maintained (nll_grid runs the Woodbury marginal), so
                // an exact decide would pay an O(n³) cold refit on
                // every hyperparameter switch at exactly the scale the
                // threshold declares intractable — serve the whole
                // iteration low-rank instead, whatever the space size.
                let large_history = n > self.nll_lowrank_min_obs;
                (large_space || large_history).then_some(DEFAULT_MAX_INDUCING)
            }
        }
    }

    /// Inducing cap for the low-rank `nll_grid`, or None for the exact
    /// incremental sweep. Engages only above the (settable) observation
    /// threshold — far past the windowed-search regime the factor cache
    /// serves — and never under [`LowRankPolicy::Off`].
    fn lowrank_nll_limit(&self, n: usize) -> Option<usize> {
        if n <= self.nll_lowrank_min_obs {
            return None;
        }
        match self.lowrank_policy {
            LowRankPolicy::Off => None,
            // No n-clamp here: the inducing cache keys on the *requested*
            // cap (selection clamps internally), so decide and nll_grid
            // asking for the same cap share one cached selection.
            LowRankPolicy::Force { max_inducing } => Some(max_inducing.max(1)),
            LowRankPolicy::Auto => Some(DEFAULT_MAX_INDUCING),
        }
    }

    /// Refresh the shared inducing-set cache for the current rows and
    /// cap, counting the outcome, and return the selection (cloned: the
    /// callers immediately hand it to fits that borrow `self` again).
    fn refresh_inducing(&mut self, x: &[f64], n: usize, d: usize, cap: usize) -> Vec<usize> {
        let (sel, full) = self.inducing.refresh(x, n, d, cap.max(1));
        if full {
            self.decide_stats.fps_full_refreshes += 1;
        } else {
            self.decide_stats.fps_incremental_refreshes += 1;
        }
        sel.to_vec()
    }

    /// Ensure `self.d2` holds the pairwise squared distances of `x`, and
    /// report how the observation set changed.
    ///
    /// The search loop appends exactly one observation per BO iteration
    /// (and slides its window by one once a capacity-limited backend
    /// saturates), so instead of recomputing all n² distances on every
    /// `nll_grid`/`decide` call the cache grows or shifts by one
    /// row+column. New entries use the same per-pair arithmetic as
    /// [`pairwise_sqdist`](super::gp::pairwise_sqdist), keeping every
    /// cached value bit-identical to a fresh computation. The returned
    /// [`ObsDelta`] drives the [`FactorCache`] plans.
    fn update_d2(&mut self, x: &[f64], n: usize, d: usize) -> ObsDelta {
        debug_assert_eq!(x.len(), n * d);
        // The shared delta detector — the same classification the
        // inducing-set cache keys on (see `ObsDelta::classify`).
        let delta =
            ObsDelta::classify(&self.cache_x, self.cache_n, self.cache_d, x, n, d);
        match delta {
            // Exact hit (e.g. `decide` right after `nll_grid`).
            ObsDelta::Unchanged => return ObsDelta::Unchanged,
            ObsDelta::Appended | ObsDelta::Slid => {
                let pn = self.cache_n;
                let old = n - 1; // rows of the previous matrix that survive
                // Build into the swap buffer (reads come from the old d2),
                // keeping the steady-state iteration allocation-free.
                let mut d2 = std::mem::take(&mut self.d2_swap);
                d2.clear();
                d2.resize(n * n, 0.0);
                if delta == ObsDelta::Appended {
                    for i in 0..old {
                        d2[i * n..i * n + old]
                            .copy_from_slice(&self.d2[i * pn..i * pn + old]);
                    }
                } else {
                    for i in 0..old {
                        for j in 0..old {
                            d2[i * n + j] = self.d2[(i + 1) * n + (j + 1)];
                        }
                    }
                }
                // New last row through the same vectorized squared-
                // distance kernel as the fresh build (bit-exact class:
                // one pair per lane in scalar feature order, no FMA),
                // then mirrored into the last column.
                let i = n - 1;
                let (head, last_row) = d2.split_at_mut(i * n);
                simd::sqdist_row(&x[i * d..(i + 1) * d], &x[..i * d], d, &mut last_row[..i]);
                for j in 0..i {
                    head[j * n + i] = last_row[j];
                }
                std::mem::swap(&mut self.d2, &mut d2);
                self.d2_swap = d2;
            }
            ObsDelta::Replaced => super::gp::pairwise_sqdist(x, n, d, &mut self.d2),
        }
        self.cache_x.clear();
        self.cache_x.extend_from_slice(x);
        self.cache_n = n;
        self.cache_d = d;
        delta
    }

    /// Bring the [`FactorCache`] slot for `hyp` up to date with the
    /// current `n` observations (distance matrix already refreshed by
    /// [`Self::update_d2`]) — the single-slot form `decide` uses,
    /// delegating to the same [`update_task`] body as the grid sweep.
    /// `row_key`/`gram_key` memoize the (ls, var) of
    /// `row_scratch`/`kern_scratch`. Returns the slot index, or None
    /// when the Gram is not SPD even from a cold refactorization.
    fn ensure_factor(
        &mut self,
        hyp: [f64; 3],
        n: usize,
        row_key: &mut (f64, f64),
        gram_key: &mut (f64, f64),
    ) -> Option<usize> {
        let (idx, mut plan) = self.factors.plan(hyp, n);
        if self.incremental_off && plan != FitPlan::Cold {
            plan = FitPlan::Cold;
        }
        let mut task = self.factors.task(idx, plan);
        let ok = update_task(
            &mut task,
            &self.d2,
            n,
            &mut self.row_scratch,
            &mut self.kern_scratch,
            row_key,
            gram_key,
        );
        let stats = task.stats();
        drop(task);
        self.factors.absorb_stats(stats);
        ok.then_some(idx)
    }

    /// Per-grid-point DTC marginal likelihood ([`LowRankGp::nll`],
    /// Woodbury form) under the stage split: grid points sharing a
    /// (lengthscale, variance) pair run one [`LowRankGp::fit_hyp_stage`]
    /// (all the kernel/GEMM work) and per-σ² [`LowRankGp::fit_noise_stage`]s
    /// — O(G·(n·u² + n·u·d) + H·u³) total instead of O(H·(n·u² + n·u·d))
    /// for G groups of H grid points, and no n×n intermediates. The
    /// inducing set comes from the incremental [`InducingCache`] instead
    /// of a per-call farthest-point re-selection. Groups are independent
    /// pure computations writing to fixed slots, so the worker-pool
    /// fan-out is bit-identical to the serial loop — and both are
    /// bit-identical to an unsplit per-point evaluation
    /// (`tests/prop_lowrank.rs`).
    fn nll_grid_lowrank(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
        max_inducing: usize,
    ) -> Vec<f64> {
        let mut out = vec![f64::INFINITY; grid.len()];
        // Inducing selection depends only on the rows, not the
        // hyperparameters: refresh once and share the set across the
        // whole grid (and across the worker lanes).
        let inducing = self.refresh_inducing(x, n, d, max_inducing);
        let ind = &inducing[..];

        // Group grid indices by (lengthscale, variance) — the stage-
        // split fan-out unit (the shared grouping definition).
        let groups_idx = group_grid_indices(grid);

        if !self.engage_pool(groups_idx.len(), n) {
            for group in &groups_idx {
                let head = grid[group[0]];
                if !self.lowrank.fit_hyp_stage(x, y, n, d, head[0], head[1], ind) {
                    continue;
                }
                for &gi in group {
                    if self.lowrank.fit_noise_stage(grid[gi][2]) {
                        out[gi] = self.lowrank.nll(y);
                    }
                }
            }
            let stats = self.lowrank.take_stats();
            self.decide_stats.absorb_lowrank(stats);
        } else {
            self.decide_stats.parallel_nll_sweeps += 1;
            // One fan-out unit per (ls, var) group, each carrying its
            // out-slots and a group-local stage-counter slot; lanes run
            // the identical two-stage body against their persistent
            // LaneScratch LowRankGp.
            let mut group_stats = vec![LowRankStats::default(); groups_idx.len()];
            let mut slot_refs: Vec<Option<&mut f64>> = out.iter_mut().map(Some).collect();
            let units: Vec<Vec<(Vec<(usize, &mut f64)>, &mut LowRankStats)>> = groups_idx
                .iter()
                .zip(group_stats.iter_mut())
                .map(|(group, gs)| {
                    let items: Vec<(usize, &mut f64)> = group
                        .iter()
                        .map(|&gi| {
                            (gi, slot_refs[gi].take().expect("grid index grouped twice"))
                        })
                        .collect();
                    vec![(items, gs)]
                })
                .collect();
            pool::global_pool().run_groups(self.epoch, units, |lane, scratch| {
                for (items, gs) in lane {
                    let lr = &mut scratch.lowrank;
                    lr.take_stats(); // group-local counting
                    let head = grid[items[0].0];
                    if lr.fit_hyp_stage(x, y, n, d, head[0], head[1], ind) {
                        for (gi, slot) in items {
                            if lr.fit_noise_stage(grid[gi][2]) {
                                *slot = lr.nll(y);
                            }
                        }
                    }
                    *gs = lr.take_stats();
                }
            });
            for gs in group_stats {
                self.decide_stats.absorb_lowrank(gs);
            }
        }
        out
    }

    /// The fit half of [`GpBackend::decide`], split out for the session
    /// engine's cross-session batched fan-out: identical routing,
    /// inducing refresh, distance-cache delta, factor update and weight
    /// solve as `decide` — arithmetic in the same order, counted in the
    /// same [`DecideStats`] — but stopping before candidate scoring.
    /// The caller then scores any candidate block through
    /// [`Self::exact_score_view`] + [`predict_into`] (exact) or
    /// [`Self::lowrank_mut`] + [`LowRankGp::predict_batch`] (low-rank);
    /// per-column arithmetic is independent of the tiling, so the split
    /// reproduces `decide`'s mu/var/EI bit for bit
    /// (`prepared_decide_scoring_matches_decide` pins this).
    pub fn prepare_decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        m: usize,
        hyp: [f64; 3],
    ) -> Result<PreparedDecide> {
        if let Some(max_inducing) = self.lowrank_limit(n, m) {
            let inducing = self.refresh_inducing(x, n, d, max_inducing);
            let fitted = self.lowrank.fit_with_inducing(x, y, n, d, hyp, &inducing);
            let stats = self.lowrank.take_stats();
            self.decide_stats.absorb_lowrank(stats);
            if fitted {
                self.decide_stats.lowrank += 1;
                return Ok(PreparedDecide::LowRank);
            }
            self.decide_stats.lowrank_fallbacks += 1;
        }
        let delta = self.update_d2(x, n, d);
        self.factors.note_delta(delta);
        let (mut row_key, mut gram_key) = ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
        let idx = self
            .ensure_factor(hyp, n, &mut row_key, &mut gram_key)
            .ok_or_else(|| anyhow::anyhow!("gram matrix not SPD"))?;
        self.decide_stats.exact += 1;
        let mut alpha = std::mem::take(&mut self.alpha_scratch);
        self.factors.factor(idx).solve_into(y, &mut alpha);
        self.alpha_scratch = alpha;
        Ok(PreparedDecide::Exact { slot: idx })
    }

    /// The borrowed factor and weights of the last
    /// [`Self::prepare_decide`] that returned
    /// [`PreparedDecide::Exact`] — everything a pure scoring pass needs
    /// to hand to [`predict_into`]. Immutable, so many sessions' views
    /// can be collected before one shared pool fans them all out.
    pub fn exact_score_view(&self, slot: usize) -> (&CholFactor, &[f64]) {
        (self.factors.factor(slot), &self.alpha_scratch)
    }

    /// The low-rank posterior fitted by the last [`Self::prepare_decide`]
    /// that returned [`PreparedDecide::LowRank`] (predict_batch needs
    /// `&mut` for its internal scratch; the posterior itself is fixed).
    pub fn lowrank_mut(&mut self) -> &mut LowRankGp {
        &mut self.lowrank
    }
}

impl GpBackend for NativeBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);

        // Large-space path: Nyström low-rank posterior, per-candidate
        // cost independent of n (see LOWRANK_CANDIDATE_THRESHOLD /
        // LowRankPolicy). The inducing set comes from the shared
        // incremental cache (a decide right after nll_grid reuses the
        // identical selection). The factor cache is untouched — nll_grid
        // keeps maintaining it, and its own update_d2 call still sees
        // the append/slide deltas of the search loop.
        if let Some(max_inducing) = self.lowrank_limit(n, m) {
            let inducing = self.refresh_inducing(x, n, d, max_inducing);
            let fitted = self.lowrank.fit_with_inducing(x, y, n, d, hyp, &inducing);
            let stats = self.lowrank.take_stats();
            self.decide_stats.absorb_lowrank(stats);
            if fitted {
                self.decide_stats.lowrank += 1;
                let mut mu = Vec::with_capacity(m);
                let mut var = Vec::with_capacity(m);
                self.lowrank.predict_batch(xc, m, &mut mu, &mut var);
                let ei = (0..m)
                    .map(|i| {
                        if cmask[i] { expected_improvement(mu[i], var[i], best) } else { 0.0 }
                    })
                    .collect();
                return Ok(Decision { ei, mu, var });
            }
            // Degenerate inducing Gram: fall through to the exact path.
            self.decide_stats.lowrank_fallbacks += 1;
        }

        let delta = self.update_d2(x, n, d);
        self.factors.note_delta(delta);
        let (mut row_key, mut gram_key) = ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
        let idx = self
            .ensure_factor(hyp, n, &mut row_key, &mut gram_key)
            .ok_or_else(|| anyhow::anyhow!("gram matrix not SPD"))?;
        self.decide_stats.exact += 1;

        // Engagement is decided before the factor borrow below: the
        // global pool is attached (and counted) here, so the fan-out
        // branch only needs immutable access to it and to the factor.
        // Decide work scales with the candidate count, not just the
        // observation count, so the floor is column-scaled: a fan-out is
        // "tiny" only when the whole n x m cross block is no bigger than
        // a floor-sized history against one tile — a 100k-candidate
        // catalog fans out even during the earliest iterations.
        let tiles = m.div_ceil(DECIDE_TILE);
        let below_floor = n * m <= self.pool_min_obs * DECIDE_TILE;
        let pooled = self.engage_pool_gated(tiles, below_floor);
        if pooled {
            self.decide_stats.parallel_decide_fanouts += 1;
        }

        // Borrow the cached packed factor — no clone into a GP: the
        // decide weights alpha = (L Lᵀ)⁻¹ y are solved against it in
        // place, then candidates stream through `predict_into` in
        // DECIDE_TILE-wide chunks. No candidate mask is passed: the
        // Decision contract exposes mu/var for *every* candidate (the
        // XLA-parity tests and the search's exploration fallback read
        // them) — only the EI respects `cmask`.
        let mut alpha = std::mem::take(&mut self.alpha_scratch);
        let factor = self.factors.factor(idx);
        factor.solve_into(y, &mut alpha);

        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        if pooled {
            // Tiles are dealt round-robin to the pool lanes; each tile
            // writes its own fixed, disjoint output range and per-column
            // arithmetic is independent of the tiling, so the fan-out is
            // bit-identical to the serial tile loop for every worker
            // count (module docs). Lanes predict through their
            // persistent LaneScratch buffers (fully overwritten per
            // tile).
            let alpha_ref = &alpha[..];
            let groups: Vec<Vec<(usize, &mut [f64], &mut [f64])>> = mu
                .chunks_mut(DECIDE_TILE)
                .zip(var.chunks_mut(DECIDE_TILE))
                .enumerate()
                .map(|(t, (mu_c, var_c))| vec![(t, mu_c, var_c)])
                .collect();
            pool::global_pool().run_groups(self.epoch, groups, |lane, scratch| {
                scratch.reserve_tiles(n, DECIDE_TILE);
                for (t, mu_c, var_c) in lane {
                    let start = t * DECIDE_TILE;
                    let w = mu_c.len();
                    predict_into(
                        factor,
                        alpha_ref,
                        x,
                        n,
                        d,
                        hyp,
                        &xc[start * d..(start + w) * d],
                        w,
                        mu_c,
                        var_c,
                        &mut scratch.ks,
                        &mut scratch.acc,
                    );
                }
            });
        } else {
            let mut ks = std::mem::take(&mut self.ks_scratch);
            let mut acc = std::mem::take(&mut self.acc_scratch);
            for (t, (mu_c, var_c)) in
                mu.chunks_mut(DECIDE_TILE).zip(var.chunks_mut(DECIDE_TILE)).enumerate()
            {
                let start = t * DECIDE_TILE;
                let w = mu_c.len();
                predict_into(
                    factor,
                    &alpha,
                    x,
                    n,
                    d,
                    hyp,
                    &xc[start * d..(start + w) * d],
                    w,
                    mu_c,
                    var_c,
                    &mut ks,
                    &mut acc,
                );
            }
            self.ks_scratch = ks;
            self.acc_scratch = acc;
        }
        self.alpha_scratch = alpha;

        let ei = (0..m)
            .map(|i| if cmask[i] { expected_improvement(mu[i], var[i], best) } else { 0.0 })
            .collect();
        Ok(Decision { ei, mu, var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        // Large-history path: Woodbury low-rank marginal per grid point.
        // The distance matrix and factor cache are deliberately left
        // untouched — they still describe the last exact-path window, so
        // a later exact call computes its delta against the right state.
        if let Some(max_inducing) = self.lowrank_nll_limit(n) {
            self.decide_stats.nll_lowrank += 1;
            return Ok(self.nll_grid_lowrank(x, y, n, d, grid, max_inducing));
        }
        self.decide_stats.nll_exact += 1;

        // Exact incremental sweep. Reuse across the grid and across
        // iterations (§Perf): the distance matrix is hyperparameter-
        // independent (cached across BO iterations, see update_d2); each
        // grid point keeps its Cholesky factor alive across iterations
        // and rank-1 extends it (O(n²)) instead of refactorizing
        // (O(n³)); and on the cold path grid entries sharing
        // (lengthscale, variance) — the 4 noise levels per lengthscale —
        // reuse one cross-row / Gram build. The slots are independent
        // units of work ([`FactorCache::plan_grid`]), swept serially or
        // across the worker pool with bit-identical results.
        let delta = self.update_d2(x, n, d);
        self.factors.note_delta(delta);
        // Fan-out units are whole (lengthscale, variance) groups; their
        // count is a pure function of the grid (the shared grouping
        // definition), so the pool decision happens before the
        // factor-cache borrow below.
        let pooled = self.engage_pool(distinct_group_count(grid), n);
        let (mut tasks, map) = self.factors.plan_grid(grid, n);
        if self.incremental_off {
            for t in tasks.iter_mut() {
                t.force_cold();
            }
        }
        let mut nlls = vec![f64::INFINITY; tasks.len()];
        // Whole (lengthscale, variance) groups are the work unit on both
        // branches: the noise levels of one group share a cross-row /
        // Gram build and run their nll triangular solves as one
        // interleaved multi-RHS batch (`sweep_group`). Count the groups
        // that actually batch (two or more noise levels) before either
        // branch consumes them.
        let groups = group_sweep_tasks(&mut tasks, &mut nlls);
        self.decide_stats.multi_rhs_noise_solves +=
            groups.iter().filter(|g| g.len() >= 2).count() as u64;
        if !pooled {
            // Serial sweep in (lengthscale, variance) group order
            // through the backend's persistent scratch.
            let (mut row_key, mut gram_key) = ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
            for group in groups {
                sweep_group(
                    group,
                    &self.d2,
                    y,
                    n,
                    &mut self.row_scratch,
                    &mut self.kern_scratch,
                    &mut row_key,
                    &mut gram_key,
                );
            }
        } else {
            self.decide_stats.parallel_nll_sweeps += 1;
            // Whole groups are also the fan-out unit: tasks sharing a
            // cross-row / Gram build (and a multi-RHS batch) stay on one
            // lane, and every task writes its nll to a fixed slot — no
            // reduction whose order could vary (see the deterministic-
            // reduction contract in chol's module docs). Each group
            // rides as one `Vec` element so the round-robin dealing
            // cannot split it across lanes, and `group_sweep_tasks`
            // mirrors `group_grid_indices` (same `hyp_group_key`), so
            // the group count used for pool engagement above matches
            // the groups fanned out here.
            let units: Vec<Vec<Vec<(&mut SlotTask<'_>, &mut f64)>>> =
                groups.into_iter().map(|g| vec![g]).collect();
            let d2 = &self.d2;
            pool::global_pool().run_groups(self.epoch, units, |lane, scratch| {
                scratch.reserve_sweep(n);
                // Memo keys are re-seeded per fan-out — the persistent
                // lane buffers are only trusted when the keys match, so
                // scratch from a previous call can never leak in.
                let (mut row_key, mut gram_key) =
                    ((f64::NAN, f64::NAN), (f64::NAN, f64::NAN));
                for group in lane {
                    sweep_group(
                        group,
                        d2,
                        y,
                        n,
                        &mut scratch.row,
                        &mut scratch.gram,
                        &mut row_key,
                        &mut gram_key,
                    );
                }
            });
        }
        let mut swept = FactorCacheStats::default();
        for t in &tasks {
            swept.merge(t.stats());
        }
        drop(tasks);
        self.factors.absorb_stats(swept);
        Ok(map.into_iter().map(|t| nlls[t]).collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The deployed backend: AOT artifacts through PJRT, loaded via the
/// pooled executor cache. Backends built from one [`ExecutorPool`] on
/// the same OS thread share a single compiled executable set — `run_reps`
/// repetitions and repeated factory calls no longer recompile per
/// backend.
pub struct XlaBackend {
    pool: ExecutorPool,
    calls: u64,
}

impl XlaBackend {
    /// Load from the default artifact directory (a private single-use
    /// pool; use [`XlaBackend::from_pool`] to share compilations).
    pub fn from_default_artifacts() -> Result<Self> {
        Self::from_pool(ExecutorPool::from_default_artifacts())
    }

    /// A backend over a shared executor pool. Probes the pool once so a
    /// missing or malformed artifact set fails here, not on the first
    /// decide call.
    pub fn from_pool(pool: ExecutorPool) -> Result<Self> {
        pool.with_executor(|_| Ok(()))?;
        Ok(Self { pool, calls: 0 })
    }

    /// PJRT executions issued through *this* backend (the pooled
    /// executor underneath is shared, so its own counter aggregates
    /// across backends).
    pub fn call_count(&self) -> u64 {
        self.calls
    }
}

impl GpBackend for XlaBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        let cm: Vec<f64> = cmask.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let out = self.pool.with_executor(|exec| exec.gp_ei(x, y, n, xc, &cm, m, hyp))?;
        self.calls += 1;
        Ok(Decision { ei: out.ei, mu: out.mu, var: out.var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        let out = self.pool.with_executor(|exec| exec.gp_nll(x, y, n, grid))?;
        self.calls += 1;
        Ok(out)
    }

    fn max_obs(&self) -> usize {
        crate::runtime::AOT_N_OBS
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// The backend families selectable by name. Both [`backend_by_name`]
/// and [`backend_factory_by_name`] parse through this, so an unknown
/// name fails identically on both paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl BackendKind {
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            other => anyhow::bail!("unknown backend {other:?} (expected native|xla)"),
        }
    }
}

/// Backend selection by name (CLI `--backend native|xla`).
pub fn backend_by_name(name: &str) -> Result<Box<dyn GpBackend>> {
    match BackendKind::parse(name)? {
        BackendKind::Native => Ok(Box::new(NativeBackend::new())),
        BackendKind::Xla => Ok(Box::new(XlaBackend::from_default_artifacts()?)),
    }
}

/// Backend *factory* selection by name — the parallel experiment engine
/// instantiates one backend per worker thread from this. Equivalent to
/// [`backend_factory_with_parallelism`] with the GP fan-out gate pinned
/// serial (deliberately: `--threads` evaluation workers already consume
/// the host's cores, so their backends share the global pool only when
/// `--gp-threads` opts them in explicitly).
pub fn backend_factory_by_name(name: &str) -> Result<BackendFactory> {
    backend_factory_with_parallelism(name, 1)
}

/// Backend factory with an explicit GP fan-out gate (CLI
/// `--gp-threads`; `0` resolves to [`adaptive_gp_threads`], the CLI
/// default): every native backend the factory produces has
/// [`NativeBackend::set_parallelism`] applied, so each evaluation
/// worker's backend fans its grid sweep and decide tiles across the one
/// process-global pool — T workers share the same W lanes instead of
/// parking T×G threads. The XLA backend has no tunable internal
/// parallelism — the knob is ignored there. Name validation is shared with
/// [`backend_by_name`] through [`BackendKind::parse`]; the xla arm
/// additionally probes the artifacts so an obviously bad configuration
/// fails at startup, and hands every produced backend a clone of one
/// shared [`ExecutorPool`] — PJRT client creation + artifact compilation
/// happens once per worker *thread*, not once per backend, and repeated
/// factory calls on the same thread reuse the compiled executables.
pub fn backend_factory_with_parallelism(
    name: &str,
    gp_threads: usize,
) -> Result<BackendFactory> {
    match BackendKind::parse(name)? {
        BackendKind::Native => Ok(Box::new(move || -> Result<Box<dyn GpBackend>> {
            let mut b = NativeBackend::new();
            b.set_parallelism(gp_threads);
            Ok(Box::new(b))
        })),
        BackendKind::Xla => {
            anyhow::ensure!(
                XlaRuntime::artifacts_available(),
                "XLA backend unavailable: AOT artifacts not found (run `make artifacts`; \
                 the binary must also be built with the `xla-pjrt` feature)"
            );
            let pool = ExecutorPool::from_default_artifacts();
            Ok(Box::new(move || -> Result<Box<dyn GpBackend>> {
                Ok(Box::new(XlaBackend::from_pool(pool.clone())?))
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_masks_candidates() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9];
        let y = [1.0, 2.0];
        let xc = [0.1, 0.2, 0.5, 0.5];
        let d = b
            .decide(&x, &y, 2, 2, &xc, &[false, true], 2, [0.5, 1.0, 1e-4])
            .unwrap();
        assert_eq!(d.ei[0], 0.0);
        assert!(d.mu[0].is_finite());
    }

    #[test]
    fn native_nll_grid_len() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9, 0.4, 0.6];
        let y = [1.0, 2.0, 1.5];
        let grid = [[0.5, 1.0, 1e-3], [1.0, 1.0, 1e-2]];
        let nll = b.nll_grid(&x, &y, 3, 2, &grid).unwrap();
        assert_eq!(nll.len(), 2);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        assert!(backend_by_name("tpu").is_err());
    }

    #[test]
    fn unknown_backend_fails_identically_on_both_paths() {
        let direct = backend_by_name("tpu").unwrap_err().to_string();
        let factory = backend_factory_by_name("tpu").unwrap_err().to_string();
        assert_eq!(direct, factory, "name validation diverged between the two paths");
        assert!(direct.contains("expected native|xla"));
        let with_pool = backend_factory_with_parallelism("tpu", 4).unwrap_err().to_string();
        assert_eq!(direct, with_pool);
    }

    #[test]
    fn default_impls_are_usable() {
        assert_eq!(NativeBackend::default().name(), "native");
        // The default pool width is adaptive (available_parallelism
        // capped at MAX_ADAPTIVE_GP_THREADS), never zero.
        assert_eq!(NativeBackend::default().parallelism(), adaptive_gp_threads());
        assert!(NativeBackend::default().parallelism() >= 1);
        assert!(adaptive_gp_threads() <= MAX_ADAPTIVE_GP_THREADS);
        // set_parallelism(0) re-resolves to the adaptive width.
        let mut b = NativeBackend::default();
        b.set_parallelism(3);
        b.set_parallelism(0);
        assert_eq!(b.parallelism(), adaptive_gp_threads());
        assert_eq!(crate::bayesopt::gp::NativeGp::default().n_obs(), 0);
    }

    #[test]
    fn incremental_grid_refit_matches_scratch() {
        // Drive a growth-then-slide sequence through two backends — one
        // incremental, one forced to cold-refit every call — and pin the
        // nll grid and decisions to each other within 1e-9, all through
        // the shared testkit parity harness (the same entry point that
        // pins low-rank-vs-exact in tests/prop_lowrank.rs).
        use crate::testkit::{assert_backend_parity, ParityScript};
        let d = 3;
        let total = 14usize;
        let window = 9usize;
        let rows: Vec<f64> =
            (0..total * d).map(|i| ((i * 23 + 5) % 73) as f64 / 73.0).collect();
        let ys: Vec<f64> = (0..total).map(|i| (i as f64 * 0.37).sin()).collect();
        let script =
            ParityScript::new(rows, ys, d).growth(window).slides(window, total - window);
        let grid = crate::bayesopt::hyperparameter_grid();
        let m = 6;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect();

        let mut inc = NativeBackend::new();
        let mut scr = NativeBackend::new();
        scr.set_incremental(false);
        let report = assert_backend_parity(&mut inc, &mut scr, &script, &xc, m, &grid, 1e-9);
        assert_eq!(report.steps, total, "growth + slide steps");
        let si = inc.factor_stats();
        assert!(si.appends > 0, "append path never taken: {si:?}");
        assert!(si.slides > 0, "slide path never taken: {si:?}");
        assert!(si.reuses > 0, "decide after nll_grid should reuse: {si:?}");
        let ss = scr.factor_stats();
        assert_eq!(ss.appends + ss.slides, 0, "scratch backend must stay cold: {ss:?}");
    }

    #[test]
    fn backend_factory_by_name_builds_native() {
        let factory = backend_factory_by_name("native").unwrap();
        assert_eq!(factory().unwrap().name(), "native");
        assert!(backend_factory_by_name("tpu").is_err());
    }

    #[test]
    fn factory_applies_gp_parallelism() {
        // The factory is the CLI's `--gp-threads` conduit: backends it
        // produces must carry the pool width (observable through the
        // parallel-sweep counter once a grid sweep runs).
        let factory = backend_factory_with_parallelism("native", 4).unwrap();
        let mut b = factory().unwrap();
        let d = 2;
        let x = [0.1, 0.2, 0.8, 0.9, 0.4, 0.6];
        let y = [1.0, 2.0, 1.5];
        let grid = crate::bayesopt::hyperparameter_grid();
        b.nll_grid(&x, &y, 3, d, &grid).unwrap();
        // The trait object hides NativeBackend; rebuild one directly to
        // check the counter wiring end to end (floor lowered so the
        // 3-observation sweep engages the pool).
        let mut nb = NativeBackend::new();
        nb.set_parallelism(4);
        nb.set_pool_min_obs(0);
        nb.nll_grid(&x, &y, 3, d, &grid).unwrap();
        assert_eq!(nb.parallelism(), 4);
        assert_eq!(nb.decide_stats().parallel_nll_sweeps, 1);
        assert_eq!(nb.decide_stats().nll_exact, 1);
        assert_eq!(nb.decide_stats().global_pool_attach, 1);
    }

    #[test]
    fn backend_attaches_to_the_global_pool_once() {
        // A backend's first engaging fan-out attaches to the process-
        // global pool (recording the width it saw); every later fan-out
        // counts as a reuse — never a second attach, never a respawn on
        // a gate change.
        let d = 3;
        let n = GP_POOL_MIN_OBS + 8; // clears the serial floor
        let (x, y, _) = synth(n, 4, d);
        let m = DECIDE_TILE * 2 + 9; // three tiles: the decide fans too
        let (_, _, xc) = synth(n, m, d);
        let cmask = vec![true; m];
        let grid = crate::bayesopt::hyperparameter_grid();
        let mut b = NativeBackend::new();
        b.set_lowrank_policy(LowRankPolicy::Off);
        b.set_parallelism(4);
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.global_pool_attach, 1, "first engaging sweep must attach: {s:?}");
        assert_eq!(s.pool_thread_count, pool::global_pool_width() as u64, "{s:?}");
        assert!(s.pool_creates <= 1, "at most one spawn per process: {s:?}");
        assert_eq!(s.pool_reuses, 0);
        assert!(pool::global_pool_is_running());
        b.decide(&x, &y, n, d, &xc, &cmask, m, grid[5]).unwrap();
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.global_pool_attach, 1, "attach is once per backend: {s:?}");
        assert_eq!(s.pool_reuses, 2, "decide + second sweep both reuse: {s:?}");
        assert_eq!(s.parallel_nll_sweeps, 2);
        assert_eq!(s.parallel_decide_fanouts, 1);
        // Changing the gate neither respawns nor resizes the shared
        // pool: the next fan-out is one more reuse.
        b.set_parallelism(2);
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.pool_reuses, 3, "gate change must not re-attach: {s:?}");
        assert_eq!(s.pool_thread_count, pool::global_pool_width() as u64);
        // A second backend sharing the process attaches to the same
        // pool without spawning another one.
        let mut b2 = NativeBackend::new();
        b2.set_lowrank_policy(LowRankPolicy::Off);
        b2.set_parallelism(4);
        b2.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s2 = b2.decide_stats();
        assert_eq!(s2.global_pool_attach, 1, "{s2:?}");
        assert_eq!(s2.pool_creates, 0, "pool already running — no second spawn: {s2:?}");
    }

    #[test]
    fn serial_floor_keeps_small_sweeps_poolless() {
        let d = 3;
        let grid = crate::bayesopt::hyperparameter_grid();
        let n = GP_POOL_MIN_OBS; // at the floor: must stay serial
        let (x, y, _) = synth(n, 4, d);
        let mut b = NativeBackend::new();
        b.set_parallelism(8);
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.parallel_nll_sweeps, 0, "floor breached: {s:?}");
        assert_eq!(s.global_pool_attach, 0, "floored sweep must not attach: {s:?}");
        assert_eq!(s.serial_floor_bypasses, 1, "bypass not counted: {s:?}");
        // Lowering the floor lets the same shape engage.
        b.set_pool_min_obs(0);
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.parallel_nll_sweeps, 1);
        assert_eq!(s.global_pool_attach, 1);
        // A single-lane backend never counts bypasses (nothing to skip).
        let mut serial = NativeBackend::new();
        serial.set_parallelism(1);
        serial.nll_grid(&x, &y, n, d, &grid).unwrap();
        assert_eq!(serial.decide_stats().serial_floor_bypasses, 0);
    }

    #[test]
    fn fps_refresh_counters_follow_deltas() {
        // The shared inducing cache: a first low-rank call re-selects in
        // full; appended-by-one follow-ups (and a decide right after an
        // nll_grid over the same rows) refresh incrementally.
        let d = 3;
        let grid = [[0.6, 1.0, 1e-2], [1.2, 1.0, 1e-2]];
        let total = 14;
        let rows: Vec<f64> =
            (0..total * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let mut b = NativeBackend::new();
        b.set_lowrank_nll_threshold(8);
        for n in 10..=13usize {
            b.nll_grid(&rows[..n * d], &ys[..n], n, d, &grid).unwrap();
        }
        let s = b.decide_stats();
        assert_eq!(s.nll_lowrank, 4);
        assert_eq!(s.fps_full_refreshes, 1, "only the first call re-selects: {s:?}");
        assert_eq!(s.fps_incremental_refreshes, 3, "appends must stay incremental: {s:?}");
        // Stage split: one hyp build per (ls, var) group per sweep, one
        // noise build per grid point per sweep.
        assert_eq!(s.lowrank_hyp_stage_builds, 4 * 2);
        assert_eq!(s.lowrank_noise_stage_builds, 4 * 2);
        // Unchanged rows (decide after nll_grid under a forced policy)
        // also count as incremental reuse.
        let mut f = NativeBackend::new();
        f.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 6 });
        let xc: Vec<f64> = (0..4 * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        let cmask = vec![true; 4];
        f.decide(&rows[..10 * d], &ys[..10], 10, d, &xc, &cmask, 4, grid[0]).unwrap();
        f.decide(&rows[..10 * d], &ys[..10], 10, d, &xc, &cmask, 4, grid[0]).unwrap();
        let s = f.decide_stats();
        assert_eq!(s.fps_full_refreshes, 1, "{s:?}");
        assert_eq!(s.fps_incremental_refreshes, 1, "{s:?}");
    }

    #[test]
    fn decide_matches_per_row_predict() {
        use crate::bayesopt::gp::NativeGp;
        let n = 6;
        let d = 3;
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let m = 9;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        let cmask: Vec<bool> = (0..m).map(|i| i % 3 != 0).collect();
        let hyp = [0.7, 1.0, 1e-3];

        let mut b = NativeBackend::new();
        let dec = b.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();

        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, hyp));
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        for i in 0..m {
            let (mu, var) = gp.predict(&xc[i * d..(i + 1) * d]);
            assert!((dec.mu[i] - mu).abs() <= 1e-12, "mu[{i}]");
            assert!((dec.var[i] - var).abs() <= 1e-12, "var[{i}]");
            let ei = if cmask[i] { expected_improvement(mu, var, best) } else { 0.0 };
            assert!((dec.ei[i] - ei).abs() <= 1e-12, "ei[{i}]");
        }
    }

    /// Synthetic observation rows + candidate rows for path tests.
    fn synth(n: usize, m: usize, d: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        (x, y, xc)
    }

    #[test]
    fn auto_policy_follows_documented_thresholds() {
        let d = 3;
        let hyp = [0.7, 1.0, 1e-3];
        let engaged = LOWRANK_MIN_OBS + 1; // smallest history the Auto policy approximates
        let routing = |s: DecideStats| (s.exact, s.lowrank);
        let mut b = NativeBackend::new();
        // Below the candidate threshold: exact, regardless of n.
        let (x, y, xc) = synth(engaged, 16, d);
        b.decide(&x, &y, engaged, d, &xc, &vec![true; 16], 16, hyp).unwrap();
        assert_eq!(routing(b.decide_stats()), (1, 0), "{:?}", b.decide_stats());
        // Above the candidate threshold with enough observations: lowrank.
        let m = LOWRANK_CANDIDATE_THRESHOLD + 1;
        let (x, y, xc) = synth(engaged, m, d);
        b.decide(&x, &y, engaged, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(routing(b.decide_stats()), (1, 1), "{:?}", b.decide_stats());
        // Large space but history within the inducing cap (the low-rank
        // posterior would be exact math at extra cost): exact again.
        let (x, y, xc) = synth(LOWRANK_MIN_OBS, m, d);
        b.decide(&x, &y, LOWRANK_MIN_OBS, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(routing(b.decide_stats()), (2, 1), "{:?}", b.decide_stats());
        assert_eq!(b.decide_stats().lowrank_fallbacks, 0);
        // Policy Off never takes the low-rank path.
        let mut off = NativeBackend::new();
        off.set_lowrank_policy(LowRankPolicy::Off);
        let (x, y, xc) = synth(engaged, m, d);
        off.decide(&x, &y, engaged, d, &xc, &vec![true; m], m, hyp).unwrap();
        assert_eq!(off.decide_stats().lowrank, 0);
        assert_eq!(off.decide_stats().exact, 1);
    }

    #[test]
    fn forced_full_inducing_decide_matches_exact() {
        // Force { max_inducing >= n } pins the exact-equality special
        // case (module docs of `lowrank`) at the backend level.
        let d = 3;
        let (n, m) = (12, 20);
        let (x, y, xc) = synth(n, m, d);
        let cmask = vec![true; m];
        let hyp = [0.6, 1.0, 1e-3];
        let mut exact = NativeBackend::new();
        exact.set_lowrank_policy(LowRankPolicy::Off);
        let mut forced = NativeBackend::new();
        forced.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 64 });
        let de = exact.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        let df = forced.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        assert_eq!(forced.decide_stats().lowrank, 1);
        for j in 0..m {
            assert!((de.mu[j] - df.mu[j]).abs() <= 1e-6, "mu[{j}]: {} vs {}", de.mu[j], df.mu[j]);
            assert!((de.var[j] - df.var[j]).abs() <= 1e-6, "var[{j}]");
            // EI amplifies variance error by ~1/(2 sigma); give it headroom.
            assert!((de.ei[j] - df.ei[j]).abs() <= 1e-5, "ei[{j}]");
        }
    }

    #[test]
    fn tiled_decide_matches_per_row_predict_across_tile_boundary() {
        use crate::bayesopt::gp::NativeGp;
        let d = 3;
        let n = 6;
        let m = DECIDE_TILE * 2 + 37; // three tiles, last one ragged
        let (x, y, xc) = synth(n, m, d);
        let cmask = vec![true; m];
        let hyp = [0.7, 1.0, 1e-3];
        let mut b = NativeBackend::new(); // Auto, but n < LOWRANK_MIN_OBS -> exact
        let dec = b.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        assert_eq!(b.decide_stats().exact, 1);
        assert_eq!(dec.mu.len(), m);
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, hyp));
        // Spot-check columns straddling every tile boundary plus the ends.
        for &j in &[0, 1, DECIDE_TILE - 1, DECIDE_TILE, 2 * DECIDE_TILE - 1, 2 * DECIDE_TILE, m - 1]
        {
            let (mu, var) = gp.predict(&xc[j * d..(j + 1) * d]);
            assert!((dec.mu[j] - mu).abs() <= 1e-12, "mu[{j}]");
            assert!((dec.var[j] - var).abs() <= 1e-12, "var[{j}]");
        }
    }

    #[test]
    fn threaded_decide_tiles_match_serial_bits() {
        // The tile fan-out across the worker pool must be bit-identical
        // to the serial tile loop — and must actually engage.
        let d = 3;
        let n = 8;
        let m = DECIDE_TILE * 3 + 11;
        let (x, y, xc) = synth(n, m, d);
        let cmask: Vec<bool> = (0..m).map(|i| i % 7 != 0).collect();
        let hyp = [0.6, 1.0, 1e-3];
        let mut serial = NativeBackend::new();
        serial.set_lowrank_policy(LowRankPolicy::Off);
        serial.set_parallelism(1);
        let mut par = NativeBackend::new();
        par.set_lowrank_policy(LowRankPolicy::Off);
        par.set_parallelism(4);
        par.set_pool_min_obs(0); // n = 8 sits under the default floor
        let ds = serial.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        let dp = par.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        assert_eq!(par.decide_stats().parallel_decide_fanouts, 1, "fan-out never engaged");
        assert_eq!(serial.decide_stats().parallel_decide_fanouts, 0);
        for j in 0..m {
            assert_eq!(ds.mu[j].to_bits(), dp.mu[j].to_bits(), "mu[{j}]");
            assert_eq!(ds.var[j].to_bits(), dp.var[j].to_bits(), "var[{j}]");
            assert_eq!(ds.ei[j].to_bits(), dp.ei[j].to_bits(), "ei[{j}]");
        }
    }

    #[test]
    fn lowrank_nll_routing_follows_threshold() {
        // Above the (lowered) observation threshold nll_grid must route
        // to the Woodbury marginal; at or below it, stay exact.
        let d = 3;
        let n = 24;
        let (x, y, _) = synth(n, 4, d);
        let grid = [[0.6, 1.0, 1e-2], [1.2, 1.0, 1e-2]];
        let mut routed = NativeBackend::new();
        routed.set_lowrank_nll_threshold(16);
        let a = routed.nll_grid(&x, &y, n, d, &grid).unwrap();
        assert_eq!(routed.decide_stats().nll_lowrank, 1);
        assert_eq!(routed.decide_stats().nll_exact, 0);
        let mut exact = NativeBackend::new();
        let b = exact.nll_grid(&x, &y, n, d, &grid).unwrap();
        assert_eq!(exact.decide_stats().nll_exact, 1);
        // n <= DEFAULT_MAX_INDUCING, so FPS selects every observation
        // and the DTC marginal reduces to the exact one (Z = X).
        for (g, (va, vb)) in a.iter().zip(&b).enumerate() {
            assert!(
                (va - vb).abs() <= 1e-4 * va.abs().max(vb.abs()).max(1.0),
                "nll[{g}]: lowrank {va} vs exact {vb}"
            );
        }
        // Off policy never routes, whatever the threshold.
        let mut off = NativeBackend::new();
        off.set_lowrank_nll_threshold(16);
        off.set_lowrank_policy(LowRankPolicy::Off);
        off.nll_grid(&x, &y, n, d, &grid).unwrap();
        assert_eq!(off.decide_stats().nll_lowrank, 0);
    }

    #[test]
    fn prepared_decide_scoring_matches_decide() {
        // The session engine's fit/score split must reproduce decide()
        // bit for bit on both routing paths.
        let d = 3;
        let hyp = [0.6, 1.0, 1e-3];
        // Exact path (small space, short history).
        let (n, m) = (8, DECIDE_TILE + 13); // two tiles, last ragged
        let (x, y, xc) = synth(n, m, d);
        let cmask: Vec<bool> = (0..m).map(|i| i % 5 != 0).collect();
        let mut whole = NativeBackend::new();
        let dec = whole.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();
        let mut split = NativeBackend::new();
        let prep = split.prepare_decide(&x, &y, n, d, m, hyp).unwrap();
        let PreparedDecide::Exact { slot } = prep else {
            panic!("small space must stay exact, got {prep:?}");
        };
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        let (factor, alpha) = split.exact_score_view(slot);
        let (mut ks, mut acc) = (Vec::new(), Vec::new());
        for (t, (mu_c, var_c)) in
            mu.chunks_mut(DECIDE_TILE).zip(var.chunks_mut(DECIDE_TILE)).enumerate()
        {
            let start = t * DECIDE_TILE;
            let w = mu_c.len();
            predict_into(
                factor,
                alpha,
                &x,
                n,
                d,
                hyp,
                &xc[start * d..(start + w) * d],
                w,
                mu_c,
                var_c,
                &mut ks,
                &mut acc,
            );
        }
        for j in 0..m {
            assert_eq!(dec.mu[j].to_bits(), mu[j].to_bits(), "mu[{j}]");
            assert_eq!(dec.var[j].to_bits(), var[j].to_bits(), "var[{j}]");
            let ei = if cmask[j] { expected_improvement(mu[j], var[j], best) } else { 0.0 };
            assert_eq!(dec.ei[j].to_bits(), ei.to_bits(), "ei[{j}]");
        }
        assert_eq!(whole.decide_stats().exact, split.decide_stats().exact);

        // Low-rank path (forced policy, same selection via the caches).
        let (n, m) = (12, 20);
        let (x, y, xc) = synth(n, m, d);
        let mut whole = NativeBackend::new();
        whole.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 6 });
        let dec = whole.decide(&x, &y, n, d, &xc, &vec![true; m], m, hyp).unwrap();
        let mut split = NativeBackend::new();
        split.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 6 });
        let prep = split.prepare_decide(&x, &y, n, d, m, hyp).unwrap();
        assert_eq!(prep, PreparedDecide::LowRank);
        let (mut mu, mut var) = (Vec::new(), Vec::new());
        split.lowrank_mut().predict_batch(&xc, m, &mut mu, &mut var);
        for j in 0..m {
            assert_eq!(dec.mu[j].to_bits(), mu[j].to_bits(), "lowrank mu[{j}]");
            assert_eq!(dec.var[j].to_bits(), var[j].to_bits(), "lowrank var[{j}]");
        }
    }

    #[test]
    fn noise_groups_batch_into_multi_rhs_solves() {
        // Grid points sharing (lengthscale, variance) must run their
        // nll solves as one multi-RHS batch — counted once per group of
        // two or more noise levels, identically on the serial and the
        // pooled sweep (whose results are pinned bit-identical by the
        // parallel parity suites).
        let d = 2;
        let n = 6;
        let (x, y, _) = synth(n, 2, d);
        let grid = [
            [0.5, 1.0, 1e-4],
            [0.5, 1.0, 1e-2],
            [1.0, 1.0, 1e-4],
            [1.0, 1.0, 1e-2],
            [2.0, 1.0, 1e-3], // singleton: must not count
        ];
        let mut b = NativeBackend::new();
        let serial = b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = b.decide_stats();
        assert_eq!(s.multi_rhs_noise_solves, 2, "{s:?}");
        let mut p = NativeBackend::new();
        p.set_parallelism(4);
        p.set_pool_min_obs(0);
        let pooled = p.nll_grid(&x, &y, n, d, &grid).unwrap();
        let s = p.decide_stats();
        assert_eq!(s.multi_rhs_noise_solves, 2, "{s:?}");
        assert_eq!(s.parallel_nll_sweeps, 1, "{s:?}");
        for (g, (a, b)) in serial.iter().zip(&pooled).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "nll[{g}]");
        }
    }

    #[test]
    fn d2_cache_incremental_matches_fresh() {
        let d = 3;
        let rows: Vec<f64> = (0..11 * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let grid = [[0.5, 1.0, 1e-3]];
        let mut b = NativeBackend::new();
        // Growth path: one appended observation per call.
        for n in 1..=10usize {
            let x = &rows[..n * d];
            let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
            b.nll_grid(x, &y, n, d, &grid).unwrap();
            let mut fresh = Vec::new();
            crate::bayesopt::gp::pairwise_sqdist(x, n, d, &mut fresh);
            assert_eq!(b.d2, fresh, "grown cache diverged at n={n}");
        }
        // Sliding-window path: drop the oldest row, append a new one.
        let n = 10;
        let x: Vec<f64> = rows[d..(n + 1) * d].to_vec();
        let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let mut fresh = Vec::new();
        crate::bayesopt::gp::pairwise_sqdist(&x, n, d, &mut fresh);
        assert_eq!(b.d2, fresh, "slid cache diverged");
    }
}
