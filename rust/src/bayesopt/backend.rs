//! The GP backend abstraction: the same decision interface served either
//! by the native f64 implementation or by the AOT-compiled XLA artifacts
//! (the deployed path). The search loop is backend-agnostic; integration
//! tests assert both backends propose the same configurations.

use super::gp::{expected_improvement, NativeGp};
use crate::runtime::{GpExecutor, XlaRuntime};
use anyhow::Result;

/// Posterior + acquisition over all candidates for one search iteration.
#[derive(Debug, Clone)]
pub struct Decision {
    pub ei: Vec<f64>,
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// One GP evaluation service. `x`/`xc` are row-major with `d` columns.
pub trait GpBackend {
    /// Fit on (x, y) and score all `m` candidates; `cmask[i] = false`
    /// forces `ei[i] = 0` (already tried / outside the current phase).
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision>;

    /// Negative log marginal likelihood per hyperparameter triple.
    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>>;

    /// Maximum observation count this backend can condition on. The
    /// search loop windows its history to this (the AOT artifacts have a
    /// frozen capacity; native is unbounded).
    fn max_obs(&self) -> usize {
        usize::MAX
    }

    fn name(&self) -> &'static str;
}

/// Creates one independent GP backend per evaluation worker. The
/// parallel experiment engine calls the factory from inside each scoped
/// worker thread, so the factory must be shareable (`Send + Sync`) but
/// the backends it produces never cross a thread boundary and need no
/// `Send` bound of their own (the PJRT-backed XLA backend is not
/// thread-safe). Construction is fallible (the XLA backend loads and
/// compiles artifacts); workers propagate the error instead of panicking.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn GpBackend>> + Send + Sync>;

/// Pure-rust backend (no artifacts needed).
#[derive(Default)]
pub struct NativeBackend {
    gp: NativeGp,
    /// Pairwise-distance cache shared across the hyperparameter grid
    /// (hyperparameter-independent) *and* across BO iterations — see
    /// [`Self::update_d2`].
    d2: Vec<f64>,
    cache_x: Vec<f64>,
    cache_n: usize,
    cache_d: usize,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure `self.d2` holds the pairwise squared distances of `x`.
    ///
    /// The search loop appends exactly one observation per BO iteration
    /// (and slides its window by one once a capacity-limited backend
    /// saturates), so instead of recomputing all n² distances on every
    /// `nll_grid`/`decide` call the cache grows or shifts by one
    /// row+column. New entries use the same per-pair arithmetic as
    /// [`pairwise_sqdist`](super::gp::pairwise_sqdist), keeping every
    /// cached value bit-identical to a fresh computation.
    fn update_d2(&mut self, x: &[f64], n: usize, d: usize) {
        debug_assert_eq!(x.len(), n * d);
        let (pn, pd) = (self.cache_n, self.cache_d);
        let appended_one = pd == d && n == pn + 1 && x[..pn * d] == self.cache_x[..];
        let slid_one =
            pd == d && n == pn && n > 0 && x[..(n - 1) * d] == self.cache_x[d..];
        if pd == d && pn == n && self.cache_x.as_slice() == x {
            return; // exact hit (e.g. `decide` right after `nll_grid`)
        } else if appended_one || slid_one {
            let old = n - 1; // rows of the previous matrix that survive
            let mut d2 = vec![0.0; n * n];
            if appended_one {
                for i in 0..old {
                    d2[i * n..i * n + old].copy_from_slice(&self.d2[i * pn..i * pn + old]);
                }
            } else {
                for i in 0..old {
                    for j in 0..old {
                        d2[i * n + j] = self.d2[(i + 1) * n + (j + 1)];
                    }
                }
            }
            let i = n - 1;
            for j in 0..i {
                let mut s = 0.0;
                for k in 0..d {
                    let diff = x[i * d + k] - x[j * d + k];
                    s += diff * diff;
                }
                d2[i * n + j] = s;
                d2[j * n + i] = s;
            }
            self.d2 = d2;
        } else {
            super::gp::pairwise_sqdist(x, n, d, &mut self.d2);
        }
        self.cache_x.clear();
        self.cache_x.extend_from_slice(x);
        self.cache_n = n;
        self.cache_d = d;
    }
}

impl GpBackend for NativeBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        self.update_d2(x, n, d);
        anyhow::ensure!(
            self.gp.fit_from_sqdist(x, y, n, d, &self.d2, hyp),
            "gram matrix not SPD"
        );
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut mu = Vec::with_capacity(m);
        let mut var = Vec::with_capacity(m);
        // One batched solve over all candidate columns. No candidate mask
        // is passed: the Decision contract exposes mu/var for *every*
        // candidate (the XLA-parity tests and the search's exploration
        // fallback read them) — only the EI respects `cmask`.
        self.gp.predict_batch(xc, m, None, &mut mu, &mut var);
        let ei = (0..m)
            .map(|i| if cmask[i] { expected_improvement(mu[i], var[i], best) } else { 0.0 })
            .collect();
        Ok(Decision { ei, mu, var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        // Three levels of reuse across the grid (§Perf): the distance
        // matrix is hyperparameter-independent (cached across BO
        // iterations, see update_d2), and the Gram matrix depends only
        // on (lengthscale, variance) — grid entries that share them (the
        // 4 noise levels per lengthscale) reuse one kernel build.
        self.update_d2(x, n, d);
        let mut out = vec![f64::INFINITY; grid.len()];
        let mut order: Vec<usize> = (0..grid.len()).collect();
        order.sort_by(|&a, &b| {
            (grid[a][0], grid[a][1]).partial_cmp(&(grid[b][0], grid[b][1])).unwrap()
        });
        let mut kern: Vec<f64> = Vec::new();
        let mut last_key = (f64::NAN, f64::NAN);
        for &gi in &order {
            let hyp = grid[gi];
            if (hyp[0], hyp[1]) != last_key {
                let (ls, var) = (hyp[0], hyp[1]);
                kern.clear();
                kern.resize(n * n, 0.0);
                for i in 0..n {
                    for j in 0..=i {
                        let k = super::gp::matern52_from_d2(self.d2[i * n + j], ls, var);
                        kern[i * n + j] = k;
                        kern[j * n + i] = k;
                    }
                }
                last_key = (ls, var);
            }
            if self.gp.fit_from_kernel(x, y, n, d, &kern, hyp) {
                out[gi] = self.gp.nll(y);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The deployed backend: AOT artifacts through PJRT.
pub struct XlaBackend {
    exec: GpExecutor,
    // keep the runtime alive as long as the executables
    _rt: XlaRuntime,
}

impl XlaBackend {
    /// Load from the default artifact directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let rt = XlaRuntime::new(XlaRuntime::default_artifact_dir())?;
        let exec = GpExecutor::new(&rt)?;
        Ok(Self { exec, _rt: rt })
    }

    pub fn call_count(&self) -> u64 {
        self.exec.call_count()
    }
}

impl GpBackend for XlaBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        let cm: Vec<f64> = cmask.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let out = self.exec.gp_ei(x, y, n, xc, &cm, m, hyp)?;
        Ok(Decision { ei: out.ei, mu: out.mu, var: out.var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        self.exec.gp_nll(x, y, n, grid)
    }

    fn max_obs(&self) -> usize {
        crate::runtime::AOT_N_OBS
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Backend selection by name (CLI `--backend native|xla`).
pub fn backend_by_name(name: &str) -> Result<Box<dyn GpBackend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::from_default_artifacts()?)),
        other => anyhow::bail!("unknown backend {other:?} (expected native|xla)"),
    }
}

/// Backend *factory* selection by name — the parallel experiment engine
/// instantiates one backend per worker thread from this. The xla arm is
/// validated with a cheap artifact probe so an obviously bad
/// configuration fails at startup; the expensive PJRT client creation +
/// artifact compilation happens once per worker, inside the worker.
pub fn backend_factory_by_name(name: &str) -> Result<BackendFactory> {
    match name {
        "native" => {
            Ok(Box::new(|| -> Result<Box<dyn GpBackend>> { Ok(Box::new(NativeBackend::new())) }))
        }
        "xla" => {
            anyhow::ensure!(
                XlaRuntime::artifacts_available(),
                "XLA backend unavailable: AOT artifacts not found (run `make artifacts`; \
                 the binary must also be built with the `xla-pjrt` feature)"
            );
            Ok(Box::new(|| -> Result<Box<dyn GpBackend>> {
                Ok(Box::new(XlaBackend::from_default_artifacts()?))
            }))
        }
        other => anyhow::bail!("unknown backend {other:?} (expected native|xla)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_masks_candidates() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9];
        let y = [1.0, 2.0];
        let xc = [0.1, 0.2, 0.5, 0.5];
        let d = b
            .decide(&x, &y, 2, 2, &xc, &[false, true], 2, [0.5, 1.0, 1e-4])
            .unwrap();
        assert_eq!(d.ei[0], 0.0);
        assert!(d.mu[0].is_finite());
    }

    #[test]
    fn native_nll_grid_len() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9, 0.4, 0.6];
        let y = [1.0, 2.0, 1.5];
        let grid = [[0.5, 1.0, 1e-3], [1.0, 1.0, 1e-2]];
        let nll = b.nll_grid(&x, &y, 3, 2, &grid).unwrap();
        assert_eq!(nll.len(), 2);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        assert!(backend_by_name("tpu").is_err());
    }

    #[test]
    fn backend_factory_by_name_builds_native() {
        let factory = backend_factory_by_name("native").unwrap();
        assert_eq!(factory().unwrap().name(), "native");
        assert!(backend_factory_by_name("tpu").is_err());
    }

    #[test]
    fn decide_matches_per_row_predict() {
        use crate::bayesopt::gp::NativeGp;
        let n = 6;
        let d = 3;
        let x: Vec<f64> = (0..n * d).map(|i| ((i * 29 + 7) % 83) as f64 / 83.0).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.43).sin()).collect();
        let m = 9;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 11) % 97) as f64 / 97.0).collect();
        let cmask: Vec<bool> = (0..m).map(|i| i % 3 != 0).collect();
        let hyp = [0.7, 1.0, 1e-3];

        let mut b = NativeBackend::new();
        let dec = b.decide(&x, &y, n, d, &xc, &cmask, m, hyp).unwrap();

        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, hyp));
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        for i in 0..m {
            let (mu, var) = gp.predict(&xc[i * d..(i + 1) * d]);
            assert!((dec.mu[i] - mu).abs() <= 1e-12, "mu[{i}]");
            assert!((dec.var[i] - var).abs() <= 1e-12, "var[{i}]");
            let ei = if cmask[i] { expected_improvement(mu, var, best) } else { 0.0 };
            assert!((dec.ei[i] - ei).abs() <= 1e-12, "ei[{i}]");
        }
    }

    #[test]
    fn d2_cache_incremental_matches_fresh() {
        let d = 3;
        let rows: Vec<f64> = (0..11 * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let grid = [[0.5, 1.0, 1e-3]];
        let mut b = NativeBackend::new();
        // Growth path: one appended observation per call.
        for n in 1..=10usize {
            let x = &rows[..n * d];
            let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
            b.nll_grid(x, &y, n, d, &grid).unwrap();
            let mut fresh = Vec::new();
            crate::bayesopt::gp::pairwise_sqdist(x, n, d, &mut fresh);
            assert_eq!(b.d2, fresh, "grown cache diverged at n={n}");
        }
        // Sliding-window path: drop the oldest row, append a new one.
        let n = 10;
        let x: Vec<f64> = rows[d..(n + 1) * d].to_vec();
        let y: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
        b.nll_grid(&x, &y, n, d, &grid).unwrap();
        let mut fresh = Vec::new();
        crate::bayesopt::gp::pairwise_sqdist(&x, n, d, &mut fresh);
        assert_eq!(b.d2, fresh, "slid cache diverged");
    }
}
