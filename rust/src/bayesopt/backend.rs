//! The GP backend abstraction: the same decision interface served either
//! by the native f64 implementation or by the AOT-compiled XLA artifacts
//! (the deployed path). The search loop is backend-agnostic; integration
//! tests assert both backends propose the same configurations.

use super::gp::{expected_improvement, NativeGp};
use crate::runtime::{GpExecutor, XlaRuntime};
use anyhow::Result;

/// Posterior + acquisition over all candidates for one search iteration.
#[derive(Debug, Clone)]
pub struct Decision {
    pub ei: Vec<f64>,
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// One GP evaluation service. `x`/`xc` are row-major with `d` columns.
pub trait GpBackend {
    /// Fit on (x, y) and score all `m` candidates; `cmask[i] = false`
    /// forces `ei[i] = 0` (already tried / outside the current phase).
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision>;

    /// Negative log marginal likelihood per hyperparameter triple.
    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>>;

    /// Maximum observation count this backend can condition on. The
    /// search loop windows its history to this (the AOT artifacts have a
    /// frozen capacity; native is unbounded).
    fn max_obs(&self) -> usize {
        usize::MAX
    }

    fn name(&self) -> &'static str;
}

/// Pure-rust backend (no artifacts needed).
#[derive(Default)]
pub struct NativeBackend {
    gp: NativeGp,
    /// Pairwise-distance scratch shared across the hyperparameter grid
    /// (hyperparameter-independent — computed once per nll_grid call).
    d2: Vec<f64>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl GpBackend for NativeBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        anyhow::ensure!(self.gp.fit(x, y, n, d, hyp), "gram matrix not SPD");
        let best = y.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut ei = Vec::with_capacity(m);
        let mut mu = Vec::with_capacity(m);
        let mut var = Vec::with_capacity(m);
        for i in 0..m {
            let (mi, vi) = self.gp.predict(&xc[i * d..(i + 1) * d]);
            mu.push(mi);
            var.push(vi);
            ei.push(if cmask[i] { expected_improvement(mi, vi, best) } else { 0.0 });
        }
        Ok(Decision { ei, mu, var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        // Two levels of reuse across the grid (§Perf): the distance
        // matrix is hyperparameter-independent (computed once), and the
        // Gram matrix depends only on (lengthscale, variance) — grid
        // entries that share them (the 4 noise levels per lengthscale)
        // reuse one kernel build.
        super::gp::pairwise_sqdist(x, n, d, &mut self.d2);
        let mut out = vec![f64::INFINITY; grid.len()];
        let mut order: Vec<usize> = (0..grid.len()).collect();
        order.sort_by(|&a, &b| {
            (grid[a][0], grid[a][1]).partial_cmp(&(grid[b][0], grid[b][1])).unwrap()
        });
        let mut kern: Vec<f64> = Vec::new();
        let mut last_key = (f64::NAN, f64::NAN);
        for &gi in &order {
            let hyp = grid[gi];
            if (hyp[0], hyp[1]) != last_key {
                let (ls, var) = (hyp[0], hyp[1]);
                kern.clear();
                kern.resize(n * n, 0.0);
                for i in 0..n {
                    for j in 0..=i {
                        let k = super::gp::matern52_from_d2(self.d2[i * n + j], ls, var);
                        kern[i * n + j] = k;
                        kern[j * n + i] = k;
                    }
                }
                last_key = (ls, var);
            }
            if self.gp.fit_from_kernel(x, y, n, d, &kern, hyp) {
                out[gi] = self.gp.nll(y);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The deployed backend: AOT artifacts through PJRT.
pub struct XlaBackend {
    exec: GpExecutor,
    // keep the runtime alive as long as the executables
    _rt: XlaRuntime,
}

impl XlaBackend {
    /// Load from the default artifact directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let rt = XlaRuntime::new(XlaRuntime::default_artifact_dir())?;
        let exec = GpExecutor::new(&rt)?;
        Ok(Self { exec, _rt: rt })
    }

    pub fn call_count(&self) -> u64 {
        self.exec.call_count()
    }
}

impl GpBackend for XlaBackend {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<Decision> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        let cm: Vec<f64> = cmask.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let out = self.exec.gp_ei(x, y, n, xc, &cm, m, hyp)?;
        Ok(Decision { ei: out.ei, mu: out.mu, var: out.var })
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        debug_assert_eq!(d, crate::runtime::AOT_N_FEATURES);
        self.exec.gp_nll(x, y, n, grid)
    }

    fn max_obs(&self) -> usize {
        crate::runtime::AOT_N_OBS
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Backend selection by name (CLI `--backend native|xla`).
pub fn backend_by_name(name: &str) -> Result<Box<dyn GpBackend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::from_default_artifacts()?)),
        other => anyhow::bail!("unknown backend {other:?} (expected native|xla)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_masks_candidates() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9];
        let y = [1.0, 2.0];
        let xc = [0.1, 0.2, 0.5, 0.5];
        let d = b
            .decide(&x, &y, 2, 2, &xc, &[false, true], 2, [0.5, 1.0, 1e-4])
            .unwrap();
        assert_eq!(d.ei[0], 0.0);
        assert!(d.mu[0].is_finite());
    }

    #[test]
    fn native_nll_grid_len() {
        let mut b = NativeBackend::new();
        let x = [0.1, 0.2, 0.8, 0.9, 0.4, 0.6];
        let y = [1.0, 2.0, 1.5];
        let grid = [[0.5, 1.0, 1e-3], [1.0, 1.0, 1e-2]];
        let nll = b.nll_grid(&x, &y, 3, 2, &grid).unwrap();
        assert_eq!(nll.len(), 2);
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        assert!(backend_by_name("tpu").is_err());
    }
}
