//! Native (pure-rust, f64) Gaussian-process regression with the Matérn-5/2
//! kernel — the same math as the AOT artifact (`python/compile/model.py`),
//! kept in-tree for three reasons: cross-validating the compiled path,
//! running without artifacts, and serving as the CPU-native baseline in
//! the §Perf comparison.

use super::chol::CholFactor;
use super::simd;
use crate::util::stats;

// Kernel math lives in [`super::kernel`] (shared with the low-rank
// posterior); re-exported here so long-standing `gp::matern52`-style
// paths keep working.
pub use super::kernel::{
    matern52, matern52_cross, matern52_from_d2, matern52_gram_from_d2, pairwise_sqdist, SQRT5,
};

/// Diagonal jitter matching python/compile/model.py.
pub const JITTER: f64 = 1e-6;
/// Posterior-variance floor: predictions clamp `k(x,x) - |v|^2` here so
/// cancellation cannot produce a negative variance, but a genuinely
/// collapsed posterior stays collapsed instead of being inflated.
pub const VAR_FLOOR: f64 = 0.0;
/// Below this posterior standard deviation [`expected_improvement`]
/// switches to the exact certain-improvement formula.
pub const EI_SIGMA_FLOOR: f64 = 1e-12;

// The shared slice dot product (the hot inner kernel of the
// factorization and the solves — see EXPERIMENTS.md §Perf) lives in
// `kernel` so the dense path here and the packed path in `chol` run the
// exact same accumulation order.
use super::kernel::dot;

/// Dense lower-triangular Cholesky factorization in place.
/// Returns false if the matrix is not (numerically) SPD.
pub fn cholesky_in_place(a: &mut [f64], n: usize) -> bool {
    for j in 0..n {
        // Split so row j (read+write) and rows i>j (read) borrow cleanly.
        let (head, tail) = a.split_at_mut((j + 1) * n);
        let row_j = &mut head[j * n..];
        let d = row_j[j] - dot(&row_j[..j], &row_j[..j]);
        if d <= 0.0 {
            return false;
        }
        let d = d.sqrt();
        row_j[j] = d;
        for i in (j + 1)..n {
            let row_i = &mut tail[(i - j - 1) * n..(i - j) * n];
            row_i[j] = (row_i[j] - dot(&row_i[..j], &row_j[..j])) / d;
        }
        // Zero the upper triangle of column j.
        for i in 0..j {
            a[i * n + j] = 0.0;
        }
    }
    true
}

/// Solve L z = b (forward substitution), in place over `b`.
pub fn solve_lower_in_place(l: &[f64], n: usize, b: &mut [f64]) {
    for i in 0..n {
        let row = &l[i * n..i * n + i];
        let s = b[i] - dot(row, &b[..i]);
        b[i] = s / l[i * n + i];
    }
}

/// Solve Lᵀ x = b (backward substitution), in place over `b`.
pub fn solve_upper_t_in_place(l: &[f64], n: usize, b: &mut [f64]) {
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Standard-normal CDF via erf (same A&S 7.1.26 approximation the AOT
/// artifact uses, so both backends agree bit-for-bit-ish).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf_approx(x / std::f64::consts::SQRT_2))
}

pub fn norm_pdf(x: f64) -> f64 {
    (2.0 * std::f64::consts::PI).sqrt().recip() * (-0.5 * x * x).exp()
}

fn erf_approx(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * ax);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-ax * ax).exp())
}

/// Expected improvement for minimization.
///
/// The degenerate branch (`sigma <= EI_SIGMA_FLOOR`) treats the posterior
/// as fully determined and returns the certain improvement `max(best -
/// mu, 0)`. It is aligned with [`NativeGp::predict`]'s variance floor of
/// [`VAR_FLOOR`]: a collapsed posterior reaches this branch instead of
/// being inflated to a fake `sigma` of ~3e-5 (the old `1e-9` variance
/// floor made the branch unreachable).
pub fn expected_improvement(mu: f64, var: f64, best: f64) -> f64 {
    let sigma = var.max(0.0).sqrt();
    let delta = best - mu;
    if sigma <= EI_SIGMA_FLOOR {
        return delta.max(0.0);
    }
    let z = delta / sigma;
    (delta * norm_cdf(z) + sigma * norm_pdf(z)).max(0.0)
}

/// A fitted GP posterior over `n` observations of dimension `d`.
///
/// Two fit families exist:
///
/// * **cold fits** ([`fit`](Self::fit) / [`fit_from_sqdist`](Self::fit_from_sqdist)
///   / [`fit_from_kernel`](Self::fit_from_kernel)) factorize the full
///   Gram from scratch, O(n³);
/// * **extend paths** ([`extend`](Self::extend) / [`slide`](Self::slide))
///   update the existing [`CholFactor`] by one observation in O(n²) —
///   the per-BO-iteration hot path (see [`super::chol`] for the math
///   and fallback rules). The backend's decide path goes further and
///   never owns a GP at all: it borrows its cached factor straight into
///   the free [`predict_into`].
///
/// Scratch buffers are reused across refits (`fit` clears and refills),
/// which keeps the per-search-iteration hot path allocation-free after
/// the first fit — one of the §Perf optimizations.
#[derive(Debug, Clone, Default)]
pub struct NativeGp {
    n: usize,
    d: usize,
    x: Vec<f64>,
    factor: CholFactor,
    alpha: Vec<f64>,
    hyp: [f64; 3],
    // scratch for predictions and distance/kernel reuse
    ks_row: Vec<f64>,
    d2_scratch: Vec<f64>,
    kern_scratch: Vec<f64>,
    // scratch for the batched prediction path (n x m cross-kernel block
    // plus one accumulator row of width m)
    ks_mat: Vec<f64>,
    col_acc: Vec<f64>,
}

impl NativeGp {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fit on `n` rows of `x` (row-major, d columns) and targets `y` with
    /// hyp = (lengthscale, signal variance, noise variance).
    /// Returns false if the Gram matrix was not SPD even with jitter.
    pub fn fit(&mut self, x: &[f64], y: &[f64], n: usize, d: usize, hyp: [f64; 3]) -> bool {
        let mut d2 = std::mem::take(&mut self.d2_scratch);
        pairwise_sqdist(x, n, d, &mut d2);
        let ok = self.fit_from_sqdist(x, y, n, d, &d2, hyp);
        self.d2_scratch = d2;
        ok
    }

    /// Fit with a precomputed pairwise squared-distance matrix (shared
    /// across hyperparameter-grid evaluations — the §Perf hot path).
    pub fn fit_from_sqdist(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        d2: &[f64],
        hyp: [f64; 3],
    ) -> bool {
        assert_eq!(d2.len(), n * n);
        let (ls, var, _) = (hyp[0], hyp[1], hyp[2]);
        let mut kern = std::mem::take(&mut self.kern_scratch);
        matern52_gram_from_d2(d2, n, ls, var, &mut kern);
        let ok = self.fit_from_kernel(x, y, n, d, &kern, hyp);
        self.kern_scratch = kern;
        ok
    }

    /// Cold fit from a prebuilt noiseless Gram matrix. Shared by the
    /// hyperparameter grid: the Gram depends only on the lengthscale, so
    /// the 4 noise levels per lengthscale reuse one kernel build (§Perf).
    pub fn fit_from_kernel(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        kern: &[f64],
        hyp: [f64; 3],
    ) -> bool {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        assert_eq!(kern.len(), n * n);
        self.n = n;
        self.d = d;
        self.hyp = hyp;
        self.x.clear();
        self.x.extend_from_slice(x);

        if !self.factor.refactorize(kern, n, hyp[2] + JITTER) {
            return false;
        }
        self.refresh_alpha(y);
        true
    }

    /// Rank-1 extend path: append one observation (features `x_new`,
    /// full target vector `y` of length `n+1`) to the fitted posterior
    /// in O(n²) instead of refitting. Returns false — leaving the fit
    /// unchanged — when the update detects loss of positive definiteness;
    /// the caller must then cold-fit.
    pub fn extend(&mut self, x_new: &[f64], y: &[f64]) -> bool {
        assert_eq!(x_new.len(), self.d);
        assert_eq!(y.len(), self.n + 1);
        let (ls, var, noise) = (self.hyp[0], self.hyp[1], self.hyp[2]);
        let mut row = std::mem::take(&mut self.ks_row);
        row.clear();
        for j in 0..self.n {
            row.push(matern52(x_new, &self.x[j * self.d..(j + 1) * self.d], ls, var));
        }
        let ok = self.factor.append(&row, var + noise + JITTER);
        self.ks_row = row;
        if !ok {
            return false;
        }
        self.x.extend_from_slice(x_new);
        self.n += 1;
        self.refresh_alpha(y);
        true
    }

    /// Sliding-window extend: drop the oldest observation, then append
    /// `x_new` (`y` holds the `n` targets of the slid window). O(n²).
    /// Returns false on loss of positive definiteness; the factor is
    /// then stale and the caller must cold-fit before predicting.
    pub fn slide(&mut self, x_new: &[f64], y: &[f64]) -> bool {
        assert!(self.n > 0, "slide on an empty fit");
        assert_eq!(y.len(), self.n);
        self.factor.drop_first();
        self.x.drain(..self.d);
        self.n -= 1;
        self.extend(x_new, y)
    }

    fn refresh_alpha(&mut self, y: &[f64]) {
        self.factor.solve_into(y, &mut self.alpha);
    }

    pub fn n_obs(&self) -> usize {
        self.n
    }

    /// Posterior (mean, variance) at one candidate row.
    pub fn predict(&mut self, xc: &[f64]) -> (f64, f64) {
        let (ls, var, _) = (self.hyp[0], self.hyp[1], self.hyp[2]);
        let n = self.n;
        let d = self.d;
        self.ks_row.clear();
        for j in 0..n {
            self.ks_row.push(matern52(xc, &self.x[j * d..(j + 1) * d], ls, var));
        }
        let mu: f64 = self.ks_row.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        // v = L^-1 ks; var = k(x,x) - |v|^2
        debug_assert_eq!(self.ks_row.len(), n);
        self.factor.forward_solve(&mut self.ks_row);
        let v2: f64 = self.ks_row.iter().map(|v| v * v).sum();
        (mu, (var - v2).max(VAR_FLOOR))
    }

    /// Posterior (mean, variance) for all `m` candidate rows at once.
    ///
    /// Builds the full `n x m` cross-kernel block once and runs a single
    /// blocked forward-solve over every candidate column instead of `m`
    /// independent [`predict`](Self::predict) calls with per-call
    /// `ks_row` refills — the batched §Perf hot path. The heavy lifting
    /// lives in the free [`predict_into`], which takes the factor *by
    /// reference*; `NativeBackend::decide` calls it directly against the
    /// cached factor (and fans tiles of it across worker threads)
    /// without ever cloning the factor into a GP. Per column the
    /// accumulation order matches `predict` exactly, so every path
    /// agrees bit-for-bit.
    ///
    /// `mask`: when given, only columns with `mask[j] == true` are
    /// computed; masked columns skip all kernel and solve work and
    /// receive the prior `(0.0, signal variance)`.
    ///
    /// `mu_out` / `var_out` are cleared and resized to `m`.
    pub fn predict_batch(
        &mut self,
        xc: &[f64],
        m: usize,
        mask: Option<&[bool]>,
        mu_out: &mut Vec<f64>,
        var_out: &mut Vec<f64>,
    ) {
        let var = self.hyp[1];
        let d = self.d;
        assert_eq!(xc.len(), m * d);
        if let Some(ma) = mask {
            assert_eq!(ma.len(), m);
        }
        mu_out.clear();
        mu_out.resize(m, 0.0);
        var_out.clear();
        var_out.resize(m, var);
        if self.n == 0 {
            return;
        }
        let mut ks = std::mem::take(&mut self.ks_mat);
        let mut acc = std::mem::take(&mut self.col_acc);
        match mask {
            None => {
                predict_into(
                    &self.factor,
                    &self.alpha,
                    &self.x,
                    self.n,
                    d,
                    self.hyp,
                    xc,
                    m,
                    mu_out,
                    var_out,
                    &mut ks,
                    &mut acc,
                );
            }
            Some(ma) => {
                // Compact the active candidates, predict the dense
                // block, scatter back. The per-column arithmetic sees
                // exactly the active rows in their original order, so
                // results match the unmasked path bit-for-bit; masked
                // columns keep the prior `(0, var)` defaults.
                let active: Vec<usize> = (0..m).filter(|&j| ma[j]).collect();
                let w = active.len();
                if w == 0 {
                    self.ks_mat = ks;
                    self.col_acc = acc;
                    return;
                }
                let mut xa = Vec::with_capacity(w * d);
                for &j in &active {
                    xa.extend_from_slice(&xc[j * d..(j + 1) * d]);
                }
                let mut mu_a = vec![0.0; w];
                let mut var_a = vec![0.0; w];
                predict_into(
                    &self.factor,
                    &self.alpha,
                    &self.x,
                    self.n,
                    d,
                    self.hyp,
                    &xa,
                    w,
                    &mut mu_a,
                    &mut var_a,
                    &mut ks,
                    &mut acc,
                );
                for (c, &j) in active.iter().enumerate() {
                    mu_out[j] = mu_a[c];
                    var_out[j] = var_a[c];
                }
            }
        }
        self.ks_mat = ks;
        self.col_acc = acc;
    }

    /// Negative log marginal likelihood of the fitted data.
    pub fn nll(&self, y: &[f64]) -> f64 {
        let n = self.n;
        let quad: f64 = y.iter().zip(&self.alpha).map(|(a, b)| a * b).sum::<f64>() * 0.5;
        quad + self.factor.sum_log_diag() + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }
}

/// Row-block width of [`predict_into`]'s blocked TRSM — also the height
/// of its accumulator scratch, which per-lane buffer sizing
/// ([`super::pool::LaneScratch`]) mirrors.
pub(crate) const PREDICT_ROW_BLOCK: usize = 32;

/// Batched posterior prediction against a *borrowed* packed factor —
/// the zero-copy core shared by [`NativeGp::predict_batch`] and
/// `NativeBackend::decide`'s tile fan-out (each persistent pool lane
/// runs this on its own tile against its own reusable
/// [`LaneScratch`](super::pool::LaneScratch) buffers; the factor,
/// weights and observations are shared read-only).
///
/// Writes mean/variance for the `w` candidate rows of `xc` into
/// `mu_out[..w]` / `var_out[..w]` (fully overwritten). `alpha` must be
/// the factor-consistent weights `(L Lᵀ)⁻¹ y`. `ks` / `acc` are caller
/// scratch, cleared and resized here so steady-state callers allocate
/// nothing.
///
/// Per column the accumulation order (cross-kernel build in ascending
/// observation order, blocked TRSM visiting `k` ascending within each
/// row, squared-norm fold ascending) matches [`NativeGp::predict`]
/// exactly, so every caller — per-row, one m-wide call, serial tiles,
/// or tiles fanned across threads — produces the same bits.
///
/// The column loops run on the bit-exact [`simd`] column-lane kernels
/// (`axpy` / `sub_div` / `sq_accum` — one candidate per vector lane,
/// no FMA), so SIMD dispatch never changes the solve/fold bits; only
/// the cross-kernel rows go through the tolerance-class vector exp
/// (see the parity contract in [`super::kernel`]).
#[allow(clippy::too_many_arguments)]
pub fn predict_into(
    factor: &CholFactor,
    alpha: &[f64],
    x: &[f64],
    n: usize,
    d: usize,
    hyp: [f64; 3],
    xc: &[f64],
    w: usize,
    mu_out: &mut [f64],
    var_out: &mut [f64],
    ks: &mut Vec<f64>,
    acc: &mut Vec<f64>,
) {
    let (ls, var, _) = (hyp[0], hyp[1], hyp[2]);
    assert_eq!(xc.len(), w * d);
    assert_eq!(mu_out.len(), w);
    assert_eq!(var_out.len(), w);
    for v in mu_out.iter_mut() {
        *v = 0.0;
    }
    for v in var_out.iter_mut() {
        *v = var;
    }
    if n == 0 || w == 0 {
        return;
    }
    debug_assert_eq!(factor.n(), n);
    debug_assert_eq!(alpha.len(), n);
    debug_assert_eq!(x.len(), n * d);

    // Row-block width of the blocked TRSM below.
    const TB: usize = PREDICT_ROW_BLOCK;
    ks.clear();
    ks.resize(n * w, 0.0);
    acc.clear();
    acc.resize(TB.min(n) * w, 0.0);

    // Cross-kernel block: row i = k(x_i, candidates), built as a
    // vectorized squared-distance row (bit-exact either dispatch arm)
    // plus an in-place Matérn map (vector exp under SIMD).
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let row = &mut ks[i * w..(i + 1) * w];
        simd::sqdist_row(xi, xc, d, row);
        simd::matern52_map_from_d2(ls, var, row);
    }

    // mu = Ks^T alpha, accumulated in ascending observation order
    // (the same order `predict` sums its dot product in).
    for i in 0..n {
        let row = &ks[i * w..(i + 1) * w];
        simd::axpy(&mut mu_out[..w], alpha[i], row);
    }

    // Blocked TRSM: Z = L^-1 Ks, all columns at once, rows in blocks
    // of TB. Row i: z_i = (ks_i - sum_{k<i} L[i,k] z_k) / L[i,i].
    // For each block the contribution of all *prior* blocks is
    // accumulated first (streaming each finished z_k row across the
    // whole block — the cache-friendly GEMM-shaped part), then the
    // small triangular block is solved in place. Per (row, column)
    // the inner sum still visits k in ascending order, so the
    // arithmetic is bit-identical to the per-column forward solve that
    // `predict` performs. `L` is indexed in its packed layout (row i at
    // offset i·(i+1)/2 — see `chol`'s module docs).
    let lmat = factor.packed();
    let rs = super::chol::packed_row_start;
    for rb in (0..n).step_by(TB) {
        let re = (rb + TB).min(n);
        for v in acc[..(re - rb) * w].iter_mut() {
            *v = 0.0;
        }
        let (done, rest) = ks.split_at_mut(rb * w);
        // GEMM part: acc[i - rb] += L[i, k] z_k for all k < rb.
        for k in 0..rb {
            let zk = &done[k * w..(k + 1) * w];
            for i in rb..re {
                let l = lmat[rs(i) + k];
                simd::axpy(&mut acc[(i - rb) * w..(i - rb + 1) * w], l, zk);
            }
        }
        // Triangular part: rows rb..re against freshly solved rows.
        for i in rb..re {
            let off = (i - rb) * w;
            let (prior, cur) = rest.split_at_mut(off);
            let row_i = &mut cur[..w];
            let a = &mut acc[off..off + w];
            for k in rb..i {
                let l = lmat[rs(i) + k];
                let zk = &prior[(k - rb) * w..(k - rb + 1) * w];
                simd::axpy(a, l, zk);
            }
            let diag = lmat[rs(i) + i];
            simd::sub_div(row_i, a, diag);
        }
    }

    // var = k(x,x) - |z|^2 per column, ascending observation order.
    for v in acc[..w].iter_mut() {
        *v = 0.0;
    }
    for i in 0..n {
        let zi = &ks[i * w..(i + 1) * w];
        simd::sq_accum(&mut acc[..w], zi);
    }
    for c in 0..w {
        var_out[c] = (var - acc[c]).max(VAR_FLOOR);
    }
}

/// Standardize targets to zero mean / unit variance; returns
/// (standardized, mean, std). (Near-)constant targets get std = 1 so the
/// standardized values are exactly ~zero instead of amplified rounding
/// noise. (A former `.max(1e-12)` pre-clamp sat dead in front of this
/// check — any value it produced was still below `1e-9`.)
pub fn standardize(y: &[f64]) -> (Vec<f64>, f64, f64) {
    let m = stats::mean(y);
    let s = stats::stddev(y);
    let s = if s < 1e-9 { 1.0 } else { s };
    (y.iter().map(|v| (v - m) / s).collect(), m, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_x(n: usize, d: usize) -> Vec<f64> {
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in 0..d {
                x.push(((i * 31 + j * 7) % 97) as f64 / 97.0);
            }
        }
        x
    }

    #[test]
    fn matern_at_zero_distance_is_variance() {
        let a = [0.3, 0.4];
        assert!((matern52(&a, &a, 0.5, 2.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn matern_decays() {
        let a = [0.0];
        assert!(matern52(&a, &[0.5], 1.0, 1.0) > matern52(&a, &[1.5], 1.0, 1.0));
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 5;
        // A = M M^T + n I is SPD
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    let mi = ((i * 13 + k * 5) % 11) as f64 / 11.0;
                    let mj = ((j * 13 + k * 5) % 11) as f64 / 11.0;
                    s += mi * mj;
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        let orig = a.clone();
        assert!(cholesky_in_place(&mut a, n));
        // recompute L L^T
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * a[j * n + k];
                }
                assert!((s - orig[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(!cholesky_in_place(&mut a, 2));
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let n = 4;
        let l = vec![
            2.0, 0.0, 0.0, 0.0, //
            0.5, 1.5, 0.0, 0.0, //
            0.3, 0.2, 1.0, 0.0, //
            0.1, 0.4, 0.6, 2.5,
        ];
        let b = [1.0, 2.0, 3.0, 4.0];
        let mut z = b;
        solve_lower_in_place(&l, n, &mut z);
        // check L z = b
        for i in 0..n {
            let s: f64 = (0..=i).map(|k| l[i * n + k] * z[k]).sum();
            assert!((s - b[i]).abs() < 1e-12);
        }
        let mut x = b;
        solve_upper_t_in_place(&l, n, &mut x);
        for i in 0..n {
            let s: f64 = (i..n).map(|k| l[k * n + i] * x[k]).sum();
            assert!((s - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn gp_interpolates_at_low_noise() {
        let n = 6;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n)
            .map(|i| x[i * d..(i + 1) * d].iter().sum::<f64>())
            .collect();
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, [0.8, 1.0, 1e-8]));
        for i in 0..n {
            let (mu, var) = gp.predict(&x[i * d..(i + 1) * d]);
            assert!((mu - y[i]).abs() < 1e-4, "mu {mu} vs {}", y[i]);
            assert!(var < 1e-4);
        }
    }

    #[test]
    fn posterior_variance_bounded_by_prior() {
        let n = 8;
        let d = 2;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, [0.5, 2.0, 1e-3]));
        let (_, var) = gp.predict(&[10.0, -4.0]); // far away -> prior
        assert!(var <= 2.0 + 1e-9 && var > 1.9);
    }

    #[test]
    fn ei_properties() {
        assert_eq!(expected_improvement(2.0, 0.0, 1.0), 0.0); // dominated, certain
        assert!((expected_improvement(0.0, 0.0, 1.0) - 1.0).abs() < 1e-12);
        // grows with sigma
        let e1 = expected_improvement(1.5, 0.25, 1.0);
        let e2 = expected_improvement(1.5, 1.0, 1.0);
        assert!(e2 > e1);
        // closed form check: mu=0, var=1, best=1
        let e = expected_improvement(0.0, 1.0, 1.0);
        let exact = 0.8413447 + 0.2419707;
        assert!((e - exact).abs() < 1e-4);
    }

    #[test]
    fn norm_cdf_accuracy() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.9750021).abs() < 1e-5);
        assert!((norm_cdf(-1.96) - 0.0249979).abs() < 1e-5);
    }

    #[test]
    fn nll_penalizes_bad_lengthscale() {
        // Smooth data: moderate lengthscale should beat a tiny one.
        let n = 10;
        let d = 1;
        let x: Vec<f64> = (0..n).map(|i| i as f64 / (n - 1) as f64).collect();
        let y: Vec<f64> = x.iter().map(|t| (3.0 * t).sin()).collect();
        let mut gp = NativeGp::new();
        gp.fit(&x, &y, n, d, [0.5, 1.0, 1e-4]);
        let nll_good = gp.nll(&y);
        gp.fit(&x, &y, n, d, [0.005, 1.0, 1e-4]);
        let nll_bad = gp.nll(&y);
        assert!(nll_good < nll_bad, "{nll_good} vs {nll_bad}");
    }

    #[test]
    fn predict_batch_matches_predict() {
        let n = 12;
        let d = 4;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.61).cos()).collect();
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, n, d, [0.6, 1.5, 1e-3]));
        let m = 20;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 17 + 5) % 89) as f64 / 89.0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        gp.predict_batch(&xc, m, None, &mut mu, &mut var);
        assert_eq!(mu.len(), m);
        assert_eq!(var.len(), m);
        for j in 0..m {
            let (mu1, var1) = gp.predict(&xc[j * d..(j + 1) * d]);
            assert!(
                (mu[j] - mu1).abs() <= 1e-12 * mu1.abs().max(1.0),
                "mu[{j}]: {} vs {}",
                mu[j],
                mu1
            );
            assert!((var[j] - var1).abs() <= 1e-12, "var[{j}]: {} vs {}", var[j], var1);
        }
    }

    #[test]
    fn predict_batch_mask_skips_columns() {
        let n = 8;
        let d = 3;
        let x = grid_x(n, d);
        let y: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.2).collect();
        let mut gp = NativeGp::new();
        let signal = 1.5;
        assert!(gp.fit(&x, &y, n, d, [0.5, signal, 1e-2]));
        let m = 10;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 13 + 3) % 71) as f64 / 71.0).collect();
        let mask: Vec<bool> = (0..m).map(|j| j % 2 == 0).collect();
        let mut mu = Vec::new();
        let mut var = Vec::new();
        gp.predict_batch(&xc, m, Some(&mask), &mut mu, &mut var);
        for j in 0..m {
            if mask[j] {
                let (mu1, var1) = gp.predict(&xc[j * d..(j + 1) * d]);
                assert!((mu[j] - mu1).abs() <= 1e-12, "mu[{j}]");
                assert!((var[j] - var1).abs() <= 1e-12, "var[{j}]");
            } else {
                // Masked columns skip all work and report the prior.
                assert_eq!(mu[j], 0.0, "masked mu[{j}]");
                assert_eq!(var[j], signal, "masked var[{j}]");
            }
        }
    }

    #[test]
    fn ei_certain_path_reachable_through_predict() {
        // A vanishing prior signal variance collapses every posterior
        // variance; with the aligned floors `predict` reports the
        // collapsed value (instead of inflating it to the old 1e-9) and
        // `expected_improvement` takes the certain-improvement branch.
        let d = 2;
        let x = [0.1, 0.2, 0.8, 0.7];
        let y = [2.0, 3.0];
        let mut gp = NativeGp::new();
        assert!(gp.fit(&x, &y, 2, d, [1.0, 1e-30, 0.0]));
        let (mu, var) = gp.predict(&[0.1, 0.2]);
        assert!(var <= 1e-24, "posterior variance {var} not collapsed");
        let best = 2.0;
        let ei = expected_improvement(mu, var, best);
        assert_eq!(ei, (best - mu).max(0.0), "EI must equal the certain improvement");
        assert!(ei > 1.0, "certain improvement should be ~{best}, got {ei}");
    }

    #[test]
    fn standardize_near_constant_uses_unit_scale() {
        let (z, _, s) = standardize(&[5.0, 5.0 + 1e-10, 5.0]);
        assert_eq!(s, 1.0);
        assert!(z.iter().all(|v| v.abs() < 1e-9));
    }

    #[test]
    fn standardize_roundtrip() {
        let y = [3.0, 5.0, 7.0, 9.0];
        let (z, m, s) = standardize(&y);
        assert!((crate::util::stats::mean(&z)).abs() < 1e-12);
        for (zi, yi) in z.iter().zip(&y) {
            assert!((zi * s + m - yi).abs() < 1e-12);
        }
        let (z2, _, s2) = standardize(&[4.0, 4.0, 4.0]);
        assert_eq!(s2, 1.0);
        assert!(z2.iter().all(|v| v.abs() < 1e-12));
    }
}
