//! Memory-usage time series of one profiling run — the data Fig. 3 plots
//! and the peak-extraction the memory readings come from.

/// One 1 Hz memory sample.
#[derive(Debug, Clone, Copy)]
pub struct MemSample {
    pub t_s: f64,
    pub used_gb: f64,
}

/// A full profiling-run memory trace.
#[derive(Debug, Clone)]
pub struct MemTimeSeries {
    pub samples: Vec<MemSample>,
    /// End of the data-loading ramp (seconds): readings before this are
    /// still ramping and excluded from the plateau estimate.
    pub load_end_s: f64,
}

impl MemTimeSeries {
    /// The stable peak: a high quantile of the post-ramp samples rather
    /// than the raw max, so one GC-jitter spike cannot inflate the
    /// reading (the aggressive-GC analog of §IV-B).
    pub fn stable_peak_gb(&self) -> f64 {
        let plateau: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.t_s >= self.load_end_s)
            .map(|s| s.used_gb)
            .collect();
        if plateau.is_empty() {
            return self.samples.iter().map(|s| s.used_gb).fold(0.0, f64::max);
        }
        crate::util::stats::quantile(&plateau, 0.5)
    }

    pub fn duration_s(&self) -> f64 {
        self.samples.last().map(|s| s.t_s).unwrap_or(0.0)
    }

    /// Export as (t, gb) rows for figure generation.
    pub fn as_rows(&self) -> Vec<(f64, f64)> {
        self.samples.iter().map(|s| (s.t_s, s.used_gb)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(values: &[f64], load_end: f64) -> MemTimeSeries {
        MemTimeSeries {
            samples: values
                .iter()
                .enumerate()
                .map(|(i, &v)| MemSample { t_s: i as f64, used_gb: v })
                .collect(),
            load_end_s: load_end,
        }
    }

    #[test]
    fn stable_peak_ignores_ramp() {
        // Ramp 0..4 then plateau at ~10.
        let s = series(&[0.0, 2.0, 4.0, 8.0, 10.0, 10.2, 9.9, 10.1, 10.0, 10.05], 4.0);
        let peak = s.stable_peak_gb();
        assert!((peak - 10.2).abs() < 0.2, "peak {peak}");
    }

    #[test]
    fn stable_peak_resists_spikes() {
        let mut vals = vec![10.0; 40];
        vals[20] = 25.0; // one-sample spike
        let s = series(&vals, 0.0);
        assert!(s.stable_peak_gb() < 12.0);
    }

    #[test]
    fn empty_plateau_falls_back_to_max() {
        let s = series(&[1.0, 2.0, 3.0], 99.0);
        assert_eq!(s.stable_peak_gb(), 3.0);
    }

    #[test]
    fn rows_roundtrip() {
        let s = series(&[1.0, 2.0], 0.0);
        assert_eq!(s.as_rows(), vec![(0.0, 1.0), (1.0, 2.0)]);
        assert_eq!(s.duration_s(), 1.0);
    }
}
