//! The single-node profiling substrate — the in-tree substitute for the
//! Crispy profiler the paper runs on a laptop (§III-B, DESIGN.md §4).
//!
//! Simulates: the dataset sampler with the 30–300 s runtime-targeting
//! controller, JVM memory time series with a GC sawtooth (Fig. 3),
//! aggressive-GC accounting, peak-memory extraction, and wall-clock
//! profiling-time bookkeeping (Table III).

mod controller;
mod memseries;

pub use controller::{ProfilingOutcome, ProfilingRun, SampleController};
pub use memseries::{MemSample, MemTimeSeries};

use crate::util::rng::Pcg64;
use crate::workload::{JobInstance, LaptopParams, MemBehavior};

/// Target runtime band for one profiling run (§III-B: "between 30 and 300
/// seconds, to reach sufficiently beyond the framework's initialization
/// phase, while also not making the profiling phase needlessly long").
pub const MIN_RUN_S: f64 = 30.0;
pub const MAX_RUN_S: f64 = 300.0;
/// Lower edge of the controller's accept window (see
/// `SampleController::calibrate`); MIN_RUN_S remains the validity floor
/// for a measurement.
pub const ACCEPT_MIN_S: f64 = 120.0;
/// Number of profiling runs at linearly spaced sample sizes (§III-B: the
/// adjusted sample plus "four more differently sized portions").
pub const N_PROFILE_RUNS: usize = 5;
/// Initial sample fraction of the original dataset (§III-B).
pub const INITIAL_FRACTION: f64 = 0.01;

/// The single-node profiler.
#[derive(Debug, Clone)]
pub struct SingleNodeProfiler {
    pub laptop: LaptopParams,
}

impl Default for SingleNodeProfiler {
    fn default() -> Self {
        Self { laptop: LaptopParams::default() }
    }
}

impl SingleNodeProfiler {
    pub fn new(laptop: LaptopParams) -> Self {
        Self { laptop }
    }

    /// Simulated wall-clock runtime (seconds) of the job on `sample_gb`
    /// of input on the profiling machine, with aggressive GC enabled.
    pub fn sample_runtime_s(&self, job: &JobInstance, sample_gb: f64) -> f64 {
        let l = &self.laptop;
        let eff_cores = l.cores * l.efficiency;
        let compute_s =
            sample_gb * job.algo.passes as f64 * job.algo.cpu_core_h_per_gb_pass * 3600.0
                / eff_cores;
        // Local SSD scan: ~ 300 GB/h effective.
        let io_s = sample_gb * job.algo.passes as f64 / 300.0 * 3600.0 * 0.3;
        l.startup_s + (compute_s + io_s) * l.gc_slowdown
    }

    /// Run the full profiling phase for a job: the sample-size controller
    /// followed by `N_PROFILE_RUNS` runs at linearly spaced sizes, memory
    /// monitoring included.
    pub fn profile(&self, job: &JobInstance, seed: u64) -> ProfilingOutcome {
        let mut rng = Pcg64::new(seed ^ job.job_id.wrapping_mul(0x9e3779b97f4a7c15), 17);
        let controller = SampleController::new(self, job);
        let (base_fraction, calibration) = controller.calibrate();

        let mut runs = Vec::with_capacity(N_PROFILE_RUNS);
        let mut total_s: f64 = calibration.iter().map(|r| r.runtime_s).sum();
        for k in 1..=N_PROFILE_RUNS {
            // Linearly spaced sample sizes: k/N of the calibrated sample.
            let fraction = base_fraction * k as f64 / N_PROFILE_RUNS as f64;
            let sample_gb = fraction * job.input_gb;
            let runtime_s = self.sample_runtime_s(job, sample_gb);
            let series = self.memory_series(job, sample_gb, runtime_s, &mut rng);
            let peak = series.stable_peak_gb() - self.laptop.base_mem_gb;
            runs.push(ProfilingRun {
                sample_gb,
                runtime_s,
                peak_mem_gb: peak.max(0.0),
                cancelled: false,
                series: Some(series),
            });
            total_s += runtime_s;
        }
        ProfilingOutcome { calibration, runs, total_s }
    }

    /// Generate the simulated memory time series of one profiling run —
    /// what Fig. 3 plots. 1 Hz sampling.
    pub fn memory_series(
        &self,
        job: &JobInstance,
        sample_gb: f64,
        runtime_s: f64,
        rng: &mut Pcg64,
    ) -> MemTimeSeries {
        let base = self.laptop.base_mem_gb;
        // The true in-memory footprint of this sample on the JVM heap.
        let plateau = match job.algo.mem_behavior {
            MemBehavior::Linear => job.algo.mem_coeff * sample_gb,
            // Flat jobs hold a fixed working set irrespective of input.
            MemBehavior::Flat => 1.15,
            // Noisy jobs: allocation outpaces GC; the observed plateau is
            // an erratic multiple of the nominal footprint. A slow phase
            // oscillation seeded per-run makes the five readings
            // non-collinear (unclear, 0.1 < R^2 < 0.99).
            MemBehavior::Noisy => {
                let phase = rng.uniform(0.0, std::f64::consts::TAU);
                let wobble = 1.0 + 0.55 * phase.sin() + 0.18 * rng.next_gaussian();
                (job.algo.mem_coeff * sample_gb * wobble.max(0.25))
                    .min(self.laptop.ram_gb * 0.8)
            }
        };
        // Small multiplicative measurement error on the plateau itself.
        let meas_noise = match job.algo.mem_behavior {
            MemBehavior::Linear => 1.0 + 0.004 * rng.next_gaussian(),
            MemBehavior::Flat => 1.0 + 0.05 * rng.next_gaussian(),
            MemBehavior::Noisy => 1.0,
        };
        let plateau = (plateau * meas_noise).max(0.05);

        let n = (runtime_s.ceil() as usize).max(8);
        let load_end = (0.25 * n as f64) as usize; // data-loading ramp
        let mut samples = Vec::with_capacity(n);
        let mut gc_phase = rng.uniform(0.0, 1.0);
        for t in 0..n {
            let target = if t < load_end {
                base + plateau * (t as f64 / load_end.max(1) as f64)
            } else {
                base + plateau
            };
            // GC sawtooth: garbage accumulates (~12% of plateau) and is
            // collected; aggressive GC keeps the amplitude small.
            gc_phase += rng.uniform(0.05, 0.15);
            if gc_phase > 1.0 {
                gc_phase -= 1.0;
            }
            let garbage = 0.06 * plateau * gc_phase;
            let jitter = 0.01 * plateau * rng.next_gaussian();
            samples.push(MemSample {
                t_s: t as f64,
                used_gb: (target + garbage + jitter).max(0.0),
            });
        }
        MemTimeSeries { samples, load_end_s: load_end as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{evaluation_jobs, Framework};

    fn job_by(name: &str, scale: &str) -> JobInstance {
        evaluation_jobs()
            .into_iter()
            .find(|j| j.algo.name == name && j.scale.name() == scale)
            .unwrap()
    }

    #[test]
    fn profiling_runs_hit_runtime_band() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs() {
            let out = p.profile(&job, 1);
            // The largest (calibrated) sample must be inside the band;
            // smaller ones may dip below but never above.
            let last = out.runs.last().unwrap();
            assert!(
                last.runtime_s >= MIN_RUN_S && last.runtime_s <= MAX_RUN_S,
                "{}: calibrated run {} s",
                job.label(),
                last.runtime_s
            );
            for r in &out.runs {
                assert!(r.runtime_s <= MAX_RUN_S + 1e-9);
            }
        }
    }

    #[test]
    fn five_runs_linearly_spaced() {
        let p = SingleNodeProfiler::default();
        let out = p.profile(&job_by("K-Means", "bigdata"), 2);
        assert_eq!(out.runs.len(), N_PROFILE_RUNS);
        let s0 = out.runs[0].sample_gb;
        for (k, r) in out.runs.iter().enumerate() {
            assert!((r.sample_gb - s0 * (k + 1) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn linear_job_readings_scale_linearly() {
        let p = SingleNodeProfiler::default();
        let out = p.profile(&job_by("K-Means", "bigdata"), 3);
        let xs: Vec<f64> = out.runs.iter().map(|r| r.sample_gb).collect();
        let ys: Vec<f64> = out.runs.iter().map(|r| r.peak_mem_gb).collect();
        let r2 = crate::util::stats::r2_score(&xs, &ys);
        assert!(r2 > 0.99, "K-Means readings R2 = {r2}");
    }

    #[test]
    fn flat_job_readings_categorize_flat() {
        // With five points the R^2 of iid noise averages 1/3, so the flat
        // check goes through the memory model's relative-growth guard.
        let p = SingleNodeProfiler::default();
        let out = p.profile(&job_by("Terasort", "bigdata"), 4);
        let model = crate::memmodel::MemoryModel::fit(&out.readings());
        assert_eq!(
            model.category,
            crate::memmodel::MemCategory::Flat,
            "r2 = {}, slope = {}",
            model.r2,
            model.slope_gb_per_gb
        );
    }

    #[test]
    fn profiling_time_plausible_table3_band() {
        // Table III: 110..1292 s per job, mean ~565 s.
        let p = SingleNodeProfiler::default();
        let mut totals = Vec::new();
        for job in evaluation_jobs() {
            let out = p.profile(&job, 5);
            assert!(
                out.total_s > 60.0 && out.total_s < 2000.0,
                "{}: {} s",
                job.label(),
                out.total_s
            );
            totals.push(out.total_s);
        }
        let mean = crate::util::stats::mean(&totals);
        assert!(
            (200.0..1000.0).contains(&mean),
            "mean profiling time {mean} s far from Table III's ~565 s"
        );
    }

    #[test]
    fn series_has_ramp_then_plateau() {
        let p = SingleNodeProfiler::default();
        let job = job_by("K-Means", "huge");
        let mut rng = Pcg64::from_seed(7);
        let s = p.memory_series(&job, 2.0, 120.0, &mut rng);
        assert!(s.samples.len() >= 120);
        let early = s.samples[2].used_gb;
        let late_avg: f64 = s.samples[60..].iter().map(|m| m.used_gb).sum::<f64>() / 60.0;
        assert!(late_avg > early, "no ramp: early {early} late {late_avg}");
    }

    #[test]
    fn memory_never_negative_or_absurd() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs() {
            let out = p.profile(&job, 8);
            for r in &out.runs {
                assert!(r.peak_mem_gb >= 0.0);
                assert!(
                    r.peak_mem_gb < p.laptop.ram_gb,
                    "{}: peak {} exceeds laptop RAM",
                    job.label(),
                    r.peak_mem_gb
                );
                if let Some(series) = &r.series {
                    assert!(series.samples.iter().all(|m| m.used_gb >= 0.0));
                }
            }
        }
    }

    #[test]
    fn hadoop_profiles_are_flat_band() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs().iter().filter(|j| j.algo.framework == Framework::Hadoop) {
            let out = p.profile(job, 9);
            let ys: Vec<f64> = out.runs.iter().map(|r| r.peak_mem_gb).collect();
            let spread = ys.iter().cloned().fold(0.0, f64::max)
                - ys.iter().cloned().fold(f64::MAX, f64::min);
            assert!(spread < 0.6, "{}: flat spread {spread}", job.label());
        }
    }
}
