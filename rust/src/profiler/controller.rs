//! The sample-size controller (§III-B): start at one percent of the
//! dataset, cancel runs that exceed the runtime ceiling and restart with
//! a smaller portion, grow samples whose runs finish too quickly — until
//! the run lands inside the 30–300 s band.

use super::{SingleNodeProfiler, ACCEPT_MIN_S, MAX_RUN_S};
#[cfg(test)]
use super::MIN_RUN_S;
use crate::workload::JobInstance;

/// One (possibly cancelled) profiling run with its memory reading.
#[derive(Debug, Clone)]
pub struct ProfilingRun {
    pub sample_gb: f64,
    pub runtime_s: f64,
    pub peak_mem_gb: f64,
    /// True for calibration runs aborted at the ceiling.
    pub cancelled: bool,
    /// Full memory time series (present for the measurement runs).
    pub series: Option<super::MemTimeSeries>,
}

/// Result of the whole profiling phase for one job.
#[derive(Debug, Clone)]
pub struct ProfilingOutcome {
    /// Calibration runs spent finding a sample size in the runtime band.
    pub calibration: Vec<ProfilingRun>,
    /// The five measurement runs at linearly spaced sample sizes.
    pub runs: Vec<ProfilingRun>,
    /// Total wall-clock profiling time in seconds (Table III).
    pub total_s: f64,
}

impl ProfilingOutcome {
    /// (sample_gb, peak_mem_gb) pairs for the memory model.
    pub fn readings(&self) -> Vec<(f64, f64)> {
        self.runs.iter().map(|r| (r.sample_gb, r.peak_mem_gb)).collect()
    }

    /// [`Self::readings`] restricted to valid measurements: finite
    /// pairs from runs that were not cancelled at the runtime ceiling.
    /// This is what the memory model should be fitted on — a truncated
    /// profiling phase (crashed runs, < 2 survivors) then degrades to
    /// an `Unclear` fit instead of extrapolating from garbage.
    pub fn valid_readings(&self) -> Vec<(f64, f64)> {
        self.runs
            .iter()
            .filter(|r| !r.cancelled && r.sample_gb.is_finite() && r.peak_mem_gb.is_finite())
            .map(|r| (r.sample_gb, r.peak_mem_gb))
            .collect()
    }
}

/// Iteratively adjusts the sample fraction until the profiling run lands
/// inside the target runtime band.
pub struct SampleController<'a> {
    profiler: &'a SingleNodeProfiler,
    job: &'a JobInstance,
}

impl<'a> SampleController<'a> {
    pub fn new(profiler: &'a SingleNodeProfiler, job: &'a JobInstance) -> Self {
        Self { profiler, job }
    }

    /// Find the base sample fraction; returns it with the calibration
    /// runs performed (whose wall-clock time counts toward Table III).
    ///
    /// The accept window is [ACCEPT_MIN_S, MAX_RUN_S] — tighter than the
    /// 30 s validity floor — so both dataset scales of an algorithm
    /// converge to the *same absolute sample size*, which is what makes
    /// the paper's Table III times identical across "huge"/"bigdata"
    /// (§IV-D: the overhead is irrespective of the full dataset size).
    pub fn calibrate(&self) -> (f64, Vec<ProfilingRun>) {
        // Aim at the center of the accept window so all five linearly
        // spaced sub-samples stay under the ceiling and the largest stays
        // above the floor.
        let target_s = 0.55 * MAX_RUN_S;
        let mut fraction = super::INITIAL_FRACTION;
        let mut runs = Vec::new();
        for _ in 0..8 {
            let sample_gb = fraction * self.job.input_gb;
            let runtime = self.profiler.sample_runtime_s(self.job, sample_gb);
            if runtime > MAX_RUN_S {
                // Cancel at the ceiling (the paper cancels over-long runs)
                // and retry smaller.
                runs.push(ProfilingRun {
                    sample_gb,
                    runtime_s: MAX_RUN_S,
                    peak_mem_gb: 0.0,
                    cancelled: true,
                    series: None,
                });
                fraction *= (target_s / runtime).max(0.05);
                continue;
            }
            if runtime < ACCEPT_MIN_S {
                // Too fast: the run completes, its time is spent, but the
                // reading is discarded and the sample grows.
                runs.push(ProfilingRun {
                    sample_gb,
                    runtime_s: runtime,
                    peak_mem_gb: 0.0,
                    cancelled: false,
                    series: None,
                });
                // Runtime has a fixed startup component, so scale by the
                // *variable* part to avoid overshooting.
                let startup = self.profiler.laptop.startup_s;
                let variable = (runtime - startup).max(1.0);
                fraction *= ((target_s - startup) / variable).clamp(1.5, 50.0);
                // Never exceed the full dataset.
                fraction = fraction.min(1.0);
                continue;
            }
            return (fraction, runs);
        }
        // Give up adjusting; use the last fraction (still deterministic).
        (fraction.min(1.0), runs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::evaluation_jobs;

    #[test]
    fn calibration_converges_for_all_jobs() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs() {
            let c = SampleController::new(&p, &job);
            let (fraction, _) = c.calibrate();
            let runtime = p.sample_runtime_s(&job, fraction * job.input_gb);
            assert!(
                (MIN_RUN_S..=MAX_RUN_S).contains(&runtime),
                "{}: fraction {fraction} gives {runtime} s",
                job.label()
            );
        }
    }

    #[test]
    fn calibration_fraction_reasonable() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs() {
            let (fraction, _) = SampleController::new(&p, &job).calibrate();
            assert!(fraction > 0.0 && fraction <= 1.0, "{}: {fraction}", job.label());
        }
    }

    #[test]
    fn cancelled_runs_capped_at_ceiling() {
        let p = SingleNodeProfiler::default();
        for job in evaluation_jobs() {
            let (_, runs) = SampleController::new(&p, &job).calibrate();
            for r in runs {
                if r.cancelled {
                    assert_eq!(r.runtime_s, MAX_RUN_S);
                }
            }
        }
    }
}
