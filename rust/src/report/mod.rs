//! Result rendering: the paper's tables as aligned text/markdown and the
//! figures as gnuplot-style `.dat` series, plus JSON export for
//! downstream tooling.

use crate::coordinator::{ExperimentResult, PipelineOutcome, ProfileSummary, THRESHOLDS};
use crate::util::json::JsonWriter;
use std::fmt::Write as _;

/// Render Table I (determined job memory requirement).
pub fn render_table1(summaries: &[ProfileSummary]) -> String {
    let mut t = TextTable::new(&["Job", "Result (Table I analogue)", "R^2"]);
    for s in summaries {
        t.row(&[s.label.clone(), s.table1_cell.clone(), format!("{:.3}", s.model.r2)]);
    }
    t.render()
}

/// Render Table III (memory profiling time for all jobs).
pub fn render_table3(summaries: &[ProfileSummary]) -> String {
    let mut t = TextTable::new(&["Job", "Time (s)"]);
    let mut total = 0.0;
    for s in summaries {
        t.row(&[s.label.clone(), format!("{:.0}", s.profiling_time_s)]);
        total += s.profiling_time_s;
    }
    t.row(&["Mean".to_string(), format!("{:.0}", total / summaries.len() as f64)]);
    t.render()
}

/// Render Table II (iterations to c<=1.2 / c<=1.1 / c=1.0).
pub fn render_table2(result: &ExperimentResult) -> String {
    let mut t = TextTable::new(&[
        "Job", "Cat.", "CP<=1.2", "CP<=1.1", "CP=1.0", "Ruya<=1.2", "Ruya<=1.1", "Ruya=1.0",
        "Q<=1.2", "Q<=1.1", "Q=1.0",
    ]);
    for j in &result.jobs {
        let q = j.quotient();
        t.row(&[
            j.label.clone(),
            j.category.name().to_string(),
            format!("{:.3}", j.cherrypick.iters_to[0]),
            format!("{:.3}", j.cherrypick.iters_to[1]),
            format!("{:.3}", j.cherrypick.iters_to[2]),
            format!("{:.3}", j.ruya.iters_to[0]),
            format!("{:.3}", j.ruya.iters_to[1]),
            format!("{:.3}", j.ruya.iters_to[2]),
            format!("{:.1}%", q[0] * 100.0),
            format!("{:.1}%", q[1] * 100.0),
            format!("{:.1}%", q[2] * 100.0),
        ]);
    }
    t.row(&[
        "Mean".to_string(),
        String::new(),
        format!("{:.3}", result.mean_cherrypick[0]),
        format!("{:.3}", result.mean_cherrypick[1]),
        format!("{:.3}", result.mean_cherrypick[2]),
        format!("{:.3}", result.mean_ruya[0]),
        format!("{:.3}", result.mean_ruya[1]),
        format!("{:.3}", result.mean_ruya[2]),
        format!("{:.1}%", result.mean_quotient[0] * 100.0),
        format!("{:.1}%", result.mean_quotient[1] * 100.0),
        format!("{:.1}%", result.mean_quotient[2] * 100.0),
    ]);
    t.render()
}

/// Averaged per-iteration series (Fig. 4 / Fig. 5) as a `.dat` block:
/// `iteration  cherrypick  ruya`.
pub fn render_series(cherrypick: &[f64], ruya: &[f64], header: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {header}");
    let _ = writeln!(s, "# iter  cherrypick  ruya");
    for i in 0..cherrypick.len().min(ruya.len()) {
        let _ = writeln!(s, "{:3}  {:10.5}  {:10.5}", i + 1, cherrypick[i], ruya[i]);
    }
    s
}

/// Export the full experiment result as JSON.
pub fn experiment_to_json(result: &ExperimentResult) -> String {
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("jobs").begin_array();
    for j in &result.jobs {
        w.begin_object();
        w.key("label").string(&j.label);
        w.key("category").string(j.category.name());
        if let Some(req) = j.requirement_gb {
            w.key("requirement_gb").number(req);
        }
        w.key("priority_fraction").number(j.priority_fraction);
        for (name, stats) in [("cherrypick", &j.cherrypick), ("ruya", &j.ruya)] {
            w.key(name).begin_object();
            w.key("iters_to").begin_array();
            for v in stats.iters_to {
                w.number(v);
            }
            w.end_array();
            w.key("mean_stop").number(stats.mean_stop);
            w.end_object();
        }
        w.key("quotient").begin_array();
        for v in j.quotient() {
            w.number(v);
        }
        w.end_array();
        w.end_object();
    }
    w.end_array();
    for (name, vals) in [
        ("mean_cherrypick", &result.mean_cherrypick),
        ("mean_ruya", &result.mean_ruya),
        ("mean_quotient", &result.mean_quotient),
    ] {
        w.key(name).begin_array();
        for v in vals.iter() {
            w.number(*v);
        }
        w.end_array();
    }
    w.end_object();
    w.finish()
}

/// Render the end-to-end pipeline experiment matrix: per job, the
/// shortlist narrowing and the narrowed-vs-full-catalog search at an
/// equal iteration `budget` ("-" = threshold not reached in budget, or
/// no observation at all under a zero budget; the quotient column is
/// "n/a" unless BOTH searches reached the threshold). Warm-start
/// columns appear only when at least one outcome ran the transfer leg.
pub fn render_pipeline_matrix(outcomes: &[PipelineOutcome], budget: usize) -> String {
    let fmt_iters = |it: Option<usize>| match it {
        Some(k) => k.to_string(),
        None => "-".to_string(),
    };
    let fmt_best = |b: f64| if b.is_finite() { format!("{b:.4}") } else { "-".to_string() };
    let fmt_quot = |q: Option<f64>| match q {
        Some(q) => format!("{:.1}%", q * 100.0),
        None => "n/a".to_string(),
    };
    let warm_cols = outcomes.iter().any(|o| o.warm.is_some());
    let mut headers = vec![
        "Job",
        "Cat.",
        "Shortlist",
        "Narrow<=1.1",
        "Full<=1.1",
        "Q<=1.1",
        "Narrow best",
        "Full best",
        "Crispy",
        "Profiling s",
    ];
    if warm_cols {
        headers.push("Warm<=1.1");
        headers.push("Warm best");
    }
    let mut t = TextTable::new(&headers);
    for o in outcomes {
        let mut cells = vec![
            o.label.clone(),
            o.category.name().to_string(),
            format!("{}/{}", o.shortlist_len, o.catalog_len),
            fmt_iters(o.narrowed_iters_to(THRESHOLDS[1])),
            fmt_iters(o.full_iters_to(THRESHOLDS[1])),
            fmt_quot(o.quotient(THRESHOLDS[1])),
            fmt_best(o.narrowed.best_after(budget)),
            fmt_best(o.full.best_after(budget)),
            format!("{:.4}", o.crispy_cost),
            format!("{:.0}", o.profiling_time_s),
        ];
        if warm_cols {
            cells.push(fmt_iters(o.warm_iters_to(THRESHOLDS[1])));
            cells.push(match &o.warm {
                Some(w) => fmt_best(w.best_after(budget)),
                None => "-".to_string(),
            });
        }
        t.row(&cells);
    }
    t.render()
}

/// Export the pipeline experiment matrix as JSON.
pub fn pipeline_to_json(outcomes: &[PipelineOutcome], budget: usize, seed: u64) -> String {
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("budget").number(budget as f64);
    w.key("seed").number(seed as f64);
    w.key("jobs").begin_array();
    for o in outcomes {
        w.begin_object();
        w.key("label").string(&o.label);
        w.key("category").string(o.category.name());
        if let Some(req) = o.requirement_gb {
            w.key("requirement_gb").number(req);
        }
        w.key("r2").number(o.r2);
        w.key("profiling_time_s").number(o.profiling_time_s);
        w.key("catalog_len").number(o.catalog_len as f64);
        w.key("shortlist_len").number(o.shortlist_len as f64);
        w.key("engaged").boolean(o.engaged());
        if let Some((lo, hi)) = o.shortlist_mem_gb {
            w.key("shortlist_mem_gb").begin_array();
            w.number(lo);
            w.number(hi);
            w.end_array();
        }
        w.key("crispy_cost").number(o.crispy_cost);
        for (name, iters, best) in [
            ("narrowed", &o.narrowed, o.narrowed.best_after(budget)),
            ("full", &o.full, o.full.best_after(budget)),
        ] {
            w.key(name).begin_object();
            w.key("iters_to").begin_array();
            for thr in THRESHOLDS {
                match iters.first_within(thr) {
                    Some(k) => w.number(k as f64),
                    None => w.null(),
                };
            }
            w.end_array();
            w.key("tried").number(iters.tried.len() as f64);
            w.key("best").number(best);
            w.end_object();
        }
        if let Some(warm) = &o.warm {
            w.key("warm").begin_object();
            w.key("iters_to").begin_array();
            for thr in THRESHOLDS {
                match warm.first_within(thr) {
                    Some(k) => w.number(k as f64),
                    None => w.null(),
                };
            }
            w.end_array();
            w.key("tried").number(warm.tried.len() as f64);
            w.key("best").number(warm.best_after(budget));
            w.key("seeds_offered").number(o.warm_seeds as f64);
            w.end_object();
        }
        // The headline quotient is always present: null (not omitted)
        // unless both searches reached the threshold, so downstream
        // tooling can tell "not measured" from "key missing".
        match o.quotient(THRESHOLDS[1]) {
            Some(q) => w.key("quotient_1_1").number(q),
            None => w.key("quotient_1_1").null(),
        };
        w.end_object();
    }
    w.end_array();
    w.end_object();
    w.finish()
}

/// Fixed-width text table with a markdown-ish separator row.
pub struct TextTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:width$} |", cells[i], width = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::{hyperparameter_grid, SearchOutcome};
    use crate::memmodel::MemCategory;

    fn outcome(costs: Vec<f64>) -> SearchOutcome {
        SearchOutcome {
            tried: (0..costs.len()).collect(),
            costs,
            stop_after: None,
            phase_starts: vec![0],
            grid_hits: vec![0; hyperparameter_grid().len()],
        }
    }

    fn pipeline_outcome(narrowed: Vec<f64>, full: Vec<f64>) -> PipelineOutcome {
        PipelineOutcome {
            label: "job".to_string(),
            category: MemCategory::Linear,
            requirement_gb: Some(100.0),
            r2: 0.99,
            profiling_time_s: 120.0,
            catalog_len: 69,
            shortlist_len: 12,
            shortlist_mem_gb: Some((100.0, 600.0)),
            crispy_cost: 1.3,
            narrowed: outcome(narrowed),
            full: outcome(full),
            warm: None,
            warm_seeds: 0,
        }
    }

    #[test]
    fn quotient_is_na_unless_both_sides_reached() {
        // Narrowed reaches 1.1, full never does: no quotient.
        let one_sided = pipeline_outcome(vec![1.05], vec![1.5, 1.4]);
        let text = render_pipeline_matrix(&[one_sided.clone()], 4);
        assert!(text.contains(" n/a "), "one-sided quotient must render n/a:\n{text}");
        let json = pipeline_to_json(&[one_sided], 4, 7);
        assert!(
            json.contains("\"quotient_1_1\":null"),
            "one-sided quotient must be JSON null: {json}"
        );
        // Both reach: a percentage and a JSON number.
        let both = pipeline_outcome(vec![1.05], vec![1.5, 1.05]);
        let text = render_pipeline_matrix(&[both.clone()], 4);
        assert!(text.contains("50.0%"), "1/2 quotient expected:\n{text}");
        let json = pipeline_to_json(&[both], 4, 7);
        assert!(json.contains("\"quotient_1_1\":0.5"), "{json}");
    }

    #[test]
    fn zero_budget_outcomes_render_without_inf() {
        // A zero-budget run has empty traces: best is -inf-free "-",
        // iteration cells are "-", the quotient is n/a.
        let empty = pipeline_outcome(vec![], vec![]);
        let text = render_pipeline_matrix(&[empty.clone()], 0);
        assert!(!text.contains("inf"), "non-finite best must not leak:\n{text}");
        assert!(text.contains(" n/a "), "{text}");
        let json = pipeline_to_json(&[empty], 0, 7);
        assert!(!json.contains("inf"), "{json}");
        assert!(json.contains("\"best\":null"), "non-finite best must be null: {json}");
    }

    #[test]
    fn warm_columns_appear_only_with_a_warm_leg() {
        let cold = pipeline_outcome(vec![1.05], vec![1.05]);
        let text = render_pipeline_matrix(&[cold.clone()], 4);
        assert!(!text.contains("Warm<=1.1"), "{text}");
        let json = pipeline_to_json(&[cold.clone()], 4, 7);
        assert!(!json.contains("\"warm\""), "{json}");

        let mut warm = cold;
        warm.warm = Some(outcome(vec![1.02]));
        warm.warm_seeds = 3;
        let text = render_pipeline_matrix(&[warm.clone()], 4);
        assert!(text.contains("Warm<=1.1") && text.contains("Warm best"), "{text}");
        let json = pipeline_to_json(&[warm], 4, 7);
        assert!(json.contains("\"warm\":{"), "{json}");
        assert!(json.contains("\"seeds_offered\":3"), "{json}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["a", "bbbb"]);
        t.row(&["xx".into(), "1".into()]);
        t.row(&["y".into(), "123456".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let widths: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(widths.iter().all(|&w| w == widths[0]), "{s}");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn series_block_format() {
        let s = render_series(&[3.0, 2.0], &[2.5, 1.5], "fig4");
        assert!(s.starts_with("# fig4"));
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("  1 "));
    }
}
