//! Result rendering: the paper's tables as aligned text/markdown and the
//! figures as gnuplot-style `.dat` series, plus JSON export for
//! downstream tooling.

use crate::coordinator::{ExperimentResult, PipelineOutcome, ProfileSummary, THRESHOLDS};
use crate::util::json::JsonWriter;
use std::fmt::Write as _;

/// Render Table I (determined job memory requirement).
pub fn render_table1(summaries: &[ProfileSummary]) -> String {
    let mut t = TextTable::new(&["Job", "Result (Table I analogue)", "R^2"]);
    for s in summaries {
        t.row(&[s.label.clone(), s.table1_cell.clone(), format!("{:.3}", s.model.r2)]);
    }
    t.render()
}

/// Render Table III (memory profiling time for all jobs).
pub fn render_table3(summaries: &[ProfileSummary]) -> String {
    let mut t = TextTable::new(&["Job", "Time (s)"]);
    let mut total = 0.0;
    for s in summaries {
        t.row(&[s.label.clone(), format!("{:.0}", s.profiling_time_s)]);
        total += s.profiling_time_s;
    }
    t.row(&["Mean".to_string(), format!("{:.0}", total / summaries.len() as f64)]);
    t.render()
}

/// Render Table II (iterations to c<=1.2 / c<=1.1 / c=1.0).
pub fn render_table2(result: &ExperimentResult) -> String {
    let mut t = TextTable::new(&[
        "Job", "Cat.", "CP<=1.2", "CP<=1.1", "CP=1.0", "Ruya<=1.2", "Ruya<=1.1", "Ruya=1.0",
        "Q<=1.2", "Q<=1.1", "Q=1.0",
    ]);
    for j in &result.jobs {
        let q = j.quotient();
        t.row(&[
            j.label.clone(),
            j.category.name().to_string(),
            format!("{:.3}", j.cherrypick.iters_to[0]),
            format!("{:.3}", j.cherrypick.iters_to[1]),
            format!("{:.3}", j.cherrypick.iters_to[2]),
            format!("{:.3}", j.ruya.iters_to[0]),
            format!("{:.3}", j.ruya.iters_to[1]),
            format!("{:.3}", j.ruya.iters_to[2]),
            format!("{:.1}%", q[0] * 100.0),
            format!("{:.1}%", q[1] * 100.0),
            format!("{:.1}%", q[2] * 100.0),
        ]);
    }
    t.row(&[
        "Mean".to_string(),
        String::new(),
        format!("{:.3}", result.mean_cherrypick[0]),
        format!("{:.3}", result.mean_cherrypick[1]),
        format!("{:.3}", result.mean_cherrypick[2]),
        format!("{:.3}", result.mean_ruya[0]),
        format!("{:.3}", result.mean_ruya[1]),
        format!("{:.3}", result.mean_ruya[2]),
        format!("{:.1}%", result.mean_quotient[0] * 100.0),
        format!("{:.1}%", result.mean_quotient[1] * 100.0),
        format!("{:.1}%", result.mean_quotient[2] * 100.0),
    ]);
    t.render()
}

/// Averaged per-iteration series (Fig. 4 / Fig. 5) as a `.dat` block:
/// `iteration  cherrypick  ruya`.
pub fn render_series(cherrypick: &[f64], ruya: &[f64], header: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {header}");
    let _ = writeln!(s, "# iter  cherrypick  ruya");
    for i in 0..cherrypick.len().min(ruya.len()) {
        let _ = writeln!(s, "{:3}  {:10.5}  {:10.5}", i + 1, cherrypick[i], ruya[i]);
    }
    s
}

/// Export the full experiment result as JSON.
pub fn experiment_to_json(result: &ExperimentResult) -> String {
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("jobs").begin_array();
    for j in &result.jobs {
        w.begin_object();
        w.key("label").string(&j.label);
        w.key("category").string(j.category.name());
        if let Some(req) = j.requirement_gb {
            w.key("requirement_gb").number(req);
        }
        w.key("priority_fraction").number(j.priority_fraction);
        for (name, stats) in [("cherrypick", &j.cherrypick), ("ruya", &j.ruya)] {
            w.key(name).begin_object();
            w.key("iters_to").begin_array();
            for v in stats.iters_to {
                w.number(v);
            }
            w.end_array();
            w.key("mean_stop").number(stats.mean_stop);
            w.end_object();
        }
        w.key("quotient").begin_array();
        for v in j.quotient() {
            w.number(v);
        }
        w.end_array();
        w.end_object();
    }
    w.end_array();
    for (name, vals) in [
        ("mean_cherrypick", &result.mean_cherrypick),
        ("mean_ruya", &result.mean_ruya),
        ("mean_quotient", &result.mean_quotient),
    ] {
        w.key(name).begin_array();
        for v in vals.iter() {
            w.number(*v);
        }
        w.end_array();
    }
    w.end_object();
    w.finish()
}

/// Render the end-to-end pipeline experiment matrix: per job, the
/// shortlist narrowing and the narrowed-vs-full-catalog search at an
/// equal iteration `budget` ("-" = threshold not reached in budget).
pub fn render_pipeline_matrix(outcomes: &[PipelineOutcome], budget: usize) -> String {
    let fmt_iters = |it: Option<usize>| match it {
        Some(k) => k.to_string(),
        None => "-".to_string(),
    };
    let mut t = TextTable::new(&[
        "Job",
        "Cat.",
        "Shortlist",
        "Narrow<=1.1",
        "Full<=1.1",
        "Narrow best",
        "Full best",
        "Crispy",
        "Profiling s",
    ]);
    for o in outcomes {
        t.row(&[
            o.label.clone(),
            o.category.name().to_string(),
            format!("{}/{}", o.shortlist_len, o.catalog_len),
            fmt_iters(o.narrowed_iters_to(THRESHOLDS[1])),
            fmt_iters(o.full_iters_to(THRESHOLDS[1])),
            format!("{:.4}", o.narrowed.best_after(budget)),
            format!("{:.4}", o.full.best_after(budget)),
            format!("{:.4}", o.crispy_cost),
            format!("{:.0}", o.profiling_time_s),
        ]);
    }
    t.render()
}

/// Export the pipeline experiment matrix as JSON.
pub fn pipeline_to_json(outcomes: &[PipelineOutcome], budget: usize, seed: u64) -> String {
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("budget").number(budget as f64);
    w.key("seed").number(seed as f64);
    w.key("jobs").begin_array();
    for o in outcomes {
        w.begin_object();
        w.key("label").string(&o.label);
        w.key("category").string(o.category.name());
        if let Some(req) = o.requirement_gb {
            w.key("requirement_gb").number(req);
        }
        w.key("r2").number(o.r2);
        w.key("profiling_time_s").number(o.profiling_time_s);
        w.key("catalog_len").number(o.catalog_len as f64);
        w.key("shortlist_len").number(o.shortlist_len as f64);
        w.key("engaged").boolean(o.engaged());
        if let Some((lo, hi)) = o.shortlist_mem_gb {
            w.key("shortlist_mem_gb").begin_array();
            w.number(lo);
            w.number(hi);
            w.end_array();
        }
        w.key("crispy_cost").number(o.crispy_cost);
        for (name, iters, best) in [
            ("narrowed", &o.narrowed, o.narrowed.best_after(budget)),
            ("full", &o.full, o.full.best_after(budget)),
        ] {
            w.key(name).begin_object();
            w.key("iters_to").begin_array();
            for thr in THRESHOLDS {
                match iters.first_within(thr) {
                    Some(k) => w.number(k as f64),
                    None => w.null(),
                };
            }
            w.end_array();
            w.key("tried").number(iters.tried.len() as f64);
            w.key("best").number(best);
            w.end_object();
        }
        if let Some(q) = o.quotient(THRESHOLDS[1]) {
            w.key("quotient_1_1").number(q);
        }
        w.end_object();
    }
    w.end_array();
    w.end_object();
    w.finish()
}

/// Fixed-width text table with a markdown-ish separator row.
pub struct TextTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:width$} |", cells[i], width = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["a", "bbbb"]);
        t.row(&["xx".into(), "1".into()]);
        t.row(&["y".into(), "123456".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let widths: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(widths.iter().all(|&w| w == widths[0]), "{s}");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn series_block_format() {
        let s = render_series(&[3.0, 2.0], &[2.5, 1.5], "fig4");
        assert!(s.starts_with("# fig4"));
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("  1 "));
    }
}
