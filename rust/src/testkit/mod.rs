//! In-tree property-testing mini-framework (the `proptest` crate is not
//! available offline). Seeded generators + a runner that, on failure,
//! re-runs a bisection-style shrink over the generator's size parameter
//! and reports the failing seed for reproduction.
//!
//! Usage:
//! ```ignore
//! use ruya::testkit::{Gen, property};
//! property("costs are normalized", 100, |g| {
//!     let n = g.usize_in(1, 50);
//!     let xs = g.vec_f64(n, 0.0, 10.0);
//!     // assert something; return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Seeded value generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Size hint in [0, 1]: shrinking retries properties at smaller sizes.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg64::from_seed(seed), size }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Integer in [lo, hi], scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + self.rng.next_below(scaled + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len())]
    }

    /// A random subset of 0..n of size k.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_distinct(n, k.min(n))
    }
}

/// Result type properties return: Err carries the violation description.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of a property. Panics with the seed and the
/// smallest failing size on violation.
pub fn property<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: u64, mut prop: F) {
    // Environment override for reproduction: RUYA_PROP_SEED=<seed>
    let base = std::env::var("RUYA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9E3779B97F4A7C15u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x2545F4914F6CDD1D));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed at smaller generator sizes and
            // report the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for step in 1..=8 {
                let size = 1.0 - step as f64 / 9.0;
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property {name:?} failed (seed {seed:#x}, smallest failing size {:.2}):\n  {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// An observation script for the backend parity harness: a shared pool
/// of feature rows/targets plus a sequence of `[start, start+n)` windows
/// to present to both backends in order. Consecutive windows encode the
/// same deltas the search loop produces — `(0,n) -> (0,n+1)` is an
/// append, `(s,n) -> (s+1,n)` a window slide, anything else a wholesale
/// replace — so the script drives a `NativeBackend`'s incremental caches
/// through exactly the paths under test.
#[derive(Debug, Clone)]
pub struct ParityScript {
    d: usize,
    rows: Vec<f64>,
    ys: Vec<f64>,
    steps: Vec<(usize, usize)>,
}

impl ParityScript {
    /// A script over `rows` (row-major, `d` columns) with targets `ys`,
    /// starting with no windows; chain the builders below.
    pub fn new(rows: Vec<f64>, ys: Vec<f64>, d: usize) -> Self {
        assert!(d > 0 && rows.len() == ys.len() * d, "rows/ys shape mismatch");
        Self { d, rows, ys, steps: Vec::new() }
    }

    /// Total observation rows in the pool.
    pub fn pool_len(&self) -> usize {
        self.ys.len()
    }

    /// Append one explicit window `[start, start+n)`.
    pub fn push_window(mut self, start: usize, n: usize) -> Self {
        assert!(n > 0 && start + n <= self.ys.len(), "window out of pool bounds");
        self.steps.push((start, n));
        self
    }

    /// Append growth windows `(0,1), (0,2), …, (0,upto)` — one append
    /// delta per step.
    pub fn growth(mut self, upto: usize) -> Self {
        assert!(upto <= self.ys.len());
        for n in 1..=upto {
            self.steps.push((0, n));
        }
        self
    }

    /// Append `count` sliding windows of width `window` starting at
    /// start offset 1 — one slide delta per step (call after
    /// [`Self::growth`]`(window)`).
    pub fn slides(mut self, window: usize, count: usize) -> Self {
        for s in 1..=count {
            assert!(s + window <= self.ys.len(), "slide past the pool end");
            self.steps.push((s, window));
        }
        self
    }

    /// The windows of the script.
    pub fn steps(&self) -> &[(usize, usize)] {
        &self.steps
    }

    /// The pooled feature rows (row-major, [`Self::dim`] columns). The
    /// session suspend/resume harnesses reuse the pool as a candidate
    /// space, so a fuzz corpus drives both the backend parity suites and
    /// the resumption pins from one description.
    pub fn rows(&self) -> &[f64] {
        &self.rows
    }

    /// The pooled targets, parallel to [`Self::rows`].
    pub fn ys(&self) -> &[f64] {
        &self.ys
    }

    /// Suspend/resume cut points: every prefix boundary of the script,
    /// `0..=steps.len()`. The suspend/resume harnesses pause a search
    /// after each cut (clamping to the search's actual round count),
    /// serialize, resume, and require the continuation to match the
    /// uninterrupted run to the bit — cutting at *every* boundary rules
    /// out "resume only works at phase edges" regressions.
    pub fn cut_points(&self) -> Vec<usize> {
        (0..=self.steps.len()).collect()
    }

    /// Feature dimension of the pooled rows (candidate matrices handed
    /// to the parity harnesses must use the same width).
    pub fn dim(&self) -> usize {
        self.d
    }
}

/// Seeded random [`ParityScript`] programs for the parity **fuzz**
/// suites (`tests/fuzz_parity.rs`, the bench smoke guards): each script
/// draws its own dimension, row pool and an op sequence biased toward
/// the search loop's append/slide deltas, with occasional wholesale
/// window jumps (replace) and repeated windows (unchanged). Fully
/// deterministic in `(seed, count)` — a failing script is reproduced by
/// its reported seed and index alone.
pub fn random_scripts(seed: u64, count: usize) -> Vec<ParityScript> {
    (0..count)
        .map(|i| {
            // One independent, seedable stream per script, so script i
            // reproduces without generating its predecessors.
            let mut r = Pcg64::new(seed, 0x5C21_F0ED ^ (i as u64).wrapping_mul(0x9E37));
            random_script(&mut r)
        })
        .collect()
}

fn random_script(r: &mut Pcg64) -> ParityScript {
    let d = 2 + r.next_below(4); // 2..=5 features
    let pool = 8 + r.next_below(9); // 8..=16 rows
    let rows: Vec<f64> = (0..pool * d).map(|_| r.uniform(0.0, 1.0)).collect();
    let ys: Vec<f64> = (0..pool).map(|_| r.uniform(0.5, 2.0)).collect();
    // A short growth prefix seeds the append path; the op loop then
    // mixes appends (biased — the search loop's common delta), slides
    // and replaces.
    let start_n = 1 + r.next_below(3); // 1..=3
    let mut script = ParityScript::new(rows, ys, d).growth(start_n);
    let (mut start, mut n) = (0usize, start_n);
    let ops = 6 + r.next_below(10); // 6..=15 further windows
    for _ in 0..ops {
        match r.next_below(4) {
            0 | 1 if start + n < pool => n += 1,  // append
            2 if start + n < pool => start += 1,  // slide
            _ => {
                // Replace: an arbitrary window jump (can also land on
                // the current window — an Unchanged delta).
                n = 1 + r.next_below(pool);
                start = r.next_below(pool - n + 1);
            }
        }
        script = script.push_window(start, n);
    }
    script
}

/// Largest parity error per compared quantity, over a whole script.
#[derive(Debug, Clone, Copy, Default)]
pub struct ParityReport {
    pub steps: usize,
    pub max_nll_err: f64,
    pub max_mu_err: f64,
    pub max_var_err: f64,
    pub max_ei_err: f64,
}

/// Drive two backends through the same observation script and assert
/// that, at every step, their hyperparameter-grid NLLs, posterior
/// means/variances over all `m` candidates, EI scores, and the chosen
/// argmax agree within relative tolerance `tol` (scale
/// `max(|a|,|b|,1)`). The decide hyperparameters are the grid argmin of
/// backend `a`'s NLL — the same selection the search loop performs — so
/// both backends are compared on the posterior that would actually be
/// used. Panics with step/index context on the first violation; returns
/// the worst observed errors for reporting.
///
/// This is the single pinning entry point for backend equivalences: the
/// incremental-vs-scratch factor-cache pin and the low-rank-vs-exact pin
/// (both the `inducing = full set` exact-equality case and the
/// tolerance-bounded large-space case) all run through here.
pub fn assert_backend_parity(
    a: &mut dyn crate::bayesopt::GpBackend,
    b: &mut dyn crate::bayesopt::GpBackend,
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
    tol: f64,
) -> ParityReport {
    assert!(!grid.is_empty(), "empty hyperparameter grid");
    assert_eq!(xc.len(), m * script.d, "candidate matrix shape mismatch");
    let d = script.d;
    let cmask = vec![true; m];
    let mut report = ParityReport::default();
    let rel = |x: f64, y: f64| (x - y).abs() / x.abs().max(y.abs()).max(1.0);

    for (step, &(start, n)) in script.steps.iter().enumerate() {
        let x = &script.rows[start * d..(start + n) * d];
        let y = &script.ys[start..start + n];

        let nll_a = a.nll_grid(x, y, n, d, grid).expect("backend a nll_grid");
        let nll_b = b.nll_grid(x, y, n, d, grid).expect("backend b nll_grid");
        let mut best_g = 0usize;
        for (g, (&va, &vb)) in nll_a.iter().zip(&nll_b).enumerate() {
            match (va.is_finite(), vb.is_finite()) {
                (true, true) => {
                    let err = rel(va, vb);
                    report.max_nll_err = report.max_nll_err.max(err);
                    assert!(
                        err <= tol,
                        "parity: nll[{g}] diverged at step {step} (n={n}): {va} vs {vb}"
                    );
                }
                (false, false) => {}
                _ => panic!(
                    "parity: nll[{g}] finiteness diverged at step {step}: {va} vs {vb}"
                ),
            }
            if nll_a[g] < nll_a[best_g] {
                best_g = g;
            }
        }

        let hyp = grid[best_g];
        let da = a.decide(x, y, n, d, xc, &cmask, m, hyp).expect("backend a decide");
        let db = b.decide(x, y, n, d, xc, &cmask, m, hyp).expect("backend b decide");
        for j in 0..m {
            let (emu, evar, eei) =
                (rel(da.mu[j], db.mu[j]), rel(da.var[j], db.var[j]), rel(da.ei[j], db.ei[j]));
            report.max_mu_err = report.max_mu_err.max(emu);
            report.max_var_err = report.max_var_err.max(evar);
            report.max_ei_err = report.max_ei_err.max(eei);
            assert!(
                emu <= tol,
                "parity: mu[{j}] diverged at step {step} (n={n}): {} vs {}",
                da.mu[j],
                db.mu[j]
            );
            assert!(
                evar <= tol,
                "parity: var[{j}] diverged at step {step} (n={n}): {} vs {}",
                da.var[j],
                db.var[j]
            );
            assert!(
                eei <= tol,
                "parity: ei[{j}] diverged at step {step} (n={n}): {} vs {}",
                da.ei[j],
                db.ei[j]
            );
        }
        // Chosen argmax: each backend must consider the other's pick
        // tol-equivalent to its own (robust to exact ties).
        let pick = |ei: &[f64]| {
            let mut best = 0usize;
            for (i, v) in ei.iter().enumerate() {
                if *v > ei[best] {
                    best = i;
                }
            }
            best
        };
        let (ia, ib) = (pick(&da.ei), pick(&db.ei));
        let scale = da.ei[ia].abs().max(db.ei[ib].abs()).max(1.0);
        assert!(
            da.ei[ia] - da.ei[ib] <= tol * scale && db.ei[ib] - db.ei[ia] <= tol * scale,
            "parity: argmax diverged at step {step} (n={n}): a picks {ia} (ei {}), \
             b picks {ib} (ei {})",
            da.ei[ia],
            db.ei[ib]
        );
        report.steps += 1;
    }
    report
}

fn argmin(xs: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, v) in xs.iter().enumerate() {
        if *v < xs[best] {
            best = i;
        }
    }
    best
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

/// One recorded step per script window: the grid NLLs and the decision
/// at the lane's *own* NLL argmin (the selection the search loop makes).
type ScriptTrace = Vec<(Vec<f64>, crate::bayesopt::Decision)>;

/// Replay a whole script on one backend, recording NLL grid + decision
/// per step — the shared producer of every replay-and-compare harness
/// below (parallel parity, SIMD-vs-scalar parity).
fn record_script_trace(
    b: &mut dyn crate::bayesopt::GpBackend,
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
) -> ScriptTrace {
    let d = script.d;
    let cmask = vec![true; m];
    let mut trace = Vec::with_capacity(script.steps.len());
    for &(start, n) in script.steps() {
        let x = &script.rows[start * d..(start + n) * d];
        let y = &script.ys[start..start + n];
        let nll = b.nll_grid(x, y, n, d, grid).expect("trace nll_grid");
        let hyp = grid[argmin(&nll)];
        let dec = b.decide(x, y, n, d, xc, &cmask, m, hyp).expect("trace decide");
        trace.push((nll, dec));
    }
    trace
}

/// The two comparison modes of the replay harnesses: `tol = None` is
/// bit identity (`f64::to_bits`); `Some(rtol)` is relative closeness on
/// finite pairs (scale `max(|a|,|b|,1)`) and sign-respecting equality
/// on non-finite ones (both sweeps must reject the same degenerate
/// grid points).
fn trace_close(a: f64, b: f64, tol: Option<f64>) -> bool {
    match tol {
        None => a.to_bits() == b.to_bits(),
        Some(rtol) => {
            if a.is_finite() && b.is_finite() {
                (a - b).abs() / a.abs().max(b.abs()).max(1.0) <= rtol
            } else {
                a == b || (a.is_nan() && b.is_nan())
            }
        }
    }
}

/// Compare two recorded traces of the same script step by step. In bit
/// mode (`tol = None`) the chosen EI argmax must match exactly; in
/// tolerance mode each side's pick must be tol-equivalent to the
/// other's (robust to near ties the rounding may reorder).
fn compare_script_traces(
    label: &str,
    steps: &[(usize, usize)],
    reference: &ScriptTrace,
    candidate: &ScriptTrace,
    tol: Option<f64>,
) {
    for (step, ((rnll, rdec), (cnll, cdec))) in reference.iter().zip(candidate).enumerate() {
        let n = steps[step].1;
        for (g, (va, vb)) in rnll.iter().zip(cnll).enumerate() {
            assert!(
                trace_close(*va, *vb, tol),
                "{label}: nll[{g}] diverged at step {step} (n={n}): {va:?} vs {vb:?}"
            );
        }
        for j in 0..rdec.mu.len() {
            assert!(
                trace_close(rdec.mu[j], cdec.mu[j], tol),
                "{label}: mu[{j}] diverged at step {step} (n={n}): {:?} vs {:?}",
                rdec.mu[j],
                cdec.mu[j]
            );
            assert!(
                trace_close(rdec.var[j], cdec.var[j], tol),
                "{label}: var[{j}] diverged at step {step} (n={n}): {:?} vs {:?}",
                rdec.var[j],
                cdec.var[j]
            );
            assert!(
                trace_close(rdec.ei[j], cdec.ei[j], tol),
                "{label}: ei[{j}] diverged at step {step} (n={n}): {:?} vs {:?}",
                rdec.ei[j],
                cdec.ei[j]
            );
        }
        let (rp, cp) = (argmax(&rdec.ei), argmax(&cdec.ei));
        match tol {
            None => assert_eq!(
                cp, rp,
                "{label}: chosen argmax diverged at step {step} (n={n})"
            ),
            Some(rtol) => {
                let scale = rdec.ei[rp].abs().max(cdec.ei[cp].abs()).max(1.0);
                assert!(
                    rdec.ei[rp] - rdec.ei[cp] <= rtol * scale
                        && cdec.ei[cp] - cdec.ei[rp] <= rtol * scale,
                    "{label}: argmax diverged at step {step} (n={n}): reference picks \
                     {rp} (ei {}), candidate picks {cp} (ei {})",
                    rdec.ei[rp],
                    cdec.ei[cp]
                );
            }
        }
    }
}

/// Drive serial-vs-threaded [`NativeBackend`](crate::bayesopt::NativeBackend)s
/// through the same observation script and assert **bit-identical**
/// outputs — the deterministic-parallelism contract of the worker-pool
/// nll sweep and the decide tile fan-out (`--gp-threads`).
///
/// `make` builds a fresh, identically-configured backend per lane (set
/// policy/thresholds there; leave the parallelism to the harness). The
/// serial lane (`set_parallelism(1)`) records the reference trace; then
/// for every entry of `threads` a new backend replays the script and
/// every hyperparameter-grid NLL, posterior mean/variance, EI score and
/// the chosen EI argmax must match the reference *to the bit*
/// (`f64::to_bits` equality — no tolerance). The decide hyperparameters
/// are the grid argmin of the lane's own NLL, as in the search loop, so
/// a bit-divergent grid would also surface as a diverged decision.
/// This holds in *either* SIMD dispatch mode — serial and pooled lanes
/// share one dispatch decision — which is why no tolerance is needed
/// here; see [`assert_simd_scalar_parity`] for the cross-dispatch pin.
pub fn assert_parallel_parity(
    make: &dyn Fn() -> crate::bayesopt::NativeBackend,
    threads: &[usize],
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
) {
    assert_parallel_parity_tol(make, threads, script, xc, m, grid, None)
}

/// [`assert_parallel_parity`]'s tolerance mode: `tol = None` is the
/// strict bit-identity contract; `Some(rtol)` relaxes every comparison
/// to relative closeness (see `trace_close`) for configurations where
/// the compared lanes legitimately round differently.
pub fn assert_parallel_parity_tol(
    make: &dyn Fn() -> crate::bayesopt::NativeBackend,
    threads: &[usize],
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
    tol: Option<f64>,
) {
    assert!(!grid.is_empty(), "empty hyperparameter grid");
    assert_eq!(xc.len(), m * script.d, "candidate matrix shape mismatch");

    // Reference lane: fully serial.
    let mut serial = make();
    serial.set_parallelism(1);
    let reference = record_script_trace(&mut serial, script, xc, m, grid);

    for &t in threads {
        let mut b = make();
        b.set_parallelism(t);
        let trace = record_script_trace(&mut b, script, xc, m, grid);
        compare_script_traces(&format!("gp-threads {t}"), script.steps(), &reference, &trace, tol);
    }
}

/// The *shared*-pool mode of [`assert_parallel_parity`]: `backends`
/// identically-configured [`NativeBackend`](crate::bayesopt::NativeBackend)s
/// replay the same script **simultaneously**, on their own OS threads,
/// all fanning out over the one process-global worker pool — and every
/// trace must still match a serial single-backend replay to the bit.
///
/// This is the determinism contract the global pool adds over the old
/// per-backend pools: a fan-out's outputs depend only on its own inputs
/// and group order, never on what other backends are concurrently
/// running on the same lanes (lane scratch is reset on epoch change and
/// every group writes disjoint output slots). `make` builds each
/// backend (lower `set_pool_min_obs` there so tiny scripts still engage
/// the pool); the harness pins the serial reference with
/// `set_parallelism(1)` and runs every concurrent backend at
/// `gp_threads`. Each concurrent backend must also report having
/// attached to the global pool — otherwise the run silently degrades to
/// the serial path and the "concurrent" part of the contract goes
/// untested — and the process must never hold more parked pool threads
/// than the global width.
pub fn assert_shared_pool_parity(
    make: &(dyn Fn() -> crate::bayesopt::NativeBackend + Sync),
    backends: usize,
    gp_threads: usize,
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
) {
    assert!(backends > 0, "need at least one concurrent backend");
    assert!(gp_threads > 1, "gp_threads must engage the pool (> 1)");
    assert!(!grid.is_empty(), "empty hyperparameter grid");
    assert_eq!(xc.len(), m * script.d, "candidate matrix shape mismatch");

    let mut serial = make();
    serial.set_parallelism(1);
    let reference = record_script_trace(&mut serial, script, xc, m, grid);

    let traces: Vec<(ScriptTrace, crate::bayesopt::DecideStats)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..backends)
            .map(|_| {
                scope.spawn(move || {
                    let mut b = make();
                    b.set_parallelism(gp_threads);
                    let trace = record_script_trace(&mut b, script, xc, m, grid);
                    (trace, b.decide_stats())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shared-pool lane panicked")).collect()
    });

    for (i, (trace, stats)) in traces.iter().enumerate() {
        assert_eq!(
            stats.global_pool_attach, 1,
            "concurrent backend {i} never attached to the global pool — \
             the script is too small for its floor, so nothing ran concurrently"
        );
        compare_script_traces(
            &format!("shared-pool backend {i} of {backends}"),
            script.steps(),
            &reference,
            trace,
            None,
        );
    }
    let (spawned, width) =
        (crate::bayesopt::spawned_pool_threads(), crate::bayesopt::global_pool_width());
    assert!(
        spawned <= width,
        "{spawned} parked pool thread(s) exceed the process-global width {width}"
    );
}

/// Pin the SIMD-dispatched backend against the forced-scalar backend
/// over a whole script, within relative tolerance `tol` (pass
/// [`SIMD_PARITY_RTOL`](crate::bayesopt::SIMD_PARITY_RTOL) — the
/// documented bound of `bayesopt::simd`'s tolerance class; reductions
/// reassociate and the Matérn builders use the vector `exp`, so bit
/// identity across dispatch modes is deliberately *not* the contract).
///
/// The scalar reference replays the script first under
/// `set_simd(false)`, then a fresh backend replays it with SIMD
/// restored; the prior dispatch mode is restored afterwards (on panic
/// too). The toggle is process-global — callers running in a shared
/// test binary must serialize through a lock. On hosts without
/// AVX2+FMA both replays run scalar and agree bit-exactly, which the
/// tolerance trivially covers.
pub fn assert_simd_scalar_parity(
    make: &dyn Fn() -> crate::bayesopt::NativeBackend,
    script: &ParityScript,
    xc: &[f64],
    m: usize,
    grid: &[[f64; 3]],
    tol: f64,
) {
    use crate::bayesopt::{set_simd, simd_active};
    assert!(!grid.is_empty(), "empty hyperparameter grid");
    assert_eq!(xc.len(), m * script.d, "candidate matrix shape mismatch");

    struct ModeGuard(bool);
    impl Drop for ModeGuard {
        fn drop(&mut self) {
            crate::bayesopt::set_simd(self.0);
        }
    }
    let _guard = ModeGuard(simd_active());

    set_simd(false);
    let mut scalar = make();
    let reference = record_script_trace(&mut scalar, script, xc, m, grid);

    set_simd(true);
    let mut vectorized = make();
    let candidate = record_script_trace(&mut vectorized, script, xc, m, grid);

    compare_script_traces("simd-vs-scalar", script.steps(), &reference, &candidate, Some(tol));
}

/// A [`GpBackend`](crate::bayesopt::GpBackend) wrapper with an
/// artificially small conditioning capacity: reproduces the
/// windowed-history regime the AOT artifacts (`max_obs`) put the search
/// loop in, around any inner backend. Shared by the search-loop
/// regression tests and the end-to-end windowed-history tests.
pub struct CappedBackend<B: crate::bayesopt::GpBackend> {
    pub inner: B,
    pub cap: usize,
}

impl<B: crate::bayesopt::GpBackend> CappedBackend<B> {
    pub fn new(inner: B, cap: usize) -> Self {
        Self { inner, cap }
    }
}

impl<B: crate::bayesopt::GpBackend> crate::bayesopt::GpBackend for CappedBackend<B> {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> anyhow::Result<crate::bayesopt::Decision> {
        self.inner.decide(x, y, n, d, xc, cmask, m, hyp)
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> anyhow::Result<Vec<f64>> {
        self.inner.nll_grid(x, y, n, d, grid)
    }

    fn max_obs(&self) -> usize {
        self.cap
    }

    fn name(&self) -> &'static str {
        "capped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("tautology", 50, |g| {
            count += 1;
            let v = g.f64_in(0.0, 1.0);
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
        assert_eq!(count, 50 );
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_panics_with_seed() {
        property("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        property("bounds", 100, |g| {
            let n = g.usize_in(3, 17);
            if !(3..=17).contains(&n) {
                return Err(format!("usize_in out of bounds: {n}"));
            }
            let sub = g.subset(20, 5);
            if sub.len() != 5 || sub.iter().any(|&i| i >= 20) {
                return Err(format!("bad subset {sub:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn parity_script_builders_produce_search_shaped_windows() {
        let d = 2;
        let rows: Vec<f64> = (0..12 * d).map(|i| i as f64).collect();
        let ys: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let script = ParityScript::new(rows, ys, d).growth(5).slides(5, 3).push_window(0, 12);
        assert_eq!(script.pool_len(), 12);
        assert_eq!(
            script.steps(),
            &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 5), (2, 5), (3, 5), (0, 12)]
        );
        assert_eq!(script.rows().len(), 12 * d);
        assert_eq!(script.ys().len(), 12);
        let cuts = script.cut_points();
        assert_eq!(cuts.len(), script.steps().len() + 1);
        assert_eq!((cuts[0], *cuts.last().unwrap()), (0, script.steps().len()));
    }

    #[test]
    fn random_scripts_are_deterministic_and_well_formed() {
        let a = random_scripts(0xFEED, 16);
        let b = random_scripts(0xFEED, 16);
        assert_eq!(a.len(), 16);
        for (i, (sa, sb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(sa.steps(), sb.steps(), "script {i} not deterministic");
            assert_eq!(sa.dim(), sb.dim(), "script {i} dim not deterministic");
            assert!(sa.steps().len() >= 7, "script {i} too short: {:?}", sa.steps());
            for &(start, n) in sa.steps() {
                assert!(n > 0 && start + n <= sa.pool_len(), "script {i} window oob");
            }
        }
        // Different seeds draw different programs (overwhelmingly).
        let c = random_scripts(0xBEEF, 16);
        assert!(
            a.iter().zip(&c).any(|(sa, sc)| sa.steps() != sc.steps()),
            "two seeds produced identical fuzz corpora"
        );
        // The corpus must exercise all three delta families somewhere:
        // appends (n grows), slides (start grows at fixed n), replaces
        // (any other transition).
        let (mut appends, mut slides, mut replaces) = (0usize, 0usize, 0usize);
        for s in &a {
            for w in s.steps().windows(2) {
                let ((s0, n0), (s1, n1)) = (w[0], w[1]);
                if s1 == s0 && n1 == n0 + 1 {
                    appends += 1;
                } else if s1 == s0 + 1 && n1 == n0 {
                    slides += 1;
                } else if (s1, n1) != (s0, n0) {
                    replaces += 1;
                }
            }
        }
        assert!(appends > 0 && slides > 0 && replaces > 0, "{appends}/{slides}/{replaces}");
    }

    #[test]
    fn parity_harness_accepts_identical_backends() {
        use crate::bayesopt::{hyperparameter_grid, NativeBackend};
        let d = 3;
        let total = 8;
        let rows: Vec<f64> =
            (0..total * d).map(|i| ((i * 23 + 5) % 73) as f64 / 73.0).collect();
        let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.37).sin()).collect();
        let script = ParityScript::new(rows, ys, d).growth(6).slides(6, 2);
        let m = 5;
        let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect();
        let mut a = NativeBackend::new();
        let mut b = NativeBackend::new();
        let report = assert_backend_parity(
            &mut a,
            &mut b,
            &script,
            &xc,
            m,
            &hyperparameter_grid(),
            1e-12,
        );
        assert_eq!(report.steps, 8);
        assert!(report.max_mu_err <= 1e-12);
    }

    #[test]
    fn shrinking_reduces_size() {
        // A property failing only for large n: the panic message must
        // report a size below 1.0 shrink attempt or stay at 1.0; we just
        // check the runner terminates and panics.
        let result = std::panic::catch_unwind(|| {
            property("large-only", 20, |g| {
                let n = g.usize_in(0, 100);
                if n > 90 {
                    Err(format!("fails at n={n}"))
                } else {
                    Ok(())
                }
            });
        });
        // Either it never generated n > 90 (fine) or it panicked with the
        // shrink report.
        if let Err(e) = result {
            let msg = e.downcast_ref::<String>().cloned().unwrap_or_default();
            assert!(msg.contains("large-only"));
        }
    }
}
