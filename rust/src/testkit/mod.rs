//! In-tree property-testing mini-framework (the `proptest` crate is not
//! available offline). Seeded generators + a runner that, on failure,
//! re-runs a bisection-style shrink over the generator's size parameter
//! and reports the failing seed for reproduction.
//!
//! Usage:
//! ```ignore
//! use ruya::testkit::{Gen, property};
//! property("costs are normalized", 100, |g| {
//!     let n = g.usize_in(1, 50);
//!     let xs = g.vec_f64(n, 0.0, 10.0);
//!     // assert something; return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Seeded value generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Size hint in [0, 1]: shrinking retries properties at smaller sizes.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg64::from_seed(seed), size }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Integer in [lo, hi], scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + self.rng.next_below(scaled + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len())]
    }

    /// A random subset of 0..n of size k.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_distinct(n, k.min(n))
    }
}

/// Result type properties return: Err carries the violation description.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of a property. Panics with the seed and the
/// smallest failing size on violation.
pub fn property<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: u64, mut prop: F) {
    // Environment override for reproduction: RUYA_PROP_SEED=<seed>
    let base = std::env::var("RUYA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9E3779B97F4A7C15u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x2545F4914F6CDD1D));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed at smaller generator sizes and
            // report the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for step in 1..=8 {
                let size = 1.0 - step as f64 / 9.0;
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property {name:?} failed (seed {seed:#x}, smallest failing size {:.2}):\n  {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// A [`GpBackend`](crate::bayesopt::GpBackend) wrapper with an
/// artificially small conditioning capacity: reproduces the
/// windowed-history regime the AOT artifacts (`max_obs`) put the search
/// loop in, around any inner backend. Shared by the search-loop
/// regression tests and the end-to-end windowed-history tests.
pub struct CappedBackend<B: crate::bayesopt::GpBackend> {
    pub inner: B,
    pub cap: usize,
}

impl<B: crate::bayesopt::GpBackend> CappedBackend<B> {
    pub fn new(inner: B, cap: usize) -> Self {
        Self { inner, cap }
    }
}

impl<B: crate::bayesopt::GpBackend> crate::bayesopt::GpBackend for CappedBackend<B> {
    fn decide(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        xc: &[f64],
        cmask: &[bool],
        m: usize,
        hyp: [f64; 3],
    ) -> anyhow::Result<crate::bayesopt::Decision> {
        self.inner.decide(x, y, n, d, xc, cmask, m, hyp)
    }

    fn nll_grid(
        &mut self,
        x: &[f64],
        y: &[f64],
        n: usize,
        d: usize,
        grid: &[[f64; 3]],
    ) -> anyhow::Result<Vec<f64>> {
        self.inner.nll_grid(x, y, n, d, grid)
    }

    fn max_obs(&self) -> usize {
        self.cap
    }

    fn name(&self) -> &'static str {
        "capped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("tautology", 50, |g| {
            count += 1;
            let v = g.f64_in(0.0, 1.0);
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
        assert_eq!(count, 50 );
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_panics_with_seed() {
        property("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        property("bounds", 100, |g| {
            let n = g.usize_in(3, 17);
            if !(3..=17).contains(&n) {
                return Err(format!("usize_in out of bounds: {n}"));
            }
            let sub = g.subset(20, 5);
            if sub.len() != 5 || sub.iter().any(|&i| i >= 20) {
                return Err(format!("bad subset {sub:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn shrinking_reduces_size() {
        // A property failing only for large n: the panic message must
        // report a size below 1.0 shrink attempt or stay at 1.0; we just
        // check the runner terminates and panics.
        let result = std::panic::catch_unwind(|| {
            property("large-only", 20, |g| {
                let n = g.usize_in(0, 100);
                if n > 90 {
                    Err(format!("fails at n={n}"))
                } else {
                    Ok(())
                }
            });
        });
        // Either it never generated n > 90 (fine) or it panicked with the
        // shrink report.
        if let Err(e) = result {
            let msg = e.downcast_ref::<String>().cloned().unwrap_or_default();
            assert!(msg.contains("large-only"));
        }
    }
}
