//! Infrastructure utilities implemented in-tree because the usual crates
//! (`rand`, `serde`, `clap`) are unavailable in this offline environment.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
