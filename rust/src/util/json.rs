//! Minimal JSON support (the `serde` stack is unavailable offline).
//!
//! `JsonValue::parse` handles the machine-generated JSON this project
//! consumes (artifacts/meta.json) and `JsonWriter` emits the result files
//! the benches and examples export. Not a general-purpose JSON library —
//! no surrogate-pair escapes, no exotic numbers — but fully covers the
//! formats produced here and by python's `json.dump`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(o) => Some(o),
            _ => None,
        }
    }
}

/// Maximum container nesting the recursive-descent parser accepts.
///
/// Every `[` or `{` recurses once through [`Parser::value`]; without a
/// cap, a few hundred KB of `[[[[…` overflows the thread stack and
/// aborts the whole process — fatal for the resident `serve` loop,
/// which must answer hostile input with an error line and keep going.
/// 128 is far beyond anything this project writes or reads.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(Self::object),
            Some(b'[') => self.nested(Self::array),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    /// Run one container parse a level deeper, enforcing [`MAX_DEPTH`]
    /// so adversarial `[[[[…` input is a parse error, not a stack
    /// overflow.
    fn nested(
        &mut self,
        parse: fn(&mut Self) -> Result<JsonValue, String>,
    ) -> Result<JsonValue, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos));
        }
        self.depth += 1;
        let v = parse(self);
        self.depth -= 1;
        v
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(JsonValue::Number).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

/// Streaming JSON writer for result export. Usage mirrors a tiny subset of
/// serde_json's `json!` ergonomics without macros.
#[derive(Default)]
pub struct JsonWriter {
    buf: String,
    stack: Vec<bool>, // per open scope: "has at least one element"
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn comma(&mut self) {
        if let Some(has) = self.stack.last_mut() {
            if *has {
                self.buf.push(',');
            }
            *has = true;
        }
    }

    pub fn begin_object(&mut self) -> &mut Self {
        self.comma();
        self.buf.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_object(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push('}');
        self
    }

    pub fn begin_array(&mut self) -> &mut Self {
        self.comma();
        self.buf.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_array(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.comma();
        write_escaped(&mut self.buf, k);
        self.buf.push(':');
        // the following value must not emit its own comma
        if let Some(has) = self.stack.last_mut() {
            *has = false;
        }
        self
    }

    pub fn string(&mut self, s: &str) -> &mut Self {
        self.comma();
        write_escaped(&mut self.buf, s);
        self
    }

    pub fn number(&mut self, n: f64) -> &mut Self {
        self.comma();
        if n.is_finite() {
            let _ = write!(self.buf, "{n}");
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn boolean(&mut self, b: bool) -> &mut Self {
        self.comma();
        self.buf.push_str(if b { "true" } else { "false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.comma();
        self.buf.push_str("null");
        self
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

fn write_escaped(buf: &mut String, s: &str) {
    buf.push('"');
    for c in s.chars() {
        match c {
            '"' => buf.push_str("\\\""),
            '\\' => buf.push_str("\\\\"),
            '\n' => buf.push_str("\\n"),
            '\t' => buf.push_str("\\t"),
            '\r' => buf.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(buf, "\\u{:04x}", c as u32);
            }
            c => buf.push(c),
        }
    }
    buf.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1.5, "b": [1, 2, 3], "c": {"d": "x\ny"}, "e": true, "f": null}"#;
        let v = JsonValue::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("e"), Some(&JsonValue::Bool(true)));
        assert_eq!(v.get("f"), Some(&JsonValue::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(JsonValue::parse("{} x").is_err());
        assert!(JsonValue::parse("").is_err());
        assert!(JsonValue::parse("[1,").is_err());
    }

    #[test]
    fn parses_nested_empty() {
        let v = JsonValue::parse(r#"{"a": {}, "b": []}"#).unwrap();
        assert!(v.get("a").unwrap().as_object().unwrap().is_empty());
        assert!(v.get("b").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn parses_numbers() {
        let v = JsonValue::parse("[-1.5e3, 0, 42, 0.125]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[3].as_f64(), Some(0.125));
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // At MAX_DEPTH the parser still works...
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(JsonValue::parse(&ok).is_ok());
        // ...one level past it is a clean error...
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let err = JsonValue::parse(&over).unwrap_err();
        assert!(err.contains("nesting deeper than"), "unexpected error: {err}");
        // ...and hostile megabyte-scale nesting (which used to overflow
        // the stack and abort the process) fails the same way, for
        // arrays, objects, and mixtures.
        let hostile = "[".repeat(200_000);
        assert!(JsonValue::parse(&hostile).unwrap_err().contains("nesting deeper than"));
        let objects = r#"{"k":"#.repeat(200_000);
        assert!(JsonValue::parse(&objects).unwrap_err().contains("nesting deeper than"));
        let mixed = r#"[{"k":["#.repeat(100_000);
        assert!(JsonValue::parse(&mixed).unwrap_err().contains("nesting deeper than"));
    }

    #[test]
    fn writer_produces_parseable_json() {
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("name").string("ruya \"quoted\"");
        w.key("values").begin_array();
        w.number(1.0).number(2.5).number(f64::NAN);
        w.end_array();
        w.key("nested").begin_object();
        w.key("ok").boolean(true);
        w.end_object();
        w.end_object();
        let text = w.finish();
        let v = JsonValue::parse(&text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("ruya \"quoted\""));
        assert_eq!(v.get("values").unwrap().as_array().unwrap()[2], JsonValue::Null);
        assert_eq!(v.get("nested").unwrap().get("ok"), Some(&JsonValue::Bool(true)));
    }
}
