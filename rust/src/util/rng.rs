//! Deterministic PRNG (PCG64-DXSM style) — the `rand` crate is not
//! available offline, and the experiment harness needs seedable,
//! reproducible streams anyway (Table II averages 200 seeded repetitions).

/// PCG64 with DXSM output permutation. 128-bit state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed the generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e39cb94b95bdb) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_add(seed as u128).wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.next_u64();
        rng.next_u64();
        rng
    }

    pub fn from_seed(seed: u64) -> Self {
        Self::new(seed, 0xa02bdbf7bb3c0a7)
    }

    /// Derive an independent child stream (used to give every experiment
    /// repetition its own reproducible sequence).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream)
    }

    pub fn next_u64(&mut self) -> u64 {
        // DXSM output on the *current* state, then advance (PCG-DXSM).
        let hi = (self.state >> 64) as u64;
        let lo = ((self.state as u64) | 1) as u64;
        let mut out = hi ^ (hi >> 32);
        out = out.wrapping_mul(0xda942042e4dd58b5);
        out ^= out >> 48;
        out = out.wrapping_mul(lo);
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        out
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough mapping; bias is negligible
        // for the n used here (<= a few thousand).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with median 1 and the given sigma
    /// of the underlying normal.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (sigma * self.next_gaussian()).exp()
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// The full generator position `(state, inc)` — everything needed to
    /// reconstruct this generator exactly. The session suspend/resume
    /// machinery serializes these (as hex strings: the increments do not
    /// survive an f64 round-trip) and uses them to verify that a resumed
    /// search's RNG landed on the identical position.
    pub fn to_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact position captured by
    /// [`Self::to_parts`]. The next `next_u64` matches the original
    /// generator's next draw bit for bit.
    pub fn from_parts(state: u128, inc: u128) -> Self {
        Self { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::from_seed(42);
        let mut b = Pcg64::from_seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::from_seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::from_seed(1);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut rng = Pcg64::from_seed(2);
        let n = 20_000;
        let mean = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::from_seed(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_below_covers_range() {
        let mut rng = Pcg64::from_seed(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.next_below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Pcg64::from_seed(5);
        for _ in 0..100 {
            let s = rng.sample_distinct(69, 10);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(s.iter().all(|&i| i < 69));
        }
    }

    #[test]
    fn parts_roundtrip_is_bit_exact() {
        let mut a = Pcg64::from_seed(0xDEAD_BEEF);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.to_parts();
        let mut b = Pcg64::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sample_distinct_full_permutation() {
        let mut rng = Pcg64::from_seed(6);
        let mut s = rng.sample_distinct(8, 8);
        s.sort_unstable();
        assert_eq!(s, (0..8).collect::<Vec<_>>());
    }
}
