//! Minimal command-line parsing (the `clap` crate is unavailable offline).
//!
//! Supports the patterns the `ruya` binary and the examples need:
//! `prog <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, `--flag`
/// booleans and positionals, in any order after the subcommand.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); `known_flags` lists the
    /// `--x` switches that do NOT consume a value.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(val) = it.peek() {
                    if val.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse(known_flags: &[&str]) -> Self {
        Self::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Worker-thread count for the parallel experiment engine:
    /// `--threads N`, default 1 (serial), floored at 1.
    pub fn opt_threads(&self) -> usize {
        self.opt_usize("threads", 1).max(1)
    }

    /// GP-internal worker-pool width (`--gp-threads N`): each backend
    /// fans its hyperparameter-grid nll sweep and its decide tiles
    /// across a persistent pool of this many threads, with bit-identical
    /// results for any value. The default `0` is the **adaptive**
    /// sentinel — the backend resolves it to
    /// `bayesopt::adaptive_gp_threads()` (available_parallelism, capped),
    /// so the parallel sweep is on by default; `--gp-threads 1` forces
    /// fully serial. Multiplies with [`Self::opt_threads`] — total
    /// threads ≈ `threads * gp_threads`.
    pub fn opt_gp_threads(&self) -> usize {
        self.opt_usize("gp-threads", 0)
    }

    /// A count option accepting `k`/`m` suffixes (see [`parse_count`]):
    /// `--sessions 10k` reads as 10_000.
    pub fn opt_count(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(parse_count).unwrap_or(default)
    }
}

/// Parse a count with an optional case-insensitive magnitude suffix:
/// `"64"` -> 64, `"10k"` -> 10_000, `"2M"` -> 2_000_000. Returns `None`
/// on malformed input or overflow (the session-scale CLI knobs use this
/// so `ruya submit --sessions 100k` reads like the bench labels).
pub fn parse_count(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'k' | b'K' => (&s[..s.len() - 1], 1_000usize),
        b'm' | b'M' => (&s[..s.len() - 1], 1_000_000usize),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok()?.checked_mul(mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(parts.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["table2", "--reps", "200", "--backend", "xla"], &[]);
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.opt_usize("reps", 0), 200);
        assert_eq!(a.opt("backend"), Some("xla"));
    }

    #[test]
    fn known_flags_do_not_consume() {
        let a = parse(&["search", "--verbose", "kmeans"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["kmeans".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["x", "--seed=99"], &[]);
        assert_eq!(a.opt_u64("seed", 0), 99);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--quiet"], &[]);
        assert!(a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"], &[]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn threads_option_floors_at_one() {
        assert_eq!(parse(&["table2", "--threads", "8"], &[]).opt_threads(), 8);
        assert_eq!(parse(&["table2", "--threads", "0"], &[]).opt_threads(), 1);
        assert_eq!(parse(&["table2"], &[]).opt_threads(), 1);
    }

    #[test]
    fn gp_threads_option_defaults_to_adaptive_sentinel() {
        assert_eq!(parse(&["table2", "--gp-threads", "4"], &[]).opt_gp_threads(), 4);
        // 0 is the adaptive sentinel (resolved by the backend), both as
        // the default and when passed explicitly.
        assert_eq!(parse(&["table2", "--gp-threads", "0"], &[]).opt_gp_threads(), 0);
        assert_eq!(parse(&["table2"], &[]).opt_gp_threads(), 0);
        assert_eq!(parse(&["table2", "--gp-threads", "1"], &[]).opt_gp_threads(), 1);
        // The two knobs parse independently.
        let a = parse(&["table2", "--threads", "2", "--gp-threads", "8"], &[]);
        assert_eq!((a.opt_threads(), a.opt_gp_threads()), (2, 8));
    }

    #[test]
    fn count_suffixes_parse() {
        assert_eq!(parse_count("64"), Some(64));
        assert_eq!(parse_count("10k"), Some(10_000));
        assert_eq!(parse_count("1K"), Some(1_000));
        assert_eq!(parse_count("2M"), Some(2_000_000));
        assert_eq!(parse_count(" 3m "), Some(3_000_000));
        assert_eq!(parse_count(""), None);
        assert_eq!(parse_count("k"), None);
        assert_eq!(parse_count("10x"), None);
        assert_eq!(parse_count("999999999999999999999k"), None);
        let a = parse(&["submit", "--sessions", "10k"], &[]);
        assert_eq!(a.opt_count("sessions", 1), 10_000);
        assert_eq!(a.opt_count("missing", 7), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"], &[]);
        assert_eq!(a.opt_f64("leeway", 0.1), 0.1);
        assert_eq!(a.opt_or("out", "results"), "results");
    }
}
