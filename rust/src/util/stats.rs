//! Small statistics helpers shared by the memory model, the experiment
//! harness and the report layer.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than two points.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1]. NaN-free input assumed.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Ordinary least squares fit y = slope * x + intercept.
/// Returns (slope, intercept). Requires >= 2 points.
///
/// Degenerate abscissas — all xs equal *up to rounding noise* — fall
/// back to the flat fit `(0, mean(y))`. The guard is an epsilon relative
/// to the data scale, not an exact `== 0.0` compare: xs that differ only
/// in the last few ulps produce a tiny nonzero `sxx`, and dividing by it
/// would manufacture an astronomical garbage slope.
pub fn ols_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "OLS needs at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    // Each centered term carries rounding noise of order
    // n*EPSILON*x_scale (the computed mean contributes up to ~n ulps),
    // so the cancellation floor of sxx is n*(n*EPSILON*x_scale)^2 — NOT
    // EPSILON*x_scale^2, which would flatten genuine spreads below
    // ~sqrt(EPSILON) relative (e.g. [1e9, 1e9+1, 1e9+2]).
    let x_scale = xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    let n = xs.len() as f64;
    let per_term = n * f64::EPSILON * x_scale;
    if sxx <= n * per_term * per_term {
        return (0.0, my);
    }
    let slope = sxy / sxx;
    (slope, my - slope * mx)
}

/// Coefficient of determination of the OLS fit on the training data
/// itself — exactly the score Ruya thresholds at 0.1 / 0.99 (§III-C).
///
/// Degenerate case: if the targets are constant, the fit is perfect and
/// the paper's "flat" reading should win, so we follow scikit-learn and
/// return 1.0 when residuals are ~zero, else 0.0.
pub fn r2_score(xs: &[f64], ys: &[f64]) -> f64 {
    let (slope, intercept) = ols_fit(xs, ys);
    let my = mean(ys);
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let pred = slope * x + intercept;
        ss_res += (y - pred) * (y - pred);
        ss_tot += (y - my) * (y - my);
    }
    if ss_tot <= f64::EPSILON * mean(ys).abs().max(1.0) {
        return if ss_res <= ss_tot { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 0.25), 2.5);
        assert_eq!(quantile(&xs, 1.0), 10.0);
    }

    #[test]
    fn ols_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (slope, intercept) = ols_fit(&xs, &ys);
        assert!((slope - 3.0).abs() < 1e-12);
        assert!((intercept - 7.0).abs() < 1e-12);
        assert!((r2_score(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_flat_noise_is_low() {
        // y uncorrelated with x -> R^2 near 0
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> =
            (0..20).map(|i| if i % 2 == 0 { 5.0 } else { 5.5 }).collect();
        let r2 = r2_score(&xs, &ys);
        assert!(r2 < 0.1, "r2 {r2}");
    }

    #[test]
    fn ols_degenerate_x_from_rounding_noise() {
        // xs equal up to float rounding: sxx is tiny but nonzero, which
        // the old exact `== 0.0` guard missed (yielding a ~1e33 slope).
        let xs = [0.1 + 0.2, 0.3, 0.3, 0.3]; // 0.1 + 0.2 != 0.3 in f64
        let ys = [1.0, 2.0, 3.0, 4.0];
        let (slope, intercept) = ols_fit(&xs, &ys);
        assert_eq!(slope, 0.0);
        assert_eq!(intercept, mean(&ys));
        // Tiny-but-genuine spread is NOT flagged as degenerate.
        let xs2 = [1e-9, 2e-9, 3e-9];
        let ys2 = [1.0, 2.0, 3.0];
        let (slope2, _) = ols_fit(&xs2, &ys2);
        assert!((slope2 - 1e9).abs() / 1e9 < 1e-6, "slope {slope2}");
        // Small genuine spread on a huge offset survives too: the floor
        // is keyed to the cancellation noise n*(eps*scale)^2, not to
        // eps*scale^2.
        let xs3 = [1e9, 1e9 + 1.0, 1e9 + 2.0];
        let ys3 = [1.0, 2.0, 3.0];
        let (slope3, _) = ols_fit(&xs3, &ys3);
        assert!((slope3 - 1.0).abs() < 1e-6, "slope {slope3}");
    }

    #[test]
    fn r2_constant_targets_is_one() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [4.0, 4.0, 4.0];
        assert_eq!(r2_score(&xs, &ys), 1.0);
    }

    #[test]
    fn variance_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
