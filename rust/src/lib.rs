//! # Ruya — memory-aware iterative optimization of cluster configurations
//!
//! A reproduction of *Ruya: Memory-Aware Iterative Optimization of Cluster
//! Configurations for Big Data Processing* (Will et al., IEEE BigData 2022)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the coordinator: profiling controller,
//!   memory modeling, search-space splitting, the Bayesian-optimized
//!   iterative search (Ruya) and the CherryPick baseline, plus the full
//!   evaluation harness (Tables I–III, Figures 1/3/4/5).
//! - **Layer 2** — the GP posterior + expected-improvement computation,
//!   written in JAX (`python/compile/model.py`) and AOT-lowered to HLO
//!   text artifacts.
//! - **Layer 1** — the Matérn-5/2 Gram-matrix Pallas kernel
//!   (`python/compile/kernels/matern.py`).
//!
//! Python is build-time only; after `make artifacts` the rust binary is
//! self-contained and loads the artifacts through PJRT (`runtime`).
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bayesopt;
pub mod coordinator;
pub mod memmodel;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod searchspace;
pub mod testkit;
pub mod util;
pub mod workload;
