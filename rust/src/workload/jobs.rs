//! The HiBench job catalog of the evaluation (§IV-A): seven algorithms on
//! Spark and Hadoop, each with a "huge" and a "bigdata" input, 16 job
//! instances in total.
//!
//! Per-algorithm constants are calibrated so the *true* in-memory
//! footprints (`mem_coeff * input_gb`) match the requirements the paper's
//! profiler reported in Table I, and so the relative profiling durations
//! reproduce Table III's spread.

/// Dataflow framework a job runs on. Hadoop writes all intermediate data
/// to disk between stages and therefore never benefits from extra cluster
/// memory (§II-A) — the source of the paper's "flat" category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    Spark,
    Hadoop,
}

impl Framework {
    pub fn name(&self) -> &'static str {
        match self {
            Framework::Spark => "Spark",
            Framework::Hadoop => "Hadoop",
        }
    }
}

/// HiBench input scale. "bigdata" is the larger of the two (§IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetScale {
    Huge,
    Bigdata,
}

impl DatasetScale {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetScale::Huge => "huge",
            DatasetScale::Bigdata => "bigdata",
        }
    }
}

/// How the job's real memory consumption relates to its input size —
/// the *ground truth* the profiler tries to recover (§III-C). `Noisy`
/// models jobs that allocate faster than GC reclaims (LogR/LinR), whose
/// readings end up in the paper's "unclear" band.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemBehavior {
    /// Footprint grows proportionally with the input (cached iterative
    /// jobs).
    Linear,
    /// Footprint independent of input (one-pass / disk-based jobs).
    Flat,
    /// Linear at heart but with GC-churn readings too erratic to model.
    Noisy,
}

/// Static per-algorithm profile.
#[derive(Debug, Clone, Copy)]
pub struct AlgoProfile {
    pub name: &'static str,
    pub framework: Framework,
    /// Passes over the input dataset (1 load + iterations).
    pub passes: u32,
    /// CPU work per GB per pass, in core-hours.
    pub cpu_core_h_per_gb_pass: f64,
    /// Inherently serial work (hours) independent of the cluster.
    pub serial_h: f64,
    /// JVM bytes occupied per input byte when the dataset is cached.
    pub mem_coeff: f64,
    /// Whether iterations re-read the cached dataset (memory cliff) or
    /// stream from disk regardless.
    pub cache_sensitive: bool,
    /// Ground-truth memory behaviour the profiler observes.
    pub mem_behavior: MemBehavior,
    /// Extra shuffle volume as a fraction of the input per pass
    /// (join/sort workloads).
    pub shuffle_frac: f64,
}

/// One of the 16 evaluated job instances.
#[derive(Debug, Clone, Copy)]
pub struct JobInstance {
    pub algo: AlgoProfile,
    pub scale: DatasetScale,
    /// Input dataset size on disk (GB).
    pub input_gb: f64,
    /// Stable per-job identifier used to freeze the simulated cost
    /// landscape (the scout dataset is one fixed realization).
    pub job_id: u64,
}

impl JobInstance {
    pub fn label(&self) -> String {
        format!("{} {} {}", self.algo.name, self.algo.framework.name(), self.scale.name())
    }

    /// True cluster-memory need for fully in-memory processing (GB):
    /// the quantity Table I's "linear" rows estimate.
    pub fn true_cache_need_gb(&self) -> f64 {
        self.algo.mem_coeff * self.input_gb
    }
}

const NAIVE_BAYES: AlgoProfile = AlgoProfile {
    name: "Naive Bayes",
    framework: Framework::Spark,
    passes: 4,
    cpu_core_h_per_gb_pass: 0.010,
    serial_h: 0.010,
    mem_coeff: 2.5,
    cache_sensitive: true,
    mem_behavior: MemBehavior::Linear,
    shuffle_frac: 0.05,
};

const KMEANS: AlgoProfile = AlgoProfile {
    name: "K-Means",
    framework: Framework::Spark,
    passes: 11,
    cpu_core_h_per_gb_pass: 0.005,
    serial_h: 0.008,
    mem_coeff: 2.5,
    cache_sensitive: true,
    mem_behavior: MemBehavior::Linear,
    shuffle_frac: 0.02,
};

const PAGERANK_SPARK: AlgoProfile = AlgoProfile {
    name: "Page Rank",
    framework: Framework::Spark,
    passes: 9,
    cpu_core_h_per_gb_pass: 0.018,
    serial_h: 0.012,
    mem_coeff: 5.0,
    cache_sensitive: true,
    mem_behavior: MemBehavior::Linear,
    shuffle_frac: 0.30,
};

const LOG_REGRESSION: AlgoProfile = AlgoProfile {
    name: "Log. Regr.",
    framework: Framework::Spark,
    passes: 13,
    cpu_core_h_per_gb_pass: 0.006,
    serial_h: 0.008,
    mem_coeff: 2.2,
    cache_sensitive: true,
    mem_behavior: MemBehavior::Noisy,
    shuffle_frac: 0.02,
};

const LIN_REGRESSION: AlgoProfile = AlgoProfile {
    name: "Lin. Regr.",
    framework: Framework::Spark,
    passes: 8,
    cpu_core_h_per_gb_pass: 0.005,
    serial_h: 0.008,
    mem_coeff: 2.2,
    cache_sensitive: true,
    mem_behavior: MemBehavior::Noisy,
    shuffle_frac: 0.02,
};

const JOIN: AlgoProfile = AlgoProfile {
    name: "Join",
    framework: Framework::Spark,
    passes: 1,
    cpu_core_h_per_gb_pass: 0.012,
    serial_h: 0.006,
    mem_coeff: 0.0,
    cache_sensitive: false,
    mem_behavior: MemBehavior::Flat,
    shuffle_frac: 0.9,
};

const PAGERANK_HADOOP: AlgoProfile = AlgoProfile {
    name: "Page Rank",
    framework: Framework::Hadoop,
    passes: 9,
    cpu_core_h_per_gb_pass: 0.018,
    serial_h: 0.015,
    mem_coeff: 0.0,
    cache_sensitive: false,
    mem_behavior: MemBehavior::Flat,
    shuffle_frac: 0.30,
};

const TERASORT: AlgoProfile = AlgoProfile {
    name: "Terasort",
    framework: Framework::Hadoop,
    passes: 2,
    cpu_core_h_per_gb_pass: 0.008,
    serial_h: 0.006,
    mem_coeff: 0.0,
    cache_sensitive: false,
    mem_behavior: MemBehavior::Flat,
    shuffle_frac: 1.0,
};

/// The 16 job instances of the evaluation, in Table I order.
///
/// Input sizes are chosen so `mem_coeff * input_gb` reproduces the
/// Table I requirements for the linear jobs (754/395, 503/252, 86/42 GB),
/// and plausible HiBench-scale inputs elsewhere.
pub fn evaluation_jobs() -> Vec<JobInstance> {
    let mk = |algo: AlgoProfile, scale: DatasetScale, input_gb: f64, job_id: u64| JobInstance {
        algo,
        scale,
        input_gb,
        job_id,
    };
    vec![
        mk(NAIVE_BAYES, DatasetScale::Bigdata, 301.6, 1), // 2.5x -> 754 GB
        mk(NAIVE_BAYES, DatasetScale::Huge, 158.0, 2),    // -> 395 GB
        mk(KMEANS, DatasetScale::Bigdata, 201.2, 3),      // -> 503 GB
        mk(KMEANS, DatasetScale::Huge, 100.8, 4),         // -> 252 GB
        mk(PAGERANK_SPARK, DatasetScale::Bigdata, 17.2, 5), // 5x -> 86 GB
        mk(PAGERANK_SPARK, DatasetScale::Huge, 8.4, 6),   // -> 42 GB
        mk(LOG_REGRESSION, DatasetScale::Bigdata, 160.0, 7),
        mk(LOG_REGRESSION, DatasetScale::Huge, 80.0, 8),
        mk(LIN_REGRESSION, DatasetScale::Bigdata, 160.0, 9),
        mk(LIN_REGRESSION, DatasetScale::Huge, 80.0, 10),
        mk(JOIN, DatasetScale::Bigdata, 220.0, 11),
        mk(JOIN, DatasetScale::Huge, 110.0, 12),
        mk(PAGERANK_HADOOP, DatasetScale::Bigdata, 90.0, 13),
        mk(PAGERANK_HADOOP, DatasetScale::Huge, 45.0, 14),
        mk(TERASORT, DatasetScale::Bigdata, 300.0, 15),
        mk(TERASORT, DatasetScale::Huge, 150.0, 16),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_jobs_in_catalog() {
        assert_eq!(evaluation_jobs().len(), 16);
    }

    #[test]
    fn job_ids_unique() {
        let jobs = evaluation_jobs();
        let mut ids: Vec<u64> = jobs.iter().map(|j| j.job_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 16);
    }

    #[test]
    fn linear_jobs_match_table1_requirements() {
        let jobs = evaluation_jobs();
        let expect = [
            ("Naive Bayes", DatasetScale::Bigdata, 754.0),
            ("Naive Bayes", DatasetScale::Huge, 395.0),
            ("K-Means", DatasetScale::Bigdata, 503.0),
            ("K-Means", DatasetScale::Huge, 252.0),
            ("Page Rank", DatasetScale::Bigdata, 86.0),
            ("Page Rank", DatasetScale::Huge, 42.0),
        ];
        for (name, scale, gb) in expect {
            let job = jobs
                .iter()
                .find(|j| {
                    j.algo.name == name
                        && j.scale == scale
                        && j.algo.framework == Framework::Spark
                })
                .unwrap();
            assert!(
                (job.true_cache_need_gb() - gb).abs() < 1.0,
                "{name} {scale:?}: {} vs Table I {gb}",
                job.true_cache_need_gb()
            );
        }
    }

    #[test]
    fn category_split_is_6_6_4() {
        let jobs = evaluation_jobs();
        let count = |b: MemBehavior| jobs.iter().filter(|j| j.algo.mem_behavior == b).count();
        assert_eq!(count(MemBehavior::Linear), 6);
        assert_eq!(count(MemBehavior::Flat), 6);
        assert_eq!(count(MemBehavior::Noisy), 4);
    }

    #[test]
    fn hadoop_jobs_are_flat_and_cache_insensitive() {
        for j in evaluation_jobs() {
            if j.algo.framework == Framework::Hadoop {
                assert_eq!(j.algo.mem_behavior, MemBehavior::Flat);
                assert!(!j.algo.cache_sensitive);
            }
        }
    }

    #[test]
    fn bigdata_larger_than_huge() {
        let jobs = evaluation_jobs();
        for pair in jobs.chunks(2) {
            assert_eq!(pair[0].algo.name, pair[1].algo.name);
            assert!(pair[0].input_gb > pair[1].input_gb);
        }
    }
}
