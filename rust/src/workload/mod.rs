//! The workload substrate: the HiBench job catalog, the analytic cluster
//! execution model and the materialized evaluation dataset — the in-tree
//! substitute for the scout dataset of 1031 real AWS executions the paper
//! evaluates on (DESIGN.md §4).

mod dataset;
mod jobs;
mod params;
mod sim;

pub use dataset::{JobCostTable, ScoutDataset};
pub use jobs::{
    evaluation_jobs, AlgoProfile, DatasetScale, Framework, JobInstance, MemBehavior,
};
pub use params::{LaptopParams, SimParams};
pub use sim::{ClusterSim, Execution};
