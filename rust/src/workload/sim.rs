//! The cluster-execution model: runtime and monetary cost of one job on
//! one configuration — the substitute for the scout dataset's real AWS
//! measurements (DESIGN.md §4, substitution 1).
//!
//! The model produces the qualitative landscape the paper's method
//! depends on:
//!   * a **memory cliff** for cache-sensitive Spark jobs (Fig. 1): once
//!     usable cluster memory falls below the job's cache need, every
//!     iteration re-reads the spilled fraction from disk;
//!   * **flat** memory response for Hadoop and one-pass Spark jobs;
//!   * USL-style diminishing (then negative) returns on scale-out;
//!   * frozen log-normal noise per (job, configuration) pair.

use super::jobs::{Framework, JobInstance};
use super::params::SimParams;
use crate::searchspace::ClusterConfig;
use crate::util::rng::Pcg64;

/// JVM headroom factor above the raw object footprint needed to cache
/// the working set without GC thrash (see [`ClusterSim::cache_fit`]).
pub const CACHE_HEADROOM: f64 = 1.08;

/// Outcome of one simulated cluster execution.
#[derive(Debug, Clone, Copy)]
pub struct Execution {
    pub runtime_h: f64,
    pub cost_usd: f64,
    /// Fraction of the cached working set that actually fit in memory.
    pub cache_fit: f64,
}

/// Deterministic cluster simulator.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    pub params: SimParams,
}

impl Default for ClusterSim {
    fn default() -> Self {
        Self { params: SimParams::default() }
    }
}

impl ClusterSim {
    pub fn new(params: SimParams) -> Self {
        Self { params }
    }

    /// Noise-free runtime model (hours).
    pub fn runtime_noiseless_h(&self, job: &JobInstance, config: &ClusterConfig) -> f64 {
        let p = &self.params;
        let cores = config.total_cores();
        let nodes = config.nodes as f64;
        let algo = &job.algo;

        // Compute phase: CPU work over all passes, scaled by USL speedup.
        let work_core_h = job.input_gb * algo.passes as f64 * algo.cpu_core_h_per_gb_pass;
        let compute_h = work_core_h / p.speedup(cores);

        // I/O phases. Disk bandwidth scales with nodes (local disks).
        let disk_gb_h = nodes * p.disk_bw_gb_h;
        let mem_gb_h = disk_gb_h * p.mem_bw_mult;
        let shuffle_gb = job.input_gb * algo.shuffle_frac;

        let io_h = match algo.framework {
            Framework::Hadoop => {
                // Every pass reads from and materializes to disk; shuffle
                // suffers the same all-to-all network contention.
                let contention = 1.0 + p.net_contention * (nodes - 1.0);
                let per_pass = job.input_gb * p.hadoop_stage_amp / disk_gb_h
                    + shuffle_gb * 2.0 * contention / disk_gb_h;
                algo.passes as f64 * per_pass
            }
            Framework::Spark => {
                // First pass always streams from disk (cold load).
                let load_h = job.input_gb / disk_gb_h;
                // Shuffles are all-to-all: effective bandwidth degrades
                // with cluster size (network contention), so shuffle-heavy
                // jobs favor small scale-outs.
                let contention = 1.0 + p.net_contention * (nodes - 1.0);
                let shuffle_h =
                    algo.passes as f64 * shuffle_gb * 2.0 * contention / disk_gb_h;
                if algo.cache_sensitive && algo.passes > 1 {
                    let fit = self.cache_fit(job, config);
                    // Subsequent passes re-read the *materialized working
                    // set* (JVM objects, mem_coeff x input): the cached
                    // fraction from memory, the spilled fraction from disk
                    // with serialization amplification — the Fig. 1 cliff.
                    let working_set = job.true_cache_need_gb();
                    let reread_gb =
                        working_set * ((1.0 - fit) * p.spill_amp + fit / p.mem_bw_mult);
                    let _ = mem_gb_h; // folded into the mem_bw_mult term
                    load_h + (algo.passes - 1) as f64 * reread_gb / disk_gb_h + shuffle_h
                } else {
                    // One-pass or cache-insensitive Spark job.
                    load_h + shuffle_h
                }
            }
        };

        p.startup_h + algo.serial_h + compute_h + io_h
    }

    /// Fraction of the job's cached working set that fits in the cluster's
    /// usable memory (1.0 when not cache-sensitive).
    ///
    /// The JVM needs headroom above the raw object footprint to cache
    /// without GC thrash, so the *effective* cliff sits at
    /// `CACHE_HEADROOM x need` — slightly above the requirement the
    /// profiler extrapolates. This keeps Ruya's (estimate + leeway)
    /// predicate conservative in the right direction: priority groups may
    /// include configs marginally below the effective cliff (small
    /// penalty) but exclude only clearly-bottlenecked ones.
    pub fn cache_fit(&self, job: &JobInstance, config: &ClusterConfig) -> f64 {
        if !job.algo.cache_sensitive {
            return 1.0;
        }
        let need = job.true_cache_need_gb() * CACHE_HEADROOM;
        if need <= 0.0 {
            return 1.0;
        }
        (config.usable_memory_gb() / need).min(1.0)
    }

    /// Frozen multiplicative noise for a (job, config) pair: the scout
    /// dataset is a single realization, so repeated queries must return
    /// identical values (search determinism depends on it).
    ///
    /// Two components: a per-(job, machine-type) effect — JVM/OS behaviour
    /// really does differ across instance families, producing rugged,
    /// learnable structure the GP must sample each family to see — and a
    /// smaller per-execution residual.
    fn noise(&self, job: &JobInstance, config: &ClusterConfig, config_idx: usize) -> f64 {
        let mut mrng =
            Pcg64::new(job.job_id.wrapping_mul(0xd1342543de82ef95), config.machine as u64);
        let machine_effect = mrng.lognormal_noise(self.params.machine_sigma);
        let mut rng = Pcg64::new(job.job_id.wrapping_mul(0x9e3779b97f4a7c15), config_idx as u64);
        machine_effect * rng.lognormal_noise(self.params.noise_sigma)
    }

    /// Simulated execution of `job` on `config` (the `config_idx` ties the
    /// frozen noise to the search-space position).
    pub fn execute(&self, job: &JobInstance, config: &ClusterConfig, config_idx: usize) -> Execution {
        let runtime_h =
            self.runtime_noiseless_h(job, config) * self.noise(job, config, config_idx);
        Execution {
            runtime_h,
            cost_usd: runtime_h * config.price_per_hour(),
            cache_fit: self.cache_fit(job, config),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::SearchSpace;
    use crate::workload::jobs::{evaluation_jobs, DatasetScale};

    fn job(name: &str, scale: DatasetScale, fw: Framework) -> JobInstance {
        evaluation_jobs()
            .into_iter()
            .find(|j| j.algo.name == name && j.scale == scale && j.algo.framework == fw)
            .unwrap()
    }

    #[test]
    fn execution_is_deterministic() {
        let sim = ClusterSim::default();
        let space = SearchSpace::scout();
        let j = job("K-Means", DatasetScale::Bigdata, Framework::Spark);
        let a = sim.execute(&j, &space.config(7), 7);
        let b = sim.execute(&j, &space.config(7), 7);
        assert_eq!(a.runtime_h, b.runtime_h);
        assert_eq!(a.cost_usd, b.cost_usd);
    }

    #[test]
    fn noise_differs_across_configs_and_jobs() {
        let sim = ClusterSim::default();
        let space = SearchSpace::scout();
        let j1 = job("K-Means", DatasetScale::Bigdata, Framework::Spark);
        let j2 = job("K-Means", DatasetScale::Huge, Framework::Spark);
        let r1 = sim.execute(&j1, &space.config(3), 3).runtime_h
            / sim.runtime_noiseless_h(&j1, &space.config(3));
        let r2 = sim.execute(&j1, &space.config(4), 4).runtime_h
            / sim.runtime_noiseless_h(&j1, &space.config(4));
        let r3 = sim.execute(&j2, &space.config(3), 3).runtime_h
            / sim.runtime_noiseless_h(&j2, &space.config(3));
        assert_ne!(r1, r2);
        assert_ne!(r1, r3);
    }

    #[test]
    fn memory_cliff_exists_for_kmeans() {
        // Two r4.xlarge clusters straddling the K-Means/huge cache need
        // (252 GB): the one below the cliff must be much slower per pass.
        let sim = ClusterSim::default();
        let j = job("K-Means", DatasetScale::Huge, Framework::Spark);
        let space = SearchSpace::scout();
        // find r4.xlarge configs (machine idx 7) below and above need
        let below = space
            .configs()
            .iter()
            .enumerate()
            .find(|(_, c)| c.machine_type().name == "r4.xlarge" && c.usable_memory_gb() < 230.0)
            .map(|(i, _)| i)
            .unwrap();
        let above = space
            .configs()
            .iter()
            .enumerate()
            .find(|(_, c)| c.machine_type().name == "r4.xlarge" && c.usable_memory_gb() > 260.0)
            .map(|(i, _)| i)
            .unwrap();
        let cb = space.config(below);
        let ca = space.config(above);
        assert!(sim.cache_fit(&j, &cb) < 1.0);
        assert!((sim.cache_fit(&j, &ca) - 1.0).abs() < 1e-12);
        // Normalize by node count to compare per-resource efficiency:
        let rb = sim.runtime_noiseless_h(&j, &cb);
        let ra = sim.runtime_noiseless_h(&j, &ca);
        // The below-cliff config has fewer nodes; check slowdown per core.
        let per_core_b = rb * cb.total_cores();
        let per_core_a = ra * ca.total_cores();
        assert!(
            per_core_b > 1.3 * per_core_a,
            "no cliff: below {per_core_b} vs above {per_core_a} core-hours"
        );
    }

    #[test]
    fn hadoop_ignores_memory() {
        // Same core count, very different memory: Hadoop runtime must not
        // improve with the extra memory (same node count => same disk bw).
        let sim = ClusterSim::default();
        let j = job("Terasort", DatasetScale::Bigdata, Framework::Hadoop);
        let space = SearchSpace::scout();
        let c_low = space
            .configs()
            .iter()
            .find(|c| c.machine_type().name == "c4.2xlarge" && c.nodes == 8)
            .unwrap();
        let r_high = space
            .configs()
            .iter()
            .find(|c| c.machine_type().name == "r4.2xlarge" && c.nodes == 8)
            .unwrap();
        let rt_low = sim.runtime_noiseless_h(&j, c_low);
        let rt_high = sim.runtime_noiseless_h(&j, r_high);
        assert!(
            (rt_low - rt_high).abs() / rt_low < 1e-9,
            "hadoop runtime depends on memory: {rt_low} vs {rt_high}"
        );
    }

    #[test]
    fn more_nodes_speed_up_moderately_sized_clusters() {
        let sim = ClusterSim::default();
        let j = job("Join", DatasetScale::Bigdata, Framework::Spark);
        let space = SearchSpace::scout();
        let c4 = space.configs().iter().find(|c| c.machine_type().name == "c4.xlarge" && c.nodes == 4).unwrap();
        let c12 = space.configs().iter().find(|c| c.machine_type().name == "c4.xlarge" && c.nodes == 12).unwrap();
        assert!(sim.runtime_noiseless_h(&j, c12) < sim.runtime_noiseless_h(&j, c4));
    }

    #[test]
    fn runtimes_are_plausible_hours() {
        // Every (job, config) lands in a sane band: minutes to a day.
        let sim = ClusterSim::default();
        let space = SearchSpace::scout();
        for j in evaluation_jobs() {
            for (i, c) in space.configs().iter().enumerate() {
                let e = sim.execute(&j, c, i);
                // Memory-bottlenecked worst cases run for days (the paper
                // reports tenfold cost blowups); just bound the absurd.
                assert!(
                    e.runtime_h > 0.02 && e.runtime_h < 120.0,
                    "{} on {}: {} h",
                    j.label(),
                    c.name(),
                    e.runtime_h
                );
                assert!(e.cost_usd > 0.0);
            }
        }
    }

    #[test]
    fn cache_fit_boundaries() {
        let sim = ClusterSim::default();
        let space = SearchSpace::scout();
        let j = job("Naive Bayes", DatasetScale::Bigdata, Framework::Spark);
        // 754 GB exceeds every configuration's usable memory (max ~670):
        for (i, c) in space.configs().iter().enumerate() {
            let fit = sim.cache_fit(&j, c);
            assert!(fit < 1.0, "config {i} unexpectedly fits NB/bigdata");
        }
        let j2 = job("Join", DatasetScale::Huge, Framework::Spark);
        assert_eq!(sim.cache_fit(&j2, &space.config(0)), 1.0);
    }
}
