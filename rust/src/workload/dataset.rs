//! The materialized evaluation dataset: one frozen cost per (job, config)
//! pair — the role the scout dataset's 1031 executions play in the paper.
//!
//! Costs are normalized per job to the cheapest configuration, exactly as
//! in §IV-C: "the cheapest cluster configuration for a job always has a
//! cost of 1.0".

use super::jobs::JobInstance;
use super::sim::ClusterSim;
use crate::searchspace::SearchSpace;

/// Per-job table of simulated executions over the whole search space.
#[derive(Debug, Clone)]
pub struct JobCostTable {
    pub job: JobInstance,
    /// Absolute cost (USD) per configuration index.
    pub cost_usd: Vec<f64>,
    /// Runtime (hours) per configuration index.
    pub runtime_h: Vec<f64>,
    /// Cost normalized to the per-job minimum (>= 1.0).
    pub normalized: Vec<f64>,
    /// Index of the optimal (cheapest) configuration.
    pub optimal_idx: usize,
}

impl JobCostTable {
    /// Run the simulator over every configuration of the space.
    pub fn build(sim: &ClusterSim, job: &JobInstance, space: &SearchSpace) -> Self {
        let mut cost_usd = Vec::with_capacity(space.len());
        let mut runtime_h = Vec::with_capacity(space.len());
        for (i, c) in space.configs().iter().enumerate() {
            let e = sim.execute(job, c, i);
            cost_usd.push(e.cost_usd);
            runtime_h.push(e.runtime_h);
        }
        let (optimal_idx, &min_cost) = cost_usd
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .expect("non-empty space");
        let normalized = cost_usd.iter().map(|&c| c / min_cost).collect();
        Self { job: *job, cost_usd, runtime_h, normalized, optimal_idx }
    }

    /// Number of configurations whose normalized cost is within `thresh`.
    pub fn count_within(&self, thresh: f64) -> usize {
        self.normalized.iter().filter(|&&c| c <= thresh).count()
    }
}

/// The whole evaluation dataset: cost tables for all 16 jobs.
#[derive(Debug, Clone)]
pub struct ScoutDataset {
    pub tables: Vec<JobCostTable>,
}

impl ScoutDataset {
    pub fn build(sim: &ClusterSim, jobs: &[JobInstance], space: &SearchSpace) -> Self {
        Self { tables: jobs.iter().map(|j| JobCostTable::build(sim, j, space)).collect() }
    }

    /// Total simulated executions materialized (the paper's dataset holds
    /// 1031 real ones; ours is the full 16 x |space| grid).
    pub fn execution_count(&self) -> usize {
        self.tables.iter().map(|t| t.cost_usd.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::jobs::evaluation_jobs;

    fn dataset() -> ScoutDataset {
        let sim = ClusterSim::default();
        let space = SearchSpace::scout();
        ScoutDataset::build(&sim, &evaluation_jobs(), &space)
    }

    #[test]
    fn full_grid_materialized() {
        let ds = dataset();
        assert_eq!(ds.execution_count(), 16 * 69);
    }

    #[test]
    fn normalization_properties() {
        for t in dataset().tables {
            let min = t.normalized.iter().cloned().fold(f64::MAX, f64::min);
            assert!((min - 1.0).abs() < 1e-12, "{}: min {min}", t.job.label());
            assert!(t.normalized.iter().all(|&c| c >= 1.0));
            assert!((t.normalized[t.optimal_idx] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn optimum_is_unique_enough() {
        // The iterations-to-optimal metric needs a well-defined optimum:
        // no job may have two configs within float-eps of the minimum.
        for t in dataset().tables {
            let near: usize = t
                .normalized
                .iter()
                .filter(|&&c| c < 1.0 + 1e-9)
                .count();
            assert_eq!(near, 1, "{} has {near} co-optimal configs", t.job.label());
        }
    }

    #[test]
    fn cost_spread_is_meaningful() {
        // The search problem must be non-trivial: the worst config should
        // cost at least 2x the best for every job (the paper reports up
        // to 10x in public clouds).
        for t in dataset().tables {
            let max = t.normalized.iter().cloned().fold(0.0, f64::max);
            assert!(max > 2.0, "{}: spread only {max}", t.job.label());
            assert!(max < 100.0, "{}: absurd spread {max}", t.job.label());
        }
    }

    #[test]
    fn near_optimal_band_not_too_wide() {
        // If half the space is within 10% of optimal, random search would
        // trivially win and the evaluation would be meaningless.
        for t in dataset().tables {
            let frac = t.count_within(1.1) as f64 / t.normalized.len() as f64;
            assert!(frac < 0.5, "{}: {frac} of space within 1.1", t.job.label());
        }
    }
}
