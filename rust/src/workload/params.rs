//! Tunable constants of the analytic cluster-execution model.
//!
//! These are the knobs the calibration pass (EXPERIMENTS.md §Calibration)
//! adjusts so the simulated cost landscapes reproduce the *shape* of the
//! paper's evaluation: the Fig-1 memory cliff, c-family cost-optimality
//! for flat jobs, r-family for memory-hungry iterative jobs, and
//! diminishing returns at large scale-outs.

/// Universal-scalability-law and I/O constants of the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// USL contention coefficient (serialization on shared resources).
    pub usl_alpha: f64,
    /// USL coherency coefficient (pairwise coordination, kills very large
    /// scale-outs — "suboptimal configurations can increase costs
    /// tenfold").
    pub usl_beta: f64,
    /// Effective per-node disk scan bandwidth in GB/h (includes
    /// deserialization and the GC pressure of spilling, hence far below
    /// raw SSD speed).
    pub disk_bw_gb_h: f64,
    /// Memory re-read speedup over disk (cached iteration vs spilled).
    pub mem_bw_mult: f64,
    /// Spill amplification: a spilled partition is written once and
    /// re-read every iteration.
    pub spill_amp: f64,
    /// Hadoop materializes intermediate data to disk between stages
    /// (read + write per pass).
    pub hadoop_stage_amp: f64,
    /// All-to-all shuffle bandwidth degradation per extra node (network
    /// contention; makes shuffle-heavy jobs favor small scale-outs).
    pub net_contention: f64,
    /// Frozen per-(job, machine-type) effect sigma: instance families
    /// behave measurably differently for the same job (JVM, NUMA, EBS),
    /// which makes the cost landscape rugged across families.
    pub machine_sigma: f64,
    /// Per-execution multiplicative log-normal noise sigma (frozen per
    /// (job, config) pair — the scout dataset is one realization).
    pub noise_sigma: f64,
    /// Fixed cluster provisioning + framework start time (hours).
    pub startup_h: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        Self {
            usl_alpha: 0.04,
            usl_beta: 0.0002,
            disk_bw_gb_h: 45.0,
            mem_bw_mult: 40.0,
            spill_amp: 3.0,
            hadoop_stage_amp: 2.2,
            net_contention: 0.03,
            machine_sigma: 0.06,
            noise_sigma: 0.025,
            startup_h: 0.02,
        }
    }
}

impl SimParams {
    /// USL effective parallel speedup at `cores` workers.
    pub fn speedup(&self, cores: f64) -> f64 {
        cores / (1.0 + self.usl_alpha * (cores - 1.0) + self.usl_beta * cores * (cores - 1.0))
    }
}

/// The simulated single-node profiling machine (§IV-A: a 2020 T14
/// ThinkPad, 8 threads, 32 GB).
#[derive(Debug, Clone, Copy)]
pub struct LaptopParams {
    pub cores: f64,
    pub ram_gb: f64,
    /// Effective parallel efficiency of the laptop for these jobs.
    pub efficiency: f64,
    /// Fixed JVM + framework startup per profiling run (seconds).
    pub startup_s: f64,
    /// Aggressive-GC slowdown factor (§IV-B: "at the expense of
    /// reasonably longer runtimes").
    pub gc_slowdown: f64,
    /// Memory the framework + OS occupy before any data is loaded (GB);
    /// discounted from the readings (§III-B).
    pub base_mem_gb: f64,
}

impl Default for LaptopParams {
    fn default() -> Self {
        Self {
            cores: 8.0,
            ram_gb: 32.0,
            efficiency: 0.75,
            startup_s: 12.0,
            gc_slowdown: 1.3,
            base_mem_gb: 0.9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_monotone_then_saturating() {
        let p = SimParams::default();
        assert!(p.speedup(2.0) > p.speedup(1.0));
        assert!(p.speedup(16.0) > p.speedup(8.0));
        // Coherency term eventually dominates: enormous clusters slow down.
        assert!(p.speedup(512.0) < p.speedup(96.0));
    }

    #[test]
    fn speedup_at_one_core_is_one() {
        let p = SimParams::default();
        assert!((p.speedup(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_sublinear() {
        let p = SimParams::default();
        for c in [2.0, 8.0, 32.0, 96.0] {
            assert!(p.speedup(c) < c);
        }
    }
}
