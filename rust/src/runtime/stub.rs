//! Dependency-free stand-in for the PJRT runtime, compiled when the
//! `xla-pjrt` feature is off. It mirrors the public surface of the real
//! runtime so the rest of the crate (and its tests) compiles unchanged:
//! artifact probing reports "unavailable" and construction fails with a
//! clear error, which every XLA-gated caller already handles by skipping.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const NO_PJRT: &str =
    "built without the `xla-pjrt` feature: the PJRT runtime and AOT artifacts are unavailable \
     (rebuild with `--features xla-pjrt` and a vendored `xla` crate)";

/// Frozen AOT shapes; kept in sync with `python/compile/model.py`.
pub const AOT_N_OBS: usize = 64;
pub const AOT_N_FEATURES: usize = 6;
pub const AOT_N_CANDIDATES: usize = 128;
pub const AOT_N_GRID: usize = 32;

/// Stub PJRT client handle; never constructible.
pub struct XlaRuntime {
    artifact_dir: PathBuf,
}

impl XlaRuntime {
    pub fn new(_artifact_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(NO_PJRT)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Same directory contract as the real runtime so error messages and
    /// docs stay truthful.
    pub fn default_artifact_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("RUYA_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        PathBuf::from("artifacts")
    }

    /// Artifacts can never be executed without PJRT, so they are always
    /// reported unavailable — callers skip the XLA path.
    pub fn artifacts_available() -> bool {
        false
    }
}

/// Mirror of `gp_exec::GpDecision`.
#[derive(Debug, Clone)]
pub struct GpDecision {
    pub ei: Vec<f64>,
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// Stub executor; never constructible.
pub struct GpExecutor {}

impl GpExecutor {
    pub fn new(_rt: &XlaRuntime) -> Result<Self> {
        bail!(NO_PJRT)
    }

    pub fn call_count(&self) -> u64 {
        0
    }

    pub fn tier_count(&self) -> usize {
        0
    }

    #[allow(clippy::too_many_arguments)]
    pub fn gp_ei(
        &self,
        _x: &[f64],
        _y: &[f64],
        _n: usize,
        _xc: &[f64],
        _cmask: &[f64],
        _m: usize,
        _hyp: [f64; 3],
    ) -> Result<GpDecision> {
        bail!(NO_PJRT)
    }

    pub fn gp_nll(&self, _x: &[f64], _y: &[f64], _n: usize, _grid: &[[f64; 3]]) -> Result<Vec<f64>> {
        bail!(NO_PJRT)
    }
}
