//! `ExecutorPool`: a `Send + Sync` pooled loader for compiled PJRT
//! executables.
//!
//! PJRT handles (`XlaRuntime`, `GpExecutor`) are not `Send`, so they can
//! never cross threads — but artifact compilation is the expensive step
//! and used to happen once per backend construction, i.e. once per
//! `run_reps` repetition and once per evaluation worker. The pool splits
//! the two concerns: the *handle* (`ExecutorPool`) is a cheap, cloneable,
//! thread-safe description of *which* artifact set to run, and the
//! compiled executables live in a per-thread cache keyed by artifact
//! directory. Every backend cloned from the same pool on the same OS
//! thread reuses one compiled executor; a new thread compiles at most
//! once and then reuses for its lifetime.
//!
//! Cached executors are retained until their thread exits (the worker
//! threads of the parallel engine and the repetition loop of `run_reps`
//! are both long-lived, which is exactly the reuse this buys).
//!
//! Compiled in both cfg branches: under the default stub runtime
//! `XlaRuntime::new` fails, so `with_executor` reports the usual
//! "built without the `xla-pjrt` feature" error and the cache stays
//! empty.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{GpExecutor, XlaRuntime};

// The runtime must outlive the executor compiled on it (the executables
// hold client-owned state), so both are kept in one Rc and dropped
// together.
type Loaded = Rc<(XlaRuntime, GpExecutor)>;

thread_local! {
    static CACHE: RefCell<Vec<(PathBuf, Loaded)>> = const { RefCell::new(Vec::new()) };
}

/// Thread-safe handle to a per-thread cache of compiled GP executors,
/// keyed by artifact directory. Clones share one compile counter.
#[derive(Clone)]
pub struct ExecutorPool {
    artifact_dir: PathBuf,
    compiles: Arc<AtomicU64>,
}

impl ExecutorPool {
    /// A pool over the given artifact directory. Nothing is compiled
    /// until the first [`with_executor`](Self::with_executor) call.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Self {
        Self {
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            compiles: Arc::new(AtomicU64::new(0)),
        }
    }

    /// A pool over [`XlaRuntime::default_artifact_dir`].
    pub fn from_default_artifacts() -> Self {
        Self::new(XlaRuntime::default_artifact_dir())
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// How many times this pool (across all clones) compiled the
    /// artifact set — one per distinct OS thread that ran on it, not one
    /// per backend or per call.
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Run `f` against the calling thread's compiled executor for this
    /// pool's artifact directory, compiling it first if this thread has
    /// never seen the directory.
    pub fn with_executor<R>(&self, f: impl FnOnce(&GpExecutor) -> Result<R>) -> Result<R> {
        let entry = CACHE.with(|cache| -> Result<Loaded> {
            let mut cache = cache.borrow_mut();
            if let Some((_, entry)) = cache.iter().find(|(dir, _)| *dir == self.artifact_dir) {
                return Ok(Rc::clone(entry));
            }
            let rt = XlaRuntime::new(&self.artifact_dir).with_context(|| {
                format!("creating PJRT runtime over {}", self.artifact_dir.display())
            })?;
            let exec = GpExecutor::new(&rt).with_context(|| {
                format!("compiling GP artifacts from {}", self.artifact_dir.display())
            })?;
            self.compiles.fetch_add(1, Ordering::Relaxed);
            let entry = Rc::new((rt, exec));
            cache.push((self.artifact_dir.clone(), Rc::clone(&entry)));
            Ok(entry)
        })?;
        f(&entry.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_handle_is_send_sync_and_clones_share_the_counter() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ExecutorPool>();

        let pool = ExecutorPool::new("definitely/not/an/artifact/dir");
        let clone = pool.clone();
        // Under every configuration this fails cleanly — the stub bails
        // outright, the vendored shim has no PJRT plugin, and the real
        // crate finds no meta.json in a bogus directory — and a failed
        // load must never count as a compile.
        let err = pool.with_executor(|_| Ok(())).expect_err("bogus dir cannot load");
        assert!(!err.to_string().is_empty());
        assert_eq!(pool.compile_count(), 0);
        assert_eq!(clone.compile_count(), 0);
    }
}
