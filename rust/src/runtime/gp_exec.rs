//! `GpExecutor`: the compiled GP decision path.
//!
//! Wraps the AOT artifacts behind a plain-slice interface. The live
//! observation count `n` and candidate count `m` are always smaller than
//! the frozen AOT shapes; this module owns the padding/masking contract
//! shared with `python/compile/model.py`:
//!   - observations are padded with zero rows and mask 0,
//!   - candidates are padded with zero rows and cmask 0,
//!   - the hyperparameter grid is padded by repeating its last row.
//!
//! **Tier dispatch (§Perf):** artifacts come in observation-capacity
//! tiers (N = 16/32/64). The padded Cholesky while-loop costs O(N³)
//! regardless of the live fill, and most search decisions happen at small
//! n, so each call is dispatched to the smallest tier that fits.

use super::{execute_f32, ArtifactMeta, XlaRuntime};
use anyhow::{ensure, Context, Result};

/// Frozen AOT shapes; must match python/compile/model.py (validated
/// against meta.json at load time). AOT_N_OBS is the largest tier.
pub const AOT_N_OBS: usize = 64;
pub const AOT_N_FEATURES: usize = 6;
pub const AOT_N_CANDIDATES: usize = 128;
pub const AOT_N_GRID: usize = 32;

/// Result of one `gp_ei` call, truncated to the live candidate count.
#[derive(Debug, Clone)]
pub struct GpDecision {
    /// Expected improvement per candidate (zero outside the eligible set).
    pub ei: Vec<f64>,
    /// Posterior mean per candidate.
    pub mu: Vec<f64>,
    /// Posterior variance per candidate.
    pub var: Vec<f64>,
}

struct Tier {
    n_obs: usize,
    ei_exe: xla::PjRtLoadedExecutable,
    nll_exe: xla::PjRtLoadedExecutable,
}

/// Compiled GP executables (one pair per tier). One per process.
pub struct GpExecutor {
    tiers: Vec<Tier>, // ascending by n_obs
    calls: std::cell::Cell<u64>,
}

impl GpExecutor {
    /// Compile all artifact tiers on the given runtime and validate
    /// shapes against meta.json.
    pub fn new(rt: &XlaRuntime) -> Result<Self> {
        let meta = ArtifactMeta::load(rt.artifact_dir())
            .context("loading artifact metadata (run `make artifacts`)")?;
        ensure!(
            meta.n_obs == AOT_N_OBS
                && meta.n_features == AOT_N_FEATURES
                && meta.n_candidates == AOT_N_CANDIDATES
                && meta.n_grid == AOT_N_GRID,
            "artifact shapes {:?} do not match compiled-in constants; \
             re-run `make artifacts` and rebuild",
            (meta.n_obs, meta.n_features, meta.n_candidates, meta.n_grid)
        );
        let mut tiers = Vec::new();
        for &n in &meta.n_obs_tiers {
            let ei_name = format!("gp_ei_n{n}");
            let nll_name = format!("gp_nll_n{n}");
            let ei_file =
                &meta.artifacts.get(&ei_name).with_context(|| format!("meta missing {ei_name}"))?.file;
            let nll_file = &meta
                .artifacts
                .get(&nll_name)
                .with_context(|| format!("meta missing {nll_name}"))?
                .file;
            tiers.push(Tier {
                n_obs: n,
                ei_exe: rt.compile_artifact(ei_file)?,
                nll_exe: rt.compile_artifact(nll_file)?,
            });
        }
        tiers.sort_by_key(|t| t.n_obs);
        ensure!(!tiers.is_empty(), "no artifact tiers found");
        ensure!(tiers.last().unwrap().n_obs == AOT_N_OBS, "largest tier must be AOT_N_OBS");
        Ok(Self { tiers, calls: std::cell::Cell::new(0) })
    }

    pub fn call_count(&self) -> u64 {
        self.calls.get()
    }

    /// Number of compiled tiers (diagnostics).
    pub fn tier_count(&self) -> usize {
        self.tiers.len()
    }

    /// Smallest tier with capacity >= n.
    fn tier_for(&self, n: usize) -> Result<&Tier> {
        self.tiers
            .iter()
            .find(|t| t.n_obs >= n)
            .with_context(|| format!("observation count {n} exceeds AOT capacity {AOT_N_OBS}"))
    }

    /// Posterior + expected improvement over `m` candidates given `n`
    /// observations.
    ///
    /// `x`: n*D row-major observed feature rows; `y`: n observed costs;
    /// `xc`: m*D candidate feature rows; `cmask`: m eligibility flags
    /// (1.0 = may be proposed). Returns vectors of length `m`.
    pub fn gp_ei(
        &self,
        x: &[f64],
        y: &[f64],
        n: usize,
        xc: &[f64],
        cmask: &[f64],
        m: usize,
        hyp: [f64; 3],
    ) -> Result<GpDecision> {
        ensure!(m <= AOT_N_CANDIDATES, "candidate count {m} exceeds AOT capacity");
        ensure!(x.len() == n * AOT_N_FEATURES && y.len() == n && xc.len() == m * AOT_N_FEATURES);
        ensure!(cmask.len() == m);
        let tier = self.tier_for(n)?;
        let n_pad = tier.n_obs;

        let xp = pad_matrix(x, n_pad);
        let yp = pad_vector(y, n_pad, 0.0);
        let mask = fill_mask(n, n_pad);
        let xcp = pad_matrix(xc, AOT_N_CANDIDATES);
        let mut cm = pad_vector(cmask, AOT_N_CANDIDATES, 0.0);
        for v in cm.iter_mut() {
            *v = if *v > 0.0 { 1.0 } else { 0.0 };
        }
        let hypv: Vec<f32> = hyp.iter().map(|&v| v as f32).collect();

        let outs = execute_f32(
            &tier.ei_exe,
            &[
                (xp, &[n_pad, AOT_N_FEATURES]),
                (yp, &[n_pad]),
                (mask, &[n_pad]),
                (xcp, &[AOT_N_CANDIDATES, AOT_N_FEATURES]),
                (cm, &[AOT_N_CANDIDATES]),
                (hypv, &[3]),
            ],
        )?;
        self.calls.set(self.calls.get() + 1);
        ensure!(outs.len() == 3, "gp_ei returned {} outputs, expected 3", outs.len());
        let take = |v: &[f32]| v[..m].iter().map(|&f| f as f64).collect::<Vec<f64>>();
        Ok(GpDecision { ei: take(&outs[0]), mu: take(&outs[1]), var: take(&outs[2]) })
    }

    /// Negative log marginal likelihood for each hyperparameter triple.
    pub fn gp_nll(
        &self,
        x: &[f64],
        y: &[f64],
        n: usize,
        grid: &[[f64; 3]],
    ) -> Result<Vec<f64>> {
        ensure!(!grid.is_empty() && grid.len() <= AOT_N_GRID);
        ensure!(x.len() == n * AOT_N_FEATURES && y.len() == n);
        let tier = self.tier_for(n)?;
        let n_pad = tier.n_obs;

        let xp = pad_matrix(x, n_pad);
        let yp = pad_vector(y, n_pad, 0.0);
        let mask = fill_mask(n, n_pad);
        let mut g: Vec<f32> = Vec::with_capacity(AOT_N_GRID * 3);
        for row in grid {
            g.extend(row.iter().map(|&v| v as f32));
        }
        let last = *grid.last().unwrap();
        for _ in grid.len()..AOT_N_GRID {
            g.extend(last.iter().map(|&v| v as f32));
        }

        let outs = execute_f32(
            &tier.nll_exe,
            &[
                (xp, &[n_pad, AOT_N_FEATURES]),
                (yp, &[n_pad]),
                (mask, &[n_pad]),
                (g, &[AOT_N_GRID, 3]),
            ],
        )?;
        self.calls.set(self.calls.get() + 1);
        ensure!(outs.len() == 1, "gp_nll returned {} outputs, expected 1", outs.len());
        Ok(outs[0][..grid.len()].iter().map(|&f| f as f64).collect())
    }
}

fn pad_matrix(rows: &[f64], n_pad: usize) -> Vec<f32> {
    let mut out = vec![0f32; n_pad * AOT_N_FEATURES];
    for (i, v) in rows.iter().enumerate() {
        out[i] = *v as f32;
    }
    out
}

fn pad_vector(v: &[f64], n_pad: usize, fill: f32) -> Vec<f32> {
    let mut out = vec![fill; n_pad];
    for (i, x) in v.iter().enumerate() {
        out[i] = *x as f32;
    }
    out
}

fn fill_mask(n: usize, n_pad: usize) -> Vec<f32> {
    let mut m = vec![0f32; n_pad];
    for v in m.iter_mut().take(n) {
        *v = 1.0;
    }
    m
}
