//! Artifact metadata: parses `artifacts/meta.json` written by
//! `python/compile/aot.py` so the rust side can validate that its
//! marshaling assumptions (shapes, argument order) match what was lowered.
//!
//! The JSON subset parser lives in `util::json`; meta.json is machine
//! generated with known structure.

use crate::util::json::JsonValue;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Frozen AOT shapes plus the per-artifact argument shape list.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub n_obs: usize,
    /// Observation-capacity tiers (ascending); each has its own
    /// (gp_ei, gp_nll) artifact pair — see gp_exec.rs tier dispatch.
    pub n_obs_tiers: Vec<usize>,
    pub n_features: usize,
    pub n_candidates: usize,
    pub n_grid: usize,
    /// artifact name -> (file name, argument shapes)
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub args: Vec<Vec<usize>>,
}

/// The artifact set on disk: metadata + directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub meta: ArtifactMeta,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = JsonValue::parse(&text)
            .map_err(|e| anyhow!("parsing meta.json: {e}"))?;

        let get_usize = |key: &str| -> Result<usize> {
            root.get(key)
                .and_then(JsonValue::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("meta.json missing numeric key {key}"))
        };

        let mut artifacts = BTreeMap::new();
        let arts = root
            .get("artifacts")
            .and_then(JsonValue::as_object)
            .ok_or_else(|| anyhow!("meta.json missing artifacts object"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let mut args = Vec::new();
            for arg in entry
                .get("args")
                .and_then(JsonValue::as_array)
                .ok_or_else(|| anyhow!("artifact {name} missing args"))?
            {
                let dims: Option<Vec<usize>> = arg
                    .as_array()
                    .map(|a| a.iter().filter_map(|d| d.as_f64().map(|v| v as usize)).collect());
                args.push(dims.ok_or_else(|| anyhow!("artifact {name} bad arg shape"))?);
            }
            artifacts.insert(name.clone(), ArtifactEntry { file, args });
        }

        let n_obs = get_usize("n_obs")?;
        let n_obs_tiers = root
            .get("n_obs_tiers")
            .and_then(JsonValue::as_array)
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|f| f as usize)).collect())
            .unwrap_or_else(|| vec![n_obs]);

        Ok(Self {
            n_obs,
            n_obs_tiers,
            n_features: get_usize("n_features")?,
            n_candidates: get_usize("n_candidates")?,
            n_grid: get_usize("n_grid")?,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_meta() {
        let dir = crate::runtime::XlaRuntime::default_artifact_dir();
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.n_features, 6);
        assert!(!meta.n_obs_tiers.is_empty());
        assert_eq!(*meta.n_obs_tiers.last().unwrap(), meta.n_obs);
        for &tier in &meta.n_obs_tiers {
            let ei = &meta.artifacts[&format!("gp_ei_n{tier}")];
            assert_eq!(ei.args.len(), 6);
            assert_eq!(ei.args[0], vec![tier, meta.n_features]);
            assert!(meta.artifacts.contains_key(&format!("gp_nll_n{tier}")));
        }
    }
}
