//! GP execution runtime.
//!
//! With the `xla-pjrt` feature this module loads the AOT-compiled HLO
//! artifacts produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client ([`pjrt`] is the only place the `xla` FFI crate
//! is touched). Python is never on the request path: artifacts are
//! compiled when a backend is constructed — once per evaluation worker
//! in the parallel engine (PJRT handles are not `Send`, so workers
//! cannot share one) — and reused for every search iteration that
//! worker runs.
//!
//! Without the feature (the default — the `xla` crate and its C++
//! toolchain are not vendored) a dependency-free [`stub`] keeps the
//! public surface compiling: `XlaRuntime::artifacts_available()` reports
//! `false` and runtime construction fails with a clear error, so every
//! XLA-gated test, bench and CLI path skips gracefully.

#[cfg(feature = "xla-pjrt")]
mod artifact;
#[cfg(feature = "xla-pjrt")]
mod gp_exec;
#[cfg(feature = "xla-pjrt")]
mod pjrt;

#[cfg(feature = "xla-pjrt")]
pub use artifact::{ArtifactMeta, ArtifactSet};
#[cfg(feature = "xla-pjrt")]
pub use gp_exec::{
    GpDecision, GpExecutor, AOT_N_CANDIDATES, AOT_N_FEATURES, AOT_N_GRID, AOT_N_OBS,
};
#[cfg(feature = "xla-pjrt")]
pub use pjrt::{execute_f32, XlaRuntime};

#[cfg(not(feature = "xla-pjrt"))]
mod stub;

#[cfg(not(feature = "xla-pjrt"))]
pub use stub::{
    GpDecision, GpExecutor, XlaRuntime, AOT_N_CANDIDATES, AOT_N_FEATURES, AOT_N_GRID,
    AOT_N_OBS,
};
