//! GP execution runtime.
//!
//! With the `xla-pjrt` feature this module loads the AOT-compiled HLO
//! artifacts produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client ([`pjrt`] is the only place the `xla` crate is
//! touched — by default the vendored API shim at `vendor/xla/`, which
//! type-checks this layer in CI and fails at runtime without a real
//! plugin). Python is never on the request path: PJRT handles are not
//! `Send`, so compiled executables live in [`ExecutorPool`]'s per-thread
//! cache — each OS thread (evaluation worker, repetition loop) compiles
//! the artifact set at most once and reuses it for every backend and
//! every search iteration it runs.
//!
//! Without the feature (the default) a dependency-free [`stub`] keeps
//! the public surface compiling: `XlaRuntime::artifacts_available()`
//! reports `false` and runtime construction fails with a clear error, so
//! every XLA-gated test, bench and CLI path skips gracefully.

mod executor_pool;
pub use executor_pool::ExecutorPool;

#[cfg(feature = "xla-pjrt")]
mod artifact;
#[cfg(feature = "xla-pjrt")]
mod gp_exec;
#[cfg(feature = "xla-pjrt")]
mod pjrt;

#[cfg(feature = "xla-pjrt")]
pub use artifact::{ArtifactMeta, ArtifactSet};
#[cfg(feature = "xla-pjrt")]
pub use gp_exec::{
    GpDecision, GpExecutor, AOT_N_CANDIDATES, AOT_N_FEATURES, AOT_N_GRID, AOT_N_OBS,
};
#[cfg(feature = "xla-pjrt")]
pub use pjrt::{execute_f32, XlaRuntime};

#[cfg(not(feature = "xla-pjrt"))]
mod stub;

#[cfg(not(feature = "xla-pjrt"))]
pub use stub::{
    GpDecision, GpExecutor, XlaRuntime, AOT_N_CANDIDATES, AOT_N_FEATURES, AOT_N_GRID,
    AOT_N_OBS,
};
