//! The real PJRT-backed runtime (compiled only with the `xla-pjrt`
//! feature): a shared CPU client plus artifact compilation and f32
//! execution helpers.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shared PJRT CPU client. Creating a client is expensive; the process
/// creates exactly one and hands out compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl XlaRuntime {
    /// Create a runtime rooted at an artifact directory (usually
    /// `artifacts/` at the repo root).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn compile_artifact(&self, file_name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifact_dir.join(file_name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", path.display()))
    }

    /// Default artifact directory: `$RUYA_ARTIFACTS` or `artifacts/`
    /// relative to the current directory (falling back to the crate root
    /// for tests executed from elsewhere).
    pub fn default_artifact_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("RUYA_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        let local = PathBuf::from("artifacts");
        if local.join("meta.json").exists() {
            return local;
        }
        // CARGO_MANIFEST_DIR is baked in at compile time; tests and benches
        // run with cwd=target dirs sometimes.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// True if the artifact set exists on disk (used by tests to skip
    /// gracefully when `make artifacts` has not run).
    pub fn artifacts_available() -> bool {
        Self::default_artifact_dir().join("meta.json").exists()
    }
}

/// Execute a compiled executable on f32 literal inputs, returning the
/// flattened f32 outputs of the result tuple.
pub fn execute_f32(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[(Vec<f32>, &[usize])],
) -> Result<Vec<Vec<f32>>> {
    let mut literals = Vec::with_capacity(inputs.len());
    for (data, shape) in inputs {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .context("reshaping input literal")?;
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .context("executing artifact")?[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    // aot.py lowers with return_tuple=True, so outputs are always a tuple.
    let elems = result.to_tuple().context("decomposing result tuple")?;
    let mut out = Vec::with_capacity(elems.len());
    for e in elems {
        out.push(e.to_vec::<f32>().context("reading result element")?);
    }
    Ok(out)
}
