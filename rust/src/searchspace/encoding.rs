//! Feature encoding of cluster configurations for the Gaussian process.
//!
//! CherryPick encodes each configuration "by its principal features like
//! the number of cores and the amount of memory" (§III-E); we use six:
//! nodes, cores/node, GB/node, total cores, total GB, $/h, min-max
//! normalized over the search space so the GP lengthscale is comparable
//! across dimensions. N_FEATURES must match python/compile/model.py.

use super::ClusterConfig;

/// Number of features per configuration; frozen into the AOT artifacts.
pub const N_FEATURES: usize = 6;

/// Min-max normalizer fitted on a configuration set.
#[derive(Debug, Clone)]
pub struct FeatureEncoder {
    lo: [f64; N_FEATURES],
    hi: [f64; N_FEATURES],
}

fn raw_features(c: &ClusterConfig) -> [f64; N_FEATURES] {
    let m = c.machine_type();
    [
        c.nodes as f64,
        m.cores as f64,
        m.ram_gb,
        c.total_cores(),
        c.total_memory_gb(),
        c.price_per_hour(),
    ]
}

impl FeatureEncoder {
    /// Fit normalization bounds over a configuration set.
    pub fn fit(configs: &[ClusterConfig]) -> Self {
        let mut lo = [f64::MAX; N_FEATURES];
        let mut hi = [f64::MIN; N_FEATURES];
        for c in configs {
            let f = raw_features(c);
            for i in 0..N_FEATURES {
                lo[i] = lo[i].min(f[i]);
                hi[i] = hi[i].max(f[i]);
            }
        }
        Self { lo, hi }
    }

    /// Encode one configuration to `[0, 1]^N_FEATURES` (values outside the
    /// fitted set may exceed the unit interval, which the GP tolerates).
    pub fn encode(&self, c: &ClusterConfig) -> Vec<f64> {
        let mut out = Vec::with_capacity(N_FEATURES);
        self.encode_into(c, &mut out);
        out
    }

    /// [`Self::encode`] appended onto an existing buffer — the
    /// allocation-free path `SearchSpace::feature_matrix` streams
    /// thousands of generated-catalog rows through.
    pub fn encode_into(&self, c: &ClusterConfig, out: &mut Vec<f64>) {
        let f = raw_features(c);
        out.reserve(N_FEATURES);
        for i in 0..N_FEATURES {
            let span = self.hi[i] - self.lo[i];
            out.push(if span <= 0.0 { 0.5 } else { (f[i] - self.lo[i]) / span });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::SearchSpace;

    #[test]
    fn encodings_are_normalized() {
        let s = SearchSpace::scout();
        for i in 0..s.len() {
            let f = s.features(i);
            assert_eq!(f.len(), N_FEATURES);
            for v in f {
                assert!((-1e-12..=1.0 + 1e-12).contains(&v), "feature {v} out of range");
            }
        }
    }

    #[test]
    fn encodings_hit_bounds() {
        // Some config attains 0 and some attains 1 in every dimension.
        let s = SearchSpace::scout();
        for dim in 0..N_FEATURES {
            let vals: Vec<f64> = (0..s.len()).map(|i| s.features(i)[dim]).collect();
            let min = vals.iter().cloned().fold(f64::MAX, f64::min);
            let max = vals.iter().cloned().fold(f64::MIN, f64::max);
            assert!(min.abs() < 1e-9, "dim {dim} min {min}");
            assert!((max - 1.0).abs() < 1e-9, "dim {dim} max {max}");
        }
    }

    #[test]
    fn distinct_configs_have_distinct_encodings() {
        let s = SearchSpace::scout();
        for i in 0..s.len() {
            for j in (i + 1)..s.len() {
                assert_ne!(s.features(i), s.features(j), "{} vs {}", i, j);
            }
        }
    }

    #[test]
    fn degenerate_single_config_space() {
        let c = SearchSpace::scout().config(0);
        let enc = FeatureEncoder::fit(&[c]);
        let f = enc.encode(&c);
        assert!(f.iter().all(|&v| v == 0.5));
    }
}
