//! The machine-type catalog: AWS 4th-generation instance types used by
//! the scout dataset (c/m/r families, large/xlarge/2xlarge sizes),
//! on-demand us-east-1 prices.
//!
//! c machines have the least memory per core, r the most, m in between —
//! the axis Ruya's memory-awareness exploits (§II-A).

/// Instance family: compute-optimized, general-purpose, memory-optimized.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineFamily {
    C,
    M,
    R,
}

impl MachineFamily {
    pub fn letter(&self) -> char {
        match self {
            MachineFamily::C => 'c',
            MachineFamily::M => 'm',
            MachineFamily::R => 'r',
        }
    }
}

/// Instance size; determines cores per machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineSize {
    Large,
    XLarge,
    XXLarge,
}

/// One virtual-machine type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineType {
    pub name: &'static str,
    pub family: MachineFamily,
    pub size: MachineSize,
    pub cores: u32,
    pub ram_gb: f64,
    pub price_hourly: f64,
}

/// The nine machine types of the evaluation space.
pub const MACHINE_CATALOG: [MachineType; 9] = [
    MachineType { name: "c4.large",    family: MachineFamily::C, size: MachineSize::Large,   cores: 2, ram_gb: 3.75,  price_hourly: 0.100 },
    MachineType { name: "c4.xlarge",   family: MachineFamily::C, size: MachineSize::XLarge,  cores: 4, ram_gb: 7.5,   price_hourly: 0.199 },
    MachineType { name: "c4.2xlarge",  family: MachineFamily::C, size: MachineSize::XXLarge, cores: 8, ram_gb: 15.0,  price_hourly: 0.398 },
    MachineType { name: "m4.large",    family: MachineFamily::M, size: MachineSize::Large,   cores: 2, ram_gb: 8.0,   price_hourly: 0.100 },
    MachineType { name: "m4.xlarge",   family: MachineFamily::M, size: MachineSize::XLarge,  cores: 4, ram_gb: 16.0,  price_hourly: 0.200 },
    MachineType { name: "m4.2xlarge",  family: MachineFamily::M, size: MachineSize::XXLarge, cores: 8, ram_gb: 32.0,  price_hourly: 0.400 },
    MachineType { name: "r4.large",    family: MachineFamily::R, size: MachineSize::Large,   cores: 2, ram_gb: 15.25, price_hourly: 0.133 },
    MachineType { name: "r4.xlarge",   family: MachineFamily::R, size: MachineSize::XLarge,  cores: 4, ram_gb: 30.5,  price_hourly: 0.266 },
    MachineType { name: "r4.2xlarge",  family: MachineFamily::R, size: MachineSize::XXLarge, cores: 8, ram_gb: 61.0,  price_hourly: 0.532 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_per_core_ordering_c_m_r() {
        // "c type have less memory per core than r, m in between" (§II-A)
        for size in [MachineSize::Large, MachineSize::XLarge, MachineSize::XXLarge] {
            let per_core = |fam: MachineFamily| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.ram_gb / m.cores as f64)
                    .unwrap()
            };
            assert!(per_core(MachineFamily::C) < per_core(MachineFamily::M));
            assert!(per_core(MachineFamily::M) < per_core(MachineFamily::R));
        }
    }

    #[test]
    fn sizes_double_cores() {
        for fam in [MachineFamily::C, MachineFamily::M, MachineFamily::R] {
            let cores = |size: MachineSize| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.cores)
                    .unwrap()
            };
            assert_eq!(cores(MachineSize::XLarge), 2 * cores(MachineSize::Large));
            assert_eq!(cores(MachineSize::XXLarge), 2 * cores(MachineSize::XLarge));
        }
    }

    #[test]
    fn prices_scale_with_size() {
        for fam in [MachineFamily::C, MachineFamily::M, MachineFamily::R] {
            let price = |size: MachineSize| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.price_hourly)
                    .unwrap()
            };
            assert!(price(MachineSize::Large) < price(MachineSize::XLarge));
            assert!(price(MachineSize::XLarge) < price(MachineSize::XXLarge));
        }
    }
}
