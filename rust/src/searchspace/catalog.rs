//! The machine-type catalog: AWS 4th-generation instance types used by
//! the scout dataset (c/m/r families, large/xlarge/2xlarge sizes),
//! on-demand us-east-1 prices.
//!
//! c machines have the least memory per core, r the most, m in between —
//! the axis Ruya's memory-awareness exploits (§II-A).
//!
//! Beyond the fixed 9-type scout catalog this module owns a
//! **deterministic generated machine grid** (see [`generated_grid`]):
//! synthetic newer generations (`c5.large` … `r12.16xlarge`) styled on
//! the real AWS/GCE machine grids, with per-core RAM and price derived
//! from the family bases plus a small jitter keyed only on the machine
//! *name* — so a given name always denotes the same specs, in every
//! process and for every catalog seed. Generated types live in a
//! process-global registry appended behind [`MACHINE_CATALOG`]; a
//! [`super::ClusterConfig`]'s `machine` index resolves through
//! [`machine_by_index`] regardless of which side it points into.

use crate::util::rng::Pcg64;
use std::sync::{Mutex, OnceLock};

/// Instance family: compute-optimized, general-purpose, memory-optimized.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineFamily {
    C,
    M,
    R,
}

impl MachineFamily {
    pub const ALL: [MachineFamily; 3] = [MachineFamily::C, MachineFamily::M, MachineFamily::R];

    pub fn letter(&self) -> char {
        match self {
            MachineFamily::C => 'c',
            MachineFamily::M => 'm',
            MachineFamily::R => 'r',
        }
    }

    /// Base GB of RAM per core — the c < m < r memory axis (§II-A).
    fn ram_per_core_gb(&self) -> f64 {
        match self {
            MachineFamily::C => 2.0,
            MachineFamily::M => 4.0,
            MachineFamily::R => 8.0,
        }
    }

    /// Base on-demand price per core-hour (USD), from the real gen-4
    /// catalog (c4.large $0.100 / 2 cores, r4.large $0.133 / 2 cores).
    fn price_per_core(&self) -> f64 {
        match self {
            MachineFamily::C => 0.0500,
            MachineFamily::M => 0.0500,
            MachineFamily::R => 0.0665,
        }
    }
}

/// Instance size; determines cores per machine (`2 * multiplier`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineSize {
    Large,
    XLarge,
    XXLarge,
    X4Large,
    X8Large,
    X12Large,
    X16Large,
}

impl MachineSize {
    /// All sizes of the generated grid, smallest first. The scout space
    /// only uses the first three.
    pub const ALL: [MachineSize; 7] = [
        MachineSize::Large,
        MachineSize::XLarge,
        MachineSize::XXLarge,
        MachineSize::X4Large,
        MachineSize::X8Large,
        MachineSize::X12Large,
        MachineSize::X16Large,
    ];

    /// Core-count multiplier over `large` (2 cores).
    pub fn multiplier(&self) -> u32 {
        match self {
            MachineSize::Large => 1,
            MachineSize::XLarge => 2,
            MachineSize::XXLarge => 4,
            MachineSize::X4Large => 8,
            MachineSize::X8Large => 16,
            MachineSize::X12Large => 24,
            MachineSize::X16Large => 32,
        }
    }

    /// AWS-style size suffix ("large", "xlarge", "2xlarge", …).
    pub fn suffix(&self) -> &'static str {
        match self {
            MachineSize::Large => "large",
            MachineSize::XLarge => "xlarge",
            MachineSize::XXLarge => "2xlarge",
            MachineSize::X4Large => "4xlarge",
            MachineSize::X8Large => "8xlarge",
            MachineSize::X12Large => "12xlarge",
            MachineSize::X16Large => "16xlarge",
        }
    }
}

/// One virtual-machine type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineType {
    pub name: &'static str,
    pub family: MachineFamily,
    pub size: MachineSize,
    pub cores: u32,
    pub ram_gb: f64,
    pub price_hourly: f64,
}

/// The nine machine types of the evaluation space.
pub const MACHINE_CATALOG: [MachineType; 9] = [
    MachineType { name: "c4.large",    family: MachineFamily::C, size: MachineSize::Large,   cores: 2, ram_gb: 3.75,  price_hourly: 0.100 },
    MachineType { name: "c4.xlarge",   family: MachineFamily::C, size: MachineSize::XLarge,  cores: 4, ram_gb: 7.5,   price_hourly: 0.199 },
    MachineType { name: "c4.2xlarge",  family: MachineFamily::C, size: MachineSize::XXLarge, cores: 8, ram_gb: 15.0,  price_hourly: 0.398 },
    MachineType { name: "m4.large",    family: MachineFamily::M, size: MachineSize::Large,   cores: 2, ram_gb: 8.0,   price_hourly: 0.100 },
    MachineType { name: "m4.xlarge",   family: MachineFamily::M, size: MachineSize::XLarge,  cores: 4, ram_gb: 16.0,  price_hourly: 0.200 },
    MachineType { name: "m4.2xlarge",  family: MachineFamily::M, size: MachineSize::XXLarge, cores: 8, ram_gb: 32.0,  price_hourly: 0.400 },
    MachineType { name: "r4.large",    family: MachineFamily::R, size: MachineSize::Large,   cores: 2, ram_gb: 15.25, price_hourly: 0.133 },
    MachineType { name: "r4.xlarge",   family: MachineFamily::R, size: MachineSize::XLarge,  cores: 4, ram_gb: 30.5,  price_hourly: 0.266 },
    MachineType { name: "r4.2xlarge",  family: MachineFamily::R, size: MachineSize::XXLarge, cores: 8, ram_gb: 61.0,  price_hourly: 0.532 },
];

/// First synthetic generation number ("c5.…"); gen 4 is the real catalog.
const FIRST_GENERATION: u32 = 5;
/// Safety cap on synthetic generations (bounds registry growth and keeps
/// the generation price discount positive).
const MAX_GENERATIONS: u32 = 32;
/// Scale-outs of the generated grid: every node count in this range.
const GENERATED_SCALEOUT_MIN: u32 = 2;
const GENERATED_SCALEOUT_MAX: u32 = 64;

/// Machine types beyond [`MACHINE_CATALOG`], registered at runtime by the
/// catalog generator. Entries are leaked once (deduplicated by name, and
/// specs are a pure function of the name), so the registry is bounded by
/// the finite generation x family x size grid.
static DYNAMIC_MACHINES: OnceLock<Mutex<Vec<&'static MachineType>>> = OnceLock::new();

fn dynamic_machines() -> &'static Mutex<Vec<&'static MachineType>> {
    DYNAMIC_MACHINES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Lock the registry, recovering from poisoning: a panic on some other
/// thread that happened to hold this lock (a GP worker dying mid-lookup,
/// a test's `catch_unwind`) must not turn every later catalog access
/// into a cascading panic. Recovery is sound here because the registry's
/// only mutation is a single `push` of a fully-built leaked entry — the
/// `Vec` behind a poisoned lock is always structurally intact.
fn lock_registry() -> std::sync::MutexGuard<'static, Vec<&'static MachineType>> {
    dynamic_machines().lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Resolve a machine index — static catalog first, then the generated
/// registry. Panics on an index no [`super::ClusterConfig`] can hold.
pub fn machine_by_index(idx: usize) -> &'static MachineType {
    if let Some(m) = MACHINE_CATALOG.get(idx) {
        return m;
    }
    let reg = lock_registry();
    reg[idx - MACHINE_CATALOG.len()]
}

/// Total registered machine types (static + generated).
pub fn machine_count() -> usize {
    MACHINE_CATALOG.len() + lock_registry().len()
}

/// Register a machine type, deduplicating by name (specs are derived from
/// the name alone, so a name collision is always the same machine).
/// Returns its global index.
fn register_machine(mt: MachineType) -> usize {
    let mut reg = lock_registry();
    if let Some(pos) = reg.iter().position(|m| m.name == mt.name) {
        debug_assert_eq!(*reg[pos], mt, "machine {:?} re-registered with different specs", mt.name);
        return MACHINE_CATALOG.len() + pos;
    }
    let leaked: &'static MachineType = Box::leak(Box::new(mt));
    reg.push(leaked);
    MACHINE_CATALOG.len() + reg.len() - 1
}

/// Test-only registry access: lets tests plant a machine with corrupt
/// specs (e.g. a non-finite price) behind a real catalog index, so
/// NaN-hardening paths can be exercised end to end. Deduplicates by
/// name like every registration; use a unique name per test.
#[cfg(test)]
pub(crate) fn register_machine_for_tests(mt: MachineType) -> usize {
    register_machine(mt)
}

/// FNV-1a over a machine name — the only source of spec jitter, so specs
/// are deterministic per name across processes and catalog seeds.
fn name_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Build (or look up) one synthetic machine type.
fn generated_machine(family: MachineFamily, size: MachineSize, generation: u32) -> usize {
    let name = format!("{}{}.{}", family.letter(), generation, size.suffix());
    {
        // Fast path: already registered — nothing to build or leak.
        let reg = lock_registry();
        if let Some(pos) = reg.iter().position(|m| m.name == name) {
            return MACHINE_CATALOG.len() + pos;
        }
    }
    let mut jitter = Pcg64::from_seed(name_hash(&name));
    let cores = 2 * size.multiplier();
    // +-4% RAM jitter: small enough to keep the c < m < r per-core
    // ordering (2*1.04 < 4*0.96), large enough that generations differ.
    let ram_gb = cores as f64 * family.ram_per_core_gb() * jitter.uniform(0.96, 1.04);
    // Newer generations get slightly cheaper per core, like real clouds.
    let gen_discount = 1.0 - 0.01 * (generation - 4) as f64;
    let price_hourly =
        cores as f64 * family.price_per_core() * gen_discount * jitter.uniform(0.97, 1.03);
    let mt = MachineType {
        name: Box::leak(name.into_boxed_str()),
        family,
        size,
        cores,
        ram_gb,
        price_hourly,
    };
    register_machine(mt)
}

/// The full generated configuration grid, in deterministic order
/// (generation, family, size, scale-out), grown one generation at a time
/// until it holds at least `min_len` configurations.
///
/// Returns `(machine_index, nodes)` pairs; `SearchSpace::generated`
/// subsamples these into a catalog. Panics if `min_len` exceeds the
/// capped grid (32 generations x 3 families x 7 sizes x 63 scale-outs).
/// Configurations per synthetic generation (families x sizes x
/// scale-outs).
const fn generated_per_generation() -> usize {
    let per_machine = (GENERATED_SCALEOUT_MAX - GENERATED_SCALEOUT_MIN + 1) as usize;
    MachineFamily::ALL.len() * MachineSize::ALL.len() * per_machine
}

/// Largest catalog [`generated_grid`] can produce — the validation bound
/// `SearchSpace::parse_spec` reports to the user.
pub(super) const fn max_generated_len() -> usize {
    MAX_GENERATIONS as usize * generated_per_generation()
}

pub(super) fn generated_grid(min_len: usize) -> Vec<(usize, u32)> {
    let per_generation = generated_per_generation();
    let generations = min_len.div_ceil(per_generation).max(1);
    assert!(
        generations <= MAX_GENERATIONS as usize,
        "generated search space of {min_len} configs exceeds the {} grid cap",
        max_generated_len()
    );
    let mut grid = Vec::with_capacity(generations * per_generation);
    for g in 0..generations as u32 {
        let generation = FIRST_GENERATION + g;
        for family in MachineFamily::ALL {
            for size in MachineSize::ALL {
                let machine = generated_machine(family, size, generation);
                for nodes in GENERATED_SCALEOUT_MIN..=GENERATED_SCALEOUT_MAX {
                    grid.push((machine, nodes));
                }
            }
        }
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_per_core_ordering_c_m_r() {
        // "c type have less memory per core than r, m in between" (§II-A)
        for size in [MachineSize::Large, MachineSize::XLarge, MachineSize::XXLarge] {
            let per_core = |fam: MachineFamily| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.ram_gb / m.cores as f64)
                    .unwrap()
            };
            assert!(per_core(MachineFamily::C) < per_core(MachineFamily::M));
            assert!(per_core(MachineFamily::M) < per_core(MachineFamily::R));
        }
    }

    #[test]
    fn sizes_double_cores() {
        for fam in [MachineFamily::C, MachineFamily::M, MachineFamily::R] {
            let cores = |size: MachineSize| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.cores)
                    .unwrap()
            };
            assert_eq!(cores(MachineSize::XLarge), 2 * cores(MachineSize::Large));
            assert_eq!(cores(MachineSize::XXLarge), 2 * cores(MachineSize::XLarge));
        }
    }

    #[test]
    fn prices_scale_with_size() {
        for fam in [MachineFamily::C, MachineFamily::M, MachineFamily::R] {
            let price = |size: MachineSize| {
                MACHINE_CATALOG
                    .iter()
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.price_hourly)
                    .unwrap()
            };
            assert!(price(MachineSize::Large) < price(MachineSize::XLarge));
            assert!(price(MachineSize::XLarge) < price(MachineSize::XXLarge));
        }
    }

    #[test]
    fn generated_machines_preserve_family_memory_axis() {
        let grid = generated_grid(1);
        // First generation of the grid: check per-core RAM ordering for
        // every size at that generation.
        for size in MachineSize::ALL {
            let per_core = |fam: MachineFamily| {
                grid.iter()
                    .map(|&(idx, _)| machine_by_index(idx))
                    .find(|m| m.family == fam && m.size == size)
                    .map(|m| m.ram_gb / m.cores as f64)
                    .unwrap()
            };
            assert!(per_core(MachineFamily::C) < per_core(MachineFamily::M), "{size:?}");
            assert!(per_core(MachineFamily::M) < per_core(MachineFamily::R), "{size:?}");
        }
    }

    #[test]
    fn generated_machine_registration_is_idempotent() {
        let a = generated_machine(MachineFamily::C, MachineSize::X8Large, 7);
        let count = machine_count();
        let b = generated_machine(MachineFamily::C, MachineSize::X8Large, 7);
        assert_eq!(a, b, "same name must resolve to the same registry index");
        assert_eq!(machine_count(), count, "re-registration must not grow the registry");
        let m = machine_by_index(a);
        assert_eq!(m.name, "c7.8xlarge");
        assert_eq!(m.cores, 32);
        assert!(m.ram_gb > 0.0 && m.price_hourly > 0.0);
    }

    #[test]
    fn generated_grid_is_deterministic_and_distinct() {
        let a = generated_grid(2000);
        let b = generated_grid(2000);
        assert_eq!(a, b, "grid must be deterministic");
        assert!(a.len() >= 2000);
        let mut seen = std::collections::HashSet::new();
        for &cfg in &a {
            assert!(seen.insert(cfg), "duplicate grid entry {cfg:?}");
        }
    }

    #[test]
    fn generated_grid_serves_exactly_the_cap() {
        // The documented bound is reachable, not just a rejection line.
        let grid = generated_grid(max_generated_len());
        assert_eq!(grid.len(), max_generated_len());
    }

    #[test]
    fn registry_survives_a_panic_while_the_lock_is_held() {
        // Poison the registry mutex the way a dying thread would: panic
        // with the guard live. Every registry operation afterwards must
        // recover (`into_inner`) instead of cascading the panic — a
        // resident `serve` process keeps answering requests after one
        // worker dies mid-catalog-access.
        let before = machine_count();
        let poison = std::panic::catch_unwind(|| {
            let _guard = lock_registry();
            panic!("simulated worker death with the registry lock held");
        });
        assert!(poison.is_err(), "the poisoning closure must panic");
        assert!(
            DYNAMIC_MACHINES.get().expect("registry initialized above").is_poisoned(),
            "the panic above must actually poison the mutex"
        );
        // Reads recover (>= because concurrently running tests may
        // legitimately register machines of their own)...
        assert!(machine_count() >= before, "reads must see the intact registry");
        // ...and so do registrations: the full lookup + append path.
        let idx = register_machine_for_tests(MachineType {
            name: "test.poison-recovery",
            family: MachineFamily::M,
            size: MachineSize::Large,
            cores: 2,
            ram_gb: 8.0,
            price_hourly: 0.1,
        });
        assert_eq!(machine_by_index(idx).name, "test.poison-recovery");
        assert_eq!(
            register_machine_for_tests(MachineType {
                name: "test.poison-recovery",
                family: MachineFamily::M,
                size: MachineSize::Large,
                cores: 2,
                ram_gb: 8.0,
                price_hourly: 0.1,
            }),
            idx,
            "dedup-by-name must still work on the recovered registry"
        );
    }

    #[test]
    #[should_panic(expected = "exceeds the")]
    fn generated_grid_panics_past_the_cap() {
        // `SearchSpace::parse_spec` validates first and reports a clean
        // error; the grid builder itself enforces the cap with a panic
        // (an internal-contract violation, not a user-reachable path).
        let _ = generated_grid(max_generated_len() + 1);
    }
}
