//! The cluster-configuration search space (§II-A, §IV-A of the paper).
//!
//! Mirrors the scout evaluation space: AWS 4th-generation machine types of
//! the c/m/r families in sizes large/xlarge/2xlarge, scale-outs between 4
//! and 48 nodes, 69 configurations in total. Also owns the feature
//! encoding the Gaussian process sees and the usable-memory accounting
//! used by Ruya's priority-group construction (§III-D).

mod catalog;
mod encoding;

pub use catalog::{MachineFamily, MachineSize, MachineType, MACHINE_CATALOG};
pub use encoding::FeatureEncoder;

/// Per-node memory the OS keeps for itself (GB). Part of the "overhead by
/// the operating system and the distributed dataflow framework" the paper
/// folds into the final memory requirement (§III-D).
pub const OS_OVERHEAD_GB: f64 = 0.5;
/// Per-node memory the dataflow framework itself occupies (GB).
pub const FRAMEWORK_OVERHEAD_GB: f64 = 0.45;
/// Fraction of the remaining JVM heap available for caching data
/// (legacy spark storage-fraction-style accounting; high because the
/// simulated jobs are cache-dominated). Calibrated so the paper's Table I
/// anecdotes hold: NB/bigdata (754 GB) exceeds the maximum usable memory
/// of the space (~670 GB) while K-Means/bigdata (503 GB) retains a small
/// all-r4 priority group.
pub const STORAGE_FRACTION: f64 = 0.93;

/// One cluster configuration: a machine type at a scale-out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    /// Index into [`MACHINE_CATALOG`].
    pub machine: usize,
    /// Number of worker nodes.
    pub nodes: u32,
}

impl ClusterConfig {
    pub fn machine_type(&self) -> &'static MachineType {
        &MACHINE_CATALOG[self.machine]
    }

    pub fn total_cores(&self) -> f64 {
        self.nodes as f64 * self.machine_type().cores as f64
    }

    /// Raw total cluster RAM in GB.
    pub fn total_memory_gb(&self) -> f64 {
        self.nodes as f64 * self.machine_type().ram_gb
    }

    /// Cluster memory actually available for caching job data after OS,
    /// framework and execution-memory overheads (§III-D).
    pub fn usable_memory_gb(&self) -> f64 {
        let per_node =
            (self.machine_type().ram_gb - OS_OVERHEAD_GB - FRAMEWORK_OVERHEAD_GB).max(0.0);
        self.nodes as f64 * per_node * STORAGE_FRACTION
    }

    /// Price of running this cluster for one hour (USD).
    pub fn price_per_hour(&self) -> f64 {
        self.nodes as f64 * self.machine_type().price_hourly
    }

    pub fn name(&self) -> String {
        format!("{}x{}", self.nodes, self.machine_type().name)
    }
}

/// The full evaluation search space.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    configs: Vec<ClusterConfig>,
    encoder: FeatureEncoder,
}

impl SearchSpace {
    /// The paper's evaluation space: 69 configurations (23 per family).
    /// Scale-outs per machine size follow DESIGN.md §6.
    pub fn scout() -> Self {
        let mut configs = Vec::new();
        for (idx, machine) in MACHINE_CATALOG.iter().enumerate() {
            let scaleouts: &[u32] = match machine.size {
                MachineSize::Large => &[4, 6, 8, 10, 12, 16, 20, 24, 32, 40],
                MachineSize::XLarge => &[4, 6, 8, 10, 12, 16, 20, 24],
                MachineSize::XXLarge => &[4, 6, 8, 10, 12],
            };
            for &nodes in scaleouts {
                configs.push(ClusterConfig { machine: idx, nodes });
            }
        }
        Self::from_configs(configs)
    }

    /// Build a space from an explicit configuration list (tests, what-if
    /// analyses, private-cluster catalogs).
    pub fn from_configs(configs: Vec<ClusterConfig>) -> Self {
        assert!(!configs.is_empty(), "search space cannot be empty");
        let encoder = FeatureEncoder::fit(&configs);
        Self { configs, encoder }
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    pub fn configs(&self) -> &[ClusterConfig] {
        &self.configs
    }

    pub fn config(&self, idx: usize) -> ClusterConfig {
        self.configs[idx]
    }

    pub fn encoder(&self) -> &FeatureEncoder {
        &self.encoder
    }

    /// Normalized feature row for one configuration (length = N_FEATURES).
    pub fn features(&self, idx: usize) -> Vec<f64> {
        self.encoder.encode(&self.configs[idx])
    }

    /// All feature rows, row-major (len = len() * N_FEATURES) — the
    /// candidate matrix handed to the GP backend once per search.
    pub fn feature_matrix(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.len() * encoding::N_FEATURES);
        for c in &self.configs {
            out.extend(self.encoder.encode(c));
        }
        out
    }

    /// Indices of configurations whose usable memory meets `min_gb`.
    pub fn with_usable_memory_at_least(&self, min_gb: f64) -> Vec<usize> {
        (0..self.len())
            .filter(|&i| self.configs[i].usable_memory_gb() >= min_gb)
            .collect()
    }

    /// The `k` configurations with the lowest total memory (ties broken by
    /// price) — Ruya's priority group for flat-memory jobs.
    pub fn lowest_memory_configs(&self, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        idx.sort_by(|&a, &b| {
            let ka = (self.configs[a].total_memory_gb(), self.configs[a].price_per_hour());
            let kb = (self.configs[b].total_memory_gb(), self.configs[b].price_per_hour());
            ka.partial_cmp(&kb).unwrap()
        });
        idx.truncate(k);
        idx
    }

    /// Configurations in the top or bottom `decile_fraction` of total
    /// memory — the fallback priority group when a linear job's
    /// requirement exceeds every available configuration (§III-D).
    pub fn memory_extremes(&self, decile_fraction: f64) -> Vec<usize> {
        let k = ((self.len() as f64 * decile_fraction).ceil() as usize).max(1);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        idx.sort_by(|&a, &b| {
            self.configs[a]
                .total_memory_gb()
                .partial_cmp(&self.configs[b].total_memory_gb())
                .unwrap()
        });
        let mut out: Vec<usize> = idx.iter().take(k).copied().collect();
        out.extend(idx.iter().rev().take(k).copied());
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Maximum usable memory over the whole space (GB).
    pub fn max_usable_memory_gb(&self) -> f64 {
        self.configs
            .iter()
            .map(|c| c.usable_memory_gb())
            .fold(0.0, f64::max)
    }
}

pub use encoding::N_FEATURES;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scout_space_has_69_configs() {
        let s = SearchSpace::scout();
        assert_eq!(s.len(), 69);
    }

    #[test]
    fn scaleouts_span_4_to_48_nodes() {
        let s = SearchSpace::scout();
        let min = s.configs().iter().map(|c| c.nodes).min().unwrap();
        let max = s.configs().iter().map(|c| c.nodes).max().unwrap();
        assert_eq!(min, 4);
        assert!(max >= 40, "largest scale-out {max}");
    }

    #[test]
    fn total_memory_spans_paper_range() {
        // The paper's anecdotes rely on ~15 GB at the bottom and
        // ~732 GB (r4.2xlarge x 12) at the top.
        let s = SearchSpace::scout();
        let min = s.configs().iter().map(|c| c.total_memory_gb()).fold(f64::MAX, f64::min);
        let max = s.configs().iter().map(|c| c.total_memory_gb()).fold(0.0, f64::max);
        assert!((min - 15.0).abs() < 1.0, "min total mem {min}");
        assert!((max - 732.0).abs() < 1.0, "max total mem {max}");
    }

    #[test]
    fn usable_memory_below_total() {
        let s = SearchSpace::scout();
        for c in s.configs() {
            assert!(c.usable_memory_gb() < c.total_memory_gb());
            assert!(c.usable_memory_gb() > 0.0);
        }
    }

    #[test]
    fn memory_filter_is_consistent() {
        let s = SearchSpace::scout();
        let idx = s.with_usable_memory_at_least(100.0);
        assert!(!idx.is_empty());
        for &i in &idx {
            assert!(s.config(i).usable_memory_gb() >= 100.0);
        }
        let complement: Vec<usize> =
            (0..s.len()).filter(|i| !idx.contains(i)).collect();
        for &i in &complement {
            assert!(s.config(i).usable_memory_gb() < 100.0);
        }
    }

    #[test]
    fn lowest_memory_configs_sorted_and_small() {
        let s = SearchSpace::scout();
        let low = s.lowest_memory_configs(10);
        assert_eq!(low.len(), 10);
        let max_low = low.iter().map(|&i| s.config(i).total_memory_gb()).fold(0.0, f64::max);
        let rest_min = (0..s.len())
            .filter(|i| !low.contains(i))
            .map(|i| s.config(i).total_memory_gb())
            .fold(f64::MAX, f64::min);
        assert!(max_low <= rest_min + 1e-9);
    }

    #[test]
    fn memory_extremes_contains_both_ends() {
        let s = SearchSpace::scout();
        let ext = s.memory_extremes(0.1);
        let mems: Vec<f64> = ext.iter().map(|&i| s.config(i).total_memory_gb()).collect();
        let global_min = s.configs().iter().map(|c| c.total_memory_gb()).fold(f64::MAX, f64::min);
        let global_max = s.configs().iter().map(|c| c.total_memory_gb()).fold(0.0, f64::max);
        assert!(mems.iter().any(|&m| (m - global_min).abs() < 1e-9));
        assert!(mems.iter().any(|&m| (m - global_max).abs() < 1e-9));
    }

    #[test]
    fn feature_matrix_dims() {
        let s = SearchSpace::scout();
        assert_eq!(s.feature_matrix().len(), 69 * N_FEATURES);
    }

    #[test]
    fn config_names_readable() {
        let s = SearchSpace::scout();
        assert!(s.configs().iter().any(|c| c.name() == "4xc4.large"));
    }
}
