//! The cluster-configuration search space (§II-A, §IV-A of the paper).
//!
//! Mirrors the scout evaluation space: AWS 4th-generation machine types of
//! the c/m/r families in sizes large/xlarge/2xlarge, scale-outs between 4
//! and 48 nodes, 69 configurations in total. Also owns the feature
//! encoding the Gaussian process sees and the usable-memory accounting
//! used by Ruya's priority-group construction (§III-D).
//!
//! Beyond the paper's shortlist, [`SearchSpace::generated`] opens
//! full-cloud-catalog-scale spaces (thousands of configurations drawn
//! from a deterministic synthetic machine grid, see [`catalog`]) — the
//! workload class the low-rank GP path in
//! [`bayesopt::lowrank`](crate::bayesopt::lowrank) exists for. All
//! priority-group helpers ([`SearchSpace::lowest_memory_configs`],
//! [`SearchSpace::memory_extremes`]) run in O(n) selection time with
//! deterministic tie-breaks so they stay exact and cheap on 5k-config
//! catalogs.

mod catalog;
mod encoding;

pub use catalog::{
    machine_by_index, machine_count, MachineFamily, MachineSize, MachineType, MACHINE_CATALOG,
};
#[cfg(test)]
pub(crate) use catalog::register_machine_for_tests;
pub use encoding::FeatureEncoder;

use crate::util::rng::Pcg64;
use std::cmp::Ordering;

/// Per-node memory the OS keeps for itself (GB). Part of the "overhead by
/// the operating system and the distributed dataflow framework" the paper
/// folds into the final memory requirement (§III-D).
pub const OS_OVERHEAD_GB: f64 = 0.5;
/// Per-node memory the dataflow framework itself occupies (GB).
pub const FRAMEWORK_OVERHEAD_GB: f64 = 0.45;
/// Fraction of the remaining JVM heap available for caching data
/// (legacy spark storage-fraction-style accounting; high because the
/// simulated jobs are cache-dominated). Calibrated so the paper's Table I
/// anecdotes hold: NB/bigdata (754 GB) exceeds the maximum usable memory
/// of the space (~670 GB) while K-Means/bigdata (503 GB) retains a small
/// all-r4 priority group.
pub const STORAGE_FRACTION: f64 = 0.93;

/// One cluster configuration: a machine type at a scale-out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    /// Global machine index: [`MACHINE_CATALOG`] first, then the
    /// generated-machine registry (resolved via [`machine_by_index`]).
    pub machine: usize,
    /// Number of worker nodes.
    pub nodes: u32,
}

impl ClusterConfig {
    pub fn machine_type(&self) -> &'static MachineType {
        catalog::machine_by_index(self.machine)
    }

    pub fn total_cores(&self) -> f64 {
        self.nodes as f64 * self.machine_type().cores as f64
    }

    /// Raw total cluster RAM in GB.
    pub fn total_memory_gb(&self) -> f64 {
        self.nodes as f64 * self.machine_type().ram_gb
    }

    /// Cluster memory actually available for caching job data after OS,
    /// framework and execution-memory overheads (§III-D).
    pub fn usable_memory_gb(&self) -> f64 {
        let per_node =
            (self.machine_type().ram_gb - OS_OVERHEAD_GB - FRAMEWORK_OVERHEAD_GB).max(0.0);
        self.nodes as f64 * per_node * STORAGE_FRACTION
    }

    /// Price of running this cluster for one hour (USD).
    pub fn price_per_hour(&self) -> f64 {
        self.nodes as f64 * self.machine_type().price_hourly
    }

    pub fn name(&self) -> String {
        format!("{}x{}", self.nodes, self.machine_type().name)
    }
}

/// The full evaluation search space.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    configs: Vec<ClusterConfig>,
    encoder: FeatureEncoder,
}

impl SearchSpace {
    /// The paper's evaluation space: 69 configurations (23 per family).
    /// Scale-outs per machine size follow DESIGN.md §6.
    pub fn scout() -> Self {
        let mut configs = Vec::new();
        for (idx, machine) in MACHINE_CATALOG.iter().enumerate() {
            let scaleouts: &[u32] = match machine.size {
                MachineSize::Large => &[4, 6, 8, 10, 12, 16, 20, 24, 32, 40],
                MachineSize::XLarge => &[4, 6, 8, 10, 12, 16, 20, 24],
                MachineSize::XXLarge => &[4, 6, 8, 10, 12],
                // Larger sizes exist only in the generated grid.
                _ => &[],
            };
            for &nodes in scaleouts {
                configs.push(ClusterConfig { machine: idx, nodes });
            }
        }
        Self::from_configs(configs)
    }

    /// Build a space from an explicit configuration list (tests, what-if
    /// analyses, private-cluster catalogs).
    pub fn from_configs(configs: Vec<ClusterConfig>) -> Self {
        assert!(!configs.is_empty(), "search space cannot be empty");
        let encoder = FeatureEncoder::fit(&configs);
        Self { configs, encoder }
    }

    /// A generated full-cloud-catalog-scale space of exactly
    /// `target_len` distinct configurations.
    ///
    /// The underlying machine grid (synthetic generations of the c/m/r
    /// families across seven sizes and scale-outs 2..=64, see
    /// [`catalog`]) is fully deterministic; the `seed` only selects
    /// *which* `target_len` grid entries form the catalog, so the same
    /// `(seed, target_len)` pair yields the identical space in every
    /// process while different seeds model different providers'
    /// offerings. When `target_len` matches the grid size exactly the
    /// seed is irrelevant.
    pub fn generated(seed: u64, target_len: usize) -> Self {
        assert!(target_len > 0, "generated search space must be non-empty");
        let grid = catalog::generated_grid(target_len);
        let to_config = |&(machine, nodes): &(usize, u32)| ClusterConfig { machine, nodes };
        let configs: Vec<ClusterConfig> = if grid.len() == target_len {
            grid.iter().map(to_config).collect()
        } else {
            let mut rng =
                Pcg64::new(seed, 0x6C0D_5EED ^ (target_len as u64).rotate_left(17));
            let mut picks = rng.sample_distinct(grid.len(), target_len);
            // Keep grid order so the catalog reads generation-by-
            // generation regardless of the sampling order.
            picks.sort_unstable();
            picks.iter().map(|&p| to_config(&grid[p])).collect()
        };
        Self::from_configs(configs)
    }

    /// Largest catalog [`Self::generated`] can produce (the synthetic
    /// machine grid is capped).
    pub fn max_generated_len() -> usize {
        catalog::max_generated_len()
    }

    /// Parse a CLI space spec: `scout` (the paper's 69 configurations)
    /// or `generated:<n>` (a seeded n-config generated catalog).
    pub fn parse_spec(spec: &str, seed: u64) -> anyhow::Result<Self> {
        if spec == "scout" {
            return Ok(Self::scout());
        }
        if let Some(n) = spec.strip_prefix("generated:") {
            let n: usize = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad generated-space size {n:?} in {spec:?}"))?;
            anyhow::ensure!(n > 0, "generated search space must be non-empty");
            anyhow::ensure!(
                n <= Self::max_generated_len(),
                "generated search space of {n} configs exceeds the {}-config grid cap",
                Self::max_generated_len()
            );
            return Ok(Self::generated(seed, n));
        }
        anyhow::bail!("unknown search-space spec {spec:?} (expected scout|generated:<n>)")
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    pub fn configs(&self) -> &[ClusterConfig] {
        &self.configs
    }

    pub fn config(&self, idx: usize) -> ClusterConfig {
        self.configs[idx]
    }

    pub fn encoder(&self) -> &FeatureEncoder {
        &self.encoder
    }

    /// Normalized feature row for one configuration (length = N_FEATURES).
    pub fn features(&self, idx: usize) -> Vec<f64> {
        self.encoder.encode(&self.configs[idx])
    }

    /// All feature rows, row-major (len = len() * N_FEATURES) — the
    /// candidate matrix handed to the GP backend once per search. Encodes
    /// straight into one buffer (no per-config Vec), which matters once
    /// generated catalogs put thousands of rows in this matrix.
    pub fn feature_matrix(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.len() * encoding::N_FEATURES);
        for c in &self.configs {
            self.encoder.encode_into(c, &mut out);
        }
        out
    }

    /// Indices of configurations whose usable memory meets `min_gb`.
    pub fn with_usable_memory_at_least(&self, min_gb: f64) -> Vec<usize> {
        (0..self.len())
            .filter(|&i| self.configs[i].usable_memory_gb() >= min_gb)
            .collect()
    }

    /// Precomputed (total memory, price) selection keys, one pass over
    /// the configs. Comparators below read this vector instead of
    /// calling back into `ClusterConfig` accessors, so a selection over
    /// a 5k-config generated catalog performs n accessor calls (each of
    /// which resolves the machine registry) rather than one per
    /// comparison; the index tie-break makes every selection
    /// deterministic even when a catalog holds many identically-sized
    /// configurations at a group boundary.
    fn memory_price_keys(&self) -> Vec<(f64, f64)> {
        self.configs
            .iter()
            .map(|c| (c.total_memory_gb(), c.price_per_hour()))
            .collect()
    }

    /// Total order by (total memory, price, index) over precomputed keys.
    fn cmp_keyed(keys: &[(f64, f64)], a: usize, b: usize) -> Ordering {
        let ka = (keys[a].0, keys[a].1, a);
        let kb = (keys[b].0, keys[b].1, b);
        ka.partial_cmp(&kb).expect("NaN in memory/price selection key")
    }

    /// Total order by (total memory, index) over precomputed keys — the
    /// decile-boundary order of [`Self::memory_extremes`].
    fn cmp_keyed_memory(keys: &[(f64, f64)], a: usize, b: usize) -> Ordering {
        (keys[a].0, a).partial_cmp(&(keys[b].0, b)).expect("NaN in memory selection key")
    }

    /// The `k` configurations with the lowest total memory (ties broken
    /// by price, then index) — Ruya's priority group for flat-memory
    /// jobs. O(n) selection plus an O(k log k) sort of the group, so a
    /// small group over a 5k-config generated catalog costs ~n compares
    /// instead of a full n log n sort.
    pub fn lowest_memory_configs(&self, k: usize) -> Vec<usize> {
        let k = k.min(self.len());
        if k == 0 {
            return Vec::new();
        }
        let keys = self.memory_price_keys();
        let mut idx: Vec<usize> = (0..self.len()).collect();
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, |&a, &b| Self::cmp_keyed(&keys, a, b));
            idx.truncate(k);
        }
        idx.sort_unstable_by(|&a, &b| Self::cmp_keyed(&keys, a, b));
        idx
    }

    /// Configurations in the top or bottom `decile_fraction` of total
    /// memory — the fallback priority group when a linear job's
    /// requirement exceeds every available configuration (§III-D).
    /// Returned ascending by index. Boundary ties resolve by index
    /// (lowest indices fill the bottom group, highest the top), matching
    /// the stable-sort behavior of the small-space implementation but in
    /// O(n) selection time.
    pub fn memory_extremes(&self, decile_fraction: f64) -> Vec<usize> {
        let n = self.len();
        let k = ((n as f64 * decile_fraction).ceil() as usize).max(1);
        let mut idx: Vec<usize> = (0..n).collect();
        if 2 * k >= n {
            // The two extremes cover everything.
            return idx;
        }
        let keys = self.memory_price_keys();
        // Bottom k: the k smallest by (memory, index).
        idx.select_nth_unstable_by(k - 1, |&a, &b| Self::cmp_keyed_memory(&keys, a, b));
        // Top k among the remainder — disjoint from the bottom since
        // 2k < n, and equal to the global top k because the remainder
        // holds every element the bottom selection did not take.
        let rest = &mut idx[k..];
        let cut = rest.len() - k;
        rest.select_nth_unstable_by(cut, |&a, &b| Self::cmp_keyed_memory(&keys, a, b));
        let top_start = k + cut;
        let mut out = Vec::with_capacity(2 * k);
        out.extend_from_slice(&idx[..k]);
        out.extend_from_slice(&idx[top_start..]);
        out.sort_unstable();
        out
    }

    /// Maximum usable memory over the whole space (GB).
    pub fn max_usable_memory_gb(&self) -> f64 {
        self.configs
            .iter()
            .map(|c| c.usable_memory_gb())
            .fold(0.0, f64::max)
    }

    /// (min, max) usable memory (GB) over a subset of the space — the
    /// pipeline report prints this to show what memory band a
    /// shortlist actually covers. `None` for an empty subset.
    pub fn usable_memory_bounds(&self, indices: &[usize]) -> Option<(f64, f64)> {
        let mut bounds: Option<(f64, f64)> = None;
        for &i in indices {
            let gb = self.configs[i].usable_memory_gb();
            bounds = Some(match bounds {
                None => (gb, gb),
                Some((lo, hi)) => (lo.min(gb), hi.max(gb)),
            });
        }
        bounds
    }
}

pub use encoding::N_FEATURES;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scout_space_has_69_configs() {
        let s = SearchSpace::scout();
        assert_eq!(s.len(), 69);
    }

    #[test]
    fn scaleouts_span_4_to_48_nodes() {
        let s = SearchSpace::scout();
        let min = s.configs().iter().map(|c| c.nodes).min().unwrap();
        let max = s.configs().iter().map(|c| c.nodes).max().unwrap();
        assert_eq!(min, 4);
        assert!(max >= 40, "largest scale-out {max}");
    }

    #[test]
    fn total_memory_spans_paper_range() {
        // The paper's anecdotes rely on ~15 GB at the bottom and
        // ~732 GB (r4.2xlarge x 12) at the top.
        let s = SearchSpace::scout();
        let min = s.configs().iter().map(|c| c.total_memory_gb()).fold(f64::MAX, f64::min);
        let max = s.configs().iter().map(|c| c.total_memory_gb()).fold(0.0, f64::max);
        assert!((min - 15.0).abs() < 1.0, "min total mem {min}");
        assert!((max - 732.0).abs() < 1.0, "max total mem {max}");
    }

    #[test]
    fn usable_memory_below_total() {
        let s = SearchSpace::scout();
        for c in s.configs() {
            assert!(c.usable_memory_gb() < c.total_memory_gb());
            assert!(c.usable_memory_gb() > 0.0);
        }
    }

    #[test]
    fn memory_filter_is_consistent() {
        let s = SearchSpace::scout();
        let idx = s.with_usable_memory_at_least(100.0);
        assert!(!idx.is_empty());
        for &i in &idx {
            assert!(s.config(i).usable_memory_gb() >= 100.0);
        }
        let complement: Vec<usize> =
            (0..s.len()).filter(|i| !idx.contains(i)).collect();
        for &i in &complement {
            assert!(s.config(i).usable_memory_gb() < 100.0);
        }
    }

    #[test]
    fn lowest_memory_configs_sorted_and_small() {
        let s = SearchSpace::scout();
        let low = s.lowest_memory_configs(10);
        assert_eq!(low.len(), 10);
        let max_low = low.iter().map(|&i| s.config(i).total_memory_gb()).fold(0.0, f64::max);
        let rest_min = (0..s.len())
            .filter(|i| !low.contains(i))
            .map(|i| s.config(i).total_memory_gb())
            .fold(f64::MAX, f64::min);
        assert!(max_low <= rest_min + 1e-9);
    }

    #[test]
    fn memory_extremes_contains_both_ends() {
        let s = SearchSpace::scout();
        let ext = s.memory_extremes(0.1);
        let mems: Vec<f64> = ext.iter().map(|&i| s.config(i).total_memory_gb()).collect();
        let global_min = s.configs().iter().map(|c| c.total_memory_gb()).fold(f64::MAX, f64::min);
        let global_max = s.configs().iter().map(|c| c.total_memory_gb()).fold(0.0, f64::max);
        assert!(mems.iter().any(|&m| (m - global_min).abs() < 1e-9));
        assert!(mems.iter().any(|&m| (m - global_max).abs() < 1e-9));
    }

    #[test]
    fn feature_matrix_dims() {
        let s = SearchSpace::scout();
        assert_eq!(s.feature_matrix().len(), 69 * N_FEATURES);
    }

    #[test]
    fn config_names_readable() {
        let s = SearchSpace::scout();
        assert!(s.configs().iter().any(|c| c.name() == "4xc4.large"));
    }

    #[test]
    fn generated_space_has_exact_len_distinct_and_stable() {
        for &n in &[1usize, 69, 500, 1500] {
            let a = SearchSpace::generated(7, n);
            assert_eq!(a.len(), n, "generated space must have exactly n configs");
            let mut seen = std::collections::HashSet::new();
            for c in a.configs() {
                assert!(seen.insert((c.machine, c.nodes)), "duplicate config {}", c.name());
            }
            // Stable across runs for the same seed.
            let b = SearchSpace::generated(7, n);
            assert_eq!(a.configs(), b.configs(), "n={n} not stable under the same seed");
        }
        // Different seeds select different subsets (same machine grid).
        let a = SearchSpace::generated(1, 400);
        let b = SearchSpace::generated(2, 400);
        assert_ne!(a.configs(), b.configs(), "seeds must pick different catalogs");
    }

    #[test]
    fn generated_space_memory_helpers_behave() {
        let s = SearchSpace::generated(11, 2000);
        // with_usable_memory_at_least: exact threshold semantics.
        let min_gb = 200.0;
        let idx = s.with_usable_memory_at_least(min_gb);
        assert!(!idx.is_empty() && idx.len() < s.len());
        let in_set: std::collections::HashSet<usize> = idx.iter().copied().collect();
        for i in 0..s.len() {
            assert_eq!(
                in_set.contains(&i),
                s.config(i).usable_memory_gb() >= min_gb,
                "config {i} misfiled"
            );
        }
        // memory_extremes covers the global min and max.
        let ext = s.memory_extremes(0.1);
        let mem = |i: usize| s.config(i).total_memory_gb();
        let gmin = (0..s.len()).map(mem).fold(f64::MAX, f64::min);
        let gmax = (0..s.len()).map(mem).fold(0.0, f64::max);
        assert!(ext.iter().any(|&i| (mem(i) - gmin).abs() < 1e-9));
        assert!(ext.iter().any(|&i| (mem(i) - gmax).abs() < 1e-9));
        // lowest_memory_configs: every selected config <= every excluded.
        let k = 40;
        let low = s.lowest_memory_configs(k);
        assert_eq!(low.len(), k);
        let low_set: std::collections::HashSet<usize> = low.iter().copied().collect();
        let max_low = low.iter().map(|&i| mem(i)).fold(0.0, f64::max);
        let rest_min = (0..s.len())
            .filter(|i| !low_set.contains(i))
            .map(mem)
            .fold(f64::MAX, f64::min);
        assert!(max_low <= rest_min + 1e-9, "{max_low} vs {rest_min}");
    }

    #[test]
    fn selection_helpers_match_full_sort_reference() {
        // The O(n) select_nth implementations must agree with a plain
        // full-sort reference on a generated catalog (including its
        // duplicated-memory ties).
        let s = SearchSpace::generated(3, 1200);
        let key = |i: usize| {
            (s.config(i).total_memory_gb(), s.config(i).price_per_hour(), i)
        };
        let mut sorted: Vec<usize> = (0..s.len()).collect();
        sorted.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        for &k in &[1usize, 7, 120, 1199, 1200, 5000] {
            let want: Vec<usize> = sorted.iter().take(k.min(s.len())).copied().collect();
            assert_eq!(s.lowest_memory_configs(k), want, "k={k}");
        }
        let mem_key = |i: usize| (s.config(i).total_memory_gb(), i);
        let mut by_mem: Vec<usize> = (0..s.len()).collect();
        by_mem.sort_by(|&a, &b| mem_key(a).partial_cmp(&mem_key(b)).unwrap());
        for &frac in &[0.01, 0.1, 0.25, 0.6] {
            let k = ((s.len() as f64 * frac).ceil() as usize).max(1);
            let mut want: Vec<usize> = by_mem.iter().take(k).copied().collect();
            want.extend(by_mem.iter().rev().take(k).copied());
            want.sort_unstable();
            want.dedup();
            assert_eq!(s.memory_extremes(frac), want, "frac={frac}");
        }
    }

    #[test]
    fn boundary_ties_resolve_by_index() {
        // A catalog of identical-memory configs except for scale-out
        // duplicates: machine 0 at 4 nodes repeated via distinct machine
        // indices sharing RAM. Build explicitly: four r4.large x 8 (same
        // total memory/price) followed by two larger configs.
        let mut configs = Vec::new();
        for _ in 0..4 {
            configs.push(ClusterConfig { machine: 6, nodes: 8 }); // r4.large x8
        }
        configs.push(ClusterConfig { machine: 8, nodes: 4 }); // bigger memory
        configs.push(ClusterConfig { machine: 0, nodes: 4 }); // smallest memory
        let s = SearchSpace::from_configs(configs);
        // lowest 2: the c4 config, then the first of the tied r4 block.
        assert_eq!(s.lowest_memory_configs(2), vec![5, 0]);
        // Deterministic under repetition.
        assert_eq!(s.lowest_memory_configs(2), s.lowest_memory_configs(2));
        // Extremes at 1/6: bottom pick is config 5, top is config 4; the
        // tied middle block never leaks in.
        assert_eq!(s.memory_extremes(1.0 / 6.0), vec![4, 5]);
        // A boundary running through the tied block takes the lowest
        // indices of the tie for the bottom group, the highest for the top.
        assert_eq!(s.memory_extremes(2.0 / 6.0), vec![0, 3, 4, 5]);
    }

    #[test]
    fn parse_spec_roundtrip() {
        assert_eq!(SearchSpace::parse_spec("scout", 0).unwrap().len(), 69);
        let g = SearchSpace::parse_spec("generated:123", 9).unwrap();
        assert_eq!(g.len(), 123);
        assert_eq!(g.configs(), SearchSpace::generated(9, 123).configs());
        assert!(SearchSpace::parse_spec("generated:0", 0).is_err());
        assert!(SearchSpace::parse_spec("generated:abc", 0).is_err());
        assert!(SearchSpace::parse_spec("galaxy", 0).is_err());
        // Oversized requests are a clean error, not a panic.
        let over = SearchSpace::max_generated_len() + 1;
        let err = SearchSpace::parse_spec(&format!("generated:{over}"), 0).unwrap_err();
        assert!(err.to_string().contains("grid cap"), "{err}");
    }

    #[test]
    fn parse_spec_errors_name_the_problem() {
        // Each error path must tell the operator what was wrong and what
        // would be accepted — these messages are the CLI's only feedback
        // for a bad `--space` value.
        let err = SearchSpace::parse_spec("generated:abc", 0).unwrap_err().to_string();
        assert!(err.contains("bad generated-space size"), "{err}");
        assert!(err.contains("abc"), "must echo the bad size: {err}");
        let err = SearchSpace::parse_spec("generated:", 0).unwrap_err().to_string();
        assert!(err.contains("bad generated-space size"), "empty size: {err}");
        let err = SearchSpace::parse_spec("generated:0", 0).unwrap_err().to_string();
        assert!(err.contains("non-empty"), "{err}");
        let err = SearchSpace::parse_spec("galaxy", 0).unwrap_err().to_string();
        assert!(err.contains("galaxy"), "must echo the unknown spec: {err}");
        assert!(err.contains("expected scout|generated:<n>"), "{err}");
        // The cap error names the actual bound so the user can back off.
        let cap = SearchSpace::max_generated_len();
        let err = SearchSpace::parse_spec(&format!("generated:{}", cap + 1), 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains(&cap.to_string()), "cap value missing: {err}");
        assert!(err.contains(&(cap + 1).to_string()), "request missing: {err}");
        // The boundary itself parses (exactly the full grid).
        assert_eq!(SearchSpace::parse_spec(&format!("generated:{cap}"), 0).unwrap().len(), cap);
    }

    #[test]
    fn generated_features_are_normalized_and_distinct_machines_resolve() {
        let s = SearchSpace::generated(5, 800);
        assert_eq!(s.feature_matrix().len(), 800 * N_FEATURES);
        for i in 0..s.len() {
            for v in s.features(i) {
                assert!((-1e-12..=1.0 + 1e-12).contains(&v), "feature {v} out of range");
            }
            // Every generated machine index resolves to real specs.
            let m = s.config(i).machine_type();
            assert!(m.ram_gb > 0.0 && m.cores > 0 && m.price_hourly > 0.0);
        }
    }
}
