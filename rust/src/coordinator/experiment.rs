//! The evaluation harness: runs the CherryPick-vs-Ruya comparison that
//! generates Table II, Fig. 4 and Fig. 5, plus the Table I / Table III
//! profiling summaries.
//!
//! Protocol (§IV-C): for every job the search runs repeatedly with fresh
//! random initializations; we record after how many cluster executions a
//! configuration with normalized cost <= 1.2 / 1.1 / 1.0 was first tried,
//! averaged over repetitions. Searches run to exhaustion (the stopping
//! criterion is recorded, not enforced) exactly like the paper's
//! iterations-to-reach metric.
//!
//! **Parallel engine:** repetitions are independent seeded searches, so
//! they shard across `threads` scoped workers. Each worker instantiates
//! its own GP backend from the runner's [`BackendFactory`]; repetition
//! `r` always uses the seed `seed_base + r * 7919` and outcomes are
//! folded back in repetition order, so every aggregate is bit-identical
//! to the serial engine regardless of the worker count.
//!
//! [`ExperimentRunner::run_table2`] additionally shards at the *(job,
//! method)* level: all 16 jobs × 2 methods × `reps` searches form one
//! flat task list split across the workers, so small-`reps` runs also
//! saturate `--threads` instead of serializing on the 32 (job, method)
//! pairs. Folds still walk each pair's outcomes in repetition order and
//! the pairs in job order, keeping every aggregate bit-identical.

use super::planner::{RuyaPlanner, SearchPlan};
use super::session::SessionEngine;
use crate::bayesopt::{
    run_search, BackendFactory, BoParams, GpBackend, NativeBackend, SearchOutcome,
};
use crate::memmodel::{MemCategory, MemoryModel};
use crate::profiler::SingleNodeProfiler;
use crate::searchspace::SearchSpace;
use crate::util::rng::Pcg64;
use crate::util::stats::mean;
use crate::workload::{evaluation_jobs, ClusterSim, JobCostTable, JobInstance};
use anyhow::Result;

/// Cost thresholds of Table II: near-optimal 20%, 10%, and optimal.
pub const THRESHOLDS: [f64; 3] = [1.2, 1.1, 1.0 + 1e-9];

/// Iteration ceiling for the run-to-exhaustion experiment defaults.
/// The Table II protocol exhausts the space, which is fine for the
/// 69-config scout catalog but computationally infeasible on generated
/// catalogs (an exhaustive 5k-config search pays O(H·n²) grid refits at
/// n → 5000 per repetition). Experiments on spaces larger than this run
/// capped at it instead of hanging; pass explicit `BoParams` (e.g.
/// through [`ExperimentRunner::run_one_params`]) to override.
pub const MAX_EXHAUSTIVE_ITERS: usize = 512;

/// Experiment configuration.
#[derive(Debug, Clone, Copy)]
pub struct ExperimentConfig {
    /// Repetitions per (job, method); the paper averages 200.
    pub reps: usize,
    pub seed: u64,
    /// Length of the per-iteration curves (Fig. 4 / Fig. 5).
    pub curve_len: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self { reps: 200, seed: 0xC0FFEE, curve_len: 48 }
    }
}

/// Per-job aggregate over repetitions for one method.
#[derive(Debug, Clone)]
pub struct MethodStats {
    /// Mean executions until cost <= THRESHOLDS[k] first observed.
    pub iters_to: [f64; 3],
    /// Mean best-so-far normalized cost after i+1 executions (Fig. 4).
    pub best_curve: Vec<f64>,
    /// Mean cumulative normalized execution cost (Fig. 5 semantics: the
    /// search stops at the recorded criterion, afterwards every recurrence
    /// runs on the best configuration found).
    pub cum_curve: Vec<f64>,
    /// Mean executions when the stopping criterion fired.
    pub mean_stop: f64,
}

/// One Table II row.
#[derive(Debug, Clone)]
pub struct JobComparison {
    pub label: String,
    pub category: MemCategory,
    pub requirement_gb: Option<f64>,
    pub priority_fraction: f64,
    pub cherrypick: MethodStats,
    pub ruya: MethodStats,
}

impl JobComparison {
    /// Table II "Quotient Ruya/CherryPick" cells (fractions, not %).
    pub fn quotient(&self) -> [f64; 3] {
        let mut q = [0.0; 3];
        for k in 0..3 {
            q[k] = self.ruya.iters_to[k] / self.cherrypick.iters_to[k];
        }
        q
    }
}

/// Full evaluation output.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub jobs: Vec<JobComparison>,
    pub mean_cherrypick: [f64; 3],
    pub mean_ruya: [f64; 3],
    pub mean_quotient: [f64; 3],
}

/// Profiling + memory-model summary for one job (Tables I and III).
#[derive(Debug, Clone)]
pub struct ProfileSummary {
    pub label: String,
    pub model: MemoryModel,
    pub table1_cell: String,
    pub profiling_time_s: f64,
}

/// The experiment driver. Owns the simulated substrate and instantiates
/// one [`GpBackend`] per evaluation worker from its factory.
pub struct ExperimentRunner {
    pub space: SearchSpace,
    pub sim: ClusterSim,
    pub profiler: SingleNodeProfiler,
    pub planner: RuyaPlanner,
    /// Worker threads for repetition sharding (1 = serial). Results are
    /// bit-identical for every value.
    pub threads: usize,
    factory: BackendFactory,
}

impl ExperimentRunner {
    pub fn new(factory: BackendFactory) -> Self {
        Self {
            space: SearchSpace::scout(),
            sim: ClusterSim::default(),
            profiler: SingleNodeProfiler::default(),
            planner: RuyaPlanner::default(),
            threads: 1,
            factory,
        }
    }

    /// Runner over the pure-rust backend — the common case in tests,
    /// benches and examples. Each backend's GP fan-out is kept serial,
    /// matching `backend_factory_by_name`: the engine already multiplies
    /// backends by its own worker count, so attaching them to the
    /// process-global worker pool is opted into explicitly via
    /// `backend_factory_with_parallelism` (the pool is shared, so even
    /// then total parked GP threads stay at the pool width — they are
    /// never multiplied per backend), never defaulted here.
    pub fn native() -> Self {
        Self::new(Box::new(|| -> Result<Box<dyn GpBackend>> {
            let mut b = NativeBackend::new();
            b.set_parallelism(1);
            Ok(Box::new(b))
        }))
    }

    /// Set the repetition-sharding worker count (builder style).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Replace the search space (builder style) — e.g. a generated
    /// full-catalog space from `SearchSpace::parse_spec` (`--space
    /// generated:<n>` on the CLI). Everything downstream (cost tables,
    /// plans, searches) derives from `self.space`, so no other state
    /// needs to change.
    pub fn with_space(mut self, space: SearchSpace) -> Self {
        self.space = space;
        self
    }

    /// One backend instance from the runner's factory.
    pub fn make_backend(&self) -> Result<Box<dyn GpBackend>> {
        (self.factory)()
    }

    /// Default run-to-exhaustion parameters for this runner's space,
    /// capped at [`MAX_EXHAUSTIVE_ITERS`] so experiment commands stay
    /// feasible when pointed at a generated multi-thousand-config
    /// catalog (the scout space sits far below the cap and keeps the
    /// paper's exact exhaustion protocol).
    pub fn exhaustive_params(&self) -> BoParams {
        BoParams {
            max_iters: self.space.len().min(MAX_EXHAUSTIVE_ITERS),
            ..Default::default()
        }
    }

    /// Profile one job and fit its memory model (Table I / III rows).
    pub fn profile_job(&self, job: &JobInstance, seed: u64) -> ProfileSummary {
        let outcome = self.profiler.profile(job, seed);
        let model = MemoryModel::fit(&outcome.valid_readings());
        ProfileSummary {
            label: job.label(),
            table1_cell: model.table1_cell(job.input_gb),
            model,
            profiling_time_s: outcome.total_s,
        }
    }

    /// Profile all evaluation jobs.
    pub fn profile_all(&self, seed: u64) -> Vec<ProfileSummary> {
        evaluation_jobs().iter().map(|j| self.profile_job(j, seed)).collect()
    }

    /// Run one search for `job` under `plan` with a per-repetition seed,
    /// on a fresh backend from the factory.
    pub fn run_one(
        &self,
        table: &JobCostTable,
        plan: &SearchPlan,
        rep_seed: u64,
    ) -> Result<SearchOutcome> {
        let mut backend = (self.factory)()?;
        self.run_one_with(backend.as_mut(), table, plan, rep_seed)
    }

    /// [`Self::run_one`] with explicit search parameters — the CLI uses
    /// this to cap iterations / enforce the stopping criterion on
    /// generated catalogs too large to exhaust.
    pub fn run_one_params(
        &self,
        table: &JobCostTable,
        plan: &SearchPlan,
        rep_seed: u64,
        params: &BoParams,
    ) -> Result<SearchOutcome> {
        let mut backend = (self.factory)()?;
        self.run_one_with_params(backend.as_mut(), table, plan, rep_seed, params)
    }

    /// Run one search on a caller-provided backend (reuse across calls),
    /// with the default run-to-exhaustion parameters.
    pub fn run_one_with(
        &self,
        backend: &mut dyn GpBackend,
        table: &JobCostTable,
        plan: &SearchPlan,
        rep_seed: u64,
    ) -> Result<SearchOutcome> {
        let params = self.exhaustive_params();
        self.run_one_with_params(backend, table, plan, rep_seed, &params)
    }

    /// Run one search on a caller-provided backend with explicit
    /// parameters — the common core of every single-search entry point.
    pub fn run_one_with_params(
        &self,
        backend: &mut dyn GpBackend,
        table: &JobCostTable,
        plan: &SearchPlan,
        rep_seed: u64,
        params: &BoParams,
    ) -> Result<SearchOutcome> {
        let features = self.space.feature_matrix();
        let m = self.space.len();
        let d = crate::searchspace::N_FEATURES;
        let mut rng = Pcg64::from_seed(rep_seed);
        let costs = &table.normalized;
        let mut oracle = |i: usize| costs[i];
        run_search(&features, m, d, &plan.phases, &mut oracle, backend, &mut rng, params)
    }

    /// Compare CherryPick and Ruya on one job over `cfg.reps` repetitions.
    pub fn compare_job(&self, job: &JobInstance, cfg: &ExperimentConfig) -> Result<JobComparison> {
        let table = JobCostTable::build(&self.sim, job, &self.space);
        let profile = self.profile_job(job, cfg.seed);
        let ruya_plan = self.planner.plan(&profile.model, job.input_gb, &self.space);
        let cp_plan = SearchPlan::unpartitioned(&self.space);

        let cherrypick = self.run_method(&table, &cp_plan, cfg, job.job_id ^ 0x5EED)?;
        let ruya = self.run_method(&table, &ruya_plan, cfg, job.job_id ^ 0x5EED)?;

        Ok(JobComparison {
            label: job.label(),
            category: ruya_plan.category,
            requirement_gb: ruya_plan.requirement_gb,
            priority_fraction: ruya_plan.priority_fraction,
            cherrypick,
            ruya,
        })
    }

    /// Register `job` with a resident [`SessionEngine`]: build its
    /// (simulated) cost table, profile it, derive its memory-aware
    /// search plan and hand the bundle over as shared immutable job
    /// state. Returns the engine's job handle — any number of sessions
    /// can then be opened against it (`ruya serve` does exactly this on
    /// first reference to a job label).
    pub fn register_job_with_engine(
        &self,
        engine: &mut SessionEngine,
        job: &JobInstance,
        seed: u64,
    ) -> Result<usize> {
        let table = JobCostTable::build(&self.sim, job, &self.space);
        let profile = self.profile_job(job, seed);
        let plan = self.planner.plan(&profile.model, job.input_gb, &self.space);
        engine.register_job(&job.label(), &self.space, table.normalized, plan.phases)
    }

    /// Run `reps` seeded searches for every `(table, plan, seed_base)`
    /// unit — repetition `r` of a unit uses seed `seed_base + r * 7919`,
    /// the same formula as the serial engine — sharding the flat
    /// units × reps task list across `self.threads` scoped workers. Each
    /// worker owns one backend from the factory; outcomes come back
    /// grouped per unit in repetition order, so any downstream fold is
    /// independent of the worker count.
    fn run_units(
        &self,
        units: &[(&JobCostTable, &SearchPlan, u64)],
        reps: usize,
        params: &BoParams,
    ) -> Result<Vec<Vec<SearchOutcome>>> {
        let features = self.space.feature_matrix();
        let m = self.space.len();
        let d = crate::searchspace::N_FEATURES;
        let total = units.len() * reps;
        let run_task = move |backend: &mut dyn GpBackend, task: usize| -> Result<SearchOutcome> {
            let (table, plan, seed_base) = units[task / reps];
            let rep = (task % reps) as u64;
            let mut rng = Pcg64::from_seed(seed_base.wrapping_add(rep * 7919));
            let costs = &table.normalized;
            let mut oracle = |i: usize| costs[i];
            run_search(&features, m, d, &plan.phases, &mut oracle, backend, &mut rng, params)
        };

        let workers = self.threads.min(total).max(1);
        let outcomes: Vec<Result<SearchOutcome>> = if workers == 1 {
            let mut backend = (self.factory)()?;
            (0..total).map(|task| run_task(backend.as_mut(), task)).collect()
        } else {
            let mut slots: Vec<Option<Result<SearchOutcome>>> = Vec::with_capacity(total);
            slots.resize_with(total, || None);
            let chunk = total.div_ceil(workers);
            let factory = &self.factory;
            std::thread::scope(|scope| {
                for (w, chunk_slots) in slots.chunks_mut(chunk).enumerate() {
                    let run_task = &run_task;
                    scope.spawn(move || {
                        let mut backend = match factory() {
                            Ok(b) => b,
                            Err(e) => {
                                // Propagate as an error on this worker's
                                // tasks instead of panicking the scope.
                                for (off, slot) in chunk_slots.iter_mut().enumerate() {
                                    *slot = Some(Err(anyhow::anyhow!(
                                        "backend construction failed for task {}: {e:#}",
                                        w * chunk + off
                                    )));
                                }
                                return;
                            }
                        };
                        for (off, slot) in chunk_slots.iter_mut().enumerate() {
                            *slot = Some(run_task(backend.as_mut(), w * chunk + off));
                        }
                    });
                }
            });
            slots.into_iter().map(|s| s.expect("worker filled every slot")).collect()
        };

        let mut grouped: Vec<Vec<SearchOutcome>> = Vec::with_capacity(units.len());
        let mut it = outcomes.into_iter();
        for _ in 0..units.len() {
            grouped.push(it.by_ref().take(reps).collect::<Result<Vec<_>>>()?);
        }
        Ok(grouped)
    }

    /// Run `cfg.reps` seeded searches for one (table, plan) pair —
    /// repetition sharding only (see [`Self::run_units`]).
    fn run_reps(
        &self,
        table: &JobCostTable,
        plan: &SearchPlan,
        cfg: &ExperimentConfig,
        seed_base: u64,
        params: &BoParams,
    ) -> Result<Vec<SearchOutcome>> {
        let mut grouped = self.run_units(&[(table, plan, seed_base)], cfg.reps, params)?;
        Ok(grouped.pop().expect("one unit in, one group out"))
    }

    fn run_method(
        &self,
        table: &JobCostTable,
        plan: &SearchPlan,
        cfg: &ExperimentConfig,
        seed_base: u64,
    ) -> Result<MethodStats> {
        let params = self.exhaustive_params();
        let outs = self.run_reps(table, plan, cfg, seed_base, &params)?;
        Ok(fold_method_stats(&outs, cfg))
    }

    /// The full Table II experiment over all 16 jobs.
    ///
    /// All 16 jobs × 2 methods × `cfg.reps` searches shard as one flat
    /// task list across the workers (job-level + repetition-level
    /// parallelism), so small-`reps` runs still saturate `--threads`.
    /// Per-rep seeds and fold order match the per-job
    /// [`Self::compare_job`] path exactly, keeping every aggregate
    /// bit-identical regardless of the worker count or sharding shape.
    pub fn run_table2(&self, cfg: &ExperimentConfig) -> Result<ExperimentResult> {
        // Per-job preparation (profiling + planning) is cheap and serial.
        let job_list = evaluation_jobs();
        let preps: Vec<(JobCostTable, SearchPlan, SearchPlan, u64)> = job_list
            .iter()
            .map(|job| {
                let table = JobCostTable::build(&self.sim, job, &self.space);
                let profile = self.profile_job(job, cfg.seed);
                let ruya_plan = self.planner.plan(&profile.model, job.input_gb, &self.space);
                let cp_plan = SearchPlan::unpartitioned(&self.space);
                (table, cp_plan, ruya_plan, job.job_id ^ 0x5EED)
            })
            .collect();

        // Unit order fixes the fold order: [job0·cp, job0·ruya, job1·cp, …].
        let units: Vec<(&JobCostTable, &SearchPlan, u64)> = preps
            .iter()
            .flat_map(|(table, cp, ruya, seed)| {
                [(table, cp, *seed), (table, ruya, *seed)]
            })
            .collect();
        let params = self.exhaustive_params();
        let grouped = self.run_units(&units, cfg.reps, &params)?;

        let mut jobs = Vec::new();
        for (ji, (job, prep)) in job_list.iter().zip(&preps).enumerate() {
            let ruya_plan = &prep.2;
            jobs.push(JobComparison {
                label: job.label(),
                category: ruya_plan.category,
                requirement_gb: ruya_plan.requirement_gb,
                priority_fraction: ruya_plan.priority_fraction,
                cherrypick: fold_method_stats(&grouped[ji * 2], cfg),
                ruya: fold_method_stats(&grouped[ji * 2 + 1], cfg),
            });
        }
        let mut mean_cp = [0.0; 3];
        let mut mean_ruya = [0.0; 3];
        for k in 0..3 {
            mean_cp[k] = mean(&jobs.iter().map(|j| j.cherrypick.iters_to[k]).collect::<Vec<_>>());
            mean_ruya[k] = mean(&jobs.iter().map(|j| j.ruya.iters_to[k]).collect::<Vec<_>>());
        }
        let mean_quotient = [
            mean_ruya[0] / mean_cp[0],
            mean_ruya[1] / mean_cp[1],
            mean_ruya[2] / mean_cp[2],
        ];
        Ok(ExperimentResult { jobs, mean_cherrypick: mean_cp, mean_ruya, mean_quotient })
    }
}

/// Quality of an *enforced-stop* search (§III-E): what you actually get
/// when the search ends at the stopping criterion instead of running to
/// exhaustion as the Table II measurement protocol does.
#[derive(Debug, Clone, Copy)]
pub struct StopQuality {
    /// Mean executions until the criterion fired.
    pub mean_stop_iters: f64,
    /// Mean normalized cost of the best configuration found by then.
    pub mean_best_cost: f64,
    /// Fraction of repetitions whose stopped search had found the optimum.
    pub frac_optimal: f64,
    /// Mean summed normalized cost of all search executions (exploration
    /// spend).
    pub mean_search_spend: f64,
}

impl ExperimentRunner {
    /// Run enforced-stop searches for one job under a plan and aggregate
    /// the §III-E stopping-criterion tradeoff. Shards repetitions like
    /// [`Self::run_table2`].
    pub fn stop_quality(
        &self,
        table: &JobCostTable,
        plan: &SearchPlan,
        cfg: &ExperimentConfig,
        seed_base: u64,
    ) -> Result<StopQuality> {
        let params =
            BoParams { enforce_stop: true, ..self.exhaustive_params() };
        let outs = self.run_reps(table, plan, cfg, seed_base, &params)?;

        let mut stops = Vec::new();
        let mut bests = Vec::new();
        let mut spends = Vec::new();
        let mut optimal = 0usize;
        for out in &outs {
            let stop = out.tried.len();
            let best = out.best_after(stop);
            stops.push(stop as f64);
            bests.push(best);
            spends.push(out.costs.iter().sum::<f64>());
            if best <= 1.0 + 1e-9 {
                optimal += 1;
            }
        }
        Ok(StopQuality {
            mean_stop_iters: mean(&stops),
            mean_best_cost: mean(&bests),
            frac_optimal: optimal as f64 / cfg.reps as f64,
            mean_search_spend: mean(&spends),
        })
    }
}

/// Fold one (job, method)'s outcomes into [`MethodStats`], walking
/// repetitions in order: every sum visits the same terms in the same
/// sequence as the serial engine, so the aggregates are bit-identical no
/// matter how the searches were sharded (repetition-only or flat
/// job × method × repetition).
fn fold_method_stats(outs: &[SearchOutcome], cfg: &ExperimentConfig) -> MethodStats {
    let mut iters = [Vec::new(), Vec::new(), Vec::new()];
    let mut best_curve = vec![0.0; cfg.curve_len];
    let mut cum_curve = vec![0.0; cfg.curve_len];
    let mut stops = Vec::new();
    for out in outs {
        for (k, &thr) in THRESHOLDS.iter().enumerate() {
            // The search exhausts the space, so every threshold is
            // eventually reached.
            iters[k].push(out.first_within(thr).unwrap_or(out.tried.len()) as f64);
        }
        accumulate_curves(out, &mut best_curve, &mut cum_curve);
        stops.push(out.stop_after.unwrap_or(out.tried.len()) as f64);
    }
    let n = cfg.reps as f64;
    for v in best_curve.iter_mut().chain(cum_curve.iter_mut()) {
        *v /= n;
    }
    MethodStats {
        iters_to: [mean(&iters[0]), mean(&iters[1]), mean(&iters[2])],
        best_curve,
        cum_curve,
        mean_stop: mean(&stops),
    }
}

/// Fold one search trace into the Fig. 4 / Fig. 5 accumulators.
fn accumulate_curves(out: &SearchOutcome, best_curve: &mut [f64], cum_curve: &mut [f64]) {
    let stop = out.stop_after.unwrap_or(out.tried.len());
    let mut best = f64::INFINITY;
    let mut cum = 0.0;
    let best_at_stop = out.best_after(stop);
    for i in 0..best_curve.len() {
        if i < out.costs.len() {
            best = best.min(out.costs[i]);
        }
        // Fig. 4: best configuration discovered so far.
        best_curve[i] += best;
        // Fig. 5: execution i runs a search probe while searching, the
        // best-found configuration after the search stopped.
        cum += if i < stop {
            out.costs.get(i).copied().unwrap_or(best_at_stop)
        } else {
            best_at_stop
        };
        cum_curve[i] += cum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig { reps: 8, seed: 42, curve_len: 30 }
    }

    fn job(name: &str, scale: &str) -> JobInstance {
        evaluation_jobs()
            .into_iter()
            .find(|j| j.algo.name == name && j.scale.name() == scale)
            .unwrap()
    }

    #[test]
    fn profile_all_matches_table1_categories() {
        let runner = ExperimentRunner::native();
        let summaries = runner.profile_all(7);
        assert_eq!(summaries.len(), 16);
        let count = |c: MemCategory| {
            summaries.iter().filter(|s| s.model.category == c).count()
        };
        assert_eq!(count(MemCategory::Linear), 6, "expected 6/16 linear (Table I)");
        assert_eq!(count(MemCategory::Flat), 6, "expected 6/16 flat (Table I)");
        assert_eq!(count(MemCategory::Unclear), 4, "expected 4/16 unclear (Table I)");
    }

    #[test]
    fn linear_estimates_near_table1_values() {
        let runner = ExperimentRunner::native();
        let expect = [
            ("Naive Bayes Spark bigdata", 754.0),
            ("K-Means Spark bigdata", 503.0),
            ("Page Rank Spark huge", 42.0),
        ];
        for (label, gb) in expect {
            let job = evaluation_jobs().into_iter().find(|j| j.label() == label).unwrap();
            let s = runner.profile_job(&job, 7);
            assert_eq!(s.model.category, MemCategory::Linear, "{label}");
            let est = s.model.estimate_requirement_gb(job.input_gb);
            assert!(
                (est - gb).abs() / gb < 0.25,
                "{label}: estimated {est} vs Table I {gb}"
            );
        }
    }

    #[test]
    fn flat_job_improves_substantially() {
        // Terasort (flat): the paper reports quotients of ~15%; with a
        // tiny rep count we only assert a clear win.
        let runner = ExperimentRunner::native();
        let cmp = runner.compare_job(&job("Terasort", "bigdata"), &small_cfg()).unwrap();
        assert_eq!(cmp.category, MemCategory::Flat);
        let q = cmp.quotient();
        assert!(q[2] < 0.8, "Terasort quotient {q:?} shows no clear win");
    }

    #[test]
    fn unclear_job_close_to_baseline() {
        let runner = ExperimentRunner::native();
        let cmp = runner.compare_job(&job("Lin. Regr.", "huge"), &small_cfg()).unwrap();
        assert_eq!(cmp.category, MemCategory::Unclear);
        // Identical plans -> identical seeded traces -> quotient exactly 1.
        for k in 0..3 {
            assert!(
                (cmp.quotient()[k] - 1.0).abs() < 1e-9,
                "unclear job must reduce to the baseline, quotient {:?}",
                cmp.quotient()
            );
        }
    }

    #[test]
    fn curves_are_well_formed() {
        let runner = ExperimentRunner::native();
        let cmp = runner.compare_job(&job("Join", "huge"), &small_cfg()).unwrap();
        for stats in [&cmp.cherrypick, &cmp.ruya] {
            // Fig 4: best-so-far is non-increasing and >= 1.
            for w in stats.best_curve.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
            assert!(stats.best_curve.iter().all(|&v| v >= 1.0 - 1e-12));
            // Fig 5: cumulative cost strictly increasing.
            for w in stats.cum_curve.windows(2) {
                assert!(w[1] > w[0]);
            }
        }
    }

    #[test]
    fn threads_floor_at_one() {
        let runner = ExperimentRunner::native().with_threads(0);
        assert_eq!(runner.threads, 1);
    }
}
