//! The Ruya coordinator — the paper's system contribution at Layer 3:
//! profiling orchestration, memory-aware search-space splitting
//! ([`planner`]) and the evaluation harness ([`experiment`]) that drives
//! the Bayesian-optimized search over the simulated cluster substrate.

mod crispy;
mod experiment;
mod planner;

pub use crispy::{CrispyChoice, CrispySelector};
pub use experiment::{
    ExperimentConfig, ExperimentResult, ExperimentRunner, JobComparison, MethodStats,
    ProfileSummary, StopQuality, THRESHOLDS,
};
pub use planner::{RuyaPlanner, SearchPlan};
