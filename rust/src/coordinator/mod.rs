//! The Ruya coordinator — the paper's system contribution at Layer 3:
//! profiling orchestration, memory-aware search-space splitting
//! ([`planner`]), the evaluation harness ([`experiment`]) that drives
//! the Bayesian-optimized search over the simulated cluster substrate,
//! and the end-to-end memory-aware loop ([`pipeline`]): profiler →
//! memory model → catalog shortlist → BO restricted to the shortlist,
//! run as resident sessions (`ruya pipeline` on the CLI).
//!
//! # Session architecture (optimizer-as-a-service)
//!
//! The one-shot harness ([`ExperimentRunner`]) runs a search to
//! completion and exits; the resident layer ([`session`]) keeps
//! thousands of searches in flight at once. State ownership is split
//! deliberately:
//!
//! * **Shared, immutable** (one copy per engine): each registered job's
//!   catalog feature matrix, cost table and `Arc`-shared phase plan,
//!   plus one engine-wide worker pool that serves the batched
//!   candidate-scoring fan-out of *every* session.
//! * **Per-session, mutable** (one copy per in-flight search): a
//!   `SearchCursor` (tried/costs, phase cursor, RNG position, stopping
//!   state) and a small strictly-serial `NativeBackend` whose
//!   incremental caches (distance matrix, Cholesky factors, inducing
//!   set) are derived state — rebuilt by trace replay on resume, never
//!   serialized.
//!
//! [`SessionState`] is the wire form of the per-session half:
//! suspending at any step and resuming is bit-identical to the
//! uninterrupted run (pinned by `tests/session.rs` and the
//! `fuzz_parity` seeded runner). [`SessionStats`] exposes the batching
//! and lifecycle counters the `bench_sessions` smoke asserts on.
//!
//! # Cross-job transfer
//!
//! [`transfer`] closes the loop *across* jobs: completed searches
//! deposit per-cluster posteriors (top evaluated configs + winning
//! hyperparameter slots) keyed by a deterministic behavior signature,
//! and new searches on similar jobs start from a mined
//! [`WarmStart`](crate::bayesopt::WarmStart)
//! (`ruya pipeline --warm`, inspected by `ruya transfer`) instead of
//! random initial picks.

mod crispy;
mod experiment;
mod pipeline;
mod planner;
mod session;
mod transfer;

pub use crispy::{CrispyChoice, CrispySelector};
pub use experiment::{
    ExperimentConfig, ExperimentResult, ExperimentRunner, JobComparison, MethodStats,
    ProfileSummary, StopQuality, THRESHOLDS,
};
pub use pipeline::{MemoryPipeline, PipelineOutcome, Shortlist, PIPELINE_DEFAULT_ITERS};
pub use planner::{RuyaPlanner, SearchPlan};
pub use session::{
    replay_cursor, SessionEngine, SessionState, SessionStats, SESSION_STATE_VERSION,
};
pub use transfer::{
    distance, signature, JobEvidence, JobSignature, TopConfig, TransferCluster, TransferStore,
    DEFAULT_CLUSTER_RADIUS, DEFAULT_TOP_K, SIG_DIM, TRANSFER_STORE_VERSION,
};
