//! Crispy-style one-shot configuration selection (§III-B, [16]).
//!
//! Crispy is Ruya's predecessor: for a *unique, one-off* job there is no
//! budget for iterative search, so after the same profiling phase it
//! directly picks the single most promising configuration — essentially
//! Ruya's priority-group reasoning collapsed to one decision. Implemented
//! here both as a library feature (`ruya crispy` in the CLI) and as a
//! reference point for how much the *iterative* part of Ruya adds.

use anyhow::{bail, Result};

use super::planner::{RuyaPlanner, SearchPlan};
use crate::memmodel::{MemCategory, MemoryModel};
use crate::searchspace::SearchSpace;

/// Result of a one-shot selection.
#[derive(Debug, Clone)]
pub struct CrispyChoice {
    /// Chosen configuration index.
    pub config_idx: usize,
    pub category: MemCategory,
    /// Extrapolated requirement (linear jobs).
    pub requirement_gb: Option<f64>,
    /// Number of configurations that were memory-admissible.
    pub admissible: usize,
}

/// One-shot selector sharing the planner's memory reasoning.
#[derive(Debug, Clone, Copy, Default)]
pub struct CrispySelector {
    pub planner: RuyaPlanner,
}

impl CrispySelector {
    /// Pick the single most promising configuration for a job with the
    /// given fitted memory model and full input size. `job` labels the
    /// job in error messages only.
    ///
    /// Heuristic (after the memory filter, which is Crispy's actual
    /// contribution): cost-efficiency prefers the cheapest *effective*
    /// compute — price per core discounted by a mild scale-out
    /// contention factor — which is the best prior-only guess without any
    /// execution history.
    ///
    /// Fails cleanly (instead of panicking, as it once did) when the
    /// planner produces no phases or an empty first phase — e.g. a
    /// degenerate search space with zero configurations.
    pub fn select(
        &self,
        job: &str,
        model: &MemoryModel,
        input_gb: f64,
        space: &SearchSpace,
    ) -> Result<CrispyChoice> {
        let plan = self.planner.plan(model, input_gb, space);
        self.select_from_plan(job, &plan, space)
    }

    /// The selection step of [`select`](Self::select), starting from an
    /// already-built plan. Split out so callers holding a plan (and
    /// tests exercising degenerate ones) skip the planning pass.
    pub fn select_from_plan(
        &self,
        job: &str,
        plan: &SearchPlan,
        space: &SearchSpace,
    ) -> Result<CrispyChoice> {
        let admissible = match plan.phases.first() {
            Some(phase) if !phase.is_empty() => phase,
            _ => bail!(
                "crispy selection for job {job:?}: the phase plan is empty \
                 ({} configuration(s) in the search space)",
                space.len()
            ),
        };

        let score = |idx: usize| -> f64 {
            let c = space.config(idx);
            let cores = c.total_cores();
            // Effective cores under a generic contention prior (the
            // selector must not peek at the simulator's true constants).
            let eff = cores / (1.0 + 0.05 * (cores - 1.0).max(0.0));
            c.price_per_hour() / eff
        };

        // Total order on (score, index): `total_cmp` sorts NaN after
        // +inf, so a configuration with a non-finite score (a corrupt
        // catalog price) can never shadow a finite one, and the index
        // tie-break keeps the pick deterministic when scores tie.
        let best = admissible
            .iter()
            .copied()
            .min_by(|&a, &b| score(a).total_cmp(&score(b)).then(a.cmp(&b)))
            .expect("phase emptiness was checked above");

        Ok(CrispyChoice {
            config_idx: best,
            category: plan.category,
            requirement_gb: plan.requirement_gb,
            admissible: admissible.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExperimentRunner;
    use crate::workload::{evaluation_jobs, JobCostTable};

    #[test]
    fn selects_admissible_config_for_linear_job() {
        let readings: Vec<(f64, f64)> =
            (1..=5).map(|k| (k as f64, 2.5 * k as f64)).collect();
        let model = MemoryModel::fit(&readings);
        let space = SearchSpace::scout();
        let choice = CrispySelector::default().select("kmeans", &model, 100.8, &space).unwrap();
        assert_eq!(choice.category, MemCategory::Linear);
        let req = choice.requirement_gb.unwrap();
        assert!(space.config(choice.config_idx).usable_memory_gb() >= req);
    }

    #[test]
    fn flat_job_gets_low_memory_machine() {
        let model = MemoryModel::fit(&[
            (1.0, 1.2),
            (2.0, 1.18),
            (3.0, 1.22),
            (4.0, 1.19),
            (5.0, 1.21),
        ]);
        let space = SearchSpace::scout();
        let choice = CrispySelector::default().select("flat", &model, 300.0, &space).unwrap();
        assert_eq!(choice.category, MemCategory::Flat);
        assert_eq!(choice.admissible, 10);
        // The pick comes from the low-memory priority group.
        let low = space.lowest_memory_configs(10);
        assert!(low.contains(&choice.config_idx));
    }

    #[test]
    fn non_finite_price_never_wins_selection() {
        use crate::searchspace::{
            register_machine_for_tests, ClusterConfig, MachineFamily, MachineSize, MachineType,
        };
        // A corrupt catalog entry: plausible specs but a NaN price —
        // this used to panic the comparator in `select` outright.
        let nan_machine = register_machine_for_tests(MachineType {
            name: "test.nan-price",
            family: MachineFamily::R,
            size: MachineSize::XXLarge,
            cores: 8,
            ram_gb: 61.0,
            price_hourly: f64::NAN,
        });
        let space = SearchSpace::from_configs(vec![
            ClusterConfig { machine: nan_machine, nodes: 12 },
            ClusterConfig { machine: 8, nodes: 12 }, // r4.2xlarge, finite price
        ]);
        // Linear model, modest requirement: both configs admissible, so
        // the NaN-priced one reaches the score comparator.
        let readings: Vec<(f64, f64)> = (1..=5).map(|k| (k as f64, k as f64)).collect();
        let model = MemoryModel::fit(&readings);
        let choice =
            CrispySelector::default().select("nan-price", &model, 100.8, &space).unwrap();
        assert_eq!(choice.category, MemCategory::Linear);
        assert_eq!(choice.admissible, 2, "both configs must be memory-admissible");
        assert_eq!(
            choice.config_idx, 1,
            "a non-finite score must never shadow a finite one"
        );
    }

    #[test]
    fn one_shot_choice_is_decent_across_the_evaluation() {
        // Crispy's one-shot pick should land well below the space's mean
        // cost for most jobs — but (being search-free) above the optimum
        // Ruya's iteration finds. This quantifies what iterating adds.
        let runner = ExperimentRunner::native();
        let selector = CrispySelector::default();
        let mut regrets = Vec::new();
        for job in evaluation_jobs() {
            let profile = runner.profile_job(&job, 0xC0FFEE);
            let choice = selector
                .select(&job.label(), &profile.model, job.input_gb, &runner.space)
                .unwrap();
            let table = JobCostTable::build(&runner.sim, &job, &runner.space);
            regrets.push(table.normalized[choice.config_idx]);
        }
        let mean = crate::util::stats::mean(&regrets);
        assert!(mean < 3.0, "one-shot mean normalized cost {mean}");
        assert!(mean > 1.0, "one-shot selection cannot be universally optimal");
    }

    #[test]
    fn empty_phase_plan_is_a_clean_error_naming_the_job() {
        // This used to be an `.expect("plan phases are never empty")`
        // panic. The planner cannot emit empty phases for a constructible
        // space today, but a degenerate plan must still fail cleanly —
        // the CLI and the pipeline surface this error to the user.
        let space = SearchSpace::scout();
        let selector = CrispySelector::default();
        for plan in [
            SearchPlan {
                category: MemCategory::Unclear,
                requirement_gb: None,
                phases: vec![],
                priority_fraction: 0.0,
            },
            SearchPlan {
                category: MemCategory::Flat,
                requirement_gb: None,
                phases: vec![vec![]],
                priority_fraction: 0.0,
            },
        ] {
            let err = selector
                .select_from_plan("terasort/bigdata", &plan, &space)
                .expect_err("an empty phase plan must not select anything");
            let msg = format!("{err:#}");
            assert!(msg.contains("terasort/bigdata"), "error must name the job: {msg}");
            assert!(msg.contains("phase plan is empty"), "unexpected message: {msg}");
        }
    }
}
