//! Cross-job transfer: behavior signatures, job clustering and warm
//! starts for new searches (the Flora direction — arXiv 2502.21046 —
//! grafted onto Ruya's own corpus shape).
//!
//! Every search in the repo used to start cold. This layer closes the
//! loop across *jobs*: each completed search deposits a compact
//! per-cluster posterior, and each new search draws a [`WarmStart`]
//! prior from the nearest cluster instead of random initial picks.
//!
//! * **Signature** — [`signature`] maps a job to a deterministic
//!   feature vector: static workload features (`workload/jobs.rs`),
//!   the profiler's memory series, and the fitted [`MemoryModel`]
//!   slope/R²/category. The ground-truth `mem_behavior` is
//!   deliberately excluded — the signature only sees what a real
//!   deployment could observe.
//! * **Clustering** — [`TransferStore::absorb`] runs leader-style
//!   clustering: a signature joins the nearest existing cluster within
//!   [`DEFAULT_CLUSTER_RADIUS`], else founds a new cluster whose
//!   center *is* the founding signature. No running means, no
//!   iteration-order ambiguity: the same corpus absorbed in the same
//!   order always yields bit-identical clusters.
//! * **Posterior** — per absorbed job the store keeps the top-k
//!   cheapest evaluated configurations (as portable
//!   `(machine, nodes)` pairs plus their costs) and the
//!   hyperparameter-grid slots that won nll sweeps
//!   ([`SearchOutcome::grid_hits`]).
//! * **Warm start** — [`TransferStore::warm_start`] walks clusters by
//!   center distance and mines the nearest one with usable evidence:
//!   merged top configs (deduped, cheapest first, mapped into the
//!   target catalog) become seed picks, and the union of winning grid
//!   slots — expanded to whole lengthscale rows so the noise level
//!   stays free — becomes the narrowed sweep. `exclude_label` is the
//!   leave-one-out guard: a job's own evidence never warms itself.
//!
//! The store serializes via `util/json.rs` with hex-encoded floats
//! ([`TransferStore::encode`]/[`TransferStore::decode`]), so a corpus
//! posterior survives process exit bit-exactly, like a
//! [`SessionState`](super::SessionState).

use crate::bayesopt::{hyperparameter_grid, SearchOutcome, WarmStart};
use crate::memmodel::{MemCategory, MemoryModel};
use crate::searchspace::SearchSpace;
use crate::util::json::{JsonValue, JsonWriter};
use crate::workload::{Framework, JobInstance};
use anyhow::{anyhow, ensure, Result};

/// Version tag of the [`TransferStore`] encoding.
pub const TRANSFER_STORE_VERSION: u64 = 1;

/// Dimension of a behavior signature (see [`signature`]).
pub const SIG_DIM: usize = 12;

/// Leader-clustering admission radius in signature space. Signature
/// coordinates are scaled to roughly [0, 1]; on the Table II corpus
/// this groups the two input scales of one algorithm (distance ~0.1)
/// and separates algorithms (distance ≳ 0.4).
pub const DEFAULT_CLUSTER_RADIUS: f64 = 0.25;

/// Top evaluated configurations kept per absorbed job.
pub const DEFAULT_TOP_K: usize = 8;

/// Noise levels per lengthscale row of [`hyperparameter_grid`]: slot
/// `s` belongs to lengthscale row `s / 4`.
const NOISE_LEVELS_PER_LS: usize = 4;

fn hex_f64(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_hex_f64(s: &str) -> Result<f64> {
    let bits = u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad f64 hex {s:?}: {e}"))?;
    Ok(f64::from_bits(bits))
}

fn field<'a>(v: &'a JsonValue, key: &str) -> Result<&'a JsonValue> {
    v.get(key).ok_or_else(|| anyhow!("transfer store missing field {key:?}"))
}

fn field_str<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    field(v, key)?.as_str().ok_or_else(|| anyhow!("field {key:?} is not a string"))
}

fn field_usize(v: &JsonValue, key: &str) -> Result<usize> {
    let f = field(v, key)?.as_f64().ok_or_else(|| anyhow!("field {key:?} is not a number"))?;
    ensure!(
        f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f <= 2f64.powi(53),
        "field {key:?} is not an index-sized integer: {f}"
    );
    Ok(f as usize)
}

fn field_array<'a>(v: &'a JsonValue, key: &str) -> Result<&'a [JsonValue]> {
    field(v, key)?.as_array().ok_or_else(|| anyhow!("field {key:?} is not an array"))
}

/// A job's deterministic behavior signature: the clustering key.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSignature {
    /// The job's display label (doubles as the leave-one-out key).
    pub label: String,
    /// [`SIG_DIM`] coordinates, each scaled to roughly [0, 1].
    pub features: Vec<f64>,
}

/// Squared-error distance between two signatures.
pub fn distance(a: &JobSignature, b: &JobSignature) -> f64 {
    debug_assert_eq!(a.features.len(), b.features.len());
    a.features
        .iter()
        .zip(&b.features)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Build the behavior signature of `job` from its fitted memory model
/// (which carries the profiler's memory series in
/// [`MemoryModel::readings`]). Pure and deterministic: same job + same
/// model ⇒ bit-identical signature.
pub fn signature(job: &JobInstance, model: &MemoryModel) -> JobSignature {
    let a = &job.algo;
    // Relative memory growth across the profiled sample range — the
    // series' own evidence, independent of the fitted line.
    let mut series: Vec<(f64, f64)> = model.readings.clone();
    series.sort_by(|x, y| x.0.total_cmp(&y.0));
    let series_growth = if series.len() >= 2 {
        let mean = series.iter().map(|r| r.1).sum::<f64>() / series.len() as f64;
        if mean.abs() > 1e-12 {
            (((series[series.len() - 1].1 - series[0].1) / mean).clamp(-2.0, 2.0) + 2.0) / 4.0
        } else {
            0.0
        }
    } else {
        0.0
    };
    let features = vec![
        match a.framework {
            Framework::Spark => 0.0,
            Framework::Hadoop => 1.0,
        },
        (a.passes.max(1) as f64).ln() / (16f64).ln(),
        (a.cpu_core_h_per_gb_pass / 0.02).clamp(0.0, 1.5),
        (a.serial_h / 0.02).clamp(0.0, 1.5),
        a.shuffle_frac.clamp(0.0, 1.0),
        if a.cache_sensitive { 1.0 } else { 0.0 },
        job.input_gb.max(1.0).log10() / 3.0,
        (model.slope_gb_per_gb / 6.0).clamp(-1.0, 1.0),
        model.r2.clamp(0.0, 1.0),
        if model.category == MemCategory::Linear { 1.0 } else { 0.0 },
        if model.category == MemCategory::Flat { 1.0 } else { 0.0 },
        series_growth,
    ];
    debug_assert_eq!(features.len(), SIG_DIM);
    JobSignature { label: job.label(), features }
}

/// One evaluated configuration worth remembering, stored as a portable
/// `(machine, nodes)` pair (catalog indices are catalog-specific; the
/// machine registry is process-global).
#[derive(Debug, Clone, PartialEq)]
pub struct TopConfig {
    pub machine: usize,
    pub nodes: u32,
    /// Normalized cost the source search observed.
    pub cost: f64,
}

/// The posterior one completed search deposited.
#[derive(Debug, Clone, PartialEq)]
pub struct JobEvidence {
    /// Source job label (the leave-one-out key).
    pub label: String,
    /// Full-grid hyperparameter slots that won ≥ 1 nll sweep, ascending.
    pub slots: Vec<usize>,
    /// Cheapest evaluated configurations, best first (≤ top_k).
    pub top: Vec<TopConfig>,
}

/// One behavior cluster: the founding signature plus the evidence of
/// every member job.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferCluster {
    pub center: JobSignature,
    pub evidence: Vec<JobEvidence>,
}

/// The persistent cross-job posterior store (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct TransferStore {
    radius: f64,
    top_k: usize,
    clusters: Vec<TransferCluster>,
}

impl Default for TransferStore {
    fn default() -> Self {
        Self::new(DEFAULT_CLUSTER_RADIUS, DEFAULT_TOP_K)
    }
}

impl TransferStore {
    pub fn new(radius: f64, top_k: usize) -> Self {
        Self { radius, top_k, clusters: Vec::new() }
    }

    pub fn clusters(&self) -> &[TransferCluster] {
        &self.clusters
    }

    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// Total jobs absorbed across all clusters.
    pub fn evidence_len(&self) -> usize {
        self.clusters.iter().map(|c| c.evidence.len()).sum()
    }

    /// Clusters ranked by center distance to `sig` (ties broken by the
    /// lower cluster index — founding order — for determinism).
    fn ranked(&self, sig: &JobSignature) -> Vec<(usize, f64)> {
        let mut order: Vec<(usize, f64)> = self
            .clusters
            .iter()
            .enumerate()
            .map(|(i, c)| (i, distance(&c.center, sig)))
            .collect();
        order.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        order
    }

    /// Deposit a completed search: cluster `sig` (leader clustering —
    /// join the nearest cluster within the radius, else found a new
    /// one) and record the job's top-k cheapest evaluated configs plus
    /// its winning grid slots. Re-absorbing a label replaces its
    /// evidence in place.
    pub fn absorb(&mut self, sig: &JobSignature, space: &SearchSpace, outcome: &SearchOutcome) {
        let mut order: Vec<usize> = (0..outcome.tried.len())
            .filter(|&i| outcome.tried[i] < space.len() && outcome.costs[i].is_finite())
            .collect();
        order.sort_by(|&a, &b| outcome.costs[a].total_cmp(&outcome.costs[b]).then(a.cmp(&b)));
        let top: Vec<TopConfig> = order
            .iter()
            .take(self.top_k)
            .map(|&i| {
                let c = space.config(outcome.tried[i]);
                TopConfig { machine: c.machine, nodes: c.nodes, cost: outcome.costs[i] }
            })
            .collect();
        let slots: Vec<usize> = outcome
            .grid_hits
            .iter()
            .enumerate()
            .filter(|&(_, &h)| h > 0)
            .map(|(s, _)| s)
            .collect();
        let evidence = JobEvidence { label: sig.label.clone(), slots, top };

        let target = match self.ranked(sig).first() {
            Some(&(ci, dist)) if dist <= self.radius => ci,
            _ => {
                self.clusters.push(TransferCluster { center: sig.clone(), evidence: Vec::new() });
                self.clusters.len() - 1
            }
        };
        let cluster = &mut self.clusters[target];
        match cluster.evidence.iter_mut().find(|e| e.label == evidence.label) {
            Some(existing) => *existing = evidence,
            None => cluster.evidence.push(evidence),
        }
    }

    /// Mine a warm start for the job with signature `sig` against
    /// `space`: walk clusters by center distance and use the nearest
    /// one holding evidence from a job other than `exclude_label` (the
    /// leave-one-out guard). Returns `None` when no usable evidence
    /// exists anywhere — the search then starts cold.
    pub fn warm_start(
        &self,
        sig: &JobSignature,
        space: &SearchSpace,
        exclude_label: Option<&str>,
    ) -> Option<WarmStart> {
        for (ci, _) in self.ranked(sig) {
            let evidence: Vec<&JobEvidence> = self.clusters[ci]
                .evidence
                .iter()
                .filter(|e| exclude_label != Some(e.label.as_str()))
                .collect();
            if evidence.is_empty() {
                continue;
            }

            // Seeds: merged top configs, cheapest first, deduped by the
            // catalog index they map to in *this* space (configs absent
            // from the target catalog are dropped).
            let mut ranked_tops: Vec<(f64, usize)> = Vec::new();
            for e in &evidence {
                for t in &e.top {
                    if let Some(idx) = space
                        .configs()
                        .iter()
                        .position(|c| c.machine == t.machine && c.nodes == t.nodes)
                    {
                        ranked_tops.push((t.cost, idx));
                    }
                }
            }
            ranked_tops.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut seeds: Vec<usize> = Vec::new();
            for (_, idx) in ranked_tops {
                if !seeds.contains(&idx) {
                    seeds.push(idx);
                    if seeds.len() == self.top_k {
                        break;
                    }
                }
            }

            // Grid restriction: the union of winning slots, expanded to
            // whole lengthscale rows — the transferred belief is about
            // the cost surface's smoothness, not the new job's noise
            // level, so all four noise columns of a winning row stay in.
            let mut slots: Vec<usize> = Vec::new();
            let grid_len = hyperparameter_grid().len();
            for e in &evidence {
                for &s in &e.slots {
                    let row = s.min(grid_len - 1) / NOISE_LEVELS_PER_LS;
                    for col in 0..NOISE_LEVELS_PER_LS {
                        let full = row * NOISE_LEVELS_PER_LS + col;
                        if !slots.contains(&full) {
                            slots.push(full);
                        }
                    }
                }
            }
            slots.sort_unstable();
            if slots.len() == grid_len {
                // Everything survived: that is no restriction at all.
                slots.clear();
            }

            if seeds.is_empty() && slots.is_empty() {
                continue;
            }
            return Some(WarmStart { seeds, grid_slots: slots });
        }
        None
    }

    /// Serialize to versioned JSON; floats are hex bit-patterns so the
    /// round-trip is bit-exact.
    pub fn encode(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("version").number(TRANSFER_STORE_VERSION as f64);
        w.key("radius").string(&hex_f64(self.radius));
        w.key("top_k").number(self.top_k as f64);
        w.key("clusters").begin_array();
        for cluster in &self.clusters {
            w.begin_object();
            w.key("center").begin_object();
            w.key("label").string(&cluster.center.label);
            w.key("features").begin_array();
            for &f in &cluster.center.features {
                w.string(&hex_f64(f));
            }
            w.end_array();
            w.end_object();
            w.key("evidence").begin_array();
            for e in &cluster.evidence {
                w.begin_object();
                w.key("label").string(&e.label);
                w.key("slots").begin_array();
                for &s in &e.slots {
                    w.number(s as f64);
                }
                w.end_array();
                w.key("top").begin_array();
                for t in &e.top {
                    w.begin_object();
                    w.key("machine").number(t.machine as f64);
                    w.key("nodes").number(t.nodes as f64);
                    w.key("cost").string(&hex_f64(t.cost));
                    w.end_object();
                }
                w.end_array();
                w.end_object();
            }
            w.end_array();
            w.end_object();
        }
        w.end_array();
        w.end_object();
        w.finish()
    }

    /// Parse a store produced by [`Self::encode`].
    pub fn decode(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).map_err(|e| anyhow!("bad transfer store JSON: {e}"))?;
        let version = field_usize(&v, "version")? as u64;
        ensure!(
            version == TRANSFER_STORE_VERSION,
            "transfer store version {version} (this build reads {TRANSFER_STORE_VERSION})"
        );
        let radius = parse_hex_f64(field_str(&v, "radius")?)?;
        let top_k = field_usize(&v, "top_k")?;
        let mut clusters = Vec::new();
        for cv in field_array(&v, "clusters")? {
            let center_v = field(cv, "center")?;
            let features: Vec<f64> = field_array(center_v, "features")?
                .iter()
                .map(|f| {
                    parse_hex_f64(
                        f.as_str().ok_or_else(|| anyhow!("feature is not a hex string"))?,
                    )
                })
                .collect::<Result<_>>()?;
            ensure!(
                features.len() == SIG_DIM,
                "cluster center has {} features, signatures have {SIG_DIM}",
                features.len()
            );
            let center =
                JobSignature { label: field_str(center_v, "label")?.to_string(), features };
            let mut evidence = Vec::new();
            for ev in field_array(cv, "evidence")? {
                let slots: Vec<usize> = field_array(ev, "slots")?
                    .iter()
                    .map(|s| {
                        let f = s.as_f64().ok_or_else(|| anyhow!("slot is not a number"))?;
                        ensure!(f.fract() == 0.0 && f >= 0.0, "slot {f} is not an index");
                        Ok(f as usize)
                    })
                    .collect::<Result<_>>()?;
                let mut top = Vec::new();
                for tv in field_array(ev, "top")? {
                    top.push(TopConfig {
                        machine: field_usize(tv, "machine")?,
                        nodes: u32::try_from(field_usize(tv, "nodes")?)
                            .map_err(|_| anyhow!("node count out of range"))?,
                        cost: parse_hex_f64(field_str(tv, "cost")?)?,
                    });
                }
                evidence.push(JobEvidence {
                    label: field_str(ev, "label")?.to_string(),
                    slots,
                    top,
                });
            }
            clusters.push(TransferCluster { center, evidence });
        }
        Ok(Self { radius, top_k, clusters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::evaluation_jobs;

    fn sig(label: &str, x: f64) -> JobSignature {
        JobSignature { label: label.to_string(), features: vec![x; SIG_DIM] }
    }

    fn outcome(tried: Vec<usize>, costs: Vec<f64>, hot_slots: &[usize]) -> SearchOutcome {
        let mut grid_hits = vec![0u32; hyperparameter_grid().len()];
        for &s in hot_slots {
            grid_hits[s] += 1;
        }
        SearchOutcome { tried, costs, stop_after: None, phase_starts: vec![0], grid_hits }
    }

    fn space() -> SearchSpace {
        SearchSpace::scout()
    }

    #[test]
    fn leader_clustering_groups_by_radius() {
        let mut store = TransferStore::new(0.2, 4);
        let sp = space();
        store.absorb(&sig("a", 0.0), &sp, &outcome(vec![0], vec![1.0], &[0]));
        store.absorb(&sig("b", 0.01), &sp, &outcome(vec![1], vec![1.1], &[1]));
        store.absorb(&sig("c", 0.9), &sp, &outcome(vec![2], vec![1.2], &[2]));
        assert_eq!(store.clusters().len(), 2, "a/b join, c founds its own");
        assert_eq!(store.clusters()[0].evidence.len(), 2);
        assert_eq!(store.clusters()[1].evidence.len(), 1);
        // Centers are founding signatures, not running means.
        assert_eq!(store.clusters()[0].center.label, "a");
    }

    #[test]
    fn warm_start_never_uses_the_excluded_jobs_evidence() {
        let mut store = TransferStore::default();
        let sp = space();
        store.absorb(&sig("only", 0.5), &sp, &outcome(vec![3, 4], vec![1.0, 1.3], &[8]));
        // The one job in the store is the one being warmed: leave-one-
        // out must leave nothing.
        assert!(store.warm_start(&sig("only", 0.5), &sp, Some("only")).is_none());
        // Without exclusion the evidence is usable.
        let warm = store.warm_start(&sig("only", 0.5), &sp, None).expect("warm");
        assert_eq!(warm.seeds, vec![3, 4]);
        assert_eq!(warm.grid_slots, vec![8, 9, 10, 11], "slot 8 expands to its ls row");
    }

    #[test]
    fn warm_start_merges_cluster_evidence_cheapest_first() {
        let mut store = TransferStore::new(0.2, 4);
        let sp = space();
        store.absorb(&sig("a", 0.0), &sp, &outcome(vec![5, 6], vec![1.4, 1.0], &[0]));
        store.absorb(&sig("b", 0.02), &sp, &outcome(vec![6, 7], vec![1.2, 1.1], &[4]));
        let warm = store.warm_start(&sig("q", 0.01), &sp, None).expect("warm");
        // Merged and deduped: 6 (cost 1.0) then 7 (1.1) then 5 (1.4);
        // config 6 appears once despite two sources.
        assert_eq!(warm.seeds, vec![6, 7, 5]);
        assert_eq!(warm.grid_slots, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn warm_start_falls_through_to_the_nearest_cluster_with_evidence() {
        let mut store = TransferStore::new(0.05, 4);
        let sp = space();
        store.absorb(&sig("self", 0.5), &sp, &outcome(vec![1], vec![1.0], &[0]));
        store.absorb(&sig("far", 0.8), &sp, &outcome(vec![2], vec![1.0], &[4]));
        // Nearest cluster holds only the excluded job; the farther one
        // must be used instead of returning None.
        let warm = store.warm_start(&sig("self", 0.5), &sp, Some("self")).expect("warm");
        assert_eq!(warm.seeds, vec![2]);
    }

    #[test]
    fn encode_decode_roundtrip_is_exact() {
        let mut store = TransferStore::default();
        let sp = space();
        let jobs = evaluation_jobs();
        for (i, job) in jobs.iter().take(4).enumerate() {
            let model = MemoryModel::fit(&[(1.0, 2.0 + i as f64), (2.0, 3.0 + i as f64)]);
            let s = signature(job, &model);
            store.absorb(&s, &sp, &outcome(vec![i, i + 1], vec![1.0 + i as f64 * 0.1, 1.5], &[i]));
        }
        let text = store.encode();
        let back = TransferStore::decode(&text).expect("decode");
        assert_eq!(back, store);
        assert_eq!(back.encode(), text, "re-encode must be byte-identical");
    }

    #[test]
    fn signatures_are_deterministic_and_ignore_ground_truth() {
        let jobs = evaluation_jobs();
        let model = MemoryModel::fit(&[(1.0, 2.5), (2.0, 5.0), (3.0, 7.5)]);
        let a = signature(&jobs[0], &model);
        let b = signature(&jobs[0], &model);
        assert_eq!(a, b);
        assert_eq!(a.features.len(), SIG_DIM);
        assert!(a.features.iter().all(|f| f.is_finite()));
    }

    #[test]
    fn sibling_scales_cluster_together_and_algorithms_apart() {
        // Same algorithm at its two input scales must land within the
        // default radius; structurally different algorithms must not.
        let jobs = evaluation_jobs();
        let model = MemoryModel::fit(&[(1.0, 2.5), (2.0, 5.0), (3.0, 7.5)]);
        let nb_big = signature(&jobs[0], &model); // Naive Bayes bigdata
        let nb_huge = signature(&jobs[1], &model); // Naive Bayes huge
        let terasort = signature(&jobs[14], &model); // Terasort bigdata
        assert!(
            distance(&nb_big, &nb_huge) <= DEFAULT_CLUSTER_RADIUS,
            "sibling scales too far apart: {}",
            distance(&nb_big, &nb_huge)
        );
        assert!(
            distance(&nb_big, &terasort) > DEFAULT_CLUSTER_RADIUS,
            "different algorithms clustered together: {}",
            distance(&nb_big, &terasort)
        );
    }

    #[test]
    fn full_grid_coverage_means_no_restriction() {
        let mut store = TransferStore::default();
        let sp = space();
        let all: Vec<usize> = (0..hyperparameter_grid().len()).collect();
        store.absorb(&sig("wide", 0.5), &sp, &outcome(vec![0], vec![1.0], &all));
        let warm = store.warm_start(&sig("near", 0.5), &sp, None).expect("warm");
        assert!(warm.grid_slots.is_empty(), "covering every slot is not a restriction");
    }
}
