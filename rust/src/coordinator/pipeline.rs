//! The end-to-end memory-aware pipeline (§III-B/C): the paper's actual
//! loop, wired through the fast engine at catalog scale.
//!
//! Mapping to the paper:
//!
//! 1. **§III-B small-sample profiling** — [`SingleNodeProfiler`] runs
//!    the five sample-size-controlled measurement runs (30–300 s
//!    controller band) on the simulated single node.
//! 2. **§III-C memory modeling + categorization** — [`MemoryModel::fit`]
//!    regresses peak memory on sample size and thresholds the R² score
//!    into Linear / Flat / Unclear.
//! 3. **§III-D memory-suitability shortlist** — the planner/Crispy
//!    admissibility reasoning reduces the catalog: Linear ⇒ every
//!    configuration at/above the extrapolated requirement (with leeway;
//!    both memory extremes when the requirement exceeds the whole
//!    catalog), Flat ⇒ the low-memory decile group, Unclear ⇒ the full
//!    space. The [`Shortlist`] is phase 0 of [`RuyaPlanner::plan`],
//!    taken *alone*.
//! 4. **§III-E Bayesian-optimized search** — BO runs **only inside the
//!    shortlist** ([`SearchPlan::restricted_to`]), driven through the
//!    resident [`SessionEngine`] so a pipeline search suspends and
//!    resumes like any session (the shortlist indices travel inside the
//!    serialized `SessionState` phase plan). A full-catalog baseline
//!    search at the same seed and iteration budget quantifies what the
//!    narrowing bought — the paper's headline iterations-to-optimum
//!    quotient — and a Crispy one-shot selection rides along as the
//!    zero-iteration reference point.
//!
//! [`MemoryPipeline::run_matrix`] produces one [`PipelineOutcome`] per
//! job; `report::render_pipeline_matrix` / `report::pipeline_to_json`
//! turn the batch into the ruler-style experiment-matrix artifact the
//! `ruya pipeline` verb prints and exports.

use super::experiment::ExperimentRunner;
use super::planner::SearchPlan;
use super::session::SessionEngine;
use super::transfer::{signature, TransferStore};
use crate::bayesopt::{BoParams, SearchOutcome};
use crate::coordinator::CrispySelector;
use crate::memmodel::{MemCategory, MemoryModel};
use crate::workload::{JobCostTable, JobInstance};
use anyhow::{anyhow, Result};

/// Default equal-iteration budget for the narrowed-vs-full comparison
/// on catalogs too large to exhaust (capped at the catalog size).
pub const PIPELINE_DEFAULT_ITERS: usize = 96;

/// The memory-suitability shortlist of a catalog for one job: the
/// subset of configurations the narrowed BO search is allowed to try.
#[derive(Debug, Clone)]
pub struct Shortlist {
    pub category: MemCategory,
    /// Extrapolated job memory requirement (GB), Linear jobs only.
    pub requirement_gb: Option<f64>,
    /// Catalog indices in the shortlist, ascending.
    pub indices: Vec<usize>,
    /// Size of the catalog the shortlist was derived from.
    pub catalog_len: usize,
}

impl Shortlist {
    /// Derive the shortlist from a planner phase plan: phase 0 alone.
    /// (For Unclear jobs — and Linear requirements so low the whole
    /// space qualifies — phase 0 *is* the full catalog.)
    pub fn from_plan(plan: &SearchPlan, catalog_len: usize) -> Self {
        let mut indices = plan.phases[0].clone();
        indices.sort_unstable();
        Self { category: plan.category, requirement_gb: plan.requirement_gb, indices, catalog_len }
    }

    /// True when the shortlist is a strict subset of the catalog — the
    /// narrowing actually engaged.
    pub fn engaged(&self) -> bool {
        self.indices.len() < self.catalog_len
    }

    /// The single-phase plan of the narrowed search: BO only inside the
    /// shortlist.
    pub fn plan(&self) -> SearchPlan {
        SearchPlan::restricted_to(
            self.category,
            self.requirement_gb,
            self.indices.clone(),
            self.catalog_len,
        )
    }

    /// The phase list handed to [`SessionEngine::register_job`] — one
    /// phase holding exactly the shortlist indices, which is what ends
    /// up (and is verifiable) in a suspended session's serialized state.
    pub fn phases(&self) -> Vec<Vec<usize>> {
        vec![self.indices.clone()]
    }
}

/// End-to-end result of the pipeline for one job.
#[derive(Debug, Clone)]
pub struct PipelineOutcome {
    pub label: String,
    pub category: MemCategory,
    pub requirement_gb: Option<f64>,
    /// R² of the fitted memory model.
    pub r2: f64,
    /// Wall-clock seconds the (simulated) profiling phase cost.
    pub profiling_time_s: f64,
    pub catalog_len: usize,
    pub shortlist_len: usize,
    /// (min, max) usable memory over the shortlist (GB).
    pub shortlist_mem_gb: Option<(f64, f64)>,
    /// Normalized cost of the Crispy one-shot choice (zero iterations).
    pub crispy_cost: f64,
    /// The narrowed search: BO inside the shortlist only.
    pub narrowed: SearchOutcome,
    /// Full-catalog baseline at the same seed and iteration budget.
    pub full: SearchOutcome,
    /// Warm-started narrowed search (same shortlist, seed and budget,
    /// but initialized from the transfer store's nearest-cluster
    /// posterior). None when the run was cold or no evidence applied.
    pub warm: Option<SearchOutcome>,
    /// Seed configurations the transfer store offered (before the
    /// cursor's phase filter and `n_init` cap).
    pub warm_seeds: usize,
}

impl PipelineOutcome {
    /// Whether the shortlist was a strict subset of the catalog.
    pub fn engaged(&self) -> bool {
        self.shortlist_len < self.catalog_len
    }

    /// 1-based iterations until the narrowed search first tried a
    /// configuration with normalized cost <= `thr` (None = never).
    pub fn narrowed_iters_to(&self, thr: f64) -> Option<usize> {
        self.narrowed.first_within(thr)
    }

    /// Same metric for the full-catalog baseline.
    pub fn full_iters_to(&self, thr: f64) -> Option<usize> {
        self.full.first_within(thr)
    }

    /// Same metric for the warm-started narrowed search (None when the
    /// run was cold or the warm search never reached `thr`).
    pub fn warm_iters_to(&self, thr: f64) -> Option<usize> {
        self.warm.as_ref().and_then(|w| w.first_within(thr))
    }

    /// Iterations-to-threshold quotient narrowed/full — the paper's
    /// headline metric shape. None unless both searches reached `thr`.
    pub fn quotient(&self, thr: f64) -> Option<f64> {
        match (self.narrowed_iters_to(thr), self.full_iters_to(thr)) {
            (Some(a), Some(b)) => Some(a as f64 / b as f64),
            _ => None,
        }
    }
}

/// The end-to-end pipeline driver: owns an [`ExperimentRunner`] (space,
/// simulator, profiler, planner, backend factory) and wires its stages
/// together (see the module docs for the §III mapping).
pub struct MemoryPipeline {
    pub runner: ExperimentRunner,
}

impl MemoryPipeline {
    pub fn new(runner: ExperimentRunner) -> Self {
        Self { runner }
    }

    /// Pipeline over the pure-rust backend (tests/benches).
    pub fn native() -> Self {
        Self::new(ExperimentRunner::native())
    }

    /// The default equal-iteration budget for this pipeline's catalog.
    pub fn default_budget(&self) -> usize {
        self.runner.space.len().min(PIPELINE_DEFAULT_ITERS)
    }

    /// Stages 1–3: profile the job, fit the memory model, derive the
    /// memory-suitability shortlist of the catalog.
    pub fn shortlist_job(&self, job: &JobInstance, seed: u64) -> (MemoryModel, Shortlist, f64) {
        let profile = self.runner.profile_job(job, seed);
        let shortlist = self.shortlist_for(&profile.model, job.input_gb);
        (profile.model, shortlist, profile.profiling_time_s)
    }

    /// Stage 3 alone: the shortlist a fitted model induces over the
    /// pipeline's catalog.
    pub fn shortlist_for(&self, model: &MemoryModel, input_gb: f64) -> Shortlist {
        let plan = self.runner.planner.plan(model, input_gb, &self.runner.space);
        Shortlist::from_plan(&plan, self.runner.space.len())
    }

    /// Register `job` with a resident engine under its *shortlist-only*
    /// phase plan (stages 1–3 run here; stage 4 is the engine's). Any
    /// session opened on the returned handle searches only inside the
    /// shortlist, and suspends/resumes like any other session — the
    /// shortlist indices are the phase plan inside its serialized
    /// state. Returns the engine job handle and the shortlist.
    pub fn register_job_with_engine(
        &self,
        engine: &mut SessionEngine,
        job: &JobInstance,
        seed: u64,
    ) -> Result<(usize, Shortlist)> {
        let (_, shortlist, _) = self.shortlist_job(job, seed);
        let table = JobCostTable::build(&self.runner.sim, job, &self.runner.space);
        let handle = engine.register_job(
            &job.label(),
            &self.runner.space,
            table.normalized,
            shortlist.phases(),
        )?;
        Ok((handle, shortlist))
    }

    /// Run the whole pipeline for one job: profile → fit → shortlist →
    /// narrowed BO (as a session on `engine`), plus the full-catalog
    /// baseline search and the Crispy one-shot selection at the same
    /// seed. `budget` caps both searches at an equal iteration count.
    ///
    /// The engine is caller-provided so many jobs (or repeated calls)
    /// share one scoring pool; each job registers once per engine (a
    /// label already registered is reused).
    pub fn run_job(
        &self,
        engine: &mut SessionEngine,
        job: &JobInstance,
        seed: u64,
        budget: usize,
    ) -> Result<PipelineOutcome> {
        let profile = self.runner.profile_job(job, seed);
        let shortlist = self.shortlist_for(&profile.model, job.input_gb);
        let table = JobCostTable::build(&self.runner.sim, job, &self.runner.space);

        let handle = match engine.job_index(&job.label()) {
            Some(h) => h,
            None => engine.register_job(
                &job.label(),
                &self.runner.space,
                table.normalized.clone(),
                shortlist.phases(),
            )?,
        };
        let params = BoParams { max_iters: budget, ..Default::default() };
        let rep_seed = seed ^ job.job_id;
        let sid = engine.open(handle, rep_seed, params)?;
        engine.run_all()?;
        let narrowed = engine
            .outcome(sid)
            .ok_or_else(|| anyhow!("engine lost session {sid} for {:?}", job.label()))?;

        let full = self.runner.run_one_params(
            &table,
            &SearchPlan::unpartitioned(&self.runner.space),
            rep_seed,
            &params,
        )?;

        let choice = CrispySelector::default().select(
            &job.label(),
            &profile.model,
            job.input_gb,
            &self.runner.space,
        )?;
        Ok(PipelineOutcome {
            label: job.label(),
            category: shortlist.category,
            requirement_gb: shortlist.requirement_gb,
            r2: profile.model.r2,
            profiling_time_s: profile.profiling_time_s,
            catalog_len: self.runner.space.len(),
            shortlist_len: shortlist.indices.len(),
            shortlist_mem_gb: self.runner.space.usable_memory_bounds(&shortlist.indices),
            crispy_cost: table.normalized[choice.config_idx],
            narrowed,
            full,
            warm: None,
            warm_seeds: 0,
        })
    }

    /// [`Self::run_job`] plus the cross-job transfer leg: after the cold
    /// narrowed/full/Crispy trio, mine `store` for a [`WarmStart`] from
    /// the nearest behavior cluster (the job's own label is excluded, so
    /// re-running a job never warms it with itself) and — when evidence
    /// applies — run one more narrowed search from that prior at the
    /// same seed and budget. The cold narrowed outcome is then absorbed
    /// into `store`, so jobs later in a matrix draw on every earlier
    /// one.
    ///
    /// [`WarmStart`]: crate::bayesopt::WarmStart
    pub fn run_job_warm(
        &self,
        engine: &mut SessionEngine,
        job: &JobInstance,
        seed: u64,
        budget: usize,
        store: &mut TransferStore,
    ) -> Result<PipelineOutcome> {
        let profile = self.runner.profile_job(job, seed);
        let sig = signature(job, &profile.model);
        let mut out = self.run_job(engine, job, seed, budget)?;
        if let Some(warm) = store.warm_start(&sig, &self.runner.space, Some(&job.label())) {
            let handle = engine
                .job_index(&job.label())
                .ok_or_else(|| anyhow!("run_job left {:?} unregistered", job.label()))?;
            let params = BoParams { max_iters: budget, ..Default::default() };
            let sid = engine.open_warm(handle, seed ^ job.job_id, params, &warm)?;
            engine.run_all()?;
            out.warm_seeds = warm.seeds.len();
            out.warm = Some(engine.outcome(sid).ok_or_else(|| {
                anyhow!("engine lost warm session {sid} for {:?}", job.label())
            })?);
        }
        store.absorb(&sig, &self.runner.space, &out.narrowed);
        Ok(out)
    }

    /// [`Self::run_job`] over a set of jobs, sharing one engine (and
    /// hence one scoring pool) across them — the experiment-matrix run
    /// behind `ruya pipeline`. `gp_threads` sizes the engine's scoring
    /// pool exactly like `ruya serve` (0 = adaptive); results are
    /// bit-identical for any width.
    pub fn run_matrix(
        &self,
        jobs: &[JobInstance],
        seed: u64,
        budget: usize,
        gp_threads: usize,
    ) -> Result<Vec<PipelineOutcome>> {
        let mut engine = SessionEngine::new(gp_threads);
        jobs.iter().map(|job| self.run_job(&mut engine, job, seed, budget)).collect()
    }

    /// [`Self::run_matrix`] with the transfer loop engaged: jobs run in
    /// order against one growing [`TransferStore`], so each job's warm
    /// leg draws on every job before it (the first job is necessarily
    /// cold). Returns the outcomes plus the final store, ready to be
    /// persisted or inspected (`ruya pipeline --warm`).
    pub fn run_matrix_warm(
        &self,
        jobs: &[JobInstance],
        seed: u64,
        budget: usize,
        gp_threads: usize,
    ) -> Result<(Vec<PipelineOutcome>, TransferStore)> {
        let mut engine = SessionEngine::new(gp_threads);
        let mut store = TransferStore::default();
        let outcomes = jobs
            .iter()
            .map(|job| self.run_job_warm(&mut engine, job, seed, budget, &mut store))
            .collect::<Result<Vec<_>>>()?;
        Ok((outcomes, store))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::SearchSpace;
    use crate::workload::evaluation_jobs;

    fn job(label: &str) -> JobInstance {
        evaluation_jobs().into_iter().find(|j| j.label() == label).unwrap()
    }

    #[test]
    fn shortlist_is_sorted_subset_of_catalog() {
        let pipeline = MemoryPipeline::native();
        for j in evaluation_jobs() {
            let (_, shortlist, _) = pipeline.shortlist_job(&j, 7);
            assert!(!shortlist.indices.is_empty(), "{}", j.label());
            assert!(shortlist.indices.windows(2).all(|w| w[0] < w[1]), "{}", j.label());
            assert!(
                shortlist.indices.iter().all(|&i| i < shortlist.catalog_len),
                "{}",
                j.label()
            );
        }
    }

    #[test]
    fn unclear_shortlist_is_the_full_space_and_not_engaged() {
        let pipeline = MemoryPipeline::native();
        let (model, shortlist, _) = pipeline.shortlist_job(&job("Lin. Regr. Spark huge"), 7);
        assert_eq!(model.category, MemCategory::Unclear);
        assert!(!shortlist.engaged());
        let all: Vec<usize> = (0..pipeline.runner.space.len()).collect();
        assert_eq!(shortlist.indices, all);
    }

    #[test]
    fn restricted_plan_holds_only_the_shortlist() {
        let pipeline = MemoryPipeline::native();
        let (_, shortlist, _) = pipeline.shortlist_job(&job("Terasort Hadoop bigdata"), 7);
        assert!(shortlist.engaged());
        let plan = shortlist.plan();
        assert_eq!(plan.phases.len(), 1, "narrowed search must have exactly one phase");
        assert_eq!(plan.phases[0], shortlist.indices);
        assert!(plan.priority_fraction < 1.0);
    }

    #[test]
    fn pipeline_runs_end_to_end_on_the_scout_space() {
        let pipeline = MemoryPipeline::native();
        let mut engine = SessionEngine::new(1);
        let out = pipeline
            .run_job(&mut engine, &job("K-Means Spark huge"), 7, 32)
            .expect("pipeline run");
        assert_eq!(out.category, MemCategory::Linear);
        assert!(out.engaged(), "linear shortlist must engage on the scout space");
        assert!(out.narrowed.tried.len() <= 32 && out.full.tried.len() <= 32);
        // Every narrowed pick stays inside the shortlist band.
        let (_, shortlist, _) = pipeline.shortlist_job(&job("K-Means Spark huge"), 7);
        for &i in &out.narrowed.tried {
            assert!(shortlist.indices.contains(&i), "pick {i} escaped the shortlist");
        }
        assert!(out.crispy_cost >= 1.0 - 1e-9);
    }

    #[test]
    fn zero_budget_degrades_gracefully() {
        let pipeline = MemoryPipeline::native();
        let mut engine = SessionEngine::new(1);
        let out =
            pipeline.run_job(&mut engine, &job("K-Means Spark huge"), 7, 0).expect("budget 0");
        assert!(out.narrowed.tried.is_empty() && out.full.tried.is_empty());
        assert_eq!(out.quotient(1.1), None, "no search reached anything");
        assert!(out.narrowed.best_after(usize::MAX).is_infinite());
    }

    #[test]
    fn warm_matrix_runs_the_transfer_leg_inside_the_shortlist() {
        let pipeline = MemoryPipeline::native();
        let jobs = [job("K-Means Spark bigdata"), job("K-Means Spark huge")];
        let (outs, store) =
            pipeline.run_matrix_warm(&jobs, 7, 24, 1).expect("warm matrix");
        assert_eq!(store.evidence_len(), 2, "both jobs deposit evidence");
        assert!(outs[0].warm.is_none(), "first job has nothing to draw on");
        let warm = outs[1].warm.as_ref().expect("sibling scale warms the second job");
        assert!(outs[1].warm_seeds > 0);
        assert!(!warm.tried.is_empty() && warm.tried.len() <= 24);
        // The warm leg obeys the same shortlist as the cold narrowed one.
        let (_, shortlist, _) = pipeline.shortlist_job(&jobs[1], 7);
        for &i in &warm.tried {
            assert!(shortlist.indices.contains(&i), "warm pick {i} escaped the shortlist");
        }
        // Same store, same inputs ⇒ bit-identical store and warm trace.
        let (outs2, store2) =
            pipeline.run_matrix_warm(&jobs, 7, 24, 1).expect("warm matrix again");
        assert_eq!(store2.encode(), store.encode());
        assert_eq!(outs2[1].warm.as_ref().unwrap().tried, warm.tried);
    }

    #[test]
    fn generated_catalog_budget_caps_at_default() {
        let pipeline = MemoryPipeline::new(
            ExperimentRunner::native().with_space(SearchSpace::generated(0xF00, 1000)),
        );
        assert_eq!(pipeline.default_budget(), PIPELINE_DEFAULT_ITERS);
        let small = MemoryPipeline::native();
        assert_eq!(small.default_budget(), 69);
    }
}
