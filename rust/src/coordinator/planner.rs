//! Search-space splitting (§III-D): turn the fitted memory model into a
//! phased search plan — Ruya's core coordination contribution.

use crate::memmodel::{MemCategory, MemoryModel};
use crate::searchspace::SearchSpace;

/// A phased exploration plan over the configuration space.
#[derive(Debug, Clone)]
pub struct SearchPlan {
    pub category: MemCategory,
    /// Extrapolated job memory requirement (GB), Linear jobs only.
    pub requirement_gb: Option<f64>,
    /// Disjoint index sets, explored in order. Union = whole space.
    pub phases: Vec<Vec<usize>>,
    /// |first phase| / |space| — how much the search was narrowed.
    pub priority_fraction: f64,
}

impl SearchPlan {
    /// A plan with a single phase spanning the whole space — plain
    /// CherryPick, and Ruya's fallback for `unclear` jobs.
    pub fn unpartitioned(space: &SearchSpace) -> Self {
        Self {
            category: MemCategory::Unclear,
            requirement_gb: None,
            phases: vec![(0..space.len()).collect()],
            priority_fraction: 1.0,
        }
    }

    /// True when the plan actually narrows the initial search space.
    pub fn is_narrowed(&self) -> bool {
        self.phases.len() > 1 && self.priority_fraction < 1.0
    }

    /// A single-phase plan *restricted* to `indices` — the end-to-end
    /// pipeline's narrowed BO search. Unlike the two-phase plans built
    /// by [`RuyaPlanner::plan`] (whose union is always the whole
    /// space), the rest of the catalog is deliberately absent: the
    /// search runs only inside the memory-suitability shortlist, so
    /// `phases` does NOT partition the space here.
    pub fn restricted_to(
        category: MemCategory,
        requirement_gb: Option<f64>,
        indices: Vec<usize>,
        catalog_len: usize,
    ) -> Self {
        assert!(!indices.is_empty(), "restricted plan needs a non-empty shortlist");
        let priority_fraction = indices.len() as f64 / catalog_len.max(1) as f64;
        Self { category, requirement_gb, phases: vec![indices], priority_fraction }
    }
}

/// Builds Ruya search plans from memory models.
#[derive(Debug, Clone, Copy)]
pub struct RuyaPlanner {
    /// Safety margin on the extrapolated requirement (§III-D "leeway to
    /// account for slight miscalculations").
    pub leeway: f64,
    /// Priority-group size *floor* for flat jobs (§IV-C: "the ten
    /// configurations with the lowest total memory").
    pub flat_group_size: usize,
    /// Priority-group size as a fraction of the space for flat jobs.
    /// The paper's absolute 10 is ~1/7 of the 69-config scout catalog
    /// but would starve the priority phase on generated full catalogs
    /// (10 of 10000 is 0.1%), so the group scales as
    /// `max(flat_group_size, round(len * flat_group_fraction))` —
    /// exactly 10 on the scout space, ~1/7 everywhere else.
    pub flat_group_fraction: f64,
    /// Fraction of the space taken from EACH memory extreme when a linear
    /// requirement exceeds every configuration (§III-D: "very high or
    /// very low total cluster memory").
    pub extremes_fraction: f64,
}

impl Default for RuyaPlanner {
    fn default() -> Self {
        Self {
            leeway: 0.02,
            flat_group_size: 10,
            flat_group_fraction: 1.0 / 7.0,
            extremes_fraction: 0.12,
        }
    }
}

impl RuyaPlanner {
    /// Build the phased plan for a job whose profiling produced `model`,
    /// to be executed on the full dataset of `input_gb`.
    pub fn plan(&self, model: &MemoryModel, input_gb: f64, space: &SearchSpace) -> SearchPlan {
        match model.category {
            MemCategory::Unclear => SearchPlan::unpartitioned(space),
            MemCategory::Flat => {
                // Extra memory only adds cost: prioritize the cheapest-
                // memory corner of the space.
                let k = self.flat_priority_len(space.len());
                let priority = space.lowest_memory_configs(k);
                self.two_phase(MemCategory::Flat, None, priority, space)
            }
            MemCategory::Linear => {
                let req = model.estimate_requirement_gb(input_gb);
                let need = req * (1.0 + self.leeway);
                let priority = space.with_usable_memory_at_least(need);
                if priority.is_empty() {
                    // Requirement beyond the whole space: "some jobs can
                    // make use of all memory they are given and others
                    // need either enough or none" -> both extremes.
                    let extremes = space.memory_extremes(self.extremes_fraction);
                    self.two_phase(MemCategory::Linear, Some(req), extremes, space)
                } else {
                    self.two_phase(MemCategory::Linear, Some(req), priority, space)
                }
            }
        }
    }

    /// Flat-job priority-group size for a catalog of `len` configs:
    /// the floor `flat_group_size` or `flat_group_fraction` of the
    /// space, whichever is larger (capped at the space itself).
    pub fn flat_priority_len(&self, len: usize) -> usize {
        let scaled = (len as f64 * self.flat_group_fraction).round() as usize;
        self.flat_group_size.max(scaled).min(len)
    }

    fn two_phase(
        &self,
        category: MemCategory,
        requirement_gb: Option<f64>,
        priority: Vec<usize>,
        space: &SearchSpace,
    ) -> SearchPlan {
        let in_priority: Vec<bool> = {
            let mut f = vec![false; space.len()];
            for &i in &priority {
                f[i] = true;
            }
            f
        };
        let rest: Vec<usize> = (0..space.len()).filter(|&i| !in_priority[i]).collect();
        let priority_fraction = priority.len() as f64 / space.len() as f64;
        let phases = if rest.is_empty() {
            vec![priority] // requirement so low the whole space qualifies
        } else if priority.is_empty() {
            vec![rest]
        } else {
            vec![priority, rest]
        };
        SearchPlan { category, requirement_gb, phases, priority_fraction }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::MemoryModel;

    fn linear_model(slope: f64) -> MemoryModel {
        let readings: Vec<(f64, f64)> =
            (1..=5).map(|k| (k as f64, slope * k as f64)).collect();
        let m = MemoryModel::fit(&readings);
        assert_eq!(m.category, MemCategory::Linear);
        m
    }

    fn flat_model() -> MemoryModel {
        MemoryModel::fit(&[(1.0, 1.2), (2.0, 1.15), (3.0, 1.22), (4.0, 1.18), (5.0, 1.2)])
    }

    fn unclear_model() -> MemoryModel {
        let m =
            MemoryModel::fit(&[(1.0, 2.0), (2.0, 7.0), (3.0, 6.0), (4.0, 14.0), (5.0, 10.0)]);
        assert_eq!(m.category, MemCategory::Unclear);
        m
    }

    #[test]
    fn unclear_plan_is_plain_cherrypick() {
        let space = SearchSpace::scout();
        let plan = RuyaPlanner::default().plan(&unclear_model(), 100.0, &space);
        assert_eq!(plan.phases.len(), 1);
        assert_eq!(plan.phases[0].len(), space.len());
        assert!(!plan.is_narrowed());
    }

    #[test]
    fn flat_plan_prioritizes_ten_lowest_memory() {
        let space = SearchSpace::scout();
        let plan = RuyaPlanner::default().plan(&flat_model(), 100.0, &space);
        assert_eq!(plan.category, MemCategory::Flat);
        assert_eq!(plan.phases.len(), 2);
        assert_eq!(plan.phases[0].len(), 10);
        // ~1/7 of the space, as the paper notes.
        assert!((plan.priority_fraction - 10.0 / 69.0).abs() < 1e-9);
    }

    #[test]
    fn linear_plan_filters_by_usable_memory() {
        let space = SearchSpace::scout();
        // K-Means/bigdata-like: 2.5 GB/GB slope, 201.2 GB input -> 503 GB
        let plan = RuyaPlanner::default().plan(&linear_model(2.5), 201.2, &space);
        assert_eq!(plan.category, MemCategory::Linear);
        let req = plan.requirement_gb.unwrap();
        assert!((req - 503.0).abs() < 1.0);
        assert!(plan.phases.len() == 2 && !plan.phases[0].is_empty());
        for &i in &plan.phases[0] {
            assert!(space.config(i).usable_memory_gb() >= req);
        }
        // Only big r4 clusters can hold 503 GB.
        for &i in &plan.phases[0] {
            assert_eq!(space.config(i).machine_type().family.letter(), 'r');
        }
    }

    #[test]
    fn oversized_requirement_falls_back_to_extremes() {
        let space = SearchSpace::scout();
        // NB/bigdata-like: 754 GB requirement > max usable (~670 GB).
        let plan = RuyaPlanner::default().plan(&linear_model(2.5), 301.6, &space);
        assert_eq!(plan.category, MemCategory::Linear);
        assert!(plan.phases.len() == 2);
        let mems: Vec<f64> =
            plan.phases[0].iter().map(|&i| space.config(i).total_memory_gb()).collect();
        let lo = space.configs().iter().map(|c| c.total_memory_gb()).fold(f64::MAX, f64::min);
        let hi = space.configs().iter().map(|c| c.total_memory_gb()).fold(0.0, f64::max);
        assert!(mems.iter().any(|&m| (m - lo).abs() < 1e-9), "missing low extreme");
        assert!(mems.iter().any(|&m| (m - hi).abs() < 1e-9), "missing high extreme");
    }

    #[test]
    fn tiny_requirement_may_cover_whole_space() {
        let space = SearchSpace::scout();
        // Slope so small every config qualifies (PageRank/huge anecdote).
        let plan = RuyaPlanner::default().plan(&linear_model(0.001), 8.4, &space);
        assert_eq!(plan.phases.len(), 1, "no narrowing expected");
        assert_eq!(plan.phases[0].len(), space.len());
    }

    #[test]
    fn phases_partition_the_space() {
        let space = SearchSpace::scout();
        for model in [flat_model(), linear_model(2.5), unclear_model()] {
            let plan = RuyaPlanner::default().plan(&model, 150.0, &space);
            let mut all: Vec<usize> = plan.phases.concat();
            all.sort_unstable();
            let expect: Vec<usize> = (0..space.len()).collect();
            assert_eq!(all, expect, "phases must partition the space exactly");
        }
    }

    #[test]
    fn phases_partition_catalogs_at_scale() {
        // The fraction knob must keep plans valid partitions from the
        // 69-config scout space up to full generated catalogs.
        for n in [69usize, 1000, 10_000] {
            let space = if n == 69 {
                SearchSpace::scout()
            } else {
                SearchSpace::generated(0xCA7A_106 ^ n as u64, n)
            };
            assert_eq!(space.len(), n);
            for model in [flat_model(), linear_model(2.5), unclear_model()] {
                let plan = RuyaPlanner::default().plan(&model, 150.0, &space);
                let mut all: Vec<usize> = plan.phases.concat();
                all.sort_unstable();
                let expect: Vec<usize> = (0..n).collect();
                assert_eq!(all, expect, "phases must partition a {n}-config space");
            }
        }
    }

    #[test]
    fn flat_priority_scales_with_the_catalog() {
        let planner = RuyaPlanner::default();
        // The scout space keeps the paper's exact 10 (floor == fraction).
        assert_eq!(planner.flat_priority_len(69), 10);
        // Tiny spaces are capped at the space, not padded to the floor.
        assert_eq!(planner.flat_priority_len(4), 4);
        // Catalog scale follows the ~1/7 fraction instead of starving
        // at an absolute 10.
        assert_eq!(planner.flat_priority_len(1000), 143);
        assert_eq!(planner.flat_priority_len(10_000), 1429);
        let space = SearchSpace::generated(0xF1A7, 1000);
        let plan = planner.plan(&flat_model(), 150.0, &space);
        assert_eq!(plan.phases[0].len(), 143);
        assert!((plan.priority_fraction - 143.0 / 1000.0).abs() < 1e-9);
    }

    #[test]
    fn leeway_shrinks_priority_group() {
        let space = SearchSpace::scout();
        let loose = RuyaPlanner { leeway: 0.0, ..Default::default() };
        let tight = RuyaPlanner { leeway: 0.3, ..Default::default() };
        let m = linear_model(2.5);
        let p_loose = loose.plan(&m, 201.2, &space);
        let p_tight = tight.plan(&m, 201.2, &space);
        assert!(p_tight.phases[0].len() <= p_loose.phases[0].len());
    }
}
