//! Optimizer-as-a-service: the resident session layer.
//!
//! [`SessionEngine`] multiplexes many concurrent BO searches ("sessions")
//! over shared immutable job state. Each session owns only what is truly
//! per-search — a [`SearchCursor`] (tried/costs, phase cursor, RNG
//! position, stopping state) and a small serial [`NativeBackend`] whose
//! incremental caches (distance matrix, Cholesky factors, inducing set)
//! are rewarmed from the cursor trace on resume. Everything else is
//! shared: the catalog's feature matrix and cost table live once per
//! job (`Arc`-shared phases), and the **process-global** worker pool
//! ([`pool::global_pool`]) serves the candidate-scoring fan-out of
//! every session — engines park no scoring threads of their own, so any
//! number of engines (and their `--threads` workers) share one budget
//! of `pool_width` lanes.
//!
//! # Batched decide
//!
//! `step_all` advances every live session by one search step in three
//! sub-phases. (A) serial prep: each session advances its cursor;
//! executes record immediately, decisions run their nll-grid sweep and
//! [`NativeBackend::prepare_decide`] fit on the session's own backend.
//! (B) one pooled fan-out: the pure scoring passes of *all* pending
//! decisions — borrowed factor views or fitted low-rank posteriors —
//! are dealt round-robin across the shared lanes in a single
//! `run_groups` call, instead of N serial decides.
//! (C) serial finish: EI + stopping criterion close each decision via
//! [`SearchCursor::finish_decision`]. Per session the arithmetic is the
//! call-for-call sequence of [`SearchCursor::decide_with_backend`], and
//! the scoring tiles are bit-identical under any pool width (the
//! backend's deterministic-parallelism contract), so an engine-stepped
//! session reproduces `run_search`'s trace exactly.
//!
//! # Suspend / resume
//!
//! [`SessionState`] is the compact serializable form of a mid-flight
//! session: the [`CursorSnapshot`] plus the job binding and search
//! parameters, encoded dependency-free via `util/json.rs`. Floats and
//! RNG positions are hex bit-patterns (an `f64` text round-trip is not
//! bit-exact; the 128-bit RNG words do not fit an `f64` at all).
//! Resume does not deserialize backend caches: [`replay_cursor`]
//! re-executes the recorded trace against a fresh backend — the same
//! append-one calling pattern the live search used — which rewarms
//! every incremental cache to the identical state, then verifies the
//! rebuilt cursor's snapshot equals the suspended one bit for bit.
//! Warm-started sessions ([`SessionEngine::open_warm`]) serialize their
//! [`WarmStart`] prior inside the state, so the replay reconstructs the
//! same seeded initial design and narrowed hyperparameter grid — a
//! warm session suspends/resumes exactly like a cold one.

use crate::bayesopt::gp::{expected_improvement, predict_into, standardize};
use crate::bayesopt::pool;
use crate::bayesopt::{
    BoParams, CholFactor, CursorSnapshot, GpBackend, LowRankGp, NativeBackend,
    PreparedDecide, SearchCursor, SearchOutcome, SearchStep, WarmStart, DECIDE_TILE,
};
use crate::searchspace::SearchSpace;
use crate::util::json::{JsonValue, JsonWriter};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::Arc;

/// Version tag of the [`SessionState`] encoding; bumped on any schema
/// change so stale states fail loudly instead of resuming wrongly.
pub const SESSION_STATE_VERSION: u64 = 1;

/// Everything a suspended search needs to resume bit-identically:
/// the job binding (by label — the catalog itself is shared engine
/// state, not serialized), the search parameters, the phase plan and
/// the cursor's cross-iteration state.
#[derive(Debug, Clone)]
pub struct SessionState {
    /// Label of the registered job this session searches.
    pub job_label: String,
    /// Seed the session's RNG stream was started from.
    pub seed: u64,
    /// Candidate-space size the state was captured against.
    pub m: usize,
    /// Feature dimension the state was captured against.
    pub d: usize,
    /// Search hyperparameters of the suspended session.
    pub params: BoParams,
    /// The phase plan (disjoint index sets explored in order).
    pub phases: Vec<Vec<usize>>,
    /// The transfer prior the session was opened with (cold =
    /// `WarmStart::default()`). Rides along so a warm-started search
    /// resumes under the identical initial design and narrowed grid —
    /// replay would diverge without it.
    pub warm: WarmStart,
    /// The cursor's serializable cross-iteration state.
    pub snapshot: CursorSnapshot,
}

fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

fn hex_u128(v: u128) -> String {
    format!("{v:032x}")
}

fn hex_f64(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad u64 hex {s:?}: {e}"))
}

fn parse_hex_u128(s: &str) -> Result<u128> {
    u128::from_str_radix(s, 16).map_err(|e| anyhow!("bad u128 hex {s:?}: {e}"))
}

fn parse_hex_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(s)?))
}

fn field<'a>(v: &'a JsonValue, key: &str) -> Result<&'a JsonValue> {
    v.get(key).ok_or_else(|| anyhow!("session state missing field {key:?}"))
}

fn field_str<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    field(v, key)?.as_str().ok_or_else(|| anyhow!("field {key:?} is not a string"))
}

fn as_usize(v: &JsonValue, key: &str) -> Result<usize> {
    let f = v.as_f64().ok_or_else(|| anyhow!("field {key:?} is not a number"))?;
    ensure!(
        f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f <= 2f64.powi(53),
        "field {key:?} is not an index-sized integer: {f}"
    );
    Ok(f as usize)
}

fn field_usize(v: &JsonValue, key: &str) -> Result<usize> {
    as_usize(field(v, key)?, key)
}

fn field_bool(v: &JsonValue, key: &str) -> Result<bool> {
    match field(v, key)? {
        JsonValue::Bool(b) => Ok(*b),
        _ => bail!("field {key:?} is not a boolean"),
    }
}

fn field_usize_list(v: &JsonValue, key: &str) -> Result<Vec<usize>> {
    field(v, key)?
        .as_array()
        .ok_or_else(|| anyhow!("field {key:?} is not an array"))?
        .iter()
        .map(|item| as_usize(item, key))
        .collect()
}

/// `null` decodes to `None` (used for `stop_after` and the
/// `usize::MAX` sentinel of `max_iters`).
fn field_opt_usize(v: &JsonValue, key: &str) -> Result<Option<usize>> {
    match field(v, key)? {
        JsonValue::Null => Ok(None),
        other => Ok(Some(as_usize(other, key)?)),
    }
}

impl SessionState {
    /// Capture a suspended session's state.
    pub fn capture(
        job_label: &str,
        seed: u64,
        params: BoParams,
        phases: &[Vec<usize>],
        cursor: &SearchCursor,
    ) -> Self {
        Self {
            job_label: job_label.to_string(),
            seed,
            m: cursor.space_len(),
            d: cursor.dim(),
            params,
            phases: phases.to_vec(),
            warm: cursor.warm_start(),
            snapshot: cursor.snapshot(),
        }
    }

    /// Serialize to the versioned JSON form. Costs, `ei_stop_rel` and
    /// the RNG position are hex bit-patterns so the round-trip is
    /// bit-exact; `max_iters = usize::MAX` and `stop_after = None`
    /// encode as `null`.
    pub fn encode(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("version").number(SESSION_STATE_VERSION as f64);
        w.key("job").string(&self.job_label);
        w.key("seed").string(&hex_u64(self.seed));
        w.key("m").number(self.m as f64);
        w.key("d").number(self.d as f64);
        w.key("params").begin_object();
        w.key("n_init").number(self.params.n_init as f64);
        w.key("min_obs_for_stop").number(self.params.min_obs_for_stop as f64);
        w.key("ei_stop_rel").string(&hex_f64(self.params.ei_stop_rel));
        if self.params.max_iters == usize::MAX {
            w.key("max_iters").number(f64::NAN);
        } else {
            w.key("max_iters").number(self.params.max_iters as f64);
        }
        w.key("enforce_stop").boolean(self.params.enforce_stop);
        w.end_object();
        w.key("phases").begin_array();
        for phase in &self.phases {
            w.begin_array();
            for &i in phase {
                w.number(i as f64);
            }
            w.end_array();
        }
        w.end_array();
        // The warm block is omitted entirely for cold sessions, so
        // every pre-transfer state (and its hash) is unchanged — the
        // version stays at 1 and old states keep decoding.
        if !self.warm.is_cold() {
            w.key("warm").begin_object();
            w.key("seeds").begin_array();
            for &s in &self.warm.seeds {
                w.number(s as f64);
            }
            w.end_array();
            w.key("grid_slots").begin_array();
            for &s in &self.warm.grid_slots {
                w.number(s as f64);
            }
            w.end_array();
            w.end_object();
        }
        w.key("trace").begin_object();
        w.key("tried").begin_array();
        for &i in &self.snapshot.tried {
            w.number(i as f64);
        }
        w.end_array();
        w.key("costs").begin_array();
        for &c in &self.snapshot.costs {
            w.string(&hex_f64(c));
        }
        w.end_array();
        w.end_object();
        w.key("cursor").begin_object();
        match self.snapshot.stop_after {
            Some(s) => w.key("stop_after").number(s as f64),
            None => w.key("stop_after").number(f64::NAN),
        };
        w.key("phase_starts").begin_array();
        for &s in &self.snapshot.phase_starts {
            w.number(s as f64);
        }
        w.end_array();
        w.key("phase_idx").number(self.snapshot.phase_idx as f64);
        w.key("phase_entered").boolean(self.snapshot.phase_entered);
        w.key("pending").begin_array();
        for &p in &self.snapshot.pending {
            w.number(p as f64);
        }
        w.end_array();
        w.key("pending_gate").boolean(self.snapshot.pending_gate);
        w.key("done").boolean(self.snapshot.done);
        w.key("rng_state").string(&hex_u128(self.snapshot.rng_state));
        w.key("rng_inc").string(&hex_u128(self.snapshot.rng_inc));
        w.end_object();
        w.end_object();
        w.finish()
    }

    /// Parse a state produced by [`Self::encode`], validating version,
    /// structure and trace consistency.
    pub fn decode(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).map_err(|e| anyhow!("bad session state JSON: {e}"))?;
        Self::from_value(&v)
    }

    /// [`Self::decode`] over an already-parsed value (e.g. the `state`
    /// field of a `ruya serve` resume request).
    pub fn from_value(v: &JsonValue) -> Result<Self> {
        let version = field_usize(v, "version")? as u64;
        ensure!(
            version == SESSION_STATE_VERSION,
            "session state version {version} (this build reads {SESSION_STATE_VERSION})"
        );
        let job_label = field_str(v, "job")?.to_string();
        let seed = parse_hex_u64(field_str(v, "seed")?)?;
        let m = field_usize(v, "m")?;
        let d = field_usize(v, "d")?;

        let p = field(v, "params")?;
        let params = BoParams {
            n_init: field_usize(p, "n_init")?,
            min_obs_for_stop: field_usize(p, "min_obs_for_stop")?,
            ei_stop_rel: parse_hex_f64(field_str(p, "ei_stop_rel")?)?,
            max_iters: field_opt_usize(p, "max_iters")?.unwrap_or(usize::MAX),
            enforce_stop: field_bool(p, "enforce_stop")?,
        };

        let phases: Vec<Vec<usize>> = field(v, "phases")?
            .as_array()
            .ok_or_else(|| anyhow!("field \"phases\" is not an array"))?
            .iter()
            .map(|phase| {
                phase
                    .as_array()
                    .ok_or_else(|| anyhow!("phase entry is not an array"))?
                    .iter()
                    .map(|item| as_usize(item, "phases"))
                    .collect()
            })
            .collect::<Result<_>>()?;
        for phase in &phases {
            for &i in phase {
                ensure!(i < m, "phase index {i} out of bounds (space size {m})");
            }
        }

        let warm = match v.get("warm") {
            None | Some(JsonValue::Null) => WarmStart::default(),
            Some(wv) => WarmStart {
                seeds: field_usize_list(wv, "seeds")?,
                grid_slots: field_usize_list(wv, "grid_slots")?,
            },
        };

        let trace = field(v, "trace")?;
        let tried = field_usize_list(trace, "tried")?;
        let costs: Vec<f64> = field(trace, "costs")?
            .as_array()
            .ok_or_else(|| anyhow!("field \"costs\" is not an array"))?
            .iter()
            .map(|item| {
                parse_hex_f64(item.as_str().ok_or_else(|| anyhow!("cost is not a hex string"))?)
            })
            .collect::<Result<_>>()?;
        ensure!(
            tried.len() == costs.len(),
            "trace records {} picks but {} costs",
            tried.len(),
            costs.len()
        );
        for &i in &tried {
            ensure!(i < m, "tried index {i} out of bounds (space size {m})");
        }

        let c = field(v, "cursor")?;
        let snapshot = CursorSnapshot {
            tried,
            costs,
            stop_after: field_opt_usize(c, "stop_after")?,
            phase_starts: field_usize_list(c, "phase_starts")?,
            phase_idx: field_usize(c, "phase_idx")?,
            phase_entered: field_bool(c, "phase_entered")?,
            pending: field_usize_list(c, "pending")?,
            pending_gate: field_bool(c, "pending_gate")?,
            done: field_bool(c, "done")?,
            rng_state: parse_hex_u128(field_str(c, "rng_state")?)?,
            rng_inc: parse_hex_u128(field_str(c, "rng_inc")?)?,
        };
        Ok(Self { job_label, seed, m, d, params, phases, warm, snapshot })
    }
}

/// Rebuild a live [`SearchCursor`] from a suspended state by replaying
/// its recorded trace against `backend`: every random pick is re-drawn
/// from the seed (and checked against the record), every GP decision is
/// re-run through the identical nll-grid/decide sequence, and every
/// observation is re-recorded with its recorded cost. This is exactly
/// the live search's calling pattern, so the backend's incremental
/// caches end up in the same state the uninterrupted run would hold —
/// the resumed search continues bit-identically. The rebuilt cursor's
/// snapshot must equal the suspended one; any divergence (wrong
/// features, tampered state, different backend) is an error.
pub fn replay_cursor(
    state: &SessionState,
    features: &[f64],
    backend: &mut dyn GpBackend,
) -> Result<SearchCursor> {
    ensure!(
        features.len() == state.m * state.d,
        "feature matrix is {} values, state wants {}x{}",
        features.len(),
        state.m,
        state.d
    );
    // A cross-catalog or hand-built state can carry indices the
    // m x d check above does not see; validate them here rather than
    // panicking mid-replay (decode() performs the same checks, but
    // programmatic `SessionState`s never pass through decode).
    for (p, phase) in state.phases.iter().enumerate() {
        for &i in phase {
            ensure!(
                i < state.m,
                "phase {p} holds config index {i}, outside the {}-config catalog",
                state.m
            );
        }
    }
    let snap = &state.snapshot;
    ensure!(
        snap.tried.len() == snap.costs.len(),
        "trace records {} picks but {} costs",
        snap.tried.len(),
        snap.costs.len()
    );
    for (j, &i) in snap.tried.iter().enumerate() {
        ensure!(
            i < state.m,
            "trace execution {j} tried config index {i}, outside the {}-config catalog",
            state.m
        );
    }
    let mut cursor = SearchCursor::with_warm_start(
        Arc::new(state.phases.clone()),
        state.m,
        state.d,
        Pcg64::from_seed(state.seed),
        state.params,
        &state.warm,
    );
    let k = snap.tried.len();
    while cursor.executions() < k {
        let j = cursor.executions();
        let pick = match cursor.advance() {
            SearchStep::Done => bail!("replay ended after {j} of {k} recorded executions"),
            SearchStep::Execute(i) => i,
            SearchStep::NeedsDecision => cursor
                .decide_with_backend(features, backend)?
                .ok_or_else(|| anyhow!("replay stopped at execution {j} of {k}"))?,
        };
        ensure!(
            pick == snap.tried[j],
            "replay diverged at execution {j}: picked {pick}, recorded {}",
            snap.tried[j]
        );
        cursor.record(pick, snap.costs[j], features);
    }
    if snap.done && !cursor.is_done() {
        // The suspended search ended *after* its last record: either the
        // plan ran out / max_iters hit (advance reports Done) or an
        // enforced stop fired on the next decision (which must then
        // reproduce the recorded None pick).
        match cursor.advance() {
            SearchStep::Done => {}
            SearchStep::NeedsDecision => {
                let pick = cursor.decide_with_backend(features, backend)?;
                ensure!(pick.is_none(), "replay did not reproduce the recorded final stop");
            }
            SearchStep::Execute(i) => {
                bail!("replay surfaced execute({i}) past the recorded end of the search")
            }
        }
    }
    ensure!(
        cursor.snapshot() == *snap,
        "resumed cursor diverged from the suspended snapshot"
    );
    Ok(cursor)
}

/// Shared immutable per-job state: registered once, referenced by every
/// session searching that job.
struct EngineJob {
    label: String,
    features: Vec<f64>,
    m: usize,
    d: usize,
    costs: Vec<f64>,
    phases: Arc<Vec<Vec<usize>>>,
}

/// Prep results of one pending decision, carried from the serial prep
/// sub-phase to the pooled scoring and serial finish sub-phases.
#[derive(Debug, Clone, Copy)]
struct PrepInfo {
    skip: usize,
    n: usize,
    y_scale: f64,
    best_std: f64,
    hyp: [f64; 3],
    prepared: PreparedDecide,
}

/// One in-flight search.
struct Session {
    id: u64,
    job: usize,
    seed: u64,
    params: BoParams,
    cursor: SearchCursor,
    backend: NativeBackend,
    mu: Vec<f64>,
    var: Vec<f64>,
    ei: Vec<f64>,
    prep: Option<PrepInfo>,
    finished: bool,
}

/// One session's pure scoring pass, fanned out over the shared pool.
enum ScoreUnit<'a> {
    /// Exact posterior: tile through [`predict_into`] against the
    /// session backend's borrowed factor + weights.
    Exact {
        factor: &'a CholFactor,
        alpha: &'a [f64],
        x: &'a [f64],
        n: usize,
        d: usize,
        hyp: [f64; 3],
        xc: &'a [f64],
        mu: &'a mut [f64],
        var: &'a mut [f64],
    },
    /// Nyström low-rank posterior fitted by `prepare_decide`.
    LowRank {
        gp: &'a mut LowRankGp,
        xc: &'a [f64],
        m: usize,
        mu: &'a mut Vec<f64>,
        var: &'a mut Vec<f64>,
    },
}

/// Engine observability counters (all monotone except
/// `sessions_active`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Sessions ever opened via [`SessionEngine::open`].
    pub sessions_opened: u64,
    /// Sessions currently live (opened or resumed, not yet finished or
    /// suspended away).
    pub sessions_active: u64,
    /// Sessions that ran to completion inside the engine.
    pub sessions_finished: u64,
    /// Search steps performed (executions + decisions).
    pub steps: u64,
    /// Random-pick executions recorded.
    pub executes: u64,
    /// GP decisions closed.
    pub decides: u64,
    /// Decisions that shared a fan-out with >= 1 other same-job decision
    /// in the same round — the admission/batching win.
    pub batched_decides: u64,
    /// Decisions that went through a round's fan-out alone.
    pub solo_decides: u64,
    /// Pooled scoring fan-outs issued (one per round with any decision).
    pub fanout_rounds: u64,
    /// Sessions suspended into a [`SessionState`].
    pub suspends: u64,
    /// Sessions resumed from a [`SessionState`].
    pub resumes: u64,
    /// 1 once this engine's first scoring fan-out has attached to the
    /// process-global pool, 0 while it has only prepped serially.
    pub global_pool_attach: u64,
    /// The global pool width observed at attach time (0 before attach).
    pub pool_thread_count: u64,
}

/// A resident multi-session optimizer (see the module docs).
pub struct SessionEngine {
    jobs: Vec<EngineJob>,
    sessions: Vec<Session>,
    next_id: u64,
    /// Scratch-keying epoch on the process-global pool (the engine's
    /// batched fan-outs stamp their tasks with it, like a backend).
    epoch: u64,
    stats: SessionStats,
}

/// Per-session backends are strictly serial: all scoring parallelism
/// belongs to the one process-global pool the engine fans out on, so
/// thousands of sessions never attach (let alone spawn) a pool each
/// (`global_pool_attach` and `pool_creates` stay 0 across session
/// backends — the bench smoke asserts exactly that).
fn session_backend() -> NativeBackend {
    let mut b = NativeBackend::new();
    b.set_parallelism(1);
    b
}

fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v < xs[best] {
            best = i;
        }
    }
    best
}

impl SessionEngine {
    /// An engine fanning its batched scoring out on the process-global
    /// pool. `gp_threads` is forwarded to
    /// [`pool::configure_global_pool_width`] (0 = adaptive, matching
    /// `--gp-threads` semantics) — it sets the *process* width if no
    /// pool width was established yet, and is otherwise a no-op: the
    /// first configuration per process wins, and every engine after it
    /// shares the same lanes instead of parking more threads.
    pub fn new(gp_threads: usize) -> Self {
        pool::configure_global_pool_width(gp_threads);
        Self {
            jobs: Vec::new(),
            sessions: Vec::new(),
            next_id: 1,
            epoch: pool::next_pool_epoch(),
            stats: SessionStats::default(),
        }
    }

    /// Register a job: its catalog features, (simulated) cost table and
    /// phase plan become shared immutable state for any number of
    /// sessions. Returns the job handle for [`Self::open`].
    pub fn register_job(
        &mut self,
        label: &str,
        space: &SearchSpace,
        costs: Vec<f64>,
        phases: Vec<Vec<usize>>,
    ) -> Result<usize> {
        ensure!(!space.is_empty(), "cannot register a job over an empty space");
        ensure!(
            costs.len() == space.len(),
            "cost table has {} entries for a {}-config space",
            costs.len(),
            space.len()
        );
        ensure!(self.job_index(label).is_none(), "job {label:?} is already registered");
        let m = space.len();
        for phase in &phases {
            for &i in phase {
                ensure!(i < m, "phase index {i} out of bounds (space size {m})");
            }
        }
        self.jobs.push(EngineJob {
            label: label.to_string(),
            features: space.feature_matrix(),
            m,
            d: crate::searchspace::N_FEATURES,
            costs,
            phases: Arc::new(phases),
        });
        Ok(self.jobs.len() - 1)
    }

    /// Handle of a registered job, by label.
    pub fn job_index(&self, label: &str) -> Option<usize> {
        self.jobs.iter().position(|j| j.label == label)
    }

    /// Open a session on a registered job; returns its engine-unique id.
    pub fn open(&mut self, job: usize, seed: u64, params: BoParams) -> Result<u64> {
        self.open_warm(job, seed, params, &WarmStart::default())
    }

    /// Open a session seeded from a transfer prior (see
    /// `coordinator::transfer`): `warm.seeds` replace the random initial
    /// design and `warm.grid_slots` narrow the hyperparameter sweep. A
    /// cold prior is exactly [`Self::open`]. The prior rides in the
    /// suspended [`SessionState`], so warm sessions suspend/resume
    /// bit-identically like cold ones.
    pub fn open_warm(
        &mut self,
        job: usize,
        seed: u64,
        params: BoParams,
        warm: &WarmStart,
    ) -> Result<u64> {
        let j = self.jobs.get(job).ok_or_else(|| anyhow!("no job with handle {job}"))?;
        let cursor = SearchCursor::with_warm_start(
            Arc::clone(&j.phases),
            j.m,
            j.d,
            Pcg64::from_seed(seed),
            params,
            warm,
        );
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.push(Session {
            id,
            job,
            seed,
            params,
            cursor,
            backend: session_backend(),
            mu: Vec::new(),
            var: Vec::new(),
            ei: Vec::new(),
            prep: None,
            finished: false,
        });
        self.stats.sessions_opened += 1;
        self.stats.sessions_active += 1;
        Ok(id)
    }

    /// Advance every live session by one search step, batching all
    /// pending GP decisions into one pooled scoring fan-out. Returns
    /// the number of steps performed (0 = every session is finished).
    pub fn step_all(&mut self) -> Result<usize> {
        let mut stepped = 0usize;
        let mut decides_per_job = vec![0u64; self.jobs.len()];

        // (A) serial prep: advance cursors, record executes, fit the
        // per-session GP for pending decisions.
        {
            let jobs = &self.jobs;
            let stats = &mut self.stats;
            for sess in self.sessions.iter_mut() {
                if sess.finished {
                    continue;
                }
                let job = &jobs[sess.job];
                match sess.cursor.advance() {
                    SearchStep::Done => {
                        sess.finished = true;
                        stats.sessions_finished += 1;
                        stats.sessions_active -= 1;
                    }
                    SearchStep::Execute(i) => {
                        sess.cursor.record(i, job.costs[i], &job.features);
                        stats.executes += 1;
                        stats.steps += 1;
                        stepped += 1;
                    }
                    SearchStep::NeedsDecision => {
                        // The serial half of decide_with_backend, verbatim:
                        // window, standardize, nll grid, argmin, fit.
                        let (skip, n) = sess.cursor.window(sess.backend.max_obs());
                        let (y_std, _, y_scale) = standardize(sess.cursor.y_window(skip));
                        let nll = sess.backend.nll_grid(
                            sess.cursor.x_window(skip),
                            &y_std,
                            n,
                            job.d,
                            sess.cursor.grid(),
                        )?;
                        let row = argmin(&nll);
                        sess.cursor.note_grid_choice(row);
                        let hyp = sess.cursor.grid()[row];
                        let best_std = y_std.iter().cloned().fold(f64::INFINITY, f64::min);
                        let prepared = sess.backend.prepare_decide(
                            sess.cursor.x_window(skip),
                            &y_std,
                            n,
                            job.d,
                            job.m,
                            hyp,
                        )?;
                        sess.prep = Some(PrepInfo { skip, n, y_scale, best_std, hyp, prepared });
                        decides_per_job[sess.job] += 1;
                    }
                }
            }
        }

        let any_decides = decides_per_job.iter().any(|&c| c > 0);
        for &count in &decides_per_job {
            if count >= 2 {
                self.stats.batched_decides += count;
            } else if count == 1 {
                self.stats.solo_decides += 1;
            }
        }

        // (B) one pooled fan-out over every pending decision's pure
        // scoring pass. Each session is one unit (its tile loop matches
        // the serial decide bit for bit); units are dealt round-robin,
        // write disjoint per-session outputs and share nothing mutable,
        // so the result is identical for any pool width.
        if any_decides {
            self.stats.fanout_rounds += 1;
            let (shared, _) = pool::global_pool_acquire();
            if self.stats.global_pool_attach == 0 {
                self.stats.global_pool_attach = 1;
                self.stats.pool_thread_count = shared.width() as u64;
            }
            let jobs = &self.jobs;
            let mut units: Vec<Vec<ScoreUnit>> = Vec::new();
            for sess in self.sessions.iter_mut() {
                let Some(info) = sess.prep else { continue };
                let job = &jobs[sess.job];
                let Session { cursor, backend, mu, var, .. } = sess;
                let cursor: &SearchCursor = cursor;
                let x = cursor.x_window(info.skip);
                match info.prepared {
                    PreparedDecide::Exact { slot } => {
                        // Matches decide()'s freshly zeroed vectors.
                        mu.clear();
                        mu.resize(job.m, 0.0);
                        var.clear();
                        var.resize(job.m, 0.0);
                        let backend: &NativeBackend = backend;
                        let (factor, alpha) = backend.exact_score_view(slot);
                        units.push(vec![ScoreUnit::Exact {
                            factor,
                            alpha,
                            x,
                            n: info.n,
                            d: job.d,
                            hyp: info.hyp,
                            xc: &job.features,
                            mu: &mut mu[..],
                            var: &mut var[..],
                        }]);
                    }
                    PreparedDecide::LowRank => {
                        // Matches decide()'s empty vectors into
                        // predict_batch.
                        mu.clear();
                        var.clear();
                        units.push(vec![ScoreUnit::LowRank {
                            gp: backend.lowrank_mut(),
                            xc: &job.features,
                            m: job.m,
                            mu,
                            var,
                        }]);
                    }
                }
            }
            shared.run_groups(self.epoch, units, |lane, scratch| {
                for unit in lane {
                    match unit {
                        ScoreUnit::Exact { factor, alpha, x, n, d, hyp, xc, mu, var } => {
                            for (t, (mu_c, var_c)) in mu
                                .chunks_mut(DECIDE_TILE)
                                .zip(var.chunks_mut(DECIDE_TILE))
                                .enumerate()
                            {
                                let start = t * DECIDE_TILE;
                                let w = mu_c.len();
                                predict_into(
                                    factor,
                                    alpha,
                                    x,
                                    n,
                                    d,
                                    hyp,
                                    &xc[start * d..(start + w) * d],
                                    w,
                                    mu_c,
                                    var_c,
                                    &mut scratch.ks,
                                    &mut scratch.acc,
                                );
                            }
                        }
                        ScoreUnit::LowRank { gp, xc, m, mu, var } => {
                            gp.predict_batch(xc, m, mu, var);
                        }
                    }
                }
            });
        }

        // (C) serial finish: EI + stopping criterion per decision.
        {
            let jobs = &self.jobs;
            let stats = &mut self.stats;
            for sess in self.sessions.iter_mut() {
                let Some(info) = sess.prep.take() else { continue };
                let job = &jobs[sess.job];
                let Session { cursor, mu, var, ei, .. } = sess;
                let cmask = cursor.cmask();
                ei.clear();
                ei.extend((0..job.m).map(|i| {
                    if cmask[i] {
                        expected_improvement(mu[i], var[i], info.best_std)
                    } else {
                        0.0
                    }
                }));
                match cursor.finish_decision(ei, var, info.y_scale) {
                    Some(pick) => cursor.record(pick, job.costs[pick], &job.features),
                    None => {
                        // Enforced stop: the search is over.
                        sess.finished = true;
                        stats.sessions_finished += 1;
                        stats.sessions_active -= 1;
                    }
                }
                stats.decides += 1;
                stats.steps += 1;
                stepped += 1;
            }
        }
        Ok(stepped)
    }

    /// Step every session to completion; returns total steps performed.
    pub fn run_all(&mut self) -> Result<u64> {
        let mut total = 0u64;
        loop {
            let n = self.step_all()?;
            if n == 0 {
                return Ok(total);
            }
            total += n as u64;
        }
    }

    /// Suspend a session into its serializable state, removing it from
    /// the engine. Valid between `step_all` rounds (a session's step is
    /// atomic, so its snapshot is always a consistent post-record one).
    pub fn suspend(&mut self, id: u64) -> Result<SessionState> {
        let pos = self
            .sessions
            .iter()
            .position(|s| s.id == id)
            .ok_or_else(|| anyhow!("no session with id {id}"))?;
        let sess = self.sessions.swap_remove(pos);
        self.stats.suspends += 1;
        if !sess.finished {
            self.stats.sessions_active -= 1;
        }
        let job = &self.jobs[sess.job];
        Ok(SessionState::capture(
            &job.label,
            sess.seed,
            sess.params,
            job.phases.as_ref(),
            &sess.cursor,
        ))
    }

    /// Resume a suspended session: bind it back to its registered job,
    /// replay its trace to rewarm a fresh backend (see
    /// [`replay_cursor`]) and return the new session id.
    pub fn resume(&mut self, state: &SessionState) -> Result<u64> {
        let job_idx = self
            .job_index(&state.job_label)
            .ok_or_else(|| anyhow!("job {:?} is not registered", state.job_label))?;
        let job = &self.jobs[job_idx];
        ensure!(
            job.m == state.m && job.d == state.d,
            "state is for a {}x{} space, job {:?} is {}x{}",
            state.m,
            state.d,
            state.job_label,
            job.m,
            job.d
        );
        let mut backend = session_backend();
        let cursor = replay_cursor(state, &job.features, &mut backend)?;
        let finished = cursor.is_done();
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.push(Session {
            id,
            job: job_idx,
            seed: state.seed,
            params: state.params,
            cursor,
            backend,
            mu: Vec::new(),
            var: Vec::new(),
            ei: Vec::new(),
            prep: None,
            finished,
        });
        self.stats.resumes += 1;
        if finished {
            self.stats.sessions_finished += 1;
        } else {
            self.stats.sessions_active += 1;
        }
        Ok(id)
    }

    /// Engine counters.
    pub fn stats(&self) -> SessionStats {
        self.stats
    }

    /// The trace of a session (so far, or final once it finished).
    pub fn outcome(&self, id: u64) -> Option<SearchOutcome> {
        self.sessions.iter().find(|s| s.id == id).map(|s| s.cursor.outcome())
    }

    /// Whether a session has finished (None = unknown id).
    pub fn is_done(&self, id: u64) -> Option<bool> {
        self.sessions.iter().find(|s| s.id == id).map(|s| s.finished)
    }

    /// Pool attachments across all *session* backends — the shared-pool
    /// invariant says this stays 0 no matter how many sessions run
    /// (scoring parallelism is the engine fan-out's job, on the
    /// process-global pool; session backends are pinned serial).
    pub fn session_backend_pool_creates(&self) -> u64 {
        self.sessions
            .iter()
            .map(|s| {
                let ds = s.backend.decide_stats();
                ds.pool_creates + ds.global_pool_attach
            })
            .sum()
    }

    /// Lanes in the process-global scoring pool the engine fans out on.
    pub fn pool_width(&self) -> usize {
        pool::global_pool_width()
    }

    /// Ids of all sessions currently held by the engine.
    pub fn session_ids(&self) -> Vec<u64> {
        self.sessions.iter().map(|s| s.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayesopt::run_search;

    fn scout_costs(space: &SearchSpace, salt: u64) -> Vec<f64> {
        (0..space.len())
            .map(|i| 0.5 + ((i as u64 * 37 + salt * 13) % 101) as f64 / 101.0)
            .collect()
    }

    fn two_phase(space: &SearchSpace) -> Vec<Vec<usize>> {
        let priority = space.lowest_memory_configs(10);
        let rest: Vec<usize> = (0..space.len()).filter(|i| !priority.contains(i)).collect();
        vec![priority, rest]
    }

    fn reference_outcome(
        space: &SearchSpace,
        costs: &[f64],
        phases: &[Vec<usize>],
        seed: u64,
        params: &BoParams,
    ) -> SearchOutcome {
        let features = space.feature_matrix();
        let mut backend = session_backend();
        let mut rng = Pcg64::from_seed(seed);
        let mut oracle = |i: usize| costs[i];
        run_search(
            &features,
            space.len(),
            crate::searchspace::N_FEATURES,
            phases,
            &mut oracle,
            &mut backend,
            &mut rng,
            params,
        )
        .expect("reference search")
    }

    fn assert_trace_eq(a: &SearchOutcome, b: &SearchOutcome) {
        assert_eq!(a.tried, b.tried);
        assert_eq!(
            a.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            b.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.stop_after, b.stop_after);
        assert_eq!(a.phase_starts, b.phase_starts);
    }

    fn small_params() -> BoParams {
        BoParams { max_iters: 14, ..Default::default() }
    }

    #[test]
    fn engine_session_matches_run_search() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 1);
        let phases = two_phase(&space);
        let params = small_params();
        let reference = reference_outcome(&space, &costs, &phases, 42, &params);

        let mut engine = SessionEngine::new(2);
        let job = engine.register_job("j", &space, costs, phases).expect("register");
        let id = engine.open(job, 42, params).expect("open");
        engine.run_all().expect("run");
        assert_eq!(engine.is_done(id), Some(true));
        assert_trace_eq(&engine.outcome(id).expect("outcome"), &reference);
    }

    #[test]
    fn concurrent_sessions_batch_and_stay_bit_identical() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 2);
        let phases = two_phase(&space);
        let params = small_params();

        let mut engine = SessionEngine::new(3);
        let job = engine.register_job("j", &space, costs.clone(), phases.clone()).expect("reg");
        let ids: Vec<u64> =
            (0..6).map(|s| engine.open(job, 100 + s, params).expect("open")).collect();
        engine.run_all().expect("run");

        let stats = engine.stats();
        assert!(stats.batched_decides > 0, "no decide ever batched: {stats:?}");
        assert!(stats.fanout_rounds > 0);
        assert_eq!(stats.sessions_finished, 6);
        assert_eq!(stats.sessions_active, 0);
        // Scoring parallelism is the engine pool's job, never the
        // sessions': no per-session pool may ever be created.
        assert_eq!(engine.session_backend_pool_creates(), 0);

        for (s, id) in ids.iter().enumerate() {
            let reference = reference_outcome(&space, &costs, &phases, 100 + s as u64, &params);
            assert_trace_eq(&engine.outcome(*id).expect("outcome"), &reference);
        }
    }

    #[test]
    fn suspend_resume_roundtrip_is_bit_identical() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 3);
        let phases = two_phase(&space);
        let params = small_params();
        let reference = reference_outcome(&space, &costs, &phases, 7, &params);

        let mut engine = SessionEngine::new(2);
        let job = engine.register_job("j", &space, costs, phases).expect("register");
        let id = engine.open(job, 7, params).expect("open");
        for _ in 0..5 {
            engine.step_all().expect("step");
        }
        let state = engine.suspend(id).expect("suspend");
        let text = state.encode();
        let decoded = SessionState::decode(&text).expect("decode");
        let resumed = engine.resume(&decoded).expect("resume");
        engine.run_all().expect("run");

        let stats = engine.stats();
        assert_eq!(stats.suspends, 1);
        assert_eq!(stats.resumes, 1);
        assert_trace_eq(&engine.outcome(resumed).expect("outcome"), &reference);
    }

    #[test]
    fn state_json_roundtrip_preserves_every_field() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 4);
        let phases = two_phase(&space);
        // usize::MAX max_iters exercises the null sentinel.
        let params = BoParams { enforce_stop: true, ..Default::default() };

        let mut engine = SessionEngine::new(1);
        let job = engine.register_job("j", &space, costs, phases).expect("register");
        let id = engine.open(job, 99, params).expect("open");
        for _ in 0..6 {
            engine.step_all().expect("step");
        }
        let state = engine.suspend(id).expect("suspend");
        let back = SessionState::decode(&state.encode()).expect("decode");
        assert_eq!(back.job_label, state.job_label);
        assert_eq!(back.seed, state.seed);
        assert_eq!(back.m, state.m);
        assert_eq!(back.d, state.d);
        assert_eq!(back.phases, state.phases);
        // BoParams has no PartialEq: compare field by field, floats by bits.
        assert_eq!(back.params.n_init, state.params.n_init);
        assert_eq!(back.params.min_obs_for_stop, state.params.min_obs_for_stop);
        assert_eq!(back.params.ei_stop_rel.to_bits(), state.params.ei_stop_rel.to_bits());
        assert_eq!(back.params.max_iters, state.params.max_iters);
        assert_eq!(back.params.enforce_stop, state.params.enforce_stop);
        assert_eq!(back.snapshot, state.snapshot);
        assert!(!state.snapshot.tried.is_empty(), "suspension should be mid-run");
    }

    #[test]
    fn corrupt_or_mismatched_state_is_rejected() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 5);
        let phases = two_phase(&space);
        let mut engine = SessionEngine::new(1);
        let job = engine.register_job("j", &space, costs, phases).expect("register");
        let id = engine.open(job, 5, small_params()).expect("open");
        for _ in 0..4 {
            engine.step_all().expect("step");
        }
        let state = engine.suspend(id).expect("suspend");
        let text = state.encode();

        // Wrong version.
        let wrong = text.replacen("\"version\":1", "\"version\":2", 1);
        assert!(SessionState::decode(&wrong).is_err(), "future version must be rejected");

        // Corrupt cost hex.
        let mut tampered = state.clone();
        let corrupted =
            text.replacen(&super::hex_f64(tampered.snapshot.costs[0]), "zznothex", 1);
        assert!(SessionState::decode(&corrupted).is_err(), "bad hex must be rejected");

        // A tampered cost replays into a diverged search.
        tampered.snapshot.costs[0] += 0.25;
        let mut backend = session_backend();
        assert!(
            replay_cursor(&tampered, &space.feature_matrix(), &mut backend).is_err(),
            "tampered trace must not resume"
        );

        // Unknown job label on resume.
        let mut unbound = state.clone();
        unbound.job_label = "nope".into();
        assert!(engine.resume(&unbound).is_err());
    }

    #[test]
    fn out_of_catalog_state_is_rejected_not_panicking() {
        // Regression: resume only checked m/d, so a state whose phase
        // plan or trace carried indices outside the registered job's
        // catalog assert-panicked (or index-panicked) mid-replay. It
        // must be a clean Err naming the offending index.
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 8);
        let phases = two_phase(&space);
        let mut engine = SessionEngine::new(1);
        let job = engine.register_job("j", &space, costs, phases).expect("register");
        let id = engine.open(job, 21, small_params()).expect("open");
        for _ in 0..4 {
            engine.step_all().expect("step");
        }
        let state = engine.suspend(id).expect("suspend");

        let oob = space.len() + 7;
        let mut bad = state.clone();
        bad.phases[1].push(oob);
        let err = engine.resume(&bad).expect_err("oob phase index must not resume");
        assert!(
            err.to_string().contains(&oob.to_string()),
            "error must name the offending index: {err}"
        );

        let mut bad = state.clone();
        bad.snapshot.tried[0] = oob;
        let err = engine.resume(&bad).expect_err("oob tried index must not resume");
        assert!(
            err.to_string().contains(&oob.to_string()),
            "error must name the offending index: {err}"
        );

        let mut bad = state.clone();
        bad.snapshot.costs.pop();
        assert!(
            engine.resume(&bad).is_err(),
            "a picks/costs length mismatch must not resume"
        );
    }

    #[test]
    fn warm_session_resumes_exactly_at_every_round_boundary() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 9);
        let phases = two_phase(&space);
        let params = BoParams { max_iters: 10, ..Default::default() };
        // Seeds from the priority phase (so they actually engage) and a
        // narrowed two-lengthscale grid.
        let warm = WarmStart {
            seeds: vec![phases[0][5], phases[0][1], phases[0][8]],
            grid_slots: vec![4, 5, 6, 7, 16, 17, 18, 19],
        };

        let mut engine = SessionEngine::new(2);
        let job = engine.register_job("j", &space, costs.clone(), phases.clone()).expect("reg");
        let id = engine.open_warm(job, 31, params, &warm).expect("open");
        engine.run_all().expect("run");
        let reference = engine.outcome(id).expect("outcome");
        assert_eq!(reference.tried[..3], warm.seeds[..], "warm seeds must open the trace");

        for cut in 0..12 {
            let mut engine = SessionEngine::new(2);
            let job =
                engine.register_job("j", &space, costs.clone(), phases.clone()).expect("reg");
            let id = engine.open_warm(job, 31, params, &warm).expect("open");
            for _ in 0..cut {
                engine.step_all().expect("step");
            }
            let state = engine.suspend(id).expect("suspend");
            let decoded = SessionState::decode(&state.encode()).expect("decode");
            assert_eq!(decoded.warm, warm, "the prior must ride in the serialized state");
            let resumed = engine.resume(&decoded).expect("resume");
            engine.run_all().expect("run");
            let out = engine.outcome(resumed).expect("outcome");
            assert_trace_eq(&out, &reference);
            assert_eq!(out.grid_hits, reference.grid_hits, "replay must rebuild grid hits");
        }
    }

    #[test]
    fn suspend_at_every_round_boundary_resumes_exactly() {
        let space = SearchSpace::scout();
        let costs = scout_costs(&space, 6);
        let phases = two_phase(&space);
        let params = BoParams { max_iters: 10, ..Default::default() };
        let reference = reference_outcome(&space, &costs, &phases, 13, &params);

        for cut in 0..12 {
            let mut engine = SessionEngine::new(2);
            let job =
                engine.register_job("j", &space, costs.clone(), phases.clone()).expect("reg");
            let id = engine.open(job, 13, params).expect("open");
            for _ in 0..cut {
                engine.step_all().expect("step");
            }
            let state = engine.suspend(id).expect("suspend");
            let decoded = SessionState::decode(&state.encode()).expect("decode");
            let resumed = engine.resume(&decoded).expect("resume");
            engine.run_all().expect("run");
            assert_trace_eq(&engine.outcome(resumed).expect("outcome"), &reference);
        }
    }
}
