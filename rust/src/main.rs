//! `ruya` — the Layer-3 coordinator CLI.
//!
//! Subcommands regenerate every table and figure of the paper's
//! evaluation (see DESIGN.md §3 for the experiment index):
//!
//! ```text
//! ruya table1                      # Table I  : memory categorization
//! ruya table2 [--reps N]           # Table II : CherryPick vs Ruya
//! ruya table3                      # Table III: profiling times
//! ruya fig1                        # Fig. 1   : RAM vs cost (K-Means)
//! ruya fig3                        # Fig. 3   : profiling memory trace
//! ruya fig4 [--reps N]             # Fig. 4   : best cost per iteration
//! ruya fig5 [--reps N]             # Fig. 5   : cumulative cost
//! ruya search --job <label>        # one Ruya search, verbose trace
//! ruya pipeline [--job <label>]    # profiler -> memmodel -> shortlist -> BO
//! ruya profile --job <label>       # one profiling phase, verbose
//! ruya space                       # dump the 69-configuration space
//! ruya serve [--script F]          # resident multi-session engine
//! ruya submit --job <label>        # emit a serve `open` request line
//! ruya all [--reps N]              # everything above, to --out dir
//! ```
//!
//! Global options: `--backend native|xla` (default native; xla loads the
//! AOT artifacts through PJRT), `--space scout|generated:<n>` (default
//! the paper's 69-config scout space; `generated:<n>` opens a seeded
//! synthetic n-config cloud catalog served by the low-rank GP path),
//! `--seed <u64>`, `--reps <N>` (default 200 as in the paper),
//! `--threads <N>` (worker threads; `table2` shards jobs x methods x
//! repetitions as one flat task list, other commands shard repetitions —
//! results are bit-identical for any value), `--gp-threads <N>` (the
//! **process-wide** GP worker-pool width, set once at startup: every
//! backend and session engine fans its hyperparameter-grid nll sweep
//! and decide tiles across the same shared lanes, so total parked GP
//! threads never exceed this value whatever `--threads` is — also
//! bit-identical for any value; default 0 = adaptive from
//! `available_parallelism`, with a work-size floor keeping tiny windows
//! serial), `--out <dir>` (export .dat/.json/.md files).

use anyhow::{anyhow, bail, Context, Result};
use ruya::bayesopt::backend_factory_with_parallelism;
use ruya::coordinator::{
    ExperimentConfig, ExperimentRunner, SearchPlan, SessionEngine, SessionState,
};
use ruya::report;
use ruya::searchspace::SearchSpace;
use ruya::util::cli::Args;
use ruya::util::json::{JsonValue, JsonWriter};
use ruya::workload::{evaluation_jobs, ClusterSim, JobCostTable, JobInstance};
use std::io::{BufRead, Read};
use std::path::Path;

/// Upper bound on one `serve` request line (1 MiB). Longer lines get an
/// `{"error":...}` reply and are skipped without ever being buffered
/// whole, so a runaway client cannot balloon the resident process.
const MAX_REQUEST_LINE: usize = 1 << 20;

fn main() {
    let args = Args::parse(&["verbose", "help", "warm"]);
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    if args.flag("help") || sub == "help" {
        print!("{HELP}");
        return Ok(());
    }
    if sub == "space" {
        return dump_space(args);
    }
    if sub == "fig1" {
        return fig1(args.opt("out").map(Path::new));
    }
    if sub == "fig3" {
        return fig3(args.opt_u64("seed", 0xC0FFEE), args.opt("out").map(Path::new));
    }
    if sub == "profile" {
        return profile_one(args, args.opt_u64("seed", 0xC0FFEE));
    }
    if sub == "submit" {
        return submit(args);
    }

    let backend_name = args.opt_or("backend", "native");
    // One GP worker pool serves the whole process, so `--threads` and
    // `--gp-threads` no longer multiply: every engine worker fans out
    // across the same shared lanes. Fix the pool width here, once,
    // before any backend or session engine can race to spawn it
    // (0 = adaptive from `available_parallelism`).
    let gp_threads = args.opt_gp_threads();
    ruya::bayesopt::configure_global_pool_width(gp_threads);
    let factory = backend_factory_with_parallelism(&backend_name, gp_threads)
        .with_context(|| format!("initializing backend {backend_name}"))?;
    let seed = args.opt_u64("seed", 0xC0FFEE);
    let space_spec = args.opt_or("space", "scout");
    let space = SearchSpace::parse_spec(&space_spec, seed)
        .with_context(|| format!("parsing search space {space_spec}"))?;
    let runner = ExperimentRunner::new(factory)
        .with_threads(args.opt_threads())
        .with_space(space);
    let cfg = ExperimentConfig {
        reps: args.opt_usize("reps", 200),
        seed,
        curve_len: args.opt_usize("curve-len", 48),
    };
    let out_dir = args.opt("out").map(Path::new);

    match sub.as_str() {
        "table1" => table1(&runner, cfg.seed, out_dir),
        "table2" => table2(&runner, &backend_name, &cfg, out_dir),
        "table3" => table3(&runner, cfg.seed, out_dir),
        "fig4" | "fig5" => fig45(&runner, &cfg, out_dir),
        "search" => search_one(&runner, args, &cfg),
        "serve" => serve(&runner, args, &cfg, gp_threads),
        "pipeline" => pipeline_cmd(runner, args, &cfg, gp_threads, out_dir),
        "transfer" => transfer_cmd(runner, args, &cfg, gp_threads, out_dir),
        "crispy" => crispy(&runner, args, cfg.seed),
        "stopping" => stopping(&runner, &cfg),
        "all" => {
            table1(&runner, cfg.seed, out_dir)?;
            table3(&runner, cfg.seed, out_dir)?;
            fig1(out_dir)?;
            fig3(cfg.seed, out_dir)?;
            table2(&runner, &backend_name, &cfg, out_dir)?;
            fig45(&runner, &cfg, out_dir)
        }
        other => bail!("unknown subcommand {other:?}; try `ruya help`"),
    }
}

fn write_out(out_dir: Option<&Path>, name: &str, content: &str) -> Result<()> {
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), content)
            .with_context(|| format!("writing {name}"))?;
        eprintln!("wrote {}", dir.join(name).display());
    }
    Ok(())
}

fn table1(runner: &ExperimentRunner, seed: u64, out: Option<&Path>) -> Result<()> {
    let summaries = runner.profile_all(seed);
    let rendered = report::render_table1(&summaries);
    println!("Table I: Determined Job Memory Requirement\n\n{rendered}");
    write_out(out, "table1.md", &rendered)
}

fn table3(runner: &ExperimentRunner, seed: u64, out: Option<&Path>) -> Result<()> {
    let summaries = runner.profile_all(seed);
    let rendered = report::render_table3(&summaries);
    println!("Table III: Memory Profiling Time for all Jobs\n\n{rendered}");
    write_out(out, "table3.md", &rendered)
}

fn table2(
    runner: &ExperimentRunner,
    backend_name: &str,
    cfg: &ExperimentConfig,
    out: Option<&Path>,
) -> Result<()> {
    eprintln!(
        "running Table II: 16 jobs x 2 methods x {} reps (backend: {backend_name}, {} thread(s))...",
        cfg.reps, runner.threads
    );
    let result = runner.run_table2(cfg)?;
    let rendered = report::render_table2(&result);
    println!("Table II: iterations until a configuration with cost c is found\n\n{rendered}");
    write_out(out, "table2.md", &rendered)?;
    write_out(out, "table2.json", &report::experiment_to_json(&result))
}

fn fig45(runner: &ExperimentRunner, cfg: &ExperimentConfig, out: Option<&Path>) -> Result<()> {
    let result = runner.run_table2(cfg)?;
    let n = result.jobs.len() as f64;
    let len = cfg.curve_len;
    let avg = |f: &dyn Fn(&ruya::coordinator::JobComparison) -> &Vec<f64>| {
        let mut acc = vec![0.0; len];
        for j in &result.jobs {
            for (i, v) in f(j).iter().take(len).enumerate() {
                acc[i] += v / n;
            }
        }
        acc
    };
    let fig4_cp = avg(&|j| &j.cherrypick.best_curve);
    let fig4_ruya = avg(&|j| &j.ruya.best_curve);
    let fig4 = report::render_series(
        &fig4_cp,
        &fig4_ruya,
        "Fig 4: best-found normalized cost per iteration (mean over jobs)",
    );
    println!("{fig4}");
    write_out(out, "fig4.dat", &fig4)?;

    let fig5_cp = avg(&|j| &j.cherrypick.cum_curve);
    let fig5_ruya = avg(&|j| &j.ruya.cum_curve);
    let fig5 = report::render_series(
        &fig5_cp,
        &fig5_ruya,
        "Fig 5: cumulative normalized execution cost (mean over jobs)",
    );
    println!("{fig5}");
    write_out(out, "fig5.dat", &fig5)
}

fn fig1(out: Option<&Path>) -> Result<()> {
    // RAM vs cost for K-Means on Spark, every machine type and scale-out.
    let space = SearchSpace::scout();
    let sim = ClusterSim::default();
    let mut rows = String::from(
        "# Fig 1: total RAM vs normalized cost, K-Means on Spark\n# ram_gb  cost_norm  machine  nodes\n",
    );
    for scale in ["bigdata", "huge"] {
        let job = find_spark_job("K-Means", scale)?;
        let table = JobCostTable::build(&sim, &job, &space);
        rows.push_str(&format!("\n# dataset: {scale}\n"));
        let mut by_ram: Vec<(f64, f64, String, u32)> = (0..space.len())
            .map(|i| {
                let c = space.config(i);
                (
                    c.total_memory_gb(),
                    table.normalized[i],
                    c.machine_type().name.to_string(),
                    c.nodes,
                )
            })
            .collect();
        by_ram.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (ram, cost, name, nodes) in by_ram {
            rows.push_str(&format!("{ram:8.1}  {cost:8.3}  {name}  {nodes}\n"));
        }
    }
    println!("{rows}");
    write_out(out, "fig1.dat", &rows)
}

fn fig3(seed: u64, out: Option<&Path>) -> Result<()> {
    // Memory time series of the five K-Means profiling runs.
    let profiler = ruya::profiler::SingleNodeProfiler::default();
    let job = find_spark_job("K-Means", "huge")?;
    let outcome = profiler.profile(&job, seed);
    let mut s = String::from(
        "# Fig 3: single-node memory over time, K-Means on Spark, 5 sample sizes\n",
    );
    let mut t_offset = 0.0;
    for (k, run) in outcome.runs.iter().enumerate() {
        s.push_str(&format!(
            "\n# run {} sample {:.2} GB (peak {:.2} GB)\n",
            k + 1,
            run.sample_gb,
            run.peak_mem_gb
        ));
        if let Some(series) = &run.series {
            for (t, gb) in series.as_rows() {
                s.push_str(&format!("{:8.1}  {gb:8.3}\n", t + t_offset));
            }
            t_offset += series.duration_s() + 20.0;
        }
    }
    println!("{s}");
    write_out(out, "fig3.dat", &s)
}

fn search_one(runner: &ExperimentRunner, args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let label = args
        .opt("job")
        .context("--job <label> required, e.g. --job 'K-Means Spark bigdata'")?;
    let job = job_by_label(label)?;
    let profile = runner.profile_job(&job, cfg.seed);
    println!(
        "profiling: {} -> {} (R^2 {:.3}, {:.0} s)",
        job.label(),
        profile.table1_cell,
        profile.model.r2,
        profile.profiling_time_s
    );
    let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
    println!(
        "plan: category {}, priority {}/{} configs",
        plan.category.name(),
        plan.phases[0].len(),
        runner.space.len()
    );
    let table = JobCostTable::build(&runner.sim, &job, &runner.space);
    // Generated catalogs are too large to exhaust: default to a capped,
    // criterion-stopped search there; the scout space keeps the paper's
    // run-to-exhaustion behavior. "Large" is the same candidate-count
    // threshold past which the backend switches to the low-rank path.
    let large_space = runner.space.len() > ruya::bayesopt::LOWRANK_CANDIDATE_THRESHOLD;
    let default_iters = if large_space { 150 } else { runner.space.len() };
    let params = ruya::bayesopt::BoParams {
        max_iters: args.opt_usize("max-iters", default_iters),
        enforce_stop: large_space,
        ..Default::default()
    };
    let out = runner.run_one_params(&table, &plan, cfg.seed ^ job.job_id, &params)?;
    println!("\niter  config            cost    best");
    let mut best = f64::INFINITY;
    for (i, (&idx, &cost)) in out.tried.iter().zip(&out.costs).enumerate() {
        best = best.min(cost);
        let marker = if cost <= 1.0 + 1e-9 { "  <- optimal" } else { "" };
        println!(
            "{:4}  {:16} {:6.3}  {:6.3}{marker}",
            i + 1,
            runner.space.config(idx).name(),
            cost,
            best
        );
        if cost <= 1.0 + 1e-9 {
            break;
        }
    }
    if let Some(stop) = out.stop_after {
        println!("stopping criterion fired after {stop} executions");
    }
    // Baseline comparison under the same seed and parameters.
    let cp = runner.run_one_params(
        &table,
        &SearchPlan::unpartitioned(&runner.space),
        cfg.seed ^ job.job_id,
        &params,
    )?;
    let ruya_iters = out.first_within(1.0 + 1e-9);
    let cp_iters = cp.first_within(1.0 + 1e-9);
    match iters_to_optimum_line(ruya_iters, cp_iters) {
        Some(line) => println!("\n{line}"),
        None => println!("\noptimum not reached by either method within the iteration budget"),
    }
    Ok(())
}

/// Closing line of `ruya search`: iterations-to-optimum for each method,
/// with `None` (capped or criterion-stopped searches that never hit the
/// optimum) rendered as `not reached` rather than a misleading `0`.
/// Returns `None` when neither method reached it, so the caller can
/// replace the comparison with an explanation instead.
fn iters_to_optimum_line(ruya: Option<usize>, cherrypick: Option<usize>) -> Option<String> {
    if ruya.is_none() && cherrypick.is_none() {
        return None;
    }
    let fmt = |v: Option<usize>| match v {
        Some(n) => n.to_string(),
        None => "not reached".to_string(),
    };
    Some(format!("iterations to optimum: ruya {} vs cherrypick {}", fmt(ruya), fmt(cherrypick)))
}

/// `ruya pipeline` — the paper's loop end-to-end, per job: profile on
/// the single node, fit the memory model, shortlist the catalog by
/// memory suitability, then BO *inside the shortlist only* (run as a
/// resident engine session), with a full-catalog baseline search and a
/// Crispy one-shot pick at the same seed and iteration budget for the
/// narrowed-vs-full experiment matrix.
fn pipeline_cmd(
    runner: ExperimentRunner,
    args: &Args,
    cfg: &ExperimentConfig,
    gp_threads: usize,
    out: Option<&Path>,
) -> Result<()> {
    let jobs: Vec<JobInstance> = match args.opt("job") {
        Some(label) => vec![job_by_label(label)?],
        None => evaluation_jobs(),
    };
    let pipeline = ruya::coordinator::MemoryPipeline::new(runner);
    let budget = args.opt_usize("max-iters", pipeline.default_budget());
    let warm = args.flag("warm");
    eprintln!(
        "pipeline: {} job(s) over {} configs; narrowed + full searches at {} iterations each{}",
        jobs.len(),
        pipeline.runner.space.len(),
        budget,
        if warm { " (+ warm-started leg via cross-job transfer)" } else { "" }
    );
    let (outcomes, store) = if warm {
        let (o, s) = pipeline.run_matrix_warm(&jobs, cfg.seed, budget, gp_threads)?;
        (o, Some(s))
    } else {
        (pipeline.run_matrix(&jobs, cfg.seed, budget, gp_threads)?, None)
    };
    let rendered = report::render_pipeline_matrix(&outcomes, budget);
    println!("Memory-aware pipeline: profiler -> memory model -> shortlist -> BO\n\n{rendered}");
    write_out(out, "pipeline.md", &rendered)?;
    write_out(out, "pipeline.json", &report::pipeline_to_json(&outcomes, budget, cfg.seed))?;
    if let Some(store) = store {
        eprintln!(
            "transfer store: {} behavior cluster(s) holding {} job posterior(s)",
            store.clusters().len(),
            store.evidence_len()
        );
        write_out(out, "transfer.json", &store.encode())?;
    }
    Ok(())
}

/// `ruya transfer` — inspect the cross-job transfer layer: absorb one
/// cold narrowed search per evaluation job into a fresh store, print
/// the behavior clusters with their deposited posteriors, then the
/// leave-one-out warm start each job would inherit from the others
/// (a job's own evidence is always excluded). `--out` also writes the
/// serialized store (`transfer.json`).
fn transfer_cmd(
    runner: ExperimentRunner,
    args: &Args,
    cfg: &ExperimentConfig,
    gp_threads: usize,
    out: Option<&Path>,
) -> Result<()> {
    use ruya::coordinator::{signature, TransferStore};
    use ruya::searchspace::machine_by_index;
    let pipeline = ruya::coordinator::MemoryPipeline::new(runner);
    let jobs = evaluation_jobs();
    let budget = args.opt_usize("max-iters", pipeline.default_budget());
    eprintln!(
        "transfer: absorbing {} cold narrowed searches at {} iterations each, \
         then mining leave-one-out warm starts",
        jobs.len(),
        budget
    );
    let mut engine = SessionEngine::new(gp_threads);
    let mut store = TransferStore::default();
    let mut sigs = Vec::new();
    for job in &jobs {
        let profile = pipeline.runner.profile_job(job, cfg.seed);
        let sig = signature(job, &profile.model);
        let outcome = pipeline.run_job(&mut engine, job, cfg.seed, budget)?;
        store.absorb(&sig, &pipeline.runner.space, &outcome.narrowed);
        sigs.push(sig);
    }

    println!(
        "Behavior clusters: {} over {} absorbed jobs\n",
        store.clusters().len(),
        store.evidence_len()
    );
    for (ci, cluster) in store.clusters().iter().enumerate() {
        println!("cluster {ci} (center: {})", cluster.center.label);
        for e in &cluster.evidence {
            let tops: Vec<String> = e
                .top
                .iter()
                .take(3)
                .map(|t| format!("{}x{} {:.3}", t.nodes, machine_by_index(t.machine).name, t.cost))
                .collect();
            println!(
                "  {:27} grid slots {:?}  top: {}",
                e.label,
                e.slots,
                tops.join(", ")
            );
        }
    }

    let grid_len = ruya::bayesopt::hyperparameter_grid().len();
    println!("\nLeave-one-out warm starts (what a fresh run of each job inherits):\n");
    for (job, sig) in jobs.iter().zip(&sigs) {
        match store.warm_start(sig, &pipeline.runner.space, Some(&job.label())) {
            Some(w) => {
                let seeds: Vec<String> =
                    w.seeds.iter().map(|&i| pipeline.runner.space.config(i).name()).collect();
                let grid = if w.grid_slots.is_empty() {
                    format!("full {grid_len}-slot grid")
                } else {
                    format!("{}/{grid_len} grid slots", w.grid_slots.len())
                };
                println!("{:27} seeds [{}], {grid}", job.label(), seeds.join(", "));
            }
            None => println!("{:27} cold (no usable evidence)", job.label()),
        }
    }
    write_out(out, "transfer.json", &store.encode())
}

fn profile_one(args: &Args, seed: u64) -> Result<()> {
    let label = args.opt("job").context("--job <label> required")?;
    let job = job_by_label(label)?;
    let profiler = ruya::profiler::SingleNodeProfiler::default();
    let outcome = profiler.profile(&job, seed);
    println!("profiling {} ({} GB input)", job.label(), job.input_gb);
    println!("calibration runs: {}", outcome.calibration.len());
    println!("\nsample_gb  runtime_s  peak_mem_gb");
    for r in &outcome.runs {
        println!("{:9.3}  {:9.1}  {:10.3}", r.sample_gb, r.runtime_s, r.peak_mem_gb);
    }
    let model = ruya::memmodel::MemoryModel::fit(&outcome.readings());
    println!("\ncategory: {} (R^2 {:.4})", model.category.name(), model.r2);
    println!("result: {}", model.table1_cell(job.input_gb));
    println!("total profiling time: {:.0} s", outcome.total_s);
    Ok(())
}

fn crispy(runner: &ExperimentRunner, args: &Args, seed: u64) -> Result<()> {
    // One-shot (Crispy-style) selection: either one job or the whole
    // catalog with its regret vs the simulated optimum.
    let selector = ruya::coordinator::CrispySelector::default();
    let jobs: Vec<JobInstance> = match args.opt("job") {
        Some(label) => vec![job_by_label(label)?],
        None => evaluation_jobs(),
    };
    println!("Crispy one-shot selection (no iterative search):\n");
    println!("{:27} {:16} {:>10} {:>12}", "job", "choice", "admissible", "norm. cost");
    let mut regrets = Vec::new();
    for job in jobs {
        let profile = runner.profile_job(&job, seed);
        let choice = selector.select(&job.label(), &profile.model, job.input_gb, &runner.space)?;
        let table = JobCostTable::build(&runner.sim, &job, &runner.space);
        let cost = table.normalized[choice.config_idx];
        regrets.push(cost);
        println!(
            "{:27} {:16} {:>10} {:>12.3}",
            job.label(),
            runner.space.config(choice.config_idx).name(),
            choice.admissible,
            cost
        );
    }
    println!(
        "\nmean one-shot normalized cost: {:.3} (iterative Ruya reaches 1.0; \
         this is what the search iterations buy)",
        regrets.iter().sum::<f64>() / regrets.len() as f64
    );
    Ok(())
}

fn stopping(runner: &ExperimentRunner, cfg: &ExperimentConfig) -> Result<()> {
    // The §III-E stopping-criterion tradeoff: quality of enforced-stop
    // searches per method.
    println!(
        "enforced-stop search quality ({} reps): stop-iters / best cost / %optimal / search spend\n",
        cfg.reps
    );
    println!(
        "{:27} {:>7} | {:>6} {:>6} {:>5} {:>7} | {:>6} {:>6} {:>5} {:>7}",
        "job", "cat", "CPit", "CPcost", "CP%", "CPspend", "Ruit", "Rucost", "Ru%", "Ruspend"
    );
    for job in evaluation_jobs() {
        let profile = runner.profile_job(&job, cfg.seed);
        let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
        let cp_plan = SearchPlan::unpartitioned(&runner.space);
        let table = JobCostTable::build(&runner.sim, &job, &runner.space);
        let cp = runner.stop_quality(&table, &cp_plan, cfg, job.job_id ^ 0x57AB)?;
        let ru = runner.stop_quality(&table, &plan, cfg, job.job_id ^ 0x57AB)?;
        println!(
            "{:27} {:>7} | {:>6.1} {:>6.3} {:>4.0}% {:>7.1} | {:>6.1} {:>6.3} {:>4.0}% {:>7.1}",
            job.label(),
            plan.category.name(),
            cp.mean_stop_iters,
            cp.mean_best_cost,
            cp.frac_optimal * 100.0,
            cp.mean_search_spend,
            ru.mean_stop_iters,
            ru.mean_best_cost,
            ru.frac_optimal * 100.0,
            ru.mean_search_spend
        );
    }
    Ok(())
}

fn dump_space(args: &Args) -> Result<()> {
    let spec = args.opt_or("space", "scout");
    let space = SearchSpace::parse_spec(&spec, args.opt_u64("seed", 0xC0FFEE))?;
    println!("{} configurations ({spec})", space.len());
    println!("\nidx  config            cores  total_gb  usable_gb  $/h");
    for i in 0..space.len() {
        let c = space.config(i);
        println!(
            "{i:3}  {:16} {:5}  {:8.1}  {:9.1}  {:.3}",
            c.name(),
            c.total_cores() as u64,
            c.total_memory_gb(),
            c.usable_memory_gb(),
            c.price_per_hour()
        );
    }
    Ok(())
}

/// `ruya submit` — print a ready-made `open` request line for [`serve`].
/// Validates the job label locally so typos fail here, not inside the
/// server stream; `--sessions` accepts `k`/`m` suffixes (`10k` = 10000).
fn submit(args: &Args) -> Result<()> {
    let label = args
        .opt("job")
        .context("--job <label> required, e.g. --job 'K-Means Spark bigdata'")?;
    let job = job_by_label(label)?;
    let mut w = JsonWriter::new();
    w.begin_object().key("op").string("open");
    w.key("job").string(&job.label());
    w.key("sessions").number(args.opt_count("sessions", 1) as f64);
    w.key("seed").number(args.opt_u64("seed", 0xC0FFEE) as f64);
    if let Some(iters) = args.opt("max-iters") {
        let iters: usize = iters.parse().context("--max-iters must be an unsigned integer")?;
        w.key("max_iters").number(iters as f64);
    }
    w.end_object();
    println!("{}", w.finish());
    Ok(())
}

/// `ruya serve` — the resident optimizer service. Reads line-delimited
/// JSON requests (stdin, or `--script FILE`), multiplexes every open
/// session over one [`SessionEngine`], and answers one line per request.
/// Blank lines and `#` comments are skipped; a malformed request prints
/// an `{"error":...}` line and the stream continues.
///
/// Ops: `{"op":"open","job":L,"sessions":N,"seed":S,"max_iters":K}`,
/// `{"op":"step","rounds":N}`, `{"op":"run"}`, `{"op":"suspend","id":I}`
/// (the response line IS the portable session state),
/// `{"op":"resume","state":{...}}`, `{"op":"stats"}`, `{"op":"report"}`.
fn serve(
    runner: &ExperimentRunner,
    args: &Args,
    cfg: &ExperimentConfig,
    gp_threads: usize,
) -> Result<()> {
    let mut engine = SessionEngine::new(gp_threads);
    let mut reader: Box<dyn BufRead> = match args.opt("script") {
        Some(path) => {
            let f = std::fs::File::open(path).with_context(|| format!("opening --script {path}"))?;
            Box::new(std::io::BufReader::new(f))
        }
        None => Box::new(std::io::BufReader::new(std::io::stdin())),
    };
    eprintln!(
        "ruya serve: engine up ({} scoring lane(s)); one JSON request per line",
        engine.pool_width()
    );
    let error_reply = |msg: &str| {
        let mut w = JsonWriter::new();
        w.begin_object().key("error").string(msg).end_object();
        println!("{}", w.finish());
    };
    // Bounded byte-wise reader: a resident service must survive every
    // byte sequence a client can feed it. Oversized lines are answered
    // with an error reply and skipped (never buffered whole), invalid
    // UTF-8 degrades to a parse error on the lossy text, and only a
    // hard I/O failure on the stream itself ends the loop.
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let n = {
            let mut limited = (&mut reader).take(MAX_REQUEST_LINE as u64 + 1);
            match limited.read_until(b'\n', &mut buf) {
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("reading request stream"),
            }
        };
        if n == 0 {
            break; // EOF
        }
        if buf.len() > MAX_REQUEST_LINE && buf.last() != Some(&b'\n') {
            // Drain the rest of the physical line so the stream stays
            // aligned on line boundaries, then keep serving.
            loop {
                let available = match reader.fill_buf() {
                    Ok(bytes) => bytes,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e).context("reading request stream"),
                };
                if available.is_empty() {
                    break; // EOF mid-line
                }
                let (used, done) = match available.iter().position(|&b| b == b'\n') {
                    Some(pos) => (pos + 1, true),
                    None => (available.len(), false),
                };
                reader.consume(used);
                if done {
                    break;
                }
            }
            error_reply(&format!("request line exceeds {MAX_REQUEST_LINE} bytes"));
            continue;
        }
        let text = String::from_utf8_lossy(&buf);
        let line = text.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Err(e) = serve_request(runner, &mut engine, cfg, line) {
            error_reply(&format!("{e:#}"));
        }
    }
    Ok(())
}

fn serve_request(
    runner: &ExperimentRunner,
    engine: &mut SessionEngine,
    cfg: &ExperimentConfig,
    line: &str,
) -> Result<()> {
    let req = JsonValue::parse(line).map_err(|e| anyhow!("bad request JSON: {e}"))?;
    let op = req
        .get("op")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| anyhow!("request needs an \"op\" string"))?;
    let get_usize = |key: &str| req.get(key).and_then(JsonValue::as_f64).map(|v| v as usize);
    match op {
        "open" => {
            let label = req
                .get("job")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("open needs a \"job\" label"))?;
            let job = job_by_label(label)?;
            // Lazy registration: the first open of a job profiles it,
            // plans its phases and builds its cost table once; every
            // later session shares that immutable state.
            let job_idx = match engine.job_index(&job.label()) {
                Some(i) => i,
                None => runner.register_job_with_engine(engine, &job, cfg.seed)?,
            };
            let sessions = get_usize("sessions").unwrap_or(1).max(1);
            let seed = req
                .get("seed")
                .and_then(JsonValue::as_f64)
                .map(|v| v as u64)
                .unwrap_or(cfg.seed ^ job.job_id);
            let large = runner.space.len() > ruya::bayesopt::LOWRANK_CANDIDATE_THRESHOLD;
            let default_iters = if large { 150 } else { runner.space.len() };
            let params = ruya::bayesopt::BoParams {
                max_iters: get_usize("max_iters").unwrap_or(default_iters),
                enforce_stop: true,
                ..Default::default()
            };
            let ids: Vec<u64> = (0..sessions)
                .map(|s| engine.open(job_idx, seed.wrapping_add(s as u64 * 7919), params))
                .collect::<Result<_>>()?;
            let mut w = JsonWriter::new();
            w.begin_object().key("ok").string("open");
            w.key("job").string(&job.label());
            w.key("first_id").number(ids[0] as f64);
            w.key("sessions").number(ids.len() as f64).end_object();
            println!("{}", w.finish());
        }
        "step" => {
            let rounds = get_usize("rounds").unwrap_or(1).max(1);
            let mut stepped = 0usize;
            for _ in 0..rounds {
                stepped += engine.step_all()?;
            }
            let mut w = JsonWriter::new();
            w.begin_object().key("ok").string("step");
            w.key("stepped").number(stepped as f64);
            w.key("active").number(engine.stats().sessions_active as f64).end_object();
            println!("{}", w.finish());
        }
        "run" => {
            let steps = engine.run_all()?;
            let mut w = JsonWriter::new();
            w.begin_object().key("ok").string("run");
            w.key("steps").number(steps as f64).end_object();
            println!("{}", w.finish());
        }
        "suspend" => {
            let id = get_usize("id").ok_or_else(|| anyhow!("suspend needs a session \"id\""))?;
            // The response line IS the portable state: feed it back as
            // {"op":"resume","state":<line>} to continue bit-identically.
            println!("{}", engine.suspend(id as u64)?.encode());
        }
        "resume" => {
            let state = SessionState::from_value(
                req.get("state").ok_or_else(|| anyhow!("resume needs a \"state\" object"))?,
            )?;
            if engine.job_index(&state.job_label).is_none() {
                let job = job_by_label(&state.job_label)?;
                runner.register_job_with_engine(engine, &job, cfg.seed)?;
            }
            let id = engine.resume(&state)?;
            let mut w = JsonWriter::new();
            w.begin_object().key("ok").string("resume");
            w.key("id").number(id as f64);
            w.key("executions").number(state.snapshot.tried.len() as f64).end_object();
            println!("{}", w.finish());
        }
        "stats" => {
            let s = engine.stats();
            let mut w = JsonWriter::new();
            w.begin_object().key("ok").string("stats");
            for (k, v) in [
                ("sessions_opened", s.sessions_opened),
                ("sessions_active", s.sessions_active),
                ("sessions_finished", s.sessions_finished),
                ("steps", s.steps),
                ("executes", s.executes),
                ("decides", s.decides),
                ("batched_decides", s.batched_decides),
                ("solo_decides", s.solo_decides),
                ("fanout_rounds", s.fanout_rounds),
                ("suspends", s.suspends),
                ("resumes", s.resumes),
                ("pool_width", engine.pool_width() as u64),
                ("pool_creates", engine.session_backend_pool_creates()),
                ("global_pool_attach", s.global_pool_attach),
                ("pool_thread_count", s.pool_thread_count),
                ("pool_threads_live", ruya::bayesopt::spawned_pool_threads() as u64),
            ] {
                w.key(k).number(v as f64);
            }
            w.end_object();
            println!("{}", w.finish());
        }
        "report" => {
            for id in engine.session_ids() {
                let Some(out) = engine.outcome(id) else { continue };
                let best = out.costs.iter().cloned().fold(f64::INFINITY, f64::min);
                let mut w = JsonWriter::new();
                w.begin_object().key("id").number(id as f64);
                w.key("executions").number(out.tried.len() as f64);
                w.key("best").number(best);
                w.key("done").boolean(engine.is_done(id).unwrap_or(false));
                match out.stop_after {
                    // NaN renders as JSON null: "no stop fired".
                    Some(k) => w.key("stop_after").number(k as f64),
                    None => w.key("stop_after").number(f64::NAN),
                };
                w.end_object();
                println!("{}", w.finish());
            }
        }
        other => bail!("unknown op {other:?} (open/step/run/suspend/resume/stats/report)"),
    }
    Ok(())
}

fn find_spark_job(name: &str, scale: &str) -> Result<JobInstance> {
    evaluation_jobs()
        .into_iter()
        .find(|j| {
            j.algo.name == name
                && j.scale.name() == scale
                && j.algo.framework == ruya::workload::Framework::Spark
        })
        .context("job not found")
}

fn job_by_label(label: &str) -> Result<JobInstance> {
    let all = evaluation_jobs();
    all.iter()
        .find(|j| j.label().eq_ignore_ascii_case(label))
        .copied()
        .with_context(|| {
            let labels: Vec<String> = all.iter().map(|j| j.label()).collect();
            format!("job {label:?} not found; known jobs:\n  {}", labels.join("\n  "))
        })
}

const HELP: &str = r#"ruya — memory-aware iterative optimization of cluster configurations

USAGE: ruya <subcommand> [options]

SUBCOMMANDS
  table1            Table I: per-job memory categorization + requirement
  table2            Table II: CherryPick vs Ruya iterations-to-optimal
  table3            Table III: profiling wall-clock time per job
  fig1              Fig 1: total RAM vs normalized cost (K-Means/Spark)
  fig3              Fig 3: profiling memory time series (K-Means/Spark)
  fig4, fig5        Fig 4/5: convergence + cumulative-cost curves
  search --job L    run one Ruya search (with CherryPick comparison)
  pipeline          the paper's loop end-to-end, per job: profile -> fit
                    memory model -> shortlist the catalog -> BO inside
                    the shortlist only (as engine sessions), vs a
                    full-catalog baseline at the same seed and budget
                    (--job L for one job; default all 16; --max-iters N
                    budget, default min(96, catalog size); --warm adds a
                    third, warm-started leg per job, seeded from the
                    behavior clusters of every job before it)
  transfer          inspect cross-job transfer: absorb one cold narrowed
                    search per job into a behavior-cluster store, print
                    the clusters + per-job leave-one-out warm starts
                    (--out writes the serialized store, transfer.json)
  crispy [--job L]  one-shot (Crispy-style) selection, no iteration
  stopping          enforced-stop search quality (stopping criterion)
  profile --job L   run one profiling phase, print readings + model
  space             dump the search space (respects --space)
  serve             resident session engine: one JSON request per line on
                    stdin (or --script FILE); ops open/step/run/suspend/
                    resume/stats/report — suspend's reply line is the
                    portable state that a later resume accepts back
  submit --job L    print a serve `open` request line (validates the job;
                    --sessions N opens N concurrent sessions, k/m
                    suffixes allowed: 10k = 10000)
  all               regenerate every table and figure

OPTIONS
  --backend native|xla   GP backend (default native; xla = AOT artifacts)
  --space SPEC           scout (default, the paper's 69 configs) or
                         generated:<n> — a seeded synthetic n-config cloud
                         catalog; spaces past 512 candidates are scored
                         by the Nystrom low-rank GP path automatically
  --max-iters N          cap search executions (search, submit and serve
                         opens; default: space size, or 150 with the
                         stopping criterion enforced on spaces > 512
                         configs)
  --reps N               repetitions for table2/fig4/fig5 (default 200)
  --threads N            worker threads (default 1; table2 shards jobs x
                         methods x repetitions, other commands shard
                         repetitions; results bit-identical for any value)
  --gp-threads N         process-wide GP worker-pool width, fixed once at
                         startup: ONE persistent N-lane pool serves every
                         backend and session engine in the process, which
                         fan their 32-point nll sweeps and 1024-wide
                         decide tiles across the shared lanes. Total
                         parked GP threads stay <= N no matter how many
                         backends --threads spins up (no threads x
                         gp-threads multiplication), and results are
                         bit-identical for any value. Default 0 =
                         adaptive (available_parallelism, capped at 8);
                         1 forces serial; windows of <= 16 observations
                         always run serial (work-size floor)
  --warm                 pipeline: run the warm-started transfer leg and
                         report the transfer store
  --seed S               experiment seed (default 0xC0FFEE)
  --script FILE          serve: read requests from FILE instead of stdin
  --sessions N           submit: sessions per open request (k/m suffixes)
  --out DIR              also write tables/figures to DIR
  --curve-len N          length of fig4/fig5 series (default 48)
"#;

#[cfg(test)]
mod tests {
    use super::iters_to_optimum_line;

    #[test]
    fn iters_line_reports_both_methods() {
        assert_eq!(
            iters_to_optimum_line(Some(7), Some(23)).as_deref(),
            Some("iterations to optimum: ruya 7 vs cherrypick 23")
        );
    }

    #[test]
    fn iters_line_says_not_reached_instead_of_zero() {
        // The old formatting printed `.unwrap_or(0)` — a literal 0 that
        // read as "reached instantly" when the optimum was never found.
        assert_eq!(
            iters_to_optimum_line(Some(12), None).as_deref(),
            Some("iterations to optimum: ruya 12 vs cherrypick not reached")
        );
        assert_eq!(
            iters_to_optimum_line(None, Some(40)).as_deref(),
            Some("iterations to optimum: ruya not reached vs cherrypick 40")
        );
    }

    #[test]
    fn iters_line_is_skipped_when_neither_method_reached_the_optimum() {
        assert_eq!(iters_to_optimum_line(None, None), None);
    }
}
