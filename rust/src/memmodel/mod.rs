//! Memory-usage modeling and job categorization (§III-C).
//!
//! Fits a linear regression on the profiler's (sample size → peak memory)
//! readings and categorizes the job by the training-set R² score:
//! > 0.99 ⇒ *linear* (extrapolate the requirement), < 0.1 ⇒ *flat*,
//! in between ⇒ *unclear* (fall back to plain CherryPick).

use crate::util::stats::{ols_fit, r2_score};

/// Categorization thresholds (§III-C / §IV-B).
pub const R2_LINEAR_THRESHOLD: f64 = 0.99;
pub const R2_FLAT_THRESHOLD: f64 = 0.1;
/// Relative-growth guard: with only five readings, the R² of pure noise
/// is Beta-distributed with mean 1/3, so a scale-free score alone cannot
/// recognize flat jobs. If the fitted line predicts less than this much
/// relative memory growth across the sampled range, the job is flat in
/// the paper's sense ("memory use remains flat as the input dataset size
/// increases") regardless of R².
pub const FLAT_GROWTH_THRESHOLD: f64 = 0.15;

/// The paper's three memory-usage categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemCategory {
    /// Memory grows linearly with the input: prioritize configurations
    /// with at least the extrapolated requirement.
    Linear,
    /// Memory independent of input: prioritize low-memory configurations.
    Flat,
    /// Readings inconclusive: unmodified Bayesian optimization.
    Unclear,
}

impl MemCategory {
    pub fn name(&self) -> &'static str {
        match self {
            MemCategory::Linear => "linear",
            MemCategory::Flat => "flat",
            MemCategory::Unclear => "unclear",
        }
    }
}

/// Fitted memory model for one job.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub category: MemCategory,
    pub slope_gb_per_gb: f64,
    pub intercept_gb: f64,
    pub r2: f64,
    /// The readings the model was fitted on: (sample_gb, peak_mem_gb).
    pub readings: Vec<(f64, f64)>,
}

impl MemoryModel {
    /// Fit on the profiler's readings.
    ///
    /// Robustness (edge cases surfaced by the pipeline tests):
    /// * Non-finite readings are dropped before fitting.
    /// * Fewer than two valid readings carry no growth information at
    ///   all, so the model is `Unclear` (plain CherryPick downstream)
    ///   instead of a panic or a degenerate fit.
    /// * Duplicate sample sizes — the controller re-running at the same
    ///   fraction — are fine for OLS as long as at least two *distinct*
    ///   sizes remain; if every reading sits at one sample size, growth
    ///   is unobservable and the model is `Unclear` (note that a naive
    ///   fit would call it `Flat`: zero fitted slope is absence of
    ///   evidence here, not evidence of flatness).
    pub fn fit(readings: &[(f64, f64)]) -> Self {
        let valid: Vec<(f64, f64)> =
            readings.iter().copied().filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
        let distinct_xs = {
            let mut xs: Vec<u64> = valid.iter().map(|r| r.0.to_bits()).collect();
            xs.sort_unstable();
            xs.dedup();
            xs.len()
        };
        if valid.len() < 2 || distinct_xs < 2 {
            let ys: Vec<f64> = valid.iter().map(|r| r.1).collect();
            return Self {
                category: MemCategory::Unclear,
                slope_gb_per_gb: 0.0,
                intercept_gb: crate::util::stats::mean(&ys),
                r2: 0.0,
                readings: valid,
            };
        }
        let xs: Vec<f64> = valid.iter().map(|r| r.0).collect();
        let ys: Vec<f64> = valid.iter().map(|r| r.1).collect();
        let (slope, intercept) = ols_fit(&xs, &ys);
        let r2 = r2_score(&xs, &ys);

        // Growth the fitted line predicts across the sampled range,
        // relative to the mean reading (see FLAT_GROWTH_THRESHOLD).
        let x_span = xs.iter().cloned().fold(f64::MIN, f64::max)
            - xs.iter().cloned().fold(f64::MAX, f64::min);
        let y_mean = crate::util::stats::mean(&ys).abs().max(1e-12);
        let rel_growth = slope * x_span / y_mean;

        let category = if rel_growth.abs() < FLAT_GROWTH_THRESHOLD {
            MemCategory::Flat
        } else if r2 > R2_LINEAR_THRESHOLD && slope > 0.0 {
            MemCategory::Linear
        } else if r2 < R2_FLAT_THRESHOLD {
            MemCategory::Flat
        } else {
            MemCategory::Unclear
        };
        Self { category, slope_gb_per_gb: slope, intercept_gb: intercept, r2, readings: valid }
    }

    /// Extrapolated memory requirement of the job itself (GB) for a full
    /// dataset of `input_gb` — excluding per-node OS/framework overhead,
    /// which the search-space accounting adds back (§III-D). Only
    /// meaningful for `Linear` jobs.
    pub fn estimate_requirement_gb(&self, input_gb: f64) -> f64 {
        (self.slope_gb_per_gb * input_gb + self.intercept_gb).max(0.0)
    }

    /// Human-readable Table I result cell.
    pub fn table1_cell(&self, input_gb: f64) -> String {
        match self.category {
            MemCategory::Linear => {
                format!("linear: {:.0} GB", self.estimate_requirement_gb(input_gb))
            }
            MemCategory::Flat => "flat".to_string(),
            MemCategory::Unclear => "unclear".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_line_is_linear() {
        let readings: Vec<(f64, f64)> =
            (1..=5).map(|k| (k as f64, 2.5 * k as f64 + 0.1)).collect();
        let m = MemoryModel::fit(&readings);
        assert_eq!(m.category, MemCategory::Linear);
        assert!((m.slope_gb_per_gb - 2.5).abs() < 1e-9);
        assert!((m.estimate_requirement_gb(100.0) - 250.1).abs() < 1e-6);
    }

    #[test]
    fn noisy_line_still_linear_within_threshold() {
        // 0.4% relative noise keeps R^2 > 0.99 on a strong slope.
        let readings = [
            (1.0, 2.504),
            (2.0, 4.989),
            (3.0, 7.513),
            (4.0, 9.976),
            (5.0, 12.532),
        ];
        let m = MemoryModel::fit(&readings);
        assert!(m.r2 > 0.99, "r2 = {}", m.r2);
        assert_eq!(m.category, MemCategory::Linear);
    }

    #[test]
    fn uncorrelated_readings_are_flat() {
        let readings = [(1.0, 1.2), (2.0, 1.1), (3.0, 1.25), (4.0, 1.15), (5.0, 1.18)];
        let m = MemoryModel::fit(&readings);
        assert_eq!(m.category, MemCategory::Flat, "r2 = {}", m.r2);
    }

    #[test]
    fn erratic_readings_are_unclear() {
        // Correlated but far from collinear: mid-band R^2.
        let readings = [(1.0, 2.0), (2.0, 7.0), (3.0, 6.0), (4.0, 14.0), (5.0, 10.0)];
        let m = MemoryModel::fit(&readings);
        assert!(
            m.r2 > R2_FLAT_THRESHOLD && m.r2 < R2_LINEAR_THRESHOLD,
            "r2 = {}",
            m.r2
        );
        assert_eq!(m.category, MemCategory::Unclear);
    }

    #[test]
    fn negative_slope_never_linear() {
        // A perfectly decreasing line has R^2 = 1 but extrapolating a
        // negative memory requirement is nonsense.
        let readings: Vec<(f64, f64)> =
            (1..=5).map(|k| (k as f64, 10.0 - k as f64)).collect();
        let m = MemoryModel::fit(&readings);
        assert_ne!(m.category, MemCategory::Linear);
    }

    #[test]
    fn requirement_clamped_nonnegative() {
        let readings = [(1.0, 0.1), (2.0, 0.05), (3.0, 0.12), (4.0, 0.06), (5.0, 0.1)];
        let m = MemoryModel::fit(&readings);
        assert!(m.estimate_requirement_gb(0.0) >= 0.0);
    }

    #[test]
    fn table1_cells_format() {
        let lin = MemoryModel::fit(&[(1.0, 2.5), (2.0, 5.0), (3.0, 7.5)]);
        assert!(lin.table1_cell(100.0).starts_with("linear: 250 GB"));
        let flat = MemoryModel::fit(&[(1.0, 1.0), (2.0, 1.02), (3.0, 0.98)]);
        assert_eq!(flat.table1_cell(100.0), "flat");
    }

    #[test]
    fn fewer_than_two_valid_readings_is_unclear() {
        // A single reading, an empty outcome, and a pair where one
        // reading is non-finite all carry no growth information: the
        // fit must degrade to Unclear, never panic or extrapolate.
        for readings in [
            vec![(1.0, 1.0)],
            vec![],
            vec![(1.0, 1.0), (2.0, f64::NAN)],
            vec![(f64::INFINITY, 1.0), (2.0, 1.5)],
        ] {
            let m = MemoryModel::fit(&readings);
            assert_eq!(m.category, MemCategory::Unclear, "readings {readings:?}");
            assert!(m.slope_gb_per_gb.is_finite() && m.intercept_gb.is_finite());
            assert!(m.estimate_requirement_gb(100.0).is_finite());
        }
    }

    #[test]
    fn duplicate_sample_sizes_do_not_poison_the_fit() {
        // Controller re-runs at the same fraction: partial duplicates
        // are legitimate OLS input and keep the true slope.
        let readings =
            [(1.0, 2.5), (1.0, 2.5), (2.0, 5.0), (3.0, 7.5), (4.0, 10.0)];
        let m = MemoryModel::fit(&readings);
        assert_eq!(m.category, MemCategory::Linear);
        assert!((m.slope_gb_per_gb - 2.5).abs() < 1e-9, "slope {}", m.slope_gb_per_gb);
    }

    #[test]
    fn all_readings_at_one_sample_size_are_unclear() {
        // Every run at the same fraction: growth is unobservable, so the
        // job is Unclear — a naive fit would report slope 0 and call it
        // Flat, which is absence of evidence mislabeled as evidence.
        let readings = [(2.0, 1.0), (2.0, 5.0), (2.0, 3.0), (2.0, 4.0), (2.0, 2.0)];
        let m = MemoryModel::fit(&readings);
        assert_eq!(m.category, MemCategory::Unclear);
        assert_eq!(m.slope_gb_per_gb, 0.0);
        assert!(m.intercept_gb.is_finite() && m.r2 == 0.0);
    }
}
