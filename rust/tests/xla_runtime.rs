//! Integration tests for the PJRT runtime layer: loading, compiling and
//! executing the AOT artifacts, and validating the padding/masking
//! contract shared with python/compile/model.py.
//!
//! All tests skip gracefully when `make artifacts` has not been run.

use ruya::runtime::{GpExecutor, XlaRuntime, AOT_N_FEATURES};

fn runtime_or_skip() -> Option<(XlaRuntime, GpExecutor)> {
    if !XlaRuntime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = XlaRuntime::new(XlaRuntime::default_artifact_dir()).expect("runtime");
    let exec = GpExecutor::new(&rt).expect("compiling artifacts");
    Some((rt, exec))
}

/// A tiny deterministic observation set used across the tests:
/// y = sum of features, three points in [0,1]^6.
fn toy_data() -> (Vec<f64>, Vec<f64>, usize) {
    let x: Vec<f64> = vec![
        0.1, 0.2, 0.3, 0.1, 0.2, 0.3, //
        0.9, 0.8, 0.7, 0.9, 0.8, 0.7, //
        0.5, 0.5, 0.5, 0.5, 0.5, 0.5, //
    ];
    let y: Vec<f64> = vec![1.2, 4.8, 3.0];
    (x, y, 3)
}

fn toy_candidates() -> (Vec<f64>, Vec<f64>, usize) {
    // 5 candidates: the 3 training points plus 2 fresh ones.
    let (x, _, _) = toy_data();
    let mut xc = x.clone();
    xc.extend_from_slice(&[0.0; AOT_N_FEATURES]);
    xc.extend_from_slice(&[1.0; AOT_N_FEATURES]);
    (xc, vec![1.0; 5], 5)
}

#[test]
fn artifacts_compile_and_execute() {
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let (xc, cmask, m) = toy_candidates();
    let d = exec.gp_ei(&x, &y, n, &xc, &cmask, m, [0.5, 1.0, 1e-4]).expect("gp_ei");
    assert_eq!(d.ei.len(), m);
    assert_eq!(d.mu.len(), m);
    assert_eq!(d.var.len(), m);
    assert!(d.ei.iter().all(|v| v.is_finite() && *v >= 0.0), "ei = {:?}", d.ei);
    assert!(d.var.iter().all(|v| v.is_finite() && *v >= 0.0), "var = {:?}", d.var);
}

#[test]
fn posterior_interpolates_observations() {
    // With tiny noise, the posterior mean at a training point must be close
    // to the observed value and its variance near zero.
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let (xc, cmask, m) = toy_candidates();
    let d = exec.gp_ei(&x, &y, n, &xc, &cmask, m, [0.5, 1.0, 1e-5]).expect("gp_ei");
    for i in 0..n {
        assert!(
            (d.mu[i] - y[i]).abs() < 0.05,
            "mu[{i}] = {} should be near y = {}",
            d.mu[i],
            y[i]
        );
        assert!(d.var[i] < 0.01, "var at training point = {}", d.var[i]);
    }
    // Fresh far-away candidate keeps close-to-prior variance.
    assert!(d.var[4] > 0.1, "far candidate var = {}", d.var[4]);
}

#[test]
fn candidate_mask_zeroes_ei() {
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let (xc, mut cmask, m) = toy_candidates();
    cmask[3] = 0.0;
    cmask[4] = 0.0;
    let d = exec.gp_ei(&x, &y, n, &xc, &cmask, m, [0.5, 1.0, 1e-4]).expect("gp_ei");
    assert_eq!(d.ei[3], 0.0);
    assert_eq!(d.ei[4], 0.0);
}

#[test]
fn padding_is_invisible() {
    // Padding the candidate list must not change results for live entries.
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let (xc, cmask, m) = toy_candidates();
    let hyp = [0.7, 1.3, 1e-3];
    let d1 = exec.gp_ei(&x, &y, n, &xc, &cmask, m, hyp).expect("gp_ei");

    let mut xc2 = xc.clone();
    xc2.extend_from_slice(&[0.25; AOT_N_FEATURES]);
    let mut cmask2 = cmask.clone();
    cmask2.push(1.0);
    let d2 = exec.gp_ei(&x, &y, n, &xc2, &cmask2, m + 1, hyp).expect("gp_ei");
    for i in 0..m {
        assert!((d1.mu[i] - d2.mu[i]).abs() < 1e-5);
        assert!((d1.var[i] - d2.var[i]).abs() < 1e-5);
        assert!((d1.ei[i] - d2.ei[i]).abs() < 1e-5);
    }
}

#[test]
fn nll_prefers_true_lengthscale_family() {
    // Data drawn from a smooth function should assign lower NLL to a
    // moderate lengthscale than to a pathologically tiny one.
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let mut x = Vec::new();
    let mut y = Vec::new();
    let n = 12;
    for i in 0..n {
        let t = i as f64 / (n - 1) as f64;
        let mut row = [0.0; AOT_N_FEATURES];
        row[0] = t;
        row[1] = 1.0 - t;
        x.extend_from_slice(&row);
        y.push((2.0 * t).sin());
    }
    let grid = [[0.01, 1.0, 1e-4], [0.5, 1.0, 1e-4], [1.0, 1.0, 1e-4]];
    let nll = exec.gp_nll(&x, &y, n, &grid).expect("gp_nll");
    assert_eq!(nll.len(), 3);
    assert!(nll.iter().all(|v| v.is_finite()));
    assert!(
        nll[1] < nll[0],
        "moderate lengthscale should beat tiny: {nll:?}"
    );
}

#[test]
fn nll_grid_matches_individual_calls() {
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let grid = [[0.3, 1.0, 1e-3], [0.9, 2.0, 1e-2]];
    let batch = exec.gp_nll(&x, &y, n, &grid).expect("batch");
    for (i, h) in grid.iter().enumerate() {
        let single = exec.gp_nll(&x, &y, n, &[*h]).expect("single");
        assert!((batch[i] - single[0]).abs() < 1e-4, "{} vs {}", batch[i], single[0]);
    }
}

#[test]
fn executor_counts_calls() {
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let (x, y, n) = toy_data();
    let (xc, cmask, m) = toy_candidates();
    let before = exec.call_count();
    exec.gp_ei(&x, &y, n, &xc, &cmask, m, [0.5, 1.0, 1e-4]).unwrap();
    exec.gp_nll(&x, &y, n, &[[0.5, 1.0, 1e-4]]).unwrap();
    assert_eq!(exec.call_count(), before + 2);
}

#[test]
fn rejects_oversized_inputs() {
    let Some((_rt, exec)) = runtime_or_skip() else { return };
    let n = 65; // > AOT_N_OBS
    let x = vec![0.0; n * AOT_N_FEATURES];
    let y = vec![0.0; n];
    let (xc, cmask, m) = toy_candidates();
    assert!(exec.gp_ei(&x, &y, n, &xc, &cmask, m, [0.5, 1.0, 1e-4]).is_err());
}

#[test]
fn executor_pool_compiles_once_per_thread() {
    // Backends cloned from one pool on one thread must share a single
    // compiled executable set instead of recompiling per backend.
    use ruya::bayesopt::{GpBackend, XlaBackend};
    use ruya::runtime::ExecutorPool;

    if !XlaRuntime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let pool = ExecutorPool::from_default_artifacts();
    let (x, y, n) = toy_data();
    let (xc, cmaskf, m) = toy_candidates();
    let cmask: Vec<bool> = cmaskf.iter().map(|&v| v > 0.0).collect();
    for _ in 0..3 {
        let mut b = XlaBackend::from_pool(pool.clone()).expect("backend from pool");
        b.decide(&x, &y, n, AOT_N_FEATURES, &xc, &cmask, m, [0.5, 1.0, 1e-4])
            .expect("pooled decide");
        assert_eq!(b.call_count(), 1);
    }
    assert_eq!(pool.compile_count(), 1, "three backends, one compilation");
}
