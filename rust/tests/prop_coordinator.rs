//! Property-based tests on the coordinator invariants (DESIGN.md §9),
//! using the in-tree `testkit` harness (proptest is unavailable offline).

use ruya::bayesopt::{run_search, BoParams, NativeBackend};
use ruya::coordinator::RuyaPlanner;
use ruya::memmodel::MemoryModel;
use ruya::prop_assert;
use ruya::searchspace::SearchSpace;
use ruya::testkit::{property, Gen};
use ruya::util::rng::Pcg64;

/// Random synthetic cost surface over the scout space: smooth component
/// over the feature encoding plus noise — enough structure for BO without
/// depending on the workload simulator.
fn synth_costs(g: &mut Gen, space: &SearchSpace) -> Vec<f64> {
    let w: Vec<f64> = (0..ruya::searchspace::N_FEATURES).map(|_| g.f64_in(-2.0, 2.0)).collect();
    let noise = g.f64_in(0.0, 0.3);
    let mut costs: Vec<f64> = (0..space.len())
        .map(|i| {
            let f = space.features(i);
            let s: f64 = f.iter().zip(&w).map(|(a, b)| a * b).sum();
            (s.sin() + 2.5) + noise * g.rng().next_gaussian().abs()
        })
        .collect();
    let min = costs.iter().cloned().fold(f64::MAX, f64::min);
    for c in costs.iter_mut() {
        *c /= min;
    }
    costs
}

/// Random memory model via random readings.
fn synth_model(g: &mut Gen) -> MemoryModel {
    let kind = g.usize_in(0, 2);
    let readings: Vec<(f64, f64)> = (1..=5)
        .map(|k| {
            let x = k as f64;
            let y = match kind {
                0 => 2.0 * x + 0.001 * g.rng().next_gaussian(), // linear
                1 => 1.2 + 0.02 * g.rng().next_gaussian(),      // flat
                _ => 2.0 * x * (1.0 + 0.6 * g.rng().next_gaussian().abs()), // erratic
            };
            (x, y.max(0.01))
        })
        .collect();
    MemoryModel::fit(&readings)
}

#[test]
fn prop_plans_partition_space() {
    let space = SearchSpace::scout();
    let planner = RuyaPlanner::default();
    property("plan phases partition the space", 80, |g| {
        let model = synth_model(g);
        let input_gb = g.f64_in(1.0, 400.0);
        let plan = planner.plan(&model, input_gb, &space);
        let mut all: Vec<usize> = plan.phases.concat();
        all.sort_unstable();
        let expect: Vec<usize> = (0..space.len()).collect();
        prop_assert!(all == expect, "phases do not partition: {} indices", all.len());
        prop_assert!(
            plan.phases.iter().all(|p| !p.is_empty()),
            "empty phase in plan"
        );
        Ok(())
    });
}

#[test]
fn prop_priority_groups_respect_predicates() {
    let space = SearchSpace::scout();
    let planner = RuyaPlanner::default();
    property("priority groups respect their predicate", 60, |g| {
        let model = synth_model(g);
        let input_gb = g.f64_in(1.0, 400.0);
        let plan = planner.plan(&model, input_gb, &space);
        match plan.category {
            ruya::memmodel::MemCategory::Linear => {
                if let Some(req) = plan.requirement_gb {
                    let satisfiable = !space.with_usable_memory_at_least(req * (1.0 + planner.leeway)).is_empty();
                    if satisfiable && plan.phases.len() == 2 {
                        for &i in &plan.phases[0] {
                            prop_assert!(
                                space.config(i).usable_memory_gb() >= req,
                                "priority config {i} below requirement {req}"
                            );
                        }
                    }
                }
            }
            ruya::memmodel::MemCategory::Flat => {
                prop_assert!(
                    plan.phases[0].len() == planner.flat_group_size.min(space.len()),
                    "flat priority size {}",
                    plan.phases[0].len()
                );
            }
            ruya::memmodel::MemCategory::Unclear => {
                prop_assert!(plan.phases.len() == 1, "unclear must not split");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_search_never_repeats_and_terminates() {
    let space = SearchSpace::scout();
    let features = space.feature_matrix();
    let m = space.len();
    let d = ruya::searchspace::N_FEATURES;
    property("search tries each config at most once and exhausts", 15, |g| {
        let costs = synth_costs(g, &space);
        let seed = g.rng().next_u64();
        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(seed);
        let phases = vec![(0..m).collect::<Vec<_>>()];
        let params = BoParams { max_iters: m, ..Default::default() };
        let mut oracle = |i: usize| costs[i];
        let out =
            run_search(&features, m, d, &phases, &mut oracle, &mut backend, &mut rng, &params)
                .map_err(|e| e.to_string())?;
        let mut seen = out.tried.clone();
        seen.sort_unstable();
        let dups = seen.windows(2).filter(|w| w[0] == w[1]).count();
        prop_assert!(dups == 0, "{dups} duplicate executions");
        prop_assert!(out.tried.len() == m, "search did not exhaust: {}", out.tried.len());
        Ok(())
    });
}

#[test]
fn prop_best_so_far_monotone_and_reaches_optimum() {
    let space = SearchSpace::scout();
    let features = space.feature_matrix();
    let m = space.len();
    let d = ruya::searchspace::N_FEATURES;
    property("best-so-far is monotone and ends at 1.0", 12, |g| {
        let costs = synth_costs(g, &space);
        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(g.rng().next_u64());
        // Random two-phase plan.
        let k = g.usize_in(1, m - 1);
        let priority = g.subset(m, k);
        let inp: Vec<bool> = {
            let mut f = vec![false; m];
            for &i in &priority {
                f[i] = true;
            }
            f
        };
        let rest: Vec<usize> = (0..m).filter(|&i| !inp[i]).collect();
        let phases = vec![priority, rest];
        let params = BoParams { max_iters: m, ..Default::default() };
        let mut oracle = |i: usize| costs[i];
        let out =
            run_search(&features, m, d, &phases, &mut oracle, &mut backend, &mut rng, &params)
                .map_err(|e| e.to_string())?;
        let mut best = f64::INFINITY;
        for (t, &c) in out.costs.iter().enumerate() {
            prop_assert!(c >= 1.0 - 1e-12, "normalized cost {c} < 1 at step {t}");
            best = best.min(c);
        }
        prop_assert!((best - 1.0).abs() < 1e-9, "optimum missed, best {best}");
        Ok(())
    });
}

#[test]
fn prop_phase_order_respected() {
    let space = SearchSpace::scout();
    let features = space.feature_matrix();
    let m = space.len();
    let d = ruya::searchspace::N_FEATURES;
    property("phase 1 fully precedes phase 2", 12, |g| {
        let costs = synth_costs(g, &space);
        let k = g.usize_in(2, 20);
        let priority = g.subset(m, k);
        let inp: Vec<bool> = {
            let mut f = vec![false; m];
            for &i in &priority {
                f[i] = true;
            }
            f
        };
        let rest: Vec<usize> = (0..m).filter(|&i| !inp[i]).collect();
        let mut backend = NativeBackend::new();
        let mut rng = Pcg64::from_seed(g.rng().next_u64());
        let phases = vec![priority.clone(), rest];
        let params = BoParams { max_iters: m, ..Default::default() };
        let mut oracle = |i: usize| costs[i];
        let out =
            run_search(&features, m, d, &phases, &mut oracle, &mut backend, &mut rng, &params)
                .map_err(|e| e.to_string())?;
        for (t, &idx) in out.tried.iter().enumerate() {
            if t < priority.len() {
                prop_assert!(
                    priority.contains(&idx),
                    "execution {t} ({idx}) escaped the priority phase"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_seed_determinism() {
    let space = SearchSpace::scout();
    let features = space.feature_matrix();
    let m = space.len();
    let d = ruya::searchspace::N_FEATURES;
    property("identical seeds give identical traces", 8, |g| {
        let costs = synth_costs(g, &space);
        let seed = g.rng().next_u64();
        let phases = vec![(0..m).collect::<Vec<_>>()];
        let params = BoParams { max_iters: 25, ..Default::default() };
        let mut run = || {
            let mut backend = NativeBackend::new();
            let mut rng = Pcg64::from_seed(seed);
            let mut oracle = |i: usize| costs[i];
            run_search(&features, m, d, &phases, &mut oracle, &mut backend, &mut rng, &params)
                .map_err(|e| e.to_string())
        };
        let a = run()?;
        let b = run()?;
        prop_assert!(a.tried == b.tried, "nondeterministic trace");
        Ok(())
    });
}
