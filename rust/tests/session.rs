//! Suspend/resume bit-identity pins for the session layer.
//!
//! The tentpole property: for **every** prefix length of a search driven
//! by a fuzz script's row pool, suspend -> serialize -> deserialize ->
//! resume must continue exactly as the uninterrupted run — same tried
//! indices, same cost bits, same stopping state, and a rewarmed backend
//! whose nll grids answer bit-identically to the never-suspended one.
//! Cutting at every round boundary (not just phase edges) is what rules
//! out "resume only works at nice points" regressions; the same corpus
//! also runs under the seeded `fuzz_parity` runner.

use ruya::bayesopt::{BoParams, GpBackend, NativeBackend, SearchCursor, SearchStep};
use ruya::coordinator::{replay_cursor, SessionState};
use ruya::testkit::random_scripts;
use ruya::util::rng::Pcg64;
use std::sync::Arc;

const CORPUS_SEED: u64 = 0x5E55_C0DE;

fn serial_backend() -> NativeBackend {
    let mut b = NativeBackend::new();
    b.set_parallelism(1);
    b
}

/// A two-phase plan over the script's row pool (priority = the first
/// third), so resumption crosses a phase boundary in most runs.
fn split_phases(m: usize) -> Vec<Vec<usize>> {
    let k = (m / 3).max(1);
    vec![(0..k).collect(), (k..m).collect()]
}

fn new_cursor(
    phases: &[Vec<usize>],
    m: usize,
    d: usize,
    seed: u64,
    params: BoParams,
) -> SearchCursor {
    SearchCursor::new(Arc::new(phases.to_vec()), m, d, Pcg64::from_seed(seed), params)
}

/// One engine-equivalent search step: a random-pick execution or one
/// full GP decision. Returns false once the search is over.
fn step_once(
    cursor: &mut SearchCursor,
    backend: &mut NativeBackend,
    features: &[f64],
    costs: &[f64],
) -> bool {
    match cursor.advance() {
        SearchStep::Done => false,
        SearchStep::Execute(i) => {
            cursor.record(i, costs[i], features);
            true
        }
        SearchStep::NeedsDecision => {
            match cursor.decide_with_backend(features, backend).expect("decide") {
                Some(pick) => {
                    cursor.record(pick, costs[pick], features);
                    true
                }
                None => false, // enforced stop
            }
        }
    }
}

fn run_to_end(
    cursor: &mut SearchCursor,
    backend: &mut NativeBackend,
    features: &[f64],
    costs: &[f64],
) {
    while step_once(cursor, backend, features, costs) {}
}

#[test]
fn every_prefix_suspends_and_resumes_bit_identically() {
    for (idx, script) in random_scripts(CORPUS_SEED, 6).iter().enumerate() {
        let m = script.pool_len();
        let d = script.dim();
        let features = script.rows();
        let costs = script.ys();
        let phases = split_phases(m);
        let params = BoParams { max_iters: m.min(10), ..Default::default() };
        let seed = 0xBED5 ^ (idx as u64).wrapping_mul(7919);

        let reference = {
            let mut cursor = new_cursor(&phases, m, d, seed, params);
            let mut backend = serial_backend();
            run_to_end(&mut cursor, &mut backend, features, costs);
            cursor.outcome()
        };

        for cut in script.cut_points() {
            let mut live = new_cursor(&phases, m, d, seed, params);
            let mut live_backend = serial_backend();
            for _ in 0..cut {
                if !step_once(&mut live, &mut live_backend, features, costs) {
                    break;
                }
            }

            let state = SessionState::capture("fuzz", seed, params, &phases, &live);
            let decoded = SessionState::decode(&state.encode())
                .unwrap_or_else(|e| panic!("script {idx} cut {cut}: decode failed: {e:#}"));
            assert_eq!(decoded.snapshot, state.snapshot, "script {idx} cut {cut}: lossy codec");

            let mut resumed_backend = serial_backend();
            let mut resumed = replay_cursor(&decoded, features, &mut resumed_backend)
                .unwrap_or_else(|e| panic!("script {idx} cut {cut}: resume failed: {e:#}"));
            assert_eq!(resumed.snapshot(), live.snapshot(), "script {idx} cut {cut}");

            run_to_end(&mut resumed, &mut resumed_backend, features, costs);
            run_to_end(&mut live, &mut live_backend, features, costs);

            let out = resumed.outcome();
            assert_eq!(out.tried, reference.tried, "script {idx} cut {cut}: picks diverged");
            assert_eq!(
                out.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
                reference.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
                "script {idx} cut {cut}: cost bits diverged"
            );
            assert_eq!(out.stop_after, reference.stop_after, "script {idx} cut {cut}");
            assert_eq!(out.phase_starts, reference.phase_starts, "script {idx} cut {cut}");

            // The replay-rewarmed caches must answer like the live ones:
            // probe the final window's nll grid on both backends, bit
            // for bit. (Probing after completion so the probe itself
            // cannot perturb either run.)
            let (skip, n) = live.window(live_backend.max_obs());
            let grid = live.grid();
            let a = live_backend
                .nll_grid(live.x_window(skip), live.y_window(skip), n, d, grid)
                .expect("live nll");
            let b = resumed_backend
                .nll_grid(resumed.x_window(skip), resumed.y_window(skip), n, d, grid)
                .expect("resumed nll");
            for (g, (va, vb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "script {idx} cut {cut}: nll[{g}] diverged after resume"
                );
            }
        }
    }
}

#[test]
fn finished_searches_resume_as_finished() {
    // Suspending *after* the end (plan exhausted, max_iters, or an
    // enforced stop) must round-trip too: replay performs the finishing
    // advance and the resumed cursor reports done with the same trace.
    for (idx, script) in random_scripts(CORPUS_SEED ^ 0xF00D, 4).iter().enumerate() {
        let m = script.pool_len();
        let d = script.dim();
        let features = script.rows();
        let costs = script.ys();
        let phases = split_phases(m);
        for params in [
            BoParams { max_iters: m.min(9), ..Default::default() },
            BoParams { max_iters: m, enforce_stop: true, ..Default::default() },
        ] {
            let seed = 0xF14A ^ idx as u64;
            let mut cursor = new_cursor(&phases, m, d, seed, params);
            let mut backend = serial_backend();
            run_to_end(&mut cursor, &mut backend, features, costs);
            assert!(cursor.is_done(), "script {idx}: run_to_end left the search open");

            let state = SessionState::capture("fuzz", seed, params, &phases, &cursor);
            let decoded = SessionState::decode(&state.encode()).expect("decode");
            let mut rb = serial_backend();
            let resumed = replay_cursor(&decoded, features, &mut rb)
                .unwrap_or_else(|e| panic!("script {idx}: finished resume failed: {e:#}"));
            assert_eq!(resumed.is_done(), cursor.is_done(), "script {idx}");
            assert_eq!(resumed.outcome().tried, cursor.outcome().tried, "script {idx}");
            assert_eq!(resumed.outcome().stop_after, cursor.outcome().stop_after);
        }
    }
}
