//! Property tests for the large-search-space subsystem: the Nyström
//! low-rank posterior (variance bounds, exact-equality reduction), the
//! deterministic farthest-point inducing selection, and the generated
//! cloud-catalog generator — plus the testkit parity pins of
//! low-rank-vs-exact on both the `inducing = full set` and the
//! tolerance-bounded large-space case.

use ruya::bayesopt::gp::NativeGp;
use ruya::bayesopt::{
    farthest_point_sample, hyperparameter_grid, InducingCache, LowRankGp, LowRankPolicy,
    NativeBackend, DEFAULT_MAX_INDUCING, INDUCING_DRIFT_LIMIT,
};
use ruya::prop_assert;
use ruya::searchspace::{SearchSpace, N_FEATURES};
use ruya::testkit::{assert_backend_parity, property, ParityScript};

/// A smooth synthetic cost surface over encoded features — the kind of
/// landscape the cluster simulator produces (gentle trends plus a mild
/// nonlinearity), so marginal likelihood favors moderate lengthscales.
fn smooth_cost(f: &[f64]) -> f64 {
    1.0 + f[0] + 0.5 * f[3] + 0.3 * (2.0 * (f[1] + f[4])).sin()
}

fn obs_from_space(space: &SearchSpace, idx: &[usize]) -> (Vec<f64>, Vec<f64>) {
    let d = N_FEATURES;
    let feats = space.feature_matrix();
    let mut x = Vec::with_capacity(idx.len() * d);
    let mut y = Vec::with_capacity(idx.len());
    for &i in idx {
        let row = &feats[i * d..(i + 1) * d];
        x.extend_from_slice(row);
        y.push(smooth_cost(row));
    }
    (x, y)
}

#[test]
fn prop_nystrom_variance_never_negative_nor_above_prior() {
    property("nystrom predictive variance stays in [0, prior]", 25, |g| {
        let n_cfg = g.usize_in(60, 300);
        let seed = g.rng().next_u64();
        let space = SearchSpace::generated(seed, n_cfg);
        let n_obs = g.usize_in(5, 60).min(n_cfg);
        let obs_idx = g.subset(n_cfg, n_obs);
        let (x, mut y) = obs_from_space(&space, &obs_idx);
        // Mild multiplicative noise so targets are not an exact smooth
        // function of the features.
        for v in y.iter_mut() {
            *v *= g.f64_in(0.95, 1.05);
        }
        let hyp = [g.f64_in(0.1, 2.0), g.f64_in(0.5, 3.0), g.f64_in(1e-4, 1e-1)];
        let max_u = g.usize_in(2, 32);
        let mut lr = LowRankGp::new();
        prop_assert!(
            lr.fit(&x, &y, n_obs, N_FEATURES, hyp, max_u),
            "low-rank fit failed (n={n_obs}, u<={max_u}, hyp={hyp:?})"
        );
        prop_assert!(
            lr.inducing_count() <= max_u.min(n_obs),
            "inducing count {} above cap {max_u}",
            lr.inducing_count()
        );
        let feats = space.feature_matrix();
        let (mut mu, mut var) = (Vec::new(), Vec::new());
        lr.predict_batch(&feats, n_cfg, &mut mu, &mut var);
        for j in 0..n_cfg {
            prop_assert!(mu[j].is_finite(), "non-finite mean at {j}");
            prop_assert!(var[j] >= 0.0, "negative variance {} at {j}", var[j]);
            prop_assert!(
                var[j] <= hyp[1] + 1e-9,
                "variance {} above prior {} at {j}",
                var[j],
                hyp[1]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fps_deterministic_and_candidate_order_invariant() {
    property("farthest-point selection is a function of the row set", 20, |g| {
        let n_cfg = g.usize_in(40, 250);
        let seed = g.rng().next_u64();
        let space = SearchSpace::generated(seed, n_cfg);
        let feats = space.feature_matrix();
        let d = N_FEATURES;
        let k = g.usize_in(2, 24);
        let a = farthest_point_sample(&feats, n_cfg, d, k);
        let b = farthest_point_sample(&feats, n_cfg, d, k);
        prop_assert!(a == b, "fps not deterministic: {a:?} vs {b:?}");
        // Permute the candidate order; the selected *row set* must not
        // change (indices may).
        let mut perm: Vec<usize> = (0..n_cfg).collect();
        g.rng().shuffle(&mut perm);
        let mut permuted = Vec::with_capacity(n_cfg * d);
        for &p in &perm {
            permuted.extend_from_slice(&feats[p * d..(p + 1) * d]);
        }
        let c = farthest_point_sample(&permuted, n_cfg, d, k);
        let row_set = |sel: &[usize], f: &[f64]| -> Vec<Vec<u64>> {
            let mut rows: Vec<Vec<u64>> = sel
                .iter()
                .map(|&i| f[i * d..(i + 1) * d].iter().map(|v| v.to_bits()).collect())
                .collect();
            rows.sort();
            rows
        };
        prop_assert!(
            row_set(&a, &feats) == row_set(&c, &permuted),
            "fps row set changed under candidate permutation (k={k}, n={n_cfg})"
        );
        Ok(())
    });
}

#[test]
fn prop_generated_catalog_exact_len_distinct_stable() {
    property("generated catalogs: exact n, distinct, seed-stable", 15, |g| {
        let n = g.usize_in(1, 800);
        let seed = g.rng().next_u64();
        let s1 = SearchSpace::generated(seed, n);
        prop_assert!(s1.len() == n, "len {} != requested {n}", s1.len());
        let s2 = SearchSpace::generated(seed, n);
        prop_assert!(s1.configs() == s2.configs(), "same seed produced different catalogs");
        let mut seen = std::collections::HashSet::new();
        for c in s1.configs() {
            prop_assert!(
                seen.insert((c.machine, c.nodes)),
                "duplicate config {} in generated catalog",
                c.name()
            );
            prop_assert!(c.usable_memory_gb() > 0.0, "non-positive usable memory");
        }
        Ok(())
    });
}

/// Exact-equality pin: with the inducing set forced to the full
/// observation set, the low-rank backend must match the exact backend to
/// tight tolerance over a whole append/slide script (the `Z = X`
/// reduction in `lowrank`'s module docs).
#[test]
fn parity_lowrank_full_inducing_equals_exact() {
    let space = SearchSpace::generated(42, 120);
    let d = N_FEATURES;
    let pool = 14;
    let idx: Vec<usize> = (0..pool).collect();
    let (rows, ys) = obs_from_space(&space, &idx);
    let script = ParityScript::new(rows, ys, d).growth(10).slides(10, pool - 10);
    let feats = space.feature_matrix();
    let mut exact = NativeBackend::new();
    exact.set_lowrank_policy(LowRankPolicy::Off);
    let mut lowrank = NativeBackend::new();
    lowrank.set_lowrank_policy(LowRankPolicy::Force { max_inducing: usize::MAX });
    let report = assert_backend_parity(
        &mut exact,
        &mut lowrank,
        &script,
        &feats,
        space.len(),
        &hyperparameter_grid(),
        1e-5,
    );
    assert_eq!(report.steps, pool);
    assert_eq!(
        lowrank.decide_stats().lowrank,
        pool as u64,
        "forced policy must keep every decide on the low-rank path"
    );
}

/// Tolerance-bounded large-space pin: a genuine approximation regime
/// (80 observations, 32 inducing points, 1500 candidates). The DTC
/// variance is conservative by construction, so the bound is loose; the
/// lengthscale grid is restricted to the smooth regime marginal
/// likelihood would pick on these targets anyway, keeping the bound
/// meaningful.
#[test]
fn parity_lowrank_large_space_within_tolerance() {
    let space = SearchSpace::generated(7, 1500);
    let d = N_FEATURES;
    let pool = 80;
    // Observations spread evenly across the catalog.
    let idx: Vec<usize> = (0..pool).map(|i| i * space.len() / pool).collect();
    let (rows, ys) = obs_from_space(&space, &idx);
    let script = ParityScript::new(rows, ys, d)
        .push_window(0, 40)
        .push_window(0, 60)
        .push_window(0, 80);
    let feats = space.feature_matrix();
    let grid = [[1.5, 1.0, 1e-2], [2.0, 1.0, 1e-2]];
    let mut exact = NativeBackend::new();
    exact.set_lowrank_policy(LowRankPolicy::Off);
    let mut lowrank = NativeBackend::new();
    lowrank.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 32 });
    let report = assert_backend_parity(
        &mut exact,
        &mut lowrank,
        &script,
        &feats,
        space.len(),
        &grid,
        0.5,
    );
    assert_eq!(report.steps, 3);
    assert_eq!(lowrank.decide_stats().lowrank, 3);
    // The mean must be far tighter than the conservative variance bound.
    assert!(report.max_mu_err <= 0.2, "mean drifted: {report:?}");
}

/// Stage-split pin: the backend's grouped low-rank `nll_grid` (one
/// hyperparameter stage per (lengthscale, variance) group, one noise
/// stage per grid point) must be **bit-identical** to the unsplit
/// per-point evaluation (`fit_with_inducing` + `nll` per grid slot)
/// across the full 32-slot grid — and the stage counters must show the
/// ~4x kernel/GEMM saving actually happened (8 hyperparameter builds,
/// not 32).
#[test]
fn stage_split_nll_grid_bit_identical_to_per_point() {
    let space = SearchSpace::generated(23, 200);
    let d = N_FEATURES;
    let n = 40;
    let idx: Vec<usize> = (0..n).collect();
    let (x, y) = obs_from_space(&space, &idx);
    let grid = hyperparameter_grid();
    assert_eq!(grid.len(), 32, "the pin assumes the 32-slot selection grid");

    let mut b = NativeBackend::new();
    b.set_lowrank_nll_threshold(16); // route the 40-observation sweep low-rank
    let nll = b.nll_grid(&x, &y, n, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.nll_lowrank, 1, "sweep not routed low-rank: {s:?}");
    assert_eq!(
        s.lowrank_hyp_stage_builds, 8,
        "stage split must build Kuu/B once per (ls, var) group: {s:?}"
    );
    assert_eq!(s.lowrank_noise_stage_builds, 32, "one noise stage per slot: {s:?}");
    assert_eq!(s.fps_full_refreshes, 1, "first sweep selects inducing in full: {s:?}");

    // Unsplit baseline over the identical inducing set (the first
    // refresh is exactly scratch FPS at the backend's cap).
    let inducing = farthest_point_sample(&x, n, d, DEFAULT_MAX_INDUCING);
    let mut lr = LowRankGp::new();
    for (g, &hyp) in grid.iter().enumerate() {
        assert!(
            lr.fit_with_inducing(&x, &y, n, d, hyp, &inducing),
            "baseline fit failed at grid point {g}"
        );
        assert_eq!(
            nll[g].to_bits(),
            lr.nll(&y).to_bits(),
            "nll[{g}] bits diverged from the per-point evaluation: {} vs {}",
            nll[g],
            lr.nll(&y)
        );
    }
}

/// Incremental-FPS pin at the backend level: across an append sequence
/// the cached selection refreshes incrementally (counted), stays a valid
/// distinct subset, and — immediately after any full re-selection —
/// equals scratch FPS on the current window exactly. The drift bound
/// [`INDUCING_DRIFT_LIMIT`] is pinned separately in `lowrank`'s unit
/// tests; this covers the property over random append/slide/replace
/// programs against catalog-shaped rows.
#[test]
fn prop_incremental_inducing_refresh_stays_valid_and_resyncs() {
    property("incremental inducing refresh: valid between, scratch at resync", 15, |g| {
        let d = N_FEATURES;
        let n_cfg = g.usize_in(80, 200);
        let space = SearchSpace::generated(g.rng().next_u64(), n_cfg);
        let feats = space.feature_matrix();
        let pool = g.usize_in(30, 60).min(n_cfg);
        let k = g.usize_in(2, 16);
        let mut cache = InducingCache::new();
        let (mut start, mut n) = (0usize, g.usize_in(3, 8));
        let mut incrementals = 0usize;
        let mut incremental_deltas = 0usize;
        let mut first = true;
        for _ in 0..g.usize_in(8, 20) {
            // Random walk over append / slide / replace windows.
            let prev = (start, n);
            match g.usize_in(0, 3) {
                0 | 1 if start + n < pool => n += 1,
                2 if start + n < pool => start += 1,
                _ => {
                    n = g.usize_in(1, pool);
                    start = g.usize_in(0, pool - n);
                }
            }
            let is_incremental_shape = !first
                && ((start, n) == prev                      // unchanged
                    || (start == prev.0 && n == prev.1 + 1) // append
                    || (start == prev.0 + 1 && n == prev.1)); // slide
            if is_incremental_shape {
                incremental_deltas += 1;
            }
            first = false;
            let x = &feats[start * d..(start + n) * d];
            let (sel, full) = cache.refresh(x, n, d, k);
            prop_assert!(sel.len() <= k.min(n), "selection above cap: {} > {}", sel.len(), k);
            prop_assert!(!sel.is_empty(), "empty selection at n={n}");
            prop_assert!(sel.iter().all(|&i| i < n), "index out of window: {sel:?}");
            let mut uniq = sel.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            prop_assert!(uniq.len() == sel.len(), "duplicate inducing index: {sel:?}");
            if full {
                let scratch = farthest_point_sample(x, n, d, k);
                prop_assert!(
                    sel == &scratch[..],
                    "full refresh diverged from scratch FPS: {sel:?} vs {scratch:?}"
                );
            } else {
                incrementals += 1;
            }
            prop_assert!(
                cache.drift() <= INDUCING_DRIFT_LIMIT,
                "drift {} past the documented bound",
                cache.drift()
            );
        }
        // Every append/slide/unchanged transition within the drift bound
        // must have been served incrementally (the walk stays far under
        // INDUCING_DRIFT_LIMIT, so none may fall back to a re-select).
        prop_assert!(
            incrementals == incremental_deltas,
            "incremental refreshes {incrementals} != incremental deltas {incremental_deltas}"
        );
        Ok(())
    });
}

/// Exact-equality pin for the Woodbury *marginal likelihood*: at
/// `Z = X` (`u = n`) the DTC log-det and quadratic form reduce
/// algebraically to the exact ones (`lowrank::nll` module docs), so
/// `LowRankGp::nll` must match `NativeGp::nll` up to the
/// `INDUCING_JITTER` perturbation — across lengthscales and the grid's
/// noise range.
#[test]
fn lowrank_nll_full_inducing_matches_exact() {
    let space = SearchSpace::generated(11, 200);
    let d = N_FEATURES;
    let n = 16;
    let idx: Vec<usize> = (0..n).collect();
    let (x, y) = obs_from_space(&space, &idx);
    for hyp in [[0.5, 1.0, 1e-3], [1.0, 1.0, 1e-2], [2.0, 1.0, 1e-1]] {
        let mut exact = NativeGp::new();
        assert!(exact.fit(&x, &y, n, d, hyp), "exact fit failed for {hyp:?}");
        let nll_e = exact.nll(&y);
        let mut lr = LowRankGp::new();
        assert!(lr.fit(&x, &y, n, d, hyp, n), "low-rank fit failed for {hyp:?}");
        assert_eq!(lr.inducing_count(), n, "FPS must select the full set");
        let nll_l = lr.nll(&y);
        assert!(
            (nll_l - nll_e).abs() <= 1e-4 * nll_e.abs().max(1.0),
            "hyp {hyp:?}: lowrank nll {nll_l} vs exact {nll_e}"
        );
    }
}

/// Tolerance-bounded pin of the low-rank marginal in its genuine
/// approximation regime — the observation scale `nll_grid`'s low-rank
/// routing exists for: 1500 observations against 64 inducing points,
/// smooth targets, smooth lengthscale. The DTC marginal is a surrogate,
/// not the exact value, so the bound is loose; hyperparameter selection
/// only compares it across grid points.
#[test]
fn lowrank_nll_tolerance_bounded_at_1500_obs() {
    let space = SearchSpace::generated(19, 1500);
    let d = N_FEATURES;
    let n = 1500;
    let idx: Vec<usize> = (0..n).collect();
    let (x, y) = obs_from_space(&space, &idx);
    let hyp = [1.5, 1.0, 1e-1];
    let mut exact = NativeGp::new();
    assert!(exact.fit(&x, &y, n, d, hyp), "exact dense fit failed at n=1500");
    let nll_e = exact.nll(&y);
    let mut lr = LowRankGp::new();
    assert!(lr.fit(&x, &y, n, d, hyp, 64), "low-rank fit failed at n=1500");
    assert!(lr.inducing_count() <= 64);
    let nll_l = lr.nll(&y);
    assert!(nll_e.is_finite() && nll_l.is_finite(), "{nll_l} vs {nll_e}");
    let rel = (nll_l - nll_e).abs() / nll_e.abs().max(nll_l.abs()).max(1.0);
    assert!(
        rel <= 0.5,
        "lowrank marginal drifted at n=1500: {nll_l} vs exact {nll_e} (rel {rel:.3})"
    );
}
