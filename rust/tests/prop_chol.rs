//! Property tests for the packed lower-triangular `CholFactor` layout:
//! packed-vs-dense equality of the cold factorization and every solve
//! (bit-for-bit — the packed code runs the same arithmetic in the same
//! order, only the addressing differs), tolerance-bounded tracking of
//! random append/slide sequences against dense scratch refits, and the
//! `APPEND_PIVOT_RTOL` fallback path resyncing to dense scratch bits.

use ruya::bayesopt::chol::packed_row_start;
use ruya::bayesopt::gp::{
    cholesky_in_place, matern52, solve_lower_in_place, solve_upper_t_in_place,
};
use ruya::bayesopt::CholFactor;
use ruya::prop_assert;
use ruya::testkit::property;

/// Noiseless Matérn-5/2 Gram (unit variance) of `rows[start..end)`.
fn window_gram(rows: &[f64], d: usize, start: usize, end: usize, ls: f64) -> Vec<f64> {
    let n = end - start;
    let mut k = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            k[i * n + j] = matern52(
                &rows[(start + i) * d..(start + i + 1) * d],
                &rows[(start + j) * d..(start + j + 1) * d],
                ls,
                1.0,
            );
        }
    }
    k
}

#[test]
fn prop_packed_cold_path_matches_dense_bits() {
    property("packed refactorize/solves == dense cholesky bits", 60, |g| {
        let n = g.usize_in(1, 24);
        let d = g.usize_in(1, 6);
        let rows = g.vec_f64(n * d, 0.0, 1.0);
        let ls = g.f64_in(0.1, 2.0);
        let noise = g.f64_in(1e-6, 1e-1);
        let gram = window_gram(&rows, d, 0, n, ls);

        // Dense reference: gram + noise I through the dense kernel.
        let mut dense = gram.clone();
        for i in 0..n {
            dense[i * n + i] += noise;
        }
        prop_assert!(cholesky_in_place(&mut dense, n), "dense factorization failed");

        let mut f = CholFactor::new();
        prop_assert!(f.refactorize(&gram, n, noise), "packed factorization failed");
        prop_assert!(
            f.packed().len() == packed_row_start(n),
            "packed length {} != n(n+1)/2 = {}",
            f.packed().len(),
            n * (n + 1) / 2
        );
        for i in 0..n {
            for j in 0..=i {
                prop_assert!(
                    f.at(i, j).to_bits() == dense[i * n + j].to_bits(),
                    "L[{i},{j}]: packed {} vs dense {}",
                    f.at(i, j),
                    dense[i * n + j]
                );
            }
        }

        // to_dense round-trips (upper triangle exactly zero).
        let mut back = Vec::new();
        f.to_dense(&mut back);
        for i in 0..n {
            for j in 0..n {
                prop_assert!(
                    back[i * n + j].to_bits() == dense[i * n + j].to_bits(),
                    "to_dense[{i},{j}] diverged"
                );
            }
        }

        // Forward solve, full solve and the log-det fold all agree to
        // the bit with their dense counterparts.
        let y = g.vec_f64(n, -2.0, 2.0);
        let mut z_p = y.clone();
        f.forward_solve(&mut z_p);
        let mut z_d = y.clone();
        solve_lower_in_place(&dense, n, &mut z_d);
        for i in 0..n {
            prop_assert!(z_p[i].to_bits() == z_d[i].to_bits(), "forward_solve[{i}] diverged");
        }
        let mut a_p = Vec::new();
        f.solve_into(&y, &mut a_p);
        let mut a_d = y.clone();
        solve_lower_in_place(&dense, n, &mut a_d);
        solve_upper_t_in_place(&dense, n, &mut a_d);
        for i in 0..n {
            prop_assert!(a_p[i].to_bits() == a_d[i].to_bits(), "solve_into[{i}] diverged");
        }
        let sld_dense: f64 = (0..n).map(|i| dense[i * n + i].ln()).sum();
        prop_assert!(
            f.sum_log_diag().to_bits() == sld_dense.to_bits(),
            "sum_log_diag diverged: {} vs {}",
            f.sum_log_diag(),
            sld_dense
        );
        Ok(())
    });
}

#[test]
fn prop_packed_sequences_track_dense_scratch() {
    property("append/slide sequences track dense scratch refits", 25, |g| {
        let d = g.usize_in(1, 5);
        let total = g.usize_in(4, 20);
        let rows = g.vec_f64(total * d, 0.0, 1.0);
        let ls = g.f64_in(0.2, 1.5);
        let noise = g.f64_in(1e-6, 1e-2);
        let diag = 1.0 + noise; // unit signal variance + noise

        let mut f = CholFactor::new();
        prop_assert!(f.append(&[], diag), "seed append failed");
        let (mut start, mut end) = (0usize, 1usize);
        while end < total {
            let slide = end - start > 1 && g.bool();
            if slide {
                f.drop_first();
                start += 1;
            }
            let new = end;
            let row: Vec<f64> = (start..new)
                .map(|j| {
                    matern52(
                        &rows[new * d..(new + 1) * d],
                        &rows[j * d..(j + 1) * d],
                        ls,
                        1.0,
                    )
                })
                .collect();
            prop_assert!(
                f.append(&row, diag),
                "append failed at window [{start},{}] (well-conditioned Gram)",
                new + 1
            );
            end += 1;

            // Dense scratch reference over the same window.
            let n = end - start;
            let mut dense = window_gram(&rows, d, start, end, ls);
            for i in 0..n {
                dense[i * n + i] += noise;
            }
            prop_assert!(cholesky_in_place(&mut dense, n), "dense scratch failed");
            for i in 0..n {
                for j in 0..=i {
                    let (a, b) = (f.at(i, j), dense[i * n + j]);
                    prop_assert!(
                        (a - b).abs() <= 1e-8 * a.abs().max(b.abs()).max(1.0),
                        "L[{i},{j}] diverged at window [{start},{end}): {a} vs {b}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn packed_fallback_resyncs_to_dense_bits() {
    // An exactly duplicated row with zero noise drives the append pivot
    // to ~0 — below APPEND_PIVOT_RTOL * diag — so the append must refuse
    // (leaving the factor untouched), and the documented cold
    // refactorization must then land on exactly the dense scratch bits.
    let d = 2;
    let ls = 0.7;
    let rows = [0.2, 0.4, 0.9, 0.1, 0.2, 0.4]; // row 2 duplicates row 0
    let mut f = CholFactor::new();
    assert!(f.append(&[], 1.0));
    let r1 = [matern52(&rows[2..4], &rows[0..2], ls, 1.0)];
    assert!(f.append(&r1, 1.0));
    let before = f.packed().to_vec();
    let r2: Vec<f64> = (0..2)
        .map(|j| matern52(&rows[4..6], &rows[j * d..(j + 1) * d], ls, 1.0))
        .collect();
    assert!(
        !f.append(&r2, 1.0),
        "duplicate row with zero noise must trip the pivot guard"
    );
    assert_eq!(f.n(), 2, "failed append must leave the factor untouched");
    assert_eq!(f.packed(), &before[..]);

    // Cold resync with a jitter that makes the bordered Gram SPD: the
    // packed factorization must equal the dense one bit-for-bit.
    let n = 3;
    let jit = 1e-6;
    let gram = window_gram(&rows, d, 0, n, ls);
    assert!(f.refactorize(&gram, n, jit), "cold fallback failed");
    let mut dense = gram;
    for i in 0..n {
        dense[i * n + i] += jit;
    }
    assert!(cholesky_in_place(&mut dense, n));
    for i in 0..n {
        for j in 0..=i {
            assert_eq!(
                f.at(i, j).to_bits(),
                dense[i * n + j].to_bits(),
                "fallback L[{i},{j}] not bit-identical to dense scratch"
            );
        }
    }
}
