//! Concurrency parity suite for the GP worker pool (`--gp-threads`):
//! serial-vs-threaded backends must be **bit-identical** — nll grids,
//! posteriors, EI scores and the chosen argmax — across the append,
//! slide and replace deltas of the factor cache, across the decide tile
//! fan-out, and across the low-rank nll routing; and a seeded search at
//! 8 GP threads must be perfectly repeatable run after run (the
//! loom-free determinism stress test that catches nondeterministic
//! reductions in CI).

use ruya::bayesopt::{
    hyperparameter_grid, run_search, BoParams, GpBackend, LowRankPolicy, NativeBackend,
    DECIDE_TILE,
};
use ruya::testkit::{assert_parallel_parity, assert_shared_pool_parity, ParityScript};
use ruya::util::rng::Pcg64;

/// The threaded lanes every parity test compares against the serial one.
const GP_THREADS: [usize; 3] = [2, 4, 8];

fn synth_rows(n: usize, d: usize, salt: usize) -> Vec<f64> {
    (0..n * d).map(|i| ((i * 29 + salt) % 97) as f64 / 97.0).collect()
}

#[test]
fn parallel_parity_append_slide_replace() {
    // Growth (append), window slides, a wholesale window jump (replace)
    // and a full-pool reload: every FitPlan family of the factor cache
    // runs under the worker pool and must match the serial bits.
    let d = 4;
    let total = 14;
    let rows = synth_rows(total, d, 7);
    let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.41).sin()).collect();
    let script = ParityScript::new(rows, ys, d)
        .growth(9)
        .slides(9, total - 9)
        .push_window(2, 7) // replace: arbitrary window jump
        .push_window(0, total);
    let m = 24;
    let xc = synth_rows(m, d, 13);
    // Floor lowered so the persistent pool engages on these scout-scale
    // windows (the default GP_POOL_MIN_OBS would keep them serial).
    let make = || {
        let mut b = NativeBackend::new();
        b.set_pool_min_obs(0);
        b
    };
    assert_parallel_parity(&make, &GP_THREADS, &script, &xc, m, &hyperparameter_grid());
}

#[test]
fn parallel_parity_scratch_baseline() {
    // The cold-only scratch baseline (set_incremental(false)) sweeps the
    // same worker pool: every slot refactorizes cold on every step, and
    // the threaded sweep must still match the serial bits.
    let d = 3;
    let total = 8;
    let rows = synth_rows(total, d, 31);
    let ys: Vec<f64> = (0..total).map(|i| (i as f64 * 0.53).cos()).collect();
    let script = ParityScript::new(rows, ys, d).growth(total);
    let m = 10;
    let xc = synth_rows(m, d, 17);
    let make = || {
        let mut b = NativeBackend::new();
        b.set_incremental(false);
        b.set_pool_min_obs(0);
        b
    };
    assert_parallel_parity(&make, &GP_THREADS, &script, &xc, m, &hyperparameter_grid());
}

#[test]
fn parallel_parity_across_decide_tiles() {
    // A candidate set spanning three DECIDE_TILE chunks so the decide
    // fan-out genuinely engages (and its tile seams sit inside the
    // compared range), on top of the threaded nll sweep.
    let d = 3;
    let total = 10;
    let rows = synth_rows(total, d, 3);
    let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.37).sin()).collect();
    let script = ParityScript::new(rows.clone(), ys.clone(), d).growth(7).slides(7, 3);
    let m = DECIDE_TILE * 2 + 31;
    let xc = synth_rows(m, d, 5);
    let make = || {
        let mut b = NativeBackend::new();
        b.set_lowrank_policy(LowRankPolicy::Off);
        b.set_pool_min_obs(0); // these 7..10-observation windows sit under the floor
        b
    };
    assert_parallel_parity(&make, &GP_THREADS, &script, &xc, m, &hyperparameter_grid());

    // The guarded engagement check: at this shape a threaded backend
    // must actually take both parallel paths.
    let mut b = make();
    b.set_parallelism(4);
    let grid = hyperparameter_grid();
    let n = 7;
    let x = &rows[..n * d];
    let y = &ys[..n];
    let nll = b.nll_grid(x, y, n, d, &grid).unwrap();
    let best = (0..grid.len()).min_by(|&a, &c| nll[a].partial_cmp(&nll[c]).unwrap()).unwrap();
    b.decide(x, y, n, d, &xc, &vec![true; m], m, grid[best]).unwrap();
    let s = b.decide_stats();
    assert!(s.parallel_nll_sweeps > 0, "worker-pool nll sweep never engaged: {s:?}");
    assert!(s.parallel_decide_fanouts > 0, "decide tile fan-out never engaged: {s:?}");
}

#[test]
fn parallel_parity_lowrank_nll_routing() {
    // Past the (lowered) observation threshold nll_grid routes to the
    // Woodbury low-rank marginal, whose grid points fan across the same
    // pool — per-point pure computations, so threaded results must match
    // serial bits exactly, through the routing boundary and beyond.
    let d = 3;
    let total = 30;
    let threshold = 24;
    let rows = synth_rows(total, d, 41);
    let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.29).sin()).collect();
    let script = ParityScript::new(rows, ys, d).growth(total); // crosses n = threshold
    let m = 12;
    let xc = synth_rows(m, d, 23);
    let make = move || {
        let mut b = NativeBackend::new();
        b.set_lowrank_nll_threshold(threshold);
        b.set_pool_min_obs(0); // pool engages on both sides of the routing boundary
        b
    };
    assert_parallel_parity(&make, &GP_THREADS, &script, &xc, m, &hyperparameter_grid());
    // Routing must have actually crossed into the low-rank marginal.
    let mut b = make();
    b.set_parallelism(4);
    let grid = hyperparameter_grid();
    let rows2 = synth_rows(total, d, 41);
    let ys2: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.29).sin()).collect();
    b.nll_grid(&rows2, &ys2, total, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.nll_lowrank, 1, "low-rank nll routing never engaged: {s:?}");
}

#[test]
fn concurrent_backends_on_the_shared_pool_match_serial_bits() {
    // The tentpole contract of the process-global pool: N backends on N
    // OS threads, all fanning out over the SAME worker lanes at the
    // same time, must each produce the exact bits of a lone serial
    // backend. Cross-backend interference of any kind — shared scratch
    // not reset between epochs, a lane mixing two fan-outs' outputs, a
    // reduction ordered by arrival time — would flip bits here.
    let d = 4;
    let total = 14;
    let rows = synth_rows(total, d, 19);
    let ys: Vec<f64> = (0..total).map(|i| 1.0 + (i as f64 * 0.47).sin()).collect();
    let script = ParityScript::new(rows, ys, d)
        .growth(9)
        .slides(9, total - 9)
        .push_window(1, 8) // replace delta under concurrency too
        .push_window(0, total);
    // Candidates spanning tile seams so the decide fan-out engages.
    let m = DECIDE_TILE + 57;
    let xc = synth_rows(m, d, 29);
    let make = || {
        let mut b = NativeBackend::new();
        b.set_pool_min_obs(0); // scout-scale windows must engage the pool
        b
    };
    // More concurrent backends than pool lanes, twice, so lanes are
    // certainly reused across epochs mid-flight.
    for _round in 0..2 {
        assert_shared_pool_parity(&make, 6, 4, &script, &xc, m, &hyperparameter_grid());
    }
}

/// Smooth synthetic search space in the style of the search-loop tests:
/// a 1-D bowl embedded in 6 features, optimum near t = 0.62.
fn toy_space(m: usize) -> (Vec<f64>, Vec<f64>) {
    let d = 6;
    let mut features = Vec::with_capacity(m * d);
    let mut costs = Vec::with_capacity(m);
    for i in 0..m {
        let t = i as f64 / (m - 1) as f64;
        features.extend_from_slice(&[t, 1.0 - t, t * t, 0.5, (3.0 * t).sin() * 0.5 + 0.5, t]);
        costs.push(1.0 + 8.0 * (t - 0.62) * (t - 0.62));
    }
    (features, costs)
}

#[test]
fn threaded_search_is_perfectly_repeatable() {
    // The determinism stress test: the same seeded search 20 times at
    // --gp-threads 8 over a multi-tile candidate space. Any
    // nondeterministic reduction in the pool would perturb EI bits and
    // eventually flip an argmax, forking the iteration trace.
    let d = 6;
    let m = DECIDE_TILE + 289; // two decide tiles
    let (features, costs) = toy_space(m);
    let phases = vec![(0..m).collect::<Vec<usize>>()];
    let params = BoParams { max_iters: 24, ..Default::default() };
    let mut reference: Option<(Vec<usize>, Vec<f64>)> = None;
    for run in 0..20 {
        let mut backend = NativeBackend::new();
        backend.set_parallelism(8);
        let mut rng = Pcg64::from_seed(0xD15EA5E);
        let mut oracle = |i: usize| costs[i];
        let out = run_search(
            &features,
            m,
            d,
            &phases,
            &mut oracle,
            &mut backend,
            &mut rng,
            &params,
        )
        .expect("threaded search");
        assert_eq!(out.tried.len(), params.max_iters);
        let s = backend.decide_stats();
        // The search grows its history past GP_POOL_MIN_OBS, so both
        // fan-outs must engage under the default serial floor — and the
        // backend must have attached to the process-global pool exactly
        // once and reused it for every later fan-out (whether it also
        // *spawned* the pool depends on which test in this binary got
        // there first, so only an upper bound is pinned).
        assert!(s.parallel_nll_sweeps > 0, "run {run}: nll sweep never threaded: {s:?}");
        assert!(s.parallel_decide_fanouts > 0, "run {run}: tile fan-out never engaged: {s:?}");
        assert_eq!(s.global_pool_attach, 1, "run {run}: never attached to the pool: {s:?}");
        assert!(s.pool_creates <= 1, "run {run}: pool spawned more than once: {s:?}");
        assert_eq!(
            s.pool_reuses + 1,
            s.parallel_nll_sweeps + s.parallel_decide_fanouts,
            "run {run}: some fan-out skipped the persistent pool: {s:?}"
        );
        assert!(s.serial_floor_bypasses > 0, "run {run}: small-n floor never applied: {s:?}");
        match &reference {
            None => reference = Some((out.tried.clone(), out.costs.clone())),
            Some((tried, ref_costs)) => {
                assert_eq!(&out.tried, tried, "iteration trace diverged on run {run}");
                for (i, (a, b)) in out.costs.iter().zip(ref_costs).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "cost[{i}] bits diverged on run {run}"
                    );
                }
            }
        }
    }
}
