//! Property-based tests on the Gaussian-process layer: posterior
//! well-posedness, EI soundness, agreement between the native GP and
//! first principles, and equivalence of the incremental (rank-1
//! append/slide) factorization paths with from-scratch refits.

use ruya::bayesopt::gp::{
    cholesky_in_place, expected_improvement, matern52, solve_lower_in_place,
    solve_upper_t_in_place, standardize, NativeGp,
};
use ruya::bayesopt::{hyperparameter_grid, NativeBackend};
use ruya::prop_assert;
use ruya::testkit::{property, Gen};

/// Relative tolerance pinning incremental posteriors to scratch refits
/// (the ISSUE acceptance bound; the observed error is ~1e-14).
const INC_RTOL: f64 = 1e-9;

fn random_points(g: &mut Gen, n: usize, d: usize) -> Vec<f64> {
    g.vec_f64(n * d, 0.0, 1.0)
}

#[test]
fn prop_kernel_bounds_and_symmetry() {
    property("matern52 is symmetric, positive, bounded by variance", 200, |g| {
        let d = g.usize_in(1, 8);
        let a = g.vec_f64(d, -3.0, 3.0);
        let b = g.vec_f64(d, -3.0, 3.0);
        let ls = g.f64_in(0.05, 5.0);
        let var = g.f64_in(0.1, 10.0);
        let kab = matern52(&a, &b, ls, var);
        let kba = matern52(&b, &a, ls, var);
        prop_assert!((kab - kba).abs() < 1e-12, "asymmetric: {kab} vs {kba}");
        prop_assert!(kab > 0.0, "non-positive kernel {kab}");
        prop_assert!(kab <= var + 1e-12, "kernel {kab} exceeds variance {var}");
        let kaa = matern52(&a, &a, ls, var);
        prop_assert!((kaa - var).abs() < 1e-9, "diagonal {kaa} != variance {var}");
        Ok(())
    });
}

#[test]
fn prop_gram_cholesky_succeeds_with_noise() {
    property("noisy Matern Gram matrices are SPD", 60, |g| {
        let n = g.usize_in(1, 24);
        let d = g.usize_in(1, 6);
        let x = random_points(g, n, d);
        let ls = g.f64_in(0.1, 2.0);
        let noise = g.f64_in(1e-6, 1e-1);
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = matern52(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d], ls, 1.0);
            }
            k[i * n + i] += noise;
        }
        prop_assert!(cholesky_in_place(&mut k, n), "cholesky failed at n={n} noise={noise}");
        // Diagonal of L is positive.
        for i in 0..n {
            prop_assert!(k[i * n + i] > 0.0, "non-positive pivot");
        }
        Ok(())
    });
}

#[test]
fn prop_triangular_solves_invert() {
    property("forward+backward substitution solve L L^T x = b", 80, |g| {
        let n = g.usize_in(1, 20);
        // Build L lower-triangular with positive diagonal.
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..i {
                l[i * n + j] = g.f64_in(-1.0, 1.0);
            }
            l[i * n + i] = g.f64_in(0.5, 2.0);
        }
        let b = g.vec_f64(n, -5.0, 5.0);
        let mut x = b.clone();
        solve_lower_in_place(&l, n, &mut x);
        solve_upper_t_in_place(&l, n, &mut x);
        // Check A x = b with A = L L^T.
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                let mut a_ij = 0.0;
                for k in 0..=i.min(j) {
                    a_ij += l[i * n + k] * l[j * n + k];
                }
                s += a_ij * x[j];
            }
            prop_assert!((s - b[i]).abs() < 1e-6, "residual {} at row {i}", s - b[i]);
        }
        Ok(())
    });
}

#[test]
fn prop_posterior_well_posed() {
    property("posterior: finite mean, 0 <= var <= prior", 40, |g| {
        let n = g.usize_in(1, 20);
        let d = 6;
        let x = random_points(g, n, d);
        let y = g.vec_f64(n, 0.5, 5.0);
        let ls = g.f64_in(0.1, 2.0);
        let var = g.f64_in(0.5, 3.0);
        let noise = g.f64_in(1e-5, 1e-1);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [ls, var, noise]), "fit failed");
        for _ in 0..10 {
            let xc = g.vec_f64(d, -0.5, 1.5);
            let (mu, v) = gp.predict(&xc);
            prop_assert!(mu.is_finite(), "non-finite mean");
            prop_assert!((0.0..=var + 1e-6).contains(&v), "variance {v} outside [0, {var}]");
        }
        Ok(())
    });
}

#[test]
fn prop_posterior_shrinks_near_observations() {
    property("variance at an observation < variance far away", 40, |g| {
        let n = g.usize_in(2, 15);
        let d = 6;
        let x = random_points(g, n, d);
        let y = g.vec_f64(n, 0.5, 5.0);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [0.5, 1.0, 1e-4]), "fit failed");
        let (_, v_at) = gp.predict(&x[0..d].to_vec());
        let far = vec![25.0; d];
        let (_, v_far) = gp.predict(&far);
        prop_assert!(v_at < v_far, "no shrinkage: {v_at} vs {v_far}");
        Ok(())
    });
}

#[test]
fn prop_ei_sound() {
    property("EI >= 0, zero when dominated & certain, monotone in best", 200, |g| {
        let mu = g.f64_in(-3.0, 3.0);
        let var = g.f64_in(0.0, 4.0);
        let best1 = g.f64_in(-3.0, 3.0);
        let best2 = best1 + g.f64_in(0.0, 2.0);
        let e1 = expected_improvement(mu, var, best1);
        let e2 = expected_improvement(mu, var, best2);
        prop_assert!(e1 >= 0.0 && e2 >= 0.0, "negative EI");
        // A worse incumbent (higher best cost) can only increase EI.
        prop_assert!(e2 >= e1 - 1e-12, "EI not monotone in incumbent: {e1} vs {e2}");
        if var == 0.0 && mu >= best1 {
            prop_assert!(e1 == 0.0, "dominated certain point has EI {e1}");
        }
        Ok(())
    });
}

#[test]
fn prop_standardize_is_affine_inverse() {
    property("standardize returns an affine transform of the input", 100, |g| {
        let n = g.usize_in(2, 30);
        let y = g.vec_f64(n, -10.0, 10.0);
        let (z, m, s) = standardize(&y);
        prop_assert!(s > 0.0, "non-positive scale");
        for (zi, yi) in z.iter().zip(&y) {
            prop_assert!((zi * s + m - yi).abs() < 1e-9, "roundtrip failed");
        }
        Ok(())
    });
}

fn close(a: f64, b: f64, rtol: f64) -> bool {
    (a - b).abs() <= rtol * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn prop_incremental_extend_matches_scratch() {
    property("rank-1 append posterior == scratch-fit posterior", 30, |g| {
        let d = g.usize_in(1, 6);
        let total = g.usize_in(3, 24);
        let x = g.vec_f64(total * d, 0.0, 1.0);
        let y = g.vec_f64(total, -2.0, 2.0);
        let hyp = [g.f64_in(0.1, 2.0), g.f64_in(0.5, 2.0), g.f64_in(1e-5, 1e-1)];
        let n0 = g.usize_in(1, total - 1);
        let mut inc = NativeGp::new();
        prop_assert!(inc.fit(&x[..n0 * d], &y[..n0], n0, d, hyp), "seed fit failed");
        let mut scr = NativeGp::new();
        for n in (n0 + 1)..=total {
            prop_assert!(
                inc.extend(&x[(n - 1) * d..n * d], &y[..n]),
                "extend failed at n={n} (well-conditioned Gram)"
            );
            prop_assert!(scr.fit(&x[..n * d], &y[..n], n, d, hyp), "scratch fit failed");
            prop_assert!(
                close(inc.nll(&y[..n]), scr.nll(&y[..n]), INC_RTOL),
                "nll diverged at n={n}: {} vs {}",
                inc.nll(&y[..n]),
                scr.nll(&y[..n])
            );
            for _ in 0..3 {
                let xc = g.vec_f64(d, -0.2, 1.2);
                let (mi, vi) = inc.predict(&xc);
                let (ms, vs) = scr.predict(&xc);
                prop_assert!(close(mi, ms, INC_RTOL), "mu diverged at n={n}: {mi} vs {ms}");
                prop_assert!(close(vi, vs, INC_RTOL), "var diverged at n={n}: {vi} vs {vs}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_slide_matches_scratch() {
    property("slide (drop-first + append) posterior == scratch refit", 30, |g| {
        let d = g.usize_in(1, 6);
        let w = g.usize_in(2, 12);
        let slides = g.usize_in(1, 10);
        let total = w + slides;
        let x = g.vec_f64(total * d, 0.0, 1.0);
        let y = g.vec_f64(total, -2.0, 2.0);
        let hyp = [g.f64_in(0.1, 2.0), g.f64_in(0.5, 2.0), g.f64_in(1e-5, 1e-1)];
        let mut inc = NativeGp::new();
        prop_assert!(inc.fit(&x[..w * d], &y[..w], w, d, hyp), "seed fit failed");
        let mut scr = NativeGp::new();
        for s in 1..=slides {
            let new = s + w - 1;
            prop_assert!(
                inc.slide(&x[new * d..(new + 1) * d], &y[s..s + w]),
                "slide failed at s={s}"
            );
            prop_assert!(
                scr.fit(&x[s * d..(s + w) * d], &y[s..s + w], w, d, hyp),
                "scratch fit failed"
            );
            prop_assert!(
                close(inc.nll(&y[s..s + w]), scr.nll(&y[s..s + w]), INC_RTOL),
                "nll diverged at s={s}"
            );
            let xc = g.vec_f64(d, -0.2, 1.2);
            let (mi, vi) = inc.predict(&xc);
            let (ms, vs) = scr.predict(&xc);
            prop_assert!(close(mi, ms, INC_RTOL), "mu diverged at s={s}: {mi} vs {ms}");
            prop_assert!(close(vi, vs, INC_RTOL), "var diverged at s={s}: {vi} vs {vs}");
        }
        Ok(())
    });
}

#[test]
fn prop_backend_incremental_matches_scratch_backend() {
    // Random append/slide sequences through the full backend (the real
    // FactorCache wiring), including near-degenerate Grams: duplicated
    // observation rows with the grid's smallest noise, where the rank-1
    // update must fall back to a cold refactorization and still agree.
    property("NativeBackend incremental == scratch across a sequence", 12, |g| {
        let d = g.usize_in(1, 4);
        let window = g.usize_in(4, 8);
        let steps = g.usize_in(4, 12);
        let grid = hyperparameter_grid();
        let total = 2 + steps;
        let mut rows = g.vec_f64(total * d, 0.0, 1.0);
        // Inject near-duplicates: some appended rows are (almost) copies
        // of the previous row, squeezing the append pivot toward zero.
        for i in 1..total {
            if g.bool() && g.bool() {
                for k in 0..d {
                    let prev = rows[(i - 1) * d + k];
                    rows[i * d + k] = prev + g.f64_in(-1e-9, 1e-9);
                }
            }
        }
        let y_all = g.vec_f64(total, -2.0, 2.0);
        let mut inc = NativeBackend::new();
        let mut scr = NativeBackend::new();
        scr.set_incremental(false);
        let m = 5;
        let xc = g.vec_f64(m * d, 0.0, 1.0);
        let cmask = vec![true; m];
        for step in 0..steps {
            let end = 2 + step;
            let (lo, n) = if end <= window { (0, end) } else { (end - window, window) };
            let x = &rows[lo * d..(lo + n) * d];
            let y = &y_all[lo..lo + n];
            let a = inc.nll_grid(x, y, n, d, &grid).unwrap();
            let b = scr.nll_grid(x, y, n, d, &grid).unwrap();
            for (gi, (va, vb)) in a.iter().zip(&b).enumerate() {
                if va.is_finite() || vb.is_finite() {
                    prop_assert!(
                        close(*va, *vb, INC_RTOL),
                        "nll[{gi}] diverged at step {step}: {va} vs {vb}"
                    );
                }
            }
            let hyp = *g.choose(&grid);
            let da = inc.decide(x, y, n, d, &xc, &cmask, m, hyp);
            let db = scr.decide(x, y, n, d, &xc, &cmask, m, hyp);
            prop_assert!(da.is_ok() == db.is_ok(), "SPD verdict diverged at step {step}");
            if let (Ok(da), Ok(db)) = (da, db) {
                for j in 0..m {
                    prop_assert!(
                        close(da.mu[j], db.mu[j], INC_RTOL)
                            && close(da.var[j], db.var[j], INC_RTOL)
                            && close(da.ei[j], db.ei[j], INC_RTOL),
                        "decision diverged at step {step} col {j}"
                    );
                }
            }
        }
        let s = inc.factor_stats();
        prop_assert!(s.appends + s.slides > 0, "incremental path never engaged: {s:?}");
        Ok(())
    });
}

#[test]
fn incremental_falls_back_cold_on_near_degenerate_gram() {
    // Near-duplicate observations under a huge signal variance and zero
    // noise: the rank-1 append's pivot cancels catastrophically (while
    // the jittered scratch factorization still succeeds), so the update
    // must detect the loss of positive definiteness, refactorize cold,
    // and keep matching the scratch backend exactly.
    let d = 3;
    let grid = [[0.5, 1e9, 0.0]];
    let base = [0.3, 0.6, 0.9];
    let total = 6;
    let mut rows = Vec::new();
    for i in 0..total {
        for k in 0..d {
            // Row 0 exactly, rows 1.. perturbed by ~1e-9.
            rows.push(base[k] + i as f64 * 1.7e-9 * ((k + 1) as f64));
        }
    }
    let y: Vec<f64> = (0..total).map(|i| (i as f64 * 0.31).sin()).collect();
    let mut inc = NativeBackend::new();
    let mut scr = NativeBackend::new();
    scr.set_incremental(false);
    for n in 1..=total {
        let x = &rows[..n * d];
        let a = inc.nll_grid(x, &y[..n], n, d, &grid).unwrap();
        let b = scr.nll_grid(x, &y[..n], n, d, &grid).unwrap();
        assert_eq!(
            a[0].is_finite(),
            b[0].is_finite(),
            "SPD verdict diverged at n={n}: {} vs {}",
            a[0],
            b[0]
        );
        if a[0].is_finite() {
            assert!(
                close(a[0], b[0], 1e-9),
                "nll diverged at n={n}: {} vs {}",
                a[0],
                b[0]
            );
        }
    }
    let s = inc.factor_stats();
    assert!(
        s.fallbacks > 0,
        "near-degenerate appends never triggered the cold fallback: {s:?}"
    );
}

#[test]
fn prop_gp_interpolates_with_tiny_noise() {
    property("posterior mean ~= y at training points", 30, |g| {
        let n = g.usize_in(2, 12);
        let d = 6;
        // Well-separated points to keep the Gram well-conditioned.
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in 0..d {
                x.push(i as f64 / n as f64 + 0.03 * ((i * d + j) as f64).sin());
            }
        }
        let y = g.vec_f64(n, 0.0, 3.0);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [0.7, 1.0, 1e-9]), "fit failed");
        for i in 0..n {
            let (mu, _) = gp.predict(&x[i * d..(i + 1) * d].to_vec());
            prop_assert!((mu - y[i]).abs() < 1e-2, "no interpolation: {mu} vs {}", y[i]);
        }
        Ok(())
    });
}
