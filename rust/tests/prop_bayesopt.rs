//! Property-based tests on the Gaussian-process layer: posterior
//! well-posedness, EI soundness, and agreement between the native GP and
//! first principles.

use ruya::bayesopt::gp::{
    cholesky_in_place, expected_improvement, matern52, solve_lower_in_place,
    solve_upper_t_in_place, standardize, NativeGp,
};
use ruya::prop_assert;
use ruya::testkit::{property, Gen};

fn random_points(g: &mut Gen, n: usize, d: usize) -> Vec<f64> {
    g.vec_f64(n * d, 0.0, 1.0)
}

#[test]
fn prop_kernel_bounds_and_symmetry() {
    property("matern52 is symmetric, positive, bounded by variance", 200, |g| {
        let d = g.usize_in(1, 8);
        let a = g.vec_f64(d, -3.0, 3.0);
        let b = g.vec_f64(d, -3.0, 3.0);
        let ls = g.f64_in(0.05, 5.0);
        let var = g.f64_in(0.1, 10.0);
        let kab = matern52(&a, &b, ls, var);
        let kba = matern52(&b, &a, ls, var);
        prop_assert!((kab - kba).abs() < 1e-12, "asymmetric: {kab} vs {kba}");
        prop_assert!(kab > 0.0, "non-positive kernel {kab}");
        prop_assert!(kab <= var + 1e-12, "kernel {kab} exceeds variance {var}");
        let kaa = matern52(&a, &a, ls, var);
        prop_assert!((kaa - var).abs() < 1e-9, "diagonal {kaa} != variance {var}");
        Ok(())
    });
}

#[test]
fn prop_gram_cholesky_succeeds_with_noise() {
    property("noisy Matern Gram matrices are SPD", 60, |g| {
        let n = g.usize_in(1, 24);
        let d = g.usize_in(1, 6);
        let x = random_points(g, n, d);
        let ls = g.f64_in(0.1, 2.0);
        let noise = g.f64_in(1e-6, 1e-1);
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = matern52(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d], ls, 1.0);
            }
            k[i * n + i] += noise;
        }
        prop_assert!(cholesky_in_place(&mut k, n), "cholesky failed at n={n} noise={noise}");
        // Diagonal of L is positive.
        for i in 0..n {
            prop_assert!(k[i * n + i] > 0.0, "non-positive pivot");
        }
        Ok(())
    });
}

#[test]
fn prop_triangular_solves_invert() {
    property("forward+backward substitution solve L L^T x = b", 80, |g| {
        let n = g.usize_in(1, 20);
        // Build L lower-triangular with positive diagonal.
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..i {
                l[i * n + j] = g.f64_in(-1.0, 1.0);
            }
            l[i * n + i] = g.f64_in(0.5, 2.0);
        }
        let b = g.vec_f64(n, -5.0, 5.0);
        let mut x = b.clone();
        solve_lower_in_place(&l, n, &mut x);
        solve_upper_t_in_place(&l, n, &mut x);
        // Check A x = b with A = L L^T.
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                let mut a_ij = 0.0;
                for k in 0..=i.min(j) {
                    a_ij += l[i * n + k] * l[j * n + k];
                }
                s += a_ij * x[j];
            }
            prop_assert!((s - b[i]).abs() < 1e-6, "residual {} at row {i}", s - b[i]);
        }
        Ok(())
    });
}

#[test]
fn prop_posterior_well_posed() {
    property("posterior: finite mean, 0 <= var <= prior", 40, |g| {
        let n = g.usize_in(1, 20);
        let d = 6;
        let x = random_points(g, n, d);
        let y = g.vec_f64(n, 0.5, 5.0);
        let ls = g.f64_in(0.1, 2.0);
        let var = g.f64_in(0.5, 3.0);
        let noise = g.f64_in(1e-5, 1e-1);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [ls, var, noise]), "fit failed");
        for _ in 0..10 {
            let xc = g.vec_f64(d, -0.5, 1.5);
            let (mu, v) = gp.predict(&xc);
            prop_assert!(mu.is_finite(), "non-finite mean");
            prop_assert!((0.0..=var + 1e-6).contains(&v), "variance {v} outside [0, {var}]");
        }
        Ok(())
    });
}

#[test]
fn prop_posterior_shrinks_near_observations() {
    property("variance at an observation < variance far away", 40, |g| {
        let n = g.usize_in(2, 15);
        let d = 6;
        let x = random_points(g, n, d);
        let y = g.vec_f64(n, 0.5, 5.0);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [0.5, 1.0, 1e-4]), "fit failed");
        let (_, v_at) = gp.predict(&x[0..d].to_vec());
        let far = vec![25.0; d];
        let (_, v_far) = gp.predict(&far);
        prop_assert!(v_at < v_far, "no shrinkage: {v_at} vs {v_far}");
        Ok(())
    });
}

#[test]
fn prop_ei_sound() {
    property("EI >= 0, zero when dominated & certain, monotone in best", 200, |g| {
        let mu = g.f64_in(-3.0, 3.0);
        let var = g.f64_in(0.0, 4.0);
        let best1 = g.f64_in(-3.0, 3.0);
        let best2 = best1 + g.f64_in(0.0, 2.0);
        let e1 = expected_improvement(mu, var, best1);
        let e2 = expected_improvement(mu, var, best2);
        prop_assert!(e1 >= 0.0 && e2 >= 0.0, "negative EI");
        // A worse incumbent (higher best cost) can only increase EI.
        prop_assert!(e2 >= e1 - 1e-12, "EI not monotone in incumbent: {e1} vs {e2}");
        if var == 0.0 && mu >= best1 {
            prop_assert!(e1 == 0.0, "dominated certain point has EI {e1}");
        }
        Ok(())
    });
}

#[test]
fn prop_standardize_is_affine_inverse() {
    property("standardize returns an affine transform of the input", 100, |g| {
        let n = g.usize_in(2, 30);
        let y = g.vec_f64(n, -10.0, 10.0);
        let (z, m, s) = standardize(&y);
        prop_assert!(s > 0.0, "non-positive scale");
        for (zi, yi) in z.iter().zip(&y) {
            prop_assert!((zi * s + m - yi).abs() < 1e-9, "roundtrip failed");
        }
        Ok(())
    });
}

#[test]
fn prop_gp_interpolates_with_tiny_noise() {
    property("posterior mean ~= y at training points", 30, |g| {
        let n = g.usize_in(2, 12);
        let d = 6;
        // Well-separated points to keep the Gram well-conditioned.
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in 0..d {
                x.push(i as f64 / n as f64 + 0.03 * ((i * d + j) as f64).sin());
            }
        }
        let y = g.vec_f64(n, 0.0, 3.0);
        let mut gp = NativeGp::new();
        prop_assert!(gp.fit(&x, &y, n, d, [0.7, 1.0, 1e-9]), "fit failed");
        for i in 0..n {
            let (mu, _) = gp.predict(&x[i * d..(i + 1) * d].to_vec());
            prop_assert!((mu - y[i]).abs() < 1e-2, "no interpolation: {mu} vs {}", y[i]);
        }
        Ok(())
    });
}
