//! The dedicated SIMD parity suite: everything that toggles the
//! process-global dispatch mode (`bayesopt::set_simd`) lives in this
//! one integration binary and serializes behind a single lock, so the
//! lib test binary (whose suites read `simd_active()` concurrently)
//! never observes a mid-test mode flip.
//!
//! What is pinned here, on top of the per-kernel `_scalar`-vs-`_avx2`
//! property tests inside `bayesopt/simd.rs`:
//!
//! * `assert_simd_scalar_parity` replays the randomized
//!   `testkit::random_scripts` corpus — the same programs the
//!   `tests/fuzz_parity.rs` suites drive — once with SIMD forced off
//!   and once with it on, and requires every grid NLL, posterior
//!   mean/variance, EI score and chosen argmax to agree within
//!   [`SIMD_PARITY_RTOL`] (the tolerance-class contract: reductions
//!   reassociate, the Matérn builders use the vector `exp`).
//! * The same corpus under the forced-*scalar* mode must keep the
//!   serial-vs-pooled **bit identity** contract — the escape hatch that
//!   lets every legacy bit-exact suite keep pinning the scalar path.
//! * `set_simd` / `simd_active` / `RUYA_FORCE_SCALAR` mode plumbing.
//!
//! Scripts reproduce from `RUYA_FUZZ_SEED` exactly as in
//! `tests/fuzz_parity.rs`.

use ruya::bayesopt::{
    hyperparameter_grid, set_simd, simd_active, simd_available, LowRankPolicy,
    NativeBackend, SIMD_PARITY_RTOL,
};
use ruya::testkit::{
    assert_parallel_parity, assert_simd_scalar_parity, random_scripts, ParityScript,
};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// One lock for every test in this binary: `set_simd` is process-global
/// and `cargo test` runs tests on concurrent threads.
static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

fn serialized<R>(body: impl FnOnce() -> R) -> R {
    // A poisoned lock just means an earlier test failed; the guard in
    // the harness already restored the dispatch mode.
    let _guard = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    body()
}

/// Scripts per fuzz run (matches `tests/fuzz_parity.rs`).
const FUZZ_SCRIPTS: usize = 32;

fn fuzz_seed() -> u64 {
    std::env::var("RUYA_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11C_E5EE_D5EEDu64)
}

/// Deterministic candidate matrix matching a script's feature width
/// (same shape family as the fuzz_parity corpus).
fn candidates(script: &ParityScript, salt: usize) -> (Vec<f64>, usize) {
    let d = script.dim();
    let m = 6 + (salt % 7); // 6..=12 candidates
    let xc = (0..m * d)
        .map(|i| ((i * 29 + salt * 13 + 7) % 97) as f64 / 97.0)
        .collect();
    (xc, m)
}

/// Run `body` over every generated script, re-panicking with the seed
/// and script index so failures reproduce from the log line alone.
fn for_each_script(body: impl Fn(usize, &ParityScript, &[f64], usize)) {
    let seed = fuzz_seed();
    let scripts = random_scripts(seed, FUZZ_SCRIPTS);
    for (i, script) in scripts.iter().enumerate() {
        let (xc, m) = candidates(script, i);
        let result = catch_unwind(AssertUnwindSafe(|| body(i, script, &xc, m)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "simd fuzz script {i}/{FUZZ_SCRIPTS} (RUYA_FUZZ_SEED={seed:#x}, steps \
                 {:?}) failed:\n  {msg}",
                script.steps()
            );
        }
    }
}

#[test]
fn set_simd_respects_feature_detection() {
    serialized(|| {
        let prior = simd_active();
        assert!(!set_simd(false));
        assert!(!simd_active());
        // Forcing SIMD on only sticks when the CPU has the features.
        assert_eq!(set_simd(true), simd_available());
        assert_eq!(simd_active(), simd_available());
        set_simd(prior);
    });
}

#[test]
fn fuzz_simd_vs_scalar_within_rtol_over_random_programs() {
    serialized(|| {
        let grid = hyperparameter_grid();
        for_each_script(|_, script, xc, m| {
            let make = NativeBackend::new;
            assert_simd_scalar_parity(&make, script, xc, m, &grid, SIMD_PARITY_RTOL);
        });
    });
}

#[test]
fn fuzz_simd_vs_scalar_pooled_and_lowrank_within_rtol() {
    serialized(|| {
        let grid = hyperparameter_grid();
        for_each_script(|i, script, xc, m| {
            // Alternate the two non-default configurations across the
            // corpus: the pooled exact sweep (multi-RHS batches fanned
            // across lanes) and the forced low-rank routing.
            let pooled = i % 2 == 0;
            let make = move || {
                let mut b = NativeBackend::new();
                if pooled {
                    b.set_parallelism(4);
                    b.set_pool_min_obs(0);
                } else {
                    b.set_lowrank_nll_threshold(4);
                    b.set_lowrank_policy(LowRankPolicy::Force { max_inducing: 6 });
                }
                b
            };
            assert_simd_scalar_parity(&make, script, xc, m, &grid, SIMD_PARITY_RTOL);
        });
    });
}

#[test]
fn fuzz_forced_scalar_keeps_parallel_bit_identity() {
    serialized(|| {
        // The escape hatch contract: with SIMD forced off, the whole
        // backend reproduces the legacy scalar bits, so the strict
        // serial-vs-pooled bit-identity harness must pass untouched.
        struct ModeGuard(bool);
        impl Drop for ModeGuard {
            fn drop(&mut self) {
                set_simd(self.0);
            }
        }
        let _restore = ModeGuard(simd_active());
        set_simd(false);

        let grid = hyperparameter_grid();
        for_each_script(|_, script, xc, m| {
            let make = || {
                let mut b = NativeBackend::new();
                b.set_pool_min_obs(0);
                b
            };
            assert_parallel_parity(&make, &[2, 4], script, xc, m, &grid);
        });
    });
}

#[test]
fn simd_dispatch_parallel_parity_stays_bit_identical() {
    serialized(|| {
        // With SIMD *on*, serial and pooled lanes share one dispatch
        // decision, so the strict bit contract holds there too (no
        // tolerance needed): reassociation changes bits vs scalar, not
        // vs another thread count.
        if !simd_available() {
            return;
        }
        struct ModeGuard(bool);
        impl Drop for ModeGuard {
            fn drop(&mut self) {
                set_simd(self.0);
            }
        }
        let _restore = ModeGuard(simd_active());
        set_simd(true);

        let grid = hyperparameter_grid();
        let scripts = random_scripts(fuzz_seed(), 8);
        for (i, script) in scripts.iter().enumerate() {
            let (xc, m) = candidates(script, i);
            let make = || {
                let mut b = NativeBackend::new();
                b.set_pool_min_obs(0);
                b
            };
            assert_parallel_parity(&make, &[2, 4], script, &xc, m, &grid);
        }
    });
}
