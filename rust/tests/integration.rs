//! Cross-module integration tests: the complete Ruya pipeline
//! (profile -> categorize -> plan -> search) over the simulated cluster
//! substrate, plus native-vs-XLA backend agreement.

use ruya::bayesopt::{backend_by_name, BoParams, GpBackend};
use ruya::coordinator::{ExperimentConfig, ExperimentRunner, RuyaPlanner, SearchPlan};
use ruya::memmodel::{MemCategory, MemoryModel};
use ruya::profiler::SingleNodeProfiler;
use ruya::runtime::XlaRuntime;
use ruya::searchspace::SearchSpace;
use ruya::util::rng::Pcg64;
use ruya::workload::{evaluation_jobs, ClusterSim, JobCostTable};

/// Full pipeline for every evaluation job: the plan must be well-formed
/// and the search must find the optimum within the space size.
#[test]
fn pipeline_profile_plan_search_all_jobs() {
    let runner = ExperimentRunner::native();
    for job in evaluation_jobs() {
        let profile = runner.profile_job(&job, 11);
        let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
        // Phases partition the space.
        let mut all: Vec<usize> = plan.phases.concat();
        all.sort_unstable();
        assert_eq!(all, (0..runner.space.len()).collect::<Vec<_>>(), "{}", job.label());

        let table = JobCostTable::build(&runner.sim, &job, &runner.space);
        let out = runner.run_one(&table, &plan, 1234 + job.job_id).expect("search");
        let found = out.first_within(1.0 + 1e-9).expect("optimum never tried");
        assert!(found <= runner.space.len(), "{}: {found}", job.label());
        // The trace replays the cost table faithfully.
        for (&idx, &cost) in out.tried.iter().zip(&out.costs) {
            assert_eq!(cost, table.normalized[idx]);
        }
    }
}

/// The profiling -> memory-model stage recovers the ground-truth category
/// for every job (Table I's 6/6/4 split).
#[test]
fn categories_recovered_for_multiple_seeds() {
    let profiler = SingleNodeProfiler::default();
    for seed in [1, 7, 99] {
        let mut linear = 0;
        let mut flat = 0;
        let mut unclear = 0;
        for job in evaluation_jobs() {
            let outcome = profiler.profile(&job, seed);
            let model = MemoryModel::fit(&outcome.readings());
            match model.category {
                MemCategory::Linear => linear += 1,
                MemCategory::Flat => flat += 1,
                MemCategory::Unclear => unclear += 1,
            }
        }
        assert_eq!(linear, 6, "seed {seed}");
        assert_eq!(flat, 6, "seed {seed}");
        assert_eq!(unclear, 4, "seed {seed}");
    }
}

/// Ruya with an unclear memory model must produce the identical trace to
/// CherryPick under the same seed — the paper's fallback guarantee.
#[test]
fn unclear_fallback_is_exact() {
    let runner = ExperimentRunner::native();
    let job = evaluation_jobs()
        .into_iter()
        .find(|j| j.label() == "Log. Regr. Spark huge")
        .unwrap();
    let profile = runner.profile_job(&job, 5);
    assert_eq!(profile.model.category, MemCategory::Unclear);
    let ruya_plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
    let cp_plan = SearchPlan::unpartitioned(&runner.space);
    let table = JobCostTable::build(&runner.sim, &job, &runner.space);
    let a = runner.run_one(&table, &ruya_plan, 777).unwrap();
    let b = runner.run_one(&table, &cp_plan, 777).unwrap();
    assert_eq!(a.tried, b.tried);
}

/// Both GP backends, fed identical observations, must rank candidates the
/// same way (the XLA artifact is f32; we compare proposals, not bits).
#[test]
fn xla_and_native_backends_agree() {
    if !XlaRuntime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut native = backend_by_name("native").unwrap();
    let mut xla = backend_by_name("xla").unwrap();

    let space = SearchSpace::scout();
    let features = space.feature_matrix();
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();

    // Observations: 8 configs of a K-Means cost surface.
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "K-Means Spark huge").unwrap();
    let sim = ClusterSim::default();
    let table = JobCostTable::build(&sim, &job, &space);
    let obs: Vec<usize> = vec![0, 9, 18, 27, 36, 45, 54, 63];
    let mut x = Vec::new();
    let mut y = Vec::new();
    for &i in &obs {
        x.extend(space.features(i));
        y.push(table.normalized[i]);
    }
    let (y_std, _, _) = ruya::bayesopt::gp::standardize(&y);
    let cmask: Vec<bool> = (0..m).map(|i| !obs.contains(&i)).collect();
    let hyp = [0.5, 1.0, 1e-3];

    let dn = native.decide(&x, &y_std, obs.len(), d, &features, &cmask, m, hyp).unwrap();
    let dx = xla.decide(&x, &y_std, obs.len(), d, &features, &cmask, m, hyp).unwrap();

    // Posterior agreement (f32 tolerance).
    for i in 0..m {
        assert!((dn.mu[i] - dx.mu[i]).abs() < 1e-3, "mu[{i}]: {} vs {}", dn.mu[i], dx.mu[i]);
        assert!((dn.var[i] - dx.var[i]).abs() < 1e-3, "var[{i}]");
    }
    // Same proposal.
    let argmax = |ei: &[f64]| {
        ei.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    };
    assert_eq!(argmax(&dn.ei), argmax(&dx.ei), "backends proposed different configs");

    // NLL grids agree on the best hyperparameter.
    let grid = ruya::bayesopt::hyperparameter_grid();
    let nn = native.nll_grid(&x, &y_std, obs.len(), d, &grid).unwrap();
    let nx = xla.nll_grid(&x, &y_std, obs.len(), d, &grid).unwrap();
    let argmin = |v: &[f64]| {
        v.iter().enumerate().min_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    };
    assert_eq!(argmin(&nn), argmin(&nx), "hyperparameter selection diverged");
}

/// A full seeded search must propose the same early trajectory on both
/// backends.
#[test]
fn xla_search_trace_matches_native() {
    if !XlaRuntime::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let space = SearchSpace::scout();
    let sim = ClusterSim::default();
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "Join Spark huge").unwrap();
    let table = JobCostTable::build(&sim, &job, &space);
    let features = space.feature_matrix();
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let phases = vec![(0..m).collect::<Vec<_>>()];
    let params = BoParams { max_iters: 20, ..Default::default() };

    let run = |backend: &mut dyn GpBackend| {
        let mut rng = Pcg64::from_seed(2024);
        let costs = table.normalized.clone();
        let mut oracle = |i: usize| costs[i];
        ruya::bayesopt::run_search(&features, m, d, &phases, &mut oracle, backend, &mut rng, &params)
            .unwrap()
    };
    let mut native = backend_by_name("native").unwrap();
    let mut xla = backend_by_name("xla").unwrap();
    let tn = run(native.as_mut());
    let tx = run(xla.as_mut());
    // f32-vs-f64 rounding may eventually fork the trajectory; the first
    // several proposals must match exactly.
    assert_eq!(tn.tried[..8], tx.tried[..8], "early trajectory diverged");
}

/// The experiment harness end-to-end on a small slice with both methods.
#[test]
fn experiment_slice_runs_and_reports() {
    let runner = ExperimentRunner::native();
    let cfg = ExperimentConfig { reps: 4, seed: 9, curve_len: 20 };
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "Terasort Hadoop huge").unwrap();
    let cmp = runner.compare_job(&job, &cfg).unwrap();
    assert_eq!(cmp.category, MemCategory::Flat);
    for k in 0..3 {
        assert!(cmp.cherrypick.iters_to[k] >= 1.0);
        assert!(cmp.ruya.iters_to[k] >= 1.0);
    }
    // Thresholds are nested: iterations to 1.0 >= to 1.1 >= to 1.2.
    for s in [&cmp.cherrypick, &cmp.ruya] {
        assert!(s.iters_to[2] >= s.iters_to[1] - 1e-9);
        assert!(s.iters_to[1] >= s.iters_to[0] - 1e-9);
    }
}

/// Plans derived from different profiling seeds stay structurally stable
/// (categories do not flap, priority-group size barely moves).
#[test]
fn plans_stable_across_profiling_seeds() {
    let profiler = SingleNodeProfiler::default();
    let planner = RuyaPlanner::default();
    let space = SearchSpace::scout();
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "K-Means Spark bigdata").unwrap();
    let mut sizes = Vec::new();
    for seed in 0..6 {
        let outcome = profiler.profile(&job, seed);
        let model = MemoryModel::fit(&outcome.readings());
        assert_eq!(model.category, MemCategory::Linear, "seed {seed}");
        let plan = planner.plan(&model, job.input_gb, &space);
        sizes.push(plan.phases[0].len());
    }
    let min = *sizes.iter().min().unwrap();
    let max = *sizes.iter().max().unwrap();
    assert!(max - min <= 3, "priority group unstable across seeds: {sizes:?}");
}
