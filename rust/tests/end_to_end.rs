//! End-to-end fidelity test: a reduced-repetition Table II slice must
//! reproduce the *shape* of the paper's headline result (DESIGN.md §5
//! calibration contract). The full-scale numbers live in EXPERIMENTS.md
//! and are produced by `examples/full_reproduction.rs`.

use ruya::coordinator::{ExperimentConfig, ExperimentRunner};
use ruya::memmodel::MemCategory;

#[test]
fn table2_shape_matches_paper() {
    let runner = ExperimentRunner::native();
    let cfg = ExperimentConfig { reps: 12, seed: 0xC0FFEE, curve_len: 48 };
    let result = runner.run_table2(&cfg).expect("experiment");

    assert_eq!(result.jobs.len(), 16);

    // Headline: Ruya needs roughly half the iterations on average.
    // Paper: 37.9% / 40.2% / 49.2%. Contract: 25..70% at every threshold.
    for (k, q) in result.mean_quotient.iter().enumerate() {
        assert!(
            (0.25..=0.70).contains(q),
            "mean quotient[{k}] = {q:.3} outside the fidelity band"
        );
    }

    // Unclear jobs reduce exactly to the baseline.
    for j in result.jobs.iter().filter(|j| j.category == MemCategory::Unclear) {
        for k in 0..3 {
            assert!(
                (j.quotient()[k] - 1.0).abs() < 1e-9,
                "{}: unclear quotient {:?}",
                j.label,
                j.quotient()
            );
        }
    }

    // Flat jobs improve strongly at the near-optimal thresholds
    // (paper: 10-43%).
    for j in result.jobs.iter().filter(|j| j.category == MemCategory::Flat) {
        assert!(
            j.quotient()[0] < 0.7,
            "{}: flat c<=1.2 quotient {:.3}",
            j.label,
            j.quotient()[0]
        );
    }

    // No job category may be dramatically worse than the baseline on
    // average (the paper: "about as good or better for each job").
    let mut by_cat = std::collections::BTreeMap::new();
    for j in &result.jobs {
        by_cat.entry(j.category.name()).or_insert_with(Vec::new).push(j.quotient()[2]);
    }
    for (cat, qs) in by_cat {
        let mean: f64 = qs.iter().sum::<f64>() / qs.len() as f64;
        assert!(mean < 1.25, "category {cat} mean c=1.0 quotient {mean:.3}");
    }

    // Fig. 4 shape: Ruya's average best-found curve dominates (is below)
    // CherryPick's over the early iterations where the paper's gap lives.
    let len = cfg.curve_len;
    let mut cp = vec![0.0; len];
    let mut ruya = vec![0.0; len];
    for j in &result.jobs {
        for i in 0..len {
            cp[i] += j.cherrypick.best_curve[i] / result.jobs.len() as f64;
            ruya[i] += j.ruya.best_curve[i] / result.jobs.len() as f64;
        }
    }
    let early_gap: f64 = (3..20).map(|i| cp[i] - ruya[i]).sum();
    assert!(early_gap > 0.0, "Ruya does not dominate early iterations (gap {early_gap})");

    // Fig. 5 shape: cumulative cost advantage for Ruya at iteration 25.
    let mut cp25 = 0.0;
    let mut ruya25 = 0.0;
    for j in &result.jobs {
        cp25 += j.cherrypick.cum_curve[24] / result.jobs.len() as f64;
        ruya25 += j.ruya.cum_curve[24] / result.jobs.len() as f64;
    }
    assert!(
        ruya25 < cp25,
        "no cumulative-cost advantage at iteration 25: {ruya25:.2} vs {cp25:.2}"
    );
}

/// Table I shape: 6 linear / 6 flat / 4 unclear with requirement estimates
/// within 25% of the paper's values (the simulated jobs are calibrated to
/// Table I, so this closes the loop through profiler + model).
#[test]
fn table1_shape_matches_paper() {
    let runner = ExperimentRunner::native();
    let summaries = runner.profile_all(0xC0FFEE);

    let expect: &[(&str, &str)] = &[
        ("Naive Bayes Spark bigdata", "linear"),
        ("Naive Bayes Spark huge", "linear"),
        ("K-Means Spark bigdata", "linear"),
        ("K-Means Spark huge", "linear"),
        ("Page Rank Spark bigdata", "linear"),
        ("Page Rank Spark huge", "linear"),
        ("Log. Regr. Spark bigdata", "unclear"),
        ("Log. Regr. Spark huge", "unclear"),
        ("Lin. Regr. Spark bigdata", "unclear"),
        ("Lin. Regr. Spark huge", "unclear"),
        ("Join Spark bigdata", "flat"),
        ("Join Spark huge", "flat"),
        ("Page Rank Hadoop bigdata", "flat"),
        ("Page Rank Hadoop huge", "flat"),
        ("Terasort Hadoop bigdata", "flat"),
        ("Terasort Hadoop huge", "flat"),
    ];
    for (label, cat) in expect {
        let s = summaries.iter().find(|s| s.label == *label).expect(label);
        assert_eq!(s.model.category.name(), *cat, "{label}");
    }

    let gb_expect: &[(&str, f64)] = &[
        ("Naive Bayes Spark bigdata", 754.0),
        ("Naive Bayes Spark huge", 395.0),
        ("K-Means Spark bigdata", 503.0),
        ("K-Means Spark huge", 252.0),
        ("Page Rank Spark bigdata", 86.0),
        ("Page Rank Spark huge", 42.0),
    ];
    for (label, gb) in gb_expect {
        let s = summaries.iter().find(|s| s.label == *label).unwrap();
        let job = ruya::workload::evaluation_jobs()
            .into_iter()
            .find(|j| j.label() == *label)
            .unwrap();
        let est = s.model.estimate_requirement_gb(job.input_gb);
        assert!(
            (est - gb).abs() / gb < 0.25,
            "{label}: estimate {est:.0} GB vs Table I {gb} GB"
        );
    }
}

/// Table III shape: per-job profiling times in a plausible band, mean in
/// the paper's neighbourhood (~565 s), and invariance to full dataset
/// size (§IV-D: "profiling overhead is irrespective of the size of the
/// full dataset" — same algorithm, double input, similar time).
#[test]
fn table3_shape_matches_paper() {
    let runner = ExperimentRunner::native();
    let summaries = runner.profile_all(0xC0FFEE);
    let times: Vec<f64> = summaries.iter().map(|s| s.profiling_time_s).collect();
    for (s, t) in summaries.iter().zip(&times) {
        assert!((60.0..2000.0).contains(t), "{}: {t} s", s.label);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    assert!((200.0..1000.0).contains(&mean), "mean profiling time {mean:.0} s");

    // Scale invariance: bigdata vs huge of the same algorithm within 2x.
    for pair in summaries.chunks(2) {
        let ratio = pair[0].profiling_time_s / pair[1].profiling_time_s;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "profiling time should not scale with dataset size: {} vs {}",
            pair[0].label,
            pair[1].label
        );
    }
}

/// End-to-end search over a generated full-catalog-scale space: the
/// whole stack (catalog generator -> cost table -> Ruya plan -> phased
/// BO search) must run on a >1k-config space, stay within the iteration
/// cap, and actually engage the low-rank decide path once the history is
/// long enough (the documented auto-selection thresholds).
#[test]
fn generated_space_search_end_to_end() {
    use ruya::bayesopt::{BoParams, NativeBackend, LOWRANK_MIN_OBS};
    use ruya::searchspace::SearchSpace;
    use ruya::workload::{evaluation_jobs, JobCostTable};

    let runner = ExperimentRunner::native()
        .with_space(SearchSpace::generated(0xC0FFEE, 1200));
    let job = evaluation_jobs()[0];
    let table = JobCostTable::build(&runner.sim, &job, &runner.space);
    assert_eq!(table.normalized.len(), 1200);
    let profile = runner.profile_job(&job, 7);
    let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);

    let max_iters = LOWRANK_MIN_OBS + 8;
    let params = BoParams { max_iters, ..Default::default() };
    let mut backend = NativeBackend::new();
    let out = runner
        .run_one_with_params(&mut backend, &table, &plan, 7, &params)
        .expect("generated-space search");

    assert_eq!(out.tried.len(), max_iters, "search must hit the iteration cap");
    let mut seen = out.tried.clone();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), out.tried.len(), "a config was tried twice");
    assert!(out.tried.iter().all(|&i| i < 1200), "config index out of space");
    assert!(out.costs.iter().all(|&c| c >= 1.0 - 1e-9), "normalized cost below optimum");

    let stats = backend.decide_stats();
    assert!(stats.exact > 0, "short-history decides must stay exact: {stats:?}");
    assert!(
        stats.lowrank > 0,
        "the low-rank path never engaged over a 1200-config space: {stats:?}"
    );

    // Determinism end to end: same seed, fresh backend, same trace.
    let mut backend2 = NativeBackend::new();
    let out2 = runner
        .run_one_with_params(&mut backend2, &table, &plan, 7, &params)
        .expect("repeat search");
    assert_eq!(out.tried, out2.tried);
}
