//! Randomized parity fuzz: `testkit::random_scripts` generates seeded
//! append/slide/replace observation programs and drives them through the
//! two parity harnesses — replacing the hand-written-scripts-only
//! coverage that used to pin the incremental caches and the worker pool.
//!
//! * `assert_backend_parity` pins the incremental factor cache against a
//!   forced-cold scratch backend within 1e-9 over every generated
//!   program;
//! * `assert_parallel_parity` pins serial-vs-pooled **bit identity** at
//!   `--gp-threads` 2/4/8 over every program, both on the exact sweep
//!   and with the low-rank nll routing forced to engage (stage-split
//!   marginal + incremental inducing refresh under the pool).
//!
//! Scripts are deterministic in `(RUYA_FUZZ_SEED, index)`; a failure
//! re-panics with both, so any run reproduces with
//! `RUYA_FUZZ_SEED=<seed> cargo test --test fuzz_parity`.

use ruya::bayesopt::{
    hyperparameter_grid, BoParams, NativeBackend, SearchCursor, SearchStep,
};
use ruya::coordinator::{replay_cursor, SessionState};
use ruya::testkit::{
    assert_backend_parity, assert_parallel_parity, random_scripts, ParityScript,
};
use ruya::util::rng::Pcg64;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

/// Scripts per fuzz run (the ISSUE floor is 32).
const FUZZ_SCRIPTS: usize = 32;

fn fuzz_seed() -> u64 {
    std::env::var("RUYA_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11C_E5EE_D5EEDu64)
}

/// Deterministic candidate matrix matching a script's feature width.
fn candidates(script: &ParityScript, salt: usize) -> (Vec<f64>, usize) {
    let d = script.dim();
    let m = 6 + (salt % 7); // 6..=12 candidates
    let xc = (0..m * d)
        .map(|i| ((i * 29 + salt * 13 + 7) % 97) as f64 / 97.0)
        .collect();
    (xc, m)
}

/// Run `body` over every generated script, re-panicking with the seed
/// and script index so failures reproduce from the log line alone.
fn for_each_script(body: impl Fn(usize, &ParityScript, &[f64], usize)) {
    let seed = fuzz_seed();
    let scripts = random_scripts(seed, FUZZ_SCRIPTS);
    assert_eq!(scripts.len(), FUZZ_SCRIPTS);
    for (i, script) in scripts.iter().enumerate() {
        let (xc, m) = candidates(script, i);
        let result = catch_unwind(AssertUnwindSafe(|| body(i, script, &xc, m)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "fuzz script {i}/{FUZZ_SCRIPTS} (RUYA_FUZZ_SEED={seed:#x}, steps \
                 {:?}) failed:\n  {msg}",
                script.steps()
            );
        }
    }
}

#[test]
fn fuzz_incremental_matches_scratch_over_random_programs() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        let mut inc = NativeBackend::new();
        let mut scr = NativeBackend::new();
        scr.set_incremental(false);
        let report = assert_backend_parity(&mut inc, &mut scr, script, xc, m, &grid, 1e-9);
        assert_eq!(report.steps, script.steps().len());
    });
}

#[test]
fn fuzz_parallel_parity_bit_identical_over_random_programs() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        // Exact path under the pool (floor lowered so the tiny fuzz
        // windows fan out at all).
        let make = || {
            let mut b = NativeBackend::new();
            b.set_pool_min_obs(0);
            b
        };
        assert_parallel_parity(&make, &[2, 4, 8], script, xc, m, &grid);
    });
}

/// One search step over the script's own row pool (rows = candidate
/// space, targets = costs); false once the search is over.
fn session_step(
    cursor: &mut SearchCursor,
    backend: &mut NativeBackend,
    script: &ParityScript,
) -> bool {
    let (features, costs) = (script.rows(), script.ys());
    match cursor.advance() {
        SearchStep::Done => false,
        SearchStep::Execute(i) => {
            cursor.record(i, costs[i], features);
            true
        }
        SearchStep::NeedsDecision => {
            match cursor.decide_with_backend(features, backend).expect("decide") {
                Some(pick) => {
                    cursor.record(pick, costs[pick], features);
                    true
                }
                None => false,
            }
        }
    }
}

#[test]
fn fuzz_session_resume_bit_identical() {
    // Suspend/resume over the same randomized corpus the cache and pool
    // parities fuzz: at every round boundary of every script-driven
    // search, serialize -> deserialize -> replay must rejoin the
    // uninterrupted trace to the bit. (tests/session.rs pins the
    // fixed-seed variant plus the rewarmed-backend nll probes; this is
    // the RUYA_FUZZ_SEED-reseedable sweep.)
    for_each_script(|i, script, _xc, _m| {
        let m = script.pool_len();
        let d = script.dim();
        let k = (m / 3).max(1);
        let phases: Vec<Vec<usize>> = vec![(0..k).collect(), (k..m).collect()];
        let params = BoParams { max_iters: m.min(9), ..Default::default() };
        let seed = 0x5E55 ^ (i as u64).wrapping_mul(0x9E37);
        let fresh = || {
            let mut b = NativeBackend::new();
            b.set_parallelism(1);
            let c = SearchCursor::new(
                Arc::new(phases.clone()),
                m,
                d,
                Pcg64::from_seed(seed),
                params,
            );
            (c, b)
        };

        let (mut ref_cursor, mut ref_backend) = fresh();
        while session_step(&mut ref_cursor, &mut ref_backend, script) {}
        let reference = ref_cursor.outcome();

        for cut in script.cut_points() {
            let (mut cursor, mut backend) = fresh();
            for _ in 0..cut {
                if !session_step(&mut cursor, &mut backend, script) {
                    break;
                }
            }
            let state = SessionState::capture("fuzz", seed, params, &phases, &cursor);
            let decoded = SessionState::decode(&state.encode()).expect("decode");
            let mut resumed_backend = NativeBackend::new();
            resumed_backend.set_parallelism(1);
            let mut resumed = replay_cursor(&decoded, script.rows(), &mut resumed_backend)
                .unwrap_or_else(|e| panic!("cut {cut}: resume failed: {e:#}"));
            while session_step(&mut resumed, &mut resumed_backend, script) {}
            let out = resumed.outcome();
            assert_eq!(out.tried, reference.tried, "cut {cut}: picks diverged");
            assert_eq!(
                out.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
                reference.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
                "cut {cut}: cost bits diverged"
            );
            assert_eq!(out.stop_after, reference.stop_after, "cut {cut}");
            assert_eq!(out.phase_starts, reference.phase_starts, "cut {cut}");
        }
    });
}

#[test]
fn fuzz_parallel_parity_lowrank_routing_bit_identical() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        // Low-rank nll routing forced on (threshold below every fuzz
        // window): the stage-split Woodbury sweep plus the incremental
        // inducing refresh must stay bit-identical under the pool across
        // every append/slide/replace program.
        let make = || {
            let mut b = NativeBackend::new();
            b.set_pool_min_obs(0);
            b.set_lowrank_nll_threshold(4);
            b
        };
        assert_parallel_parity(&make, &[2, 4, 8], script, xc, m, &grid);
    });
}
