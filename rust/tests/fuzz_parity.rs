//! Randomized parity fuzz: `testkit::random_scripts` generates seeded
//! append/slide/replace observation programs and drives them through the
//! two parity harnesses — replacing the hand-written-scripts-only
//! coverage that used to pin the incremental caches and the worker pool.
//!
//! * `assert_backend_parity` pins the incremental factor cache against a
//!   forced-cold scratch backend within 1e-9 over every generated
//!   program;
//! * `assert_parallel_parity` pins serial-vs-pooled **bit identity** at
//!   `--gp-threads` 2/4/8 over every program, both on the exact sweep
//!   and with the low-rank nll routing forced to engage (stage-split
//!   marginal + incremental inducing refresh under the pool).
//!
//! Scripts are deterministic in `(RUYA_FUZZ_SEED, index)`; a failure
//! re-panics with both, so any run reproduces with
//! `RUYA_FUZZ_SEED=<seed> cargo test --test fuzz_parity`.

use ruya::bayesopt::{hyperparameter_grid, NativeBackend};
use ruya::testkit::{
    assert_backend_parity, assert_parallel_parity, random_scripts, ParityScript,
};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Scripts per fuzz run (the ISSUE floor is 32).
const FUZZ_SCRIPTS: usize = 32;

fn fuzz_seed() -> u64 {
    std::env::var("RUYA_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11C_E5EE_D5EEDu64)
}

/// Deterministic candidate matrix matching a script's feature width.
fn candidates(script: &ParityScript, salt: usize) -> (Vec<f64>, usize) {
    let d = script.dim();
    let m = 6 + (salt % 7); // 6..=12 candidates
    let xc = (0..m * d)
        .map(|i| ((i * 29 + salt * 13 + 7) % 97) as f64 / 97.0)
        .collect();
    (xc, m)
}

/// Run `body` over every generated script, re-panicking with the seed
/// and script index so failures reproduce from the log line alone.
fn for_each_script(body: impl Fn(usize, &ParityScript, &[f64], usize)) {
    let seed = fuzz_seed();
    let scripts = random_scripts(seed, FUZZ_SCRIPTS);
    assert_eq!(scripts.len(), FUZZ_SCRIPTS);
    for (i, script) in scripts.iter().enumerate() {
        let (xc, m) = candidates(script, i);
        let result = catch_unwind(AssertUnwindSafe(|| body(i, script, &xc, m)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "fuzz script {i}/{FUZZ_SCRIPTS} (RUYA_FUZZ_SEED={seed:#x}, steps \
                 {:?}) failed:\n  {msg}",
                script.steps()
            );
        }
    }
}

#[test]
fn fuzz_incremental_matches_scratch_over_random_programs() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        let mut inc = NativeBackend::new();
        let mut scr = NativeBackend::new();
        scr.set_incremental(false);
        let report = assert_backend_parity(&mut inc, &mut scr, script, xc, m, &grid, 1e-9);
        assert_eq!(report.steps, script.steps().len());
    });
}

#[test]
fn fuzz_parallel_parity_bit_identical_over_random_programs() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        // Exact path under the pool (floor lowered so the tiny fuzz
        // windows fan out at all).
        let make = || {
            let mut b = NativeBackend::new();
            b.set_pool_min_obs(0);
            b
        };
        assert_parallel_parity(&make, &[2, 4, 8], script, xc, m, &grid);
    });
}

#[test]
fn fuzz_parallel_parity_lowrank_routing_bit_identical() {
    let grid = hyperparameter_grid();
    for_each_script(|_, script, xc, m| {
        // Low-rank nll routing forced on (threshold below every fuzz
        // window): the stage-split Woodbury sweep plus the incremental
        // inducing refresh must stay bit-identical under the pool across
        // every append/slide/replace program.
        let make = || {
            let mut b = NativeBackend::new();
            b.set_pool_min_obs(0);
            b.set_lowrank_nll_threshold(4);
            b
        };
        assert_parallel_parity(&make, &[2, 4, 8], script, xc, m, &grid);
    });
}
