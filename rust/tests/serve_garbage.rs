//! Resident-service robustness pins: `ruya serve` must answer malformed,
//! hostile, and oversized request lines with an `{"error":...}` reply
//! and keep serving the valid requests around them — a resident engine
//! that exits (or overflows its stack) on one bad client line loses
//! every other client's open sessions with it.
//!
//! Drives the real binary (`CARGO_BIN_EXE_ruya`) over a `--script` file
//! interleaving garbage with valid ops, end to end through the bounded
//! line reader, the depth-capped JSON parser, and the op dispatcher.

use std::io::Write as _;
use std::process::Command;

/// Must match `MAX_REQUEST_LINE` in `main.rs`.
const MAX_REQUEST_LINE: usize = 1 << 20;

#[test]
fn serve_survives_garbage_between_valid_ops() {
    let job = ruya::workload::evaluation_jobs()[0].label();

    let mut script: Vec<u8> = Vec::new();
    writeln!(script, "# comments and blank lines are skipped").unwrap();
    writeln!(script).unwrap();
    writeln!(script, r#"{{"op":"stats"}}"#).unwrap();
    // 1: not JSON at all.
    writeln!(script, "this is not json").unwrap();
    // 2: valid JSON, unknown op.
    writeln!(script, r#"{{"op":"frobnicate"}}"#).unwrap();
    // 3: invalid UTF-8 — `.lines()` used to kill the whole loop here.
    script.extend_from_slice(&[0xff, 0xfe, 0x80, b'\n']);
    // 4: hostile nesting, below the size cap so it reaches the parser —
    // used to overflow the recursive descent and abort the process.
    script.extend(std::iter::repeat(b'[').take(300_000));
    script.push(b'\n');
    // 5: oversized line — must be skipped without being buffered whole.
    script.extend(std::iter::repeat(b'x').take(MAX_REQUEST_LINE + 512));
    script.push(b'\n');
    // The engine still works after all of the above.
    writeln!(script, r#"{{"op":"open","job":"{job}","sessions":1,"max_iters":3}}"#).unwrap();
    writeln!(script, r#"{{"op":"run"}}"#).unwrap();
    writeln!(script, r#"{{"op":"stats"}}"#).unwrap();

    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("serve_garbage");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("script.jsonl");
    std::fs::write(&path, &script).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_ruya"))
        .arg("serve")
        .arg("--script")
        .arg(&path)
        .output()
        .expect("spawning ruya serve");
    assert!(
        out.status.success(),
        "serve must exit cleanly after a garbage-laced script; stderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let stdout = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = stdout.lines().collect();
    let errors: Vec<&&str> = lines.iter().filter(|l| l.starts_with(r#"{"error""#)).collect();
    let oks: Vec<&&str> = lines.iter().filter(|l| l.starts_with(r#"{"ok""#)).collect();
    assert_eq!(
        errors.len(),
        5,
        "each of the five garbage lines gets exactly one error reply; got:\n{stdout}"
    );
    assert_eq!(
        oks.len(),
        4,
        "stats/open/run/stats must all still be answered; got:\n{stdout}"
    );
    assert!(
        errors.iter().any(|l| l.contains("nesting deeper than")),
        "the hostile-nesting line must die in the parser, not the stack:\n{stdout}"
    );
    assert!(
        errors.iter().any(|l| l.contains("exceeds") && l.contains("bytes")),
        "the oversized line must be rejected by length:\n{stdout}"
    );
    // Replies stay in request order: the last line answers the last
    // stats op, after the garbage, with the completed session counted.
    let last = lines.last().expect("serve printed nothing");
    assert!(last.contains(r#""ok":"stats""#), "last reply: {last}");
    assert!(last.contains(r#""sessions_opened":1"#), "last reply: {last}");
}
